//! Bench T2: regenerate the paper's Table 2 (ping from Gridlan server),
//! plus a probe-count convergence study.
//!
//! Run: `cargo bench --bench table2_latency`
//! (plain-main bench: criterion is not in the offline vendor set)

use gridlan::bench::table2::{self, PAPER_TABLE2};
use gridlan::coordinator::gridlan::Gridlan;

fn main() {
    let mut g = Gridlan::table1();
    g.boot_all(0);

    let t0 = std::time::Instant::now();
    let rows = table2::table2_rows(&mut g, 1000);
    let elapsed = t0.elapsed();
    print!("{}", table2::render(&rows));
    println!("\n(1000 probes x 4 hosts x 2 paths in {:.1} ms wall)", elapsed.as_secs_f64() * 1e3);

    // Shape scoring vs the paper.
    let mut worst = 0.0f64;
    for r in &rows {
        let (_, ph, pv) = *PAPER_TABLE2.iter().find(|p| p.0 == r.node).unwrap();
        worst = worst.max(((r.host_mean_us - ph) / ph).abs());
        worst = worst.max(((r.node_mean_us - pv) / pv).abs());
    }
    println!("worst relative error vs paper: {:.1}%", worst * 100.0);

    // Convergence: the paper reports mean(std) — how many probes until the
    // mean stabilizes within 1%?
    println!("\nprobe-count convergence (n01 node ping):");
    let reference = rows.iter().find(|r| r.node == "n01").unwrap().node_mean_us;
    for probes in [5usize, 10, 20, 50, 100, 500] {
        let m = g.ping_node("n01", probes).unwrap().mean_us();
        println!(
            "  {probes:>4} probes: {m:7.1} µs ({:+.2}% vs 1000-probe mean)",
            100.0 * (m - reference) / reference
        );
    }
}
