//! Bench T2: regenerate the paper's Table 2 (ping from Gridlan server),
//! plus a probe-count convergence study.
//!
//! Run: `cargo bench --bench table2_latency`
//! (plain-main bench: criterion is not in the offline vendor set)
//! Writes the deterministic series to `BENCH_table2_latency.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_table2_latency();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
