//! Bench M1: the §3.3 MPI-vs-ICMP latency cross-check, plus message-size
//! and collective scaling (context the paper's §4 analysis needs).
//!
//! Run: `cargo bench --bench mpi_latency`
//! Writes the deterministic series to `BENCH_mpi_latency.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_mpi_latency();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
