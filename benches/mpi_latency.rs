//! Bench M1: the §3.3 MPI-vs-ICMP latency cross-check, plus message-size
//! and collective scaling (context the paper's §4 analysis needs).
//!
//! Run: `cargo bench --bench mpi_latency`

use gridlan::bench::mpilat;
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::mpi::collectives::{allreduce_us, bcast_us};
use gridlan::mpi::comm::{Communicator, RankLoc};
use gridlan::mpi::latency::mpi_latency_test;
use gridlan::util::rng::SplitMix64;

fn main() {
    let mut g = Gridlan::table1();
    g.boot_all(0);

    let rows = mpilat::mpi_latency_rows(&mut g, 500);
    print!("{}", mpilat::render(&rows));

    // Message-size sweep (node↔node through the hub: the paper's two-leg
    // routing property shows up as ~2x the server↔node latency).
    let node = |c: &str| RankLoc::Node {
        client: c.into(),
        vnet_us: g.client(c).unwrap().hypervisor.vnet_one_way_us,
    };
    let comm = Communicator::new(vec![RankLoc::Server, node("n01"), node("n02"), node("n03"), node("n04")]);
    println!("\nping-pong RTT vs message size (µs):");
    println!("{:>10} {:>14} {:>14}", "bytes", "server<->n01", "n01<->n02");
    let mut rng = SplitMix64::new(5);
    for bytes in [56u32, 1_024, 16_384, 262_144, 1_048_576] {
        let s2n = mpi_latency_test(&comm, &g.net, &g.hub, 0, 1, bytes, 50, &mut rng).unwrap();
        let n2n = mpi_latency_test(&comm, &g.net, &g.hub, 1, 2, bytes, 50, &mut rng).unwrap();
        println!("{bytes:>10} {:>13.0} {:>13.0}", s2n.mean(), n2n.mean());
    }

    // Collectives over the hub star.
    println!("\ncollectives over 5 ranks (µs):");
    for bytes in [56u32, 65_536] {
        let b = bcast_us(&comm, &g.net, &g.hub, 0, bytes, &mut rng).unwrap();
        let ar = allreduce_us(&comm, &g.net, &g.hub, bytes, &mut rng).unwrap();
        println!("  {bytes:>7} B: bcast {b:>8.0}   allreduce {ar:>8.0}");
    }
}
