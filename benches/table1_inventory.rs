//! Bench T1: Table 1 (client inventory) + the derived per-client compute
//! capability table the Fig. 3 model is built on.
//!
//! Run: `cargo bench --bench table1_inventory`
//! Writes the deterministic series to `BENCH_table1_inventory.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_table1_inventory();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
