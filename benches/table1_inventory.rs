//! Bench T1: Table 1 (client inventory) + the derived per-client compute
//! capability table the Fig. 3 model is built on.
//!
//! Run: `cargo bench --bench table1_inventory`

use gridlan::bench::table1;
use gridlan::config::Config;
use gridlan::host::client::ClientAgent;
use gridlan::util::table::{Align, Table};

fn main() {
    let cfg = Config::table1();
    print!("{}", table1::render_inventory(&cfg));

    println!();
    let mut t = Table::new(&[
        "Node",
        "clock@1",
        "clock@all",
        "EP Mpairs/s @1 core",
        "EP Mpairs/s @all cores",
        "hypervisor eff",
    ])
    .title("Derived per-client capability (Turbo + hypervisor model)")
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    for c in ClientAgent::table1() {
        t.row(&[
            c.name.clone(),
            format!("{:.2} GHz", c.cpu.clock_ghz(1)),
            format!("{:.2} GHz", c.cpu.clock_ghz(c.cpu.cores)),
            format!("{:.1}", c.guest_ep_rate(1)),
            format!("{:.1}", c.cpu.cores as f64 * c.guest_ep_rate(c.cpu.cores)),
            format!("{:.2}", c.hypervisor.cpu_efficiency),
        ]);
    }
    print!("{}", t.render());
    let total: f64 = ClientAgent::table1()
        .iter()
        .map(|c| c.cpu.cores as f64 * c.guest_ep_rate(c.cpu.cores))
        .sum();
    println!("\naggregate pool throughput: {total:.0} Mpairs/s (class D = 2^36 pairs → ~{:.0} s)",
        (1u64 << 36) as f64 / total / 1e6);
}
