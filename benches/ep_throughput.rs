//! Runtime/L1 perf bench: PJRT EP throughput by chunk size, vs the scalar
//! rust oracle — measures the AOT-kernel hot path the simulated jobs run.
//!
//! Run: `make artifacts && cargo bench --bench ep_throughput`

use gridlan::runtime::engine::EpEngine;
use gridlan::runtime::manifest::Manifest;
use gridlan::workload::ep::ep_scalar;

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("no artifacts at {}; run `make artifacts`", dir.display());
        std::process::exit(0); // bench is skippable, not a failure
    }
    let mut engine = EpEngine::load(&dir).expect("engine loads");
    println!("artifacts: {:?}", engine.chunk_names());

    // Warm-up (JIT caches, first-touch).
    engine.run_pairs(0, 1 << 16).unwrap();

    // Throughput per chunk size: run the same total pairs via each chunk
    // granularity by constraining counts to multiples of that chunk.
    let manifest = Manifest::load(&dir).unwrap();
    const TOTAL: u64 = 1 << 22; // 4M pairs per measurement
    println!("\n{:>8} {:>14} {:>12} {:>14}", "chunk", "execs", "wall ms", "Mpairs/s");
    for art in &manifest.artifacts {
        let mut e = EpEngine::load(&dir).unwrap();
        e.run_pairs(0, art.total_pairs).unwrap(); // warm
        let execs = TOTAL / art.total_pairs;
        if execs == 0 {
            continue;
        }
        let t0 = std::time::Instant::now();
        let mut at = 0u64;
        for _ in 0..execs {
            e.run_pairs(at, art.total_pairs).unwrap();
            at += art.total_pairs;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "{:>8} {:>14} {:>12.1} {:>14.1}",
            art.name,
            execs,
            dt * 1e3,
            (execs * art.total_pairs) as f64 / dt / 1e6
        );
    }

    // Scalar oracle comparison (the no-PJRT path).
    let t0 = std::time::Instant::now();
    let tally = ep_scalar(0, 1 << 20);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nscalar rust EP: {:.1} Mpairs/s (1M pairs in {:.1} ms; nacc={})",
        (1u64 << 20) as f64 / dt / 1e6,
        dt * 1e3,
        tally.nacc
    );
    println!(
        "PJRT/scalar speedup at best chunk: see table above (the HLO path \
         vectorizes the LCG+polar loop; interpret-mode Pallas lowered to \
         plain XLA ops)."
    );
}
