//! Runtime perf bench: EP throughput through the `ComputeBackend` trait —
//! the hot path the simulated jobs run.
//!
//! Default builds measure the pure-Rust scalar backend across chunk
//! geometries and the multi-threaded backend across thread counts (with
//! speedup vs the scalar baseline); `--features pjrt` additionally tries
//! the PJRT artifact backend and falls back (exit 0, with a note) when
//! artifacts or the `xla` crate are missing.
//!
//! Run: `cargo bench --bench ep_throughput`

use gridlan::runtime::backend::{ComputeBackend, ScalarBackend};
use gridlan::runtime::engine::EpEngine;
use gridlan::runtime::threaded::ThreadedBackend;
use gridlan::workload::ep::ep_scalar;

const TOTAL: u64 = 1 << 22; // 4M pairs per measurement

/// Measure one backend over TOTAL pairs; prints a table row (plus a
/// speedup column when a baseline rate is given) and returns the rate in
/// Mpairs/s.
fn measure(backend: &mut dyn ComputeBackend, label: &str, baseline: Option<f64>) -> f64 {
    backend.run_pairs(0, 1 << 16).unwrap(); // warm-up (spawn paths, caches)
    let t0 = std::time::Instant::now();
    backend.run_pairs(0, TOTAL).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    let rate = TOTAL as f64 / dt / 1e6;
    let speedup = baseline.map(|b| format!(" {:>8.2}x", rate / b.max(1e-9))).unwrap_or_default();
    println!("{label:>12} {:>14} {:>12.1} {:>14.1}{speedup}", TOTAL, dt * 1e3, rate);
    rate
}

fn main() {
    // Backend selection report (the `--features pjrt` story).
    let mut auto = EpEngine::auto();
    if let Some(note) = auto.fallback_note.take() {
        println!("note: {note}");
    }
    println!("active backend: {}\n", auto.backend_name());

    println!("{:>12} {:>14} {:>12} {:>14}", "chunk", "pairs", "wall ms", "Mpairs/s");
    // Scalar backend across chunk sizes: the chunking overhead (jump-ahead
    // reseeks per chunk) must vanish by ~64Ki pairs.
    let mut scalar_rate = 0.0f64;
    for chunk in [1u64 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20] {
        let mut b = ScalarBackend::with_chunk(chunk);
        let r = measure(&mut b, &format!("scalar/{chunk}"), None);
        if chunk == 1 << 16 {
            scalar_rate = r;
        }
    }

    // Threaded backend across thread counts: the acceptance bar is
    // >= 1.5x the scalar baseline at 4 threads on a multi-core host.
    println!(
        "\n{:>12} {:>14} {:>12} {:>14} {:>9}   ({} hw threads, speedup vs scalar/65536)",
        "threads",
        "pairs",
        "wall ms",
        "Mpairs/s",
        "speedup",
        ThreadedBackend::available()
    );
    for threads in [1usize, 2, 4, 8] {
        let mut b = ThreadedBackend::new(threads);
        measure(&mut b, &format!("threaded/{threads}"), Some(scalar_rate));
    }

    // The auto-selected engine end-to-end (what `gridlan ep` uses).
    let t0 = std::time::Instant::now();
    auto.run_pairs(0, TOTAL).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nauto engine ({}): {:.1} Mpairs/s over {} pairs",
        auto.backend_name(),
        TOTAL as f64 / dt / 1e6,
        TOTAL
    );

    // Single-call oracle reference (no trait, no chunking, no threads).
    let t0 = std::time::Instant::now();
    let tally = ep_scalar(0, 1 << 20);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "raw oracle:    {:.1} Mpairs/s (1M pairs in {:.1} ms; nacc={})",
        (1u64 << 20) as f64 / dt / 1e6,
        dt * 1e3,
        tally.nacc
    );
    println!(
        "\n(trait dispatch + chunk merging should cost <2% vs the raw oracle \
         at the default 64Ki chunk; threaded/4 should clear 1.5x scalar.)"
    );
}
