//! Runtime perf bench: EP throughput through the `ComputeBackend` trait —
//! the hot path the simulated jobs run.
//!
//! Default builds measure the pure-Rust scalar backend across chunk
//! geometries; `--features pjrt` additionally tries the PJRT artifact
//! backend and falls back (exit 0, with a note) when artifacts or the
//! `xla` crate are missing.
//!
//! Run: `cargo bench --bench ep_throughput`

use gridlan::runtime::backend::{ComputeBackend, ScalarBackend};
use gridlan::runtime::engine::EpEngine;
use gridlan::workload::ep::ep_scalar;

const TOTAL: u64 = 1 << 22; // 4M pairs per measurement

fn measure(backend: &mut dyn ComputeBackend, label: &str) {
    let t0 = std::time::Instant::now();
    backend.run_pairs(0, TOTAL).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{label:>12} {:>14} {:>12.1} {:>14.1}",
        TOTAL,
        dt * 1e3,
        TOTAL as f64 / dt / 1e6
    );
}

fn main() {
    // Backend selection report (the `--features pjrt` story).
    let mut auto = EpEngine::auto();
    if let Some(note) = auto.fallback_note.take() {
        println!("note: {note}");
    }
    println!("active backend: {}\n", auto.backend_name());

    println!("{:>12} {:>14} {:>12} {:>14}", "chunk", "pairs", "wall ms", "Mpairs/s");
    // Scalar backend across chunk sizes: the chunking overhead (jump-ahead
    // reseeks per chunk) must vanish by ~64Ki pairs.
    for chunk in [1u64 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20] {
        let mut b = ScalarBackend::with_chunk(chunk);
        b.run_pairs(0, 1 << 16).unwrap(); // warm-up
        measure(&mut b, &format!("scalar/{chunk}"));
    }

    // The auto-selected engine end-to-end (what `gridlan ep` uses).
    let t0 = std::time::Instant::now();
    auto.run_pairs(0, TOTAL).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nauto engine ({}): {:.1} Mpairs/s over {} pairs",
        auto.backend_name(),
        TOTAL as f64 / dt / 1e6,
        TOTAL
    );

    // Single-call oracle reference (no trait, no chunking).
    let t0 = std::time::Instant::now();
    let tally = ep_scalar(0, 1 << 20);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "raw oracle:    {:.1} Mpairs/s (1M pairs in {:.1} ms; nacc={})",
        (1u64 << 20) as f64 / dt / 1e6,
        dt * 1e3,
        tally.nacc
    );
    println!(
        "\n(trait dispatch + chunk merging should cost <2% vs the raw oracle \
         at the default 64Ki chunk.)"
    );
}
