//! Runtime perf bench: EP throughput through the `ComputeBackend` trait —
//! the hot path the simulated jobs run.
//!
//! Wall-clock rates stay on stdout; `BENCH_ep_throughput.json` carries the
//! bit-exact tally invariants.  `GRIDLAN_BENCH_QUICK=1` shrinks the
//! wall-clock loops without touching the JSON.
//!
//! Run: `cargo bench --bench ep_throughput`

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_ep_throughput();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
