//! Bench A1: scheduler ablation — FIFO (Torque 2.4 default) vs EASY
//! backfill on the synthetic lab trace, clean and under faults.
//!
//! Run: `cargo bench --bench sched_ablation`

use gridlan::config::{Config, SchedPolicy};
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{run_trace, Scenario};
use gridlan::host::faults::FaultPlan;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::rng::SplitMix64;
use gridlan::util::table::{secs, Align, Table};
use gridlan::workload::trace::TraceGenerator;

fn main() {
    let gen = TraceGenerator::lab_day();
    let mut t = Table::new(&[
        "scheduler",
        "faults",
        "completed",
        "mean wait",
        "makespan",
        "goodput",
        "sim events",
        "wall ms",
    ])
    .title("A1 — FIFO vs backfill on the lab-day trace")
    .align(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);

    for (flabel, fscale) in [("none", 0.0), ("lab x4", 4.0)] {
        for policy in [SchedPolicy::Fifo, SchedPolicy::Backfill] {
            let mut cfg = Config::table1();
            cfg.sched = policy;
            // Same trace for both policies: same generator seed.
            let mut rng = SplitMix64::new(1234);
            let trace = gen.generate(&mut rng);
            let n = trace.len() as u64;
            let faults = if fscale > 0.0 {
                FaultPlan::lab_default().scaled(fscale)
            } else {
                FaultPlan::none()
            };
            let scenario = Scenario { horizon: gen.horizon * 4, faults, ..Default::default() };
            let w0 = std::time::Instant::now();
            let report = run_trace(Gridlan::build(cfg), trace, &scenario);
            let m = report.metrics;
            t.row(&[
                format!("{policy:?}"),
                flabel.to_string(),
                format!("{}/{n}", m.jobs_completed),
                secs(m.mean_wait_secs()),
                secs(m.makespan as f64 / 1e9),
                format!("{:.1}%", 100.0 * m.goodput()),
                report.events_executed.to_string(),
                format!("{:.0}", w0.elapsed().as_secs_f64() * 1e3),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\nexpected shape: backfill lowers mean wait on mixed traces; both complete everything.");

    // Wide-vs-narrow starvation microbenchmark: one wide job at the head,
    // stream of narrow jobs behind it.
    println!("\nhead-of-line case (1 wide job then 12 narrow):");
    for policy in [SchedPolicy::Fifo, SchedPolicy::Backfill] {
        let mut cfg = Config::table1();
        cfg.sched = policy;
        let mut trace = vec![gridlan::workload::trace::TraceJob {
            at: 0,
            owner: "big".into(),
            request: gridlan::rm::alloc::ResourceRequest { nodes: 3, ppn: 6 },
            compute: 1800 * DUR_SEC,
            walltime: 3600 * DUR_SEC,
            payload: gridlan::workload::trace::JobPayload::Synthetic,
        }];
        for i in 0..12 {
            trace.push(gridlan::workload::trace::TraceJob {
                at: 10 * DUR_SEC,
                owner: format!("small{i}"),
                request: gridlan::rm::alloc::ResourceRequest { nodes: 1, ppn: 1 },
                compute: 120 * DUR_SEC,
                walltime: 240 * DUR_SEC,
                payload: gridlan::workload::trace::JobPayload::Synthetic,
            });
        }
        let scenario = Scenario { horizon: 6 * 3600 * DUR_SEC, ..Default::default() };
        let report = run_trace(Gridlan::build(cfg), trace, &scenario);
        println!(
            "  {policy:?}: mean wait {}, makespan {}",
            secs(report.metrics.mean_wait_secs()),
            secs(report.metrics.makespan as f64 / 1e9)
        );
    }
}
