//! Bench A1: scheduler ablation — FIFO (Torque 2.4 default) vs EASY
//! backfill on the synthetic lab trace, clean and under faults.  Ends with
//! a 100k-node / 100k-job drain through the indexed scheduler hot path
//! (`drain100k_*` series), fixed-size in every mode.
//!
//! Run: `cargo bench --bench sched_ablation`
//! Writes the deterministic series to `BENCH_sched_ablation.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_sched_ablation();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
