//! L3 perf bench: the discrete-event core and the scheduler hot path —
//! the targets from DESIGN.md §7 (≥1M events/s; sub-100µs qsub→decision).
//!
//! Run: `cargo bench --bench sim_engine`

use gridlan::rm::queue::NodePool;
use gridlan::rm::sched::FifoScheduler;
use gridlan::rm::script::PbsScript;
use gridlan::rm::server::PbsServer;
use gridlan::sim::Simulator;

fn bench_event_engine() {
    // Self-rescheduling event chains: the pure engine overhead.
    struct W {
        count: u64,
        limit: u64,
    }
    fn tick(s: &mut Simulator<W>, w: &mut W) {
        w.count += 1;
        if w.count < w.limit {
            s.schedule_in(1_000, tick);
        }
    }
    const N: u64 = 2_000_000;
    let mut sim = Simulator::new();
    let mut w = W { count: 0, limit: N };
    for _ in 0..64 {
        sim.schedule_at(0, tick);
    }
    w.limit = N;
    let t0 = std::time::Instant::now();
    sim.run_to_completion(&mut w);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "event engine: {} events in {:.3}s = {:.2}M events/s  (target: >=1M/s)",
        sim.executed(),
        dt,
        sim.executed() as f64 / dt / 1e6
    );
}

fn bench_sched_cycle() {
    // qsub -> scheduling decision latency at realistic queue depths.
    for depth in [1usize, 10, 100, 1000] {
        let mut s = PbsServer::new();
        for (name, cores) in [("n01", 12), ("n02", 6), ("n03", 4), ("n04", 4)] {
            s.register_node(name, cores, NodePool::Gridlan);
            s.node_up(name);
        }
        let script = PbsScript::parse("#PBS -q gridlan\n#PBS -l nodes=1:ppn=2\n./x\n").unwrap();
        for i in 0..depth {
            s.qsub(&script, "u", "", i as u64).unwrap();
        }
        let t0 = std::time::Instant::now();
        let mut cycles = 0u64;
        // Drain the whole queue: schedule, complete, repeat.
        loop {
            let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1_000_000);
            cycles += 1;
            if d.is_empty() {
                break;
            }
            for (id, _) in d {
                s.complete(id, 0, 2_000_000);
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "sched cycle: depth {depth:>5}: drained in {:.2} ms over {cycles} cycles ({:.1} µs/job)",
            dt * 1e3,
            dt * 1e6 / depth as f64
        );
    }
}

fn bench_ping_path() {
    let mut g = gridlan::coordinator::gridlan::Gridlan::table1();
    g.boot_all(0);
    let t0 = std::time::Instant::now();
    const N: usize = 50_000;
    let s = g.ping_node("n01", N).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "ping path: {N} node pings in {:.1} ms = {:.2} µs/ping (mean rtt {:.0} µs sim-time)",
        dt * 1e3,
        dt * 1e6 / N as f64,
        s.mean_us()
    );
}

fn main() {
    bench_event_engine();
    bench_sched_cycle();
    bench_ping_path();
}
