//! L3 perf bench: the discrete-event core and the scheduler hot path —
//! the targets from DESIGN.md §7 (≥10M events/s; sub-100µs scheduling at
//! a 100k-deep backlog).  Runs the timing wheel against the retired
//! `BinaryHeap` baseline on identical workloads: chain throughput, a
//! mixed schedule/cancel/advance storm whose firing traces must match
//! exactly (`storm_divergence` must stay 0), and a deep-backlog churn.
//!
//! Wall-clock rates stay on stdout; `BENCH_sim_engine.json` carries the
//! deterministic event/cycle counters.  `GRIDLAN_BENCH_QUICK=1` shrinks
//! the wall-clock loops without touching the JSON.
//!
//! Run: `cargo bench --bench sim_engine`

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_sim_engine();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
