//! L3 perf bench: the discrete-event core and the scheduler hot path —
//! the targets from DESIGN.md §7 (≥1M events/s; sub-100µs qsub→decision).
//!
//! Wall-clock rates stay on stdout; `BENCH_sim_engine.json` carries the
//! deterministic event/cycle counters.  `GRIDLAN_BENCH_QUICK=1` shrinks
//! the wall-clock loops without touching the JSON.
//!
//! Run: `cargo bench --bench sim_engine`

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_sim_engine();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
