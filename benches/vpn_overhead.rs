//! Bench A2: decompose the node-path latency overhead into its layers —
//! the §5 "optimizations in the VPN layer" discussion made quantitative.
//!
//! Run: `cargo bench --bench vpn_overhead`

use gridlan::coordinator::gridlan::Gridlan;
use gridlan::netsim::packet::Packet;
use gridlan::util::rng::SplitMix64;
use gridlan::util::table::{Align, Table};
use gridlan::vpn::tunnel::TunnelCost;

fn main() {
    let mut g = Gridlan::table1();
    g.boot_all(0);
    g.net.jitter_sigma_us = 0.0; // decomposition wants means

    let p = Packet::icmp_echo();
    let mut t = Table::new(&["Node", "wire RTT", "+VPN", "+virtio", "node RTT", "VPN share", "virtio share"])
        .title("A2 — node-path overhead decomposition (µs RTT, 56B ICMP)")
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
    for name in &names {
        let wire = 2.0 * g
            .net
            .one_way_delay_us(g.server_dev, g.client_dev[name], p.wire_bytes())
            .unwrap();
        let mut rng = SplitMix64::new(1);
        let tun_one = g.hub.server_to_client_us(&g.net, name, &p, &mut rng).unwrap();
        let vpn_rtt = 2.0 * tun_one;
        let vnet = g.client(name).unwrap().hypervisor.vnet_one_way_us;
        let node_rtt = vpn_rtt + 2.0 * vnet;
        t.row(&[
            name.clone(),
            format!("{wire:.0}"),
            format!("{vpn_rtt:.0}"),
            format!("{:.0}", 2.0 * vnet),
            format!("{node_rtt:.0}"),
            format!("{:.0}%", 100.0 * (vpn_rtt - wire) / (node_rtt - wire)),
            format!("{:.0}%", 100.0 * 2.0 * vnet / (node_rtt - wire)),
        ]);
    }
    print!("{}", t.render());

    // What would the §5 VPN optimizations buy?  Sweep the tunnel cost.
    println!("\nVPN-optimization sweep (n01 node RTT, µs):");
    let base = TunnelCost::default();
    for (label, cost) in [
        ("openvpn (paper)", base),
        ("tuned crypto (-30%)", TunnelCost { encap_us: base.encap_us * 0.7, decap_us: base.decap_us * 0.7, ..base }),
        ("kernel wireguard-like", TunnelCost { encap_us: 25.0, decap_us: 22.0, crypto_us_per_kb: 2.0 }),
        ("no vpn (hypothetical)", TunnelCost { encap_us: 0.0, decap_us: 0.0, crypto_us_per_kb: 0.0 }),
    ] {
        let one_way = cost.one_way_us(p.wire_bytes());
        let mut rng = SplitMix64::new(2);
        // Rebuild the wire path each time (the VPN header still rides).
        let wire_one = g
            .net
            .sample_one_way(g.server_dev, g.client_dev["n01"], Packet::icmp_echo_tunneled().wire_bytes(), &mut rng)
            .unwrap() as f64
            / 1e3;
        let vnet = g.client("n01").unwrap().hypervisor.vnet_one_way_us;
        let rtt = 2.0 * (wire_one + one_way + vnet) + gridlan::netsim::icmp::ECHO_PROC_US;
        println!("  {label:<24} {rtt:7.0}");
    }
}
