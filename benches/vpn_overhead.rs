//! Bench A2: decompose the node-path latency overhead into its layers —
//! the §5 "optimizations in the VPN layer" discussion made quantitative.
//!
//! Run: `cargo bench --bench vpn_overhead`
//! Writes the deterministic series to `BENCH_vpn_overhead.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_vpn_overhead();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
