//! Bench F3: regenerate the paper's Fig. 3 (NPB-EP class D speed-up,
//! Gridlan vs 64-core comparison server vs ideal t1/n).
//!
//! Run: `cargo bench --bench fig3_speedup`

use gridlan::bench::fig3;
use gridlan::perf::speedmodel::{ComparisonServer, GridlanPool};
use gridlan::workload::ep::EpClass;

fn main() {
    let pool = GridlanPool::table1();
    let t0 = std::time::Instant::now();
    let series = fig3::fig3_series(&pool, EpClass::D, 60, 42);
    print!("{}", fig3::render(&series));
    for (name, ok) in fig3::shape_checks(&series) {
        println!("  [{}] {name}", if ok { "ok" } else { "FAIL" });
    }

    // The deterministic full curve 1..26 (the figure's x-axis), Gridlan
    // best/worst placement band vs the server.
    println!("\ndeterministic curve (best placement over 200 draws per n):");
    println!("{:>5} {:>12} {:>12} {:>12}", "cores", "gridlan best", "gridlan worst", "server");
    let server = ComparisonServer::opteron();
    let mut rng = gridlan::util::rng::SplitMix64::new(7);
    for n in [1u32, 2, 4, 8, 13, 20, 26] {
        let mut best = f64::INFINITY;
        let mut worst = 0.0f64;
        for _ in 0..200 {
            let t = pool.elapsed_secs(EpClass::D.pairs(), &pool.random_placement(n, &mut rng));
            best = best.min(t);
            worst = worst.max(t);
        }
        let s = server.elapsed_secs(EpClass::D.pairs(), n);
        println!("{n:>5} {best:>11.1}s {worst:>11.1}s {s:>11.1}s");
    }
    println!("\nwall time: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
}
