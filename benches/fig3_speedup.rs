//! Bench F3: regenerate the paper's Fig. 3 (NPB-EP class D speed-up,
//! Gridlan vs 64-core comparison server vs ideal t1/n).
//!
//! Run: `cargo bench --bench fig3_speedup`
//! Writes the deterministic series to `BENCH_fig3_speedup.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_fig3_speedup();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
