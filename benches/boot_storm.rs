//! Bench A3: boot-storm scaling — how node count and TFTP block size
//! affect PXE/nfsroot boot time (the §5 "iPXE/HTTP alternative" motivation).
//! Includes a 100k-node analytic storm (`storm100k_*` series) that runs to
//! completion in quick mode too.
//!
//! Run: `cargo bench --bench boot_storm`
//! Writes the deterministic series to `BENCH_boot_storm.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_boot_storm();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
