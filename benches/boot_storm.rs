//! Bench A3: boot-storm scaling — how node count and TFTP block size
//! affect PXE/nfsroot boot time (the §5 "iPXE/HTTP alternative" motivation).
//!
//! Run: `cargo bench --bench boot_storm`

use gridlan::boot::nfs::NfsExport;
use gridlan::boot::pxe::{BootParams, BootPlan};
use gridlan::boot::tftp::{TftpServer, BLKSIZE_DEFAULT, BLKSIZE_PXE};
use gridlan::config::Config;
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::host::client::ClientOs;
use gridlan::util::table::{secs, Align, Table};
use gridlan::vm::cpu::CpuModel;
use gridlan::vm::hypervisor::{Hypervisor, HypervisorKind};

fn scaled_config(n: u32) -> Config {
    let mut cfg = Config::table1();
    let template = cfg.clients[0].clone();
    cfg.clients = (0..n)
        .map(|i| {
            let mut c = template.clone();
            c.name = format!("n{:02}", i + 1);
            c.cpu = CpuModel::i7_960();
            c.os = if i % 2 == 0 { ClientOs::Linux } else { ClientOs::Windows };
            c.switch_hops = 2 + (i % 3);
            c
        })
        .collect();
    cfg
}

fn main() {
    // Per-node boot decomposition on the paper's testbed.
    let mut g = Gridlan::table1();
    println!("per-node boot plans (paper testbed):");
    for name in ["n01", "n02", "n03", "n04"] {
        g.connect_client(name).unwrap();
        let plan = g.boot_plan(name);
        print!("  {name}: total {:>8}  ", secs(plan.total() as f64 / 1e9));
        for (state, dur) in &plan.phases {
            if *dur > 0 {
                print!("{state:?}={} ", secs(*dur as f64 / 1e9));
            }
        }
        println!();
    }

    // Scaling the fleet: slowest boot vs node count (boots overlap; the
    // TFTP path is per-node lock-step so the curve is flat until the
    // server link saturates — which the model exposes via us_per_byte).
    println!("\nboot storm: fleet size vs slowest boot:");
    let mut t = Table::new(&["nodes", "slowest boot", "mean boot"])
        .align(&[Align::Right, Align::Right, Align::Right]);
    for n in [1u32, 4, 8, 16, 32, 64] {
        let mut g = Gridlan::build(scaled_config(n));
        let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
        let mut total = 0u64;
        let mut slowest = 0u64;
        for name in &names {
            g.connect_client(name).unwrap();
            let p = g.boot_plan(name).total();
            total += p;
            slowest = slowest.max(p);
        }
        t.row(&[
            n.to_string(),
            secs(slowest as f64 / 1e9),
            secs(total as f64 / n as f64 / 1e9),
        ]);
    }
    print!("{}", t.render());

    // Ablation: TFTP block size (512 vs PXE-negotiated 1432) and the
    // hypervisor's kernel-init penalty.
    println!("\nTFTP blksize x hypervisor ablation (n01-like node, 700 µs one-way):");
    let nfs = NfsExport::debian();
    let params = BootParams { one_way_us: 700.0, us_per_byte: 0.008, kernel_init_ms: 2800.0 };
    for blk in [BLKSIZE_DEFAULT, BLKSIZE_PXE] {
        for hv in [HypervisorKind::QemuKvm, HypervisorKind::VirtualBox, HypervisorKind::PureQemu] {
            let plan =
                BootPlan::compute(&Hypervisor::new(hv), &TftpServer::new(blk), &nfs, &params);
            println!("  blksize {blk:>5}, {hv:?}: {}", secs(plan.total() as f64 / 1e9));
        }
    }
}
