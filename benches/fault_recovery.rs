//! Bench X1: goodput and completion under increasing fault pressure — the
//! quantitative version of §2.6/§4's resilience story.
//!
//! Run: `cargo bench --bench fault_recovery`

use gridlan::config::Config;
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{run_trace, Scenario};
use gridlan::host::faults::FaultPlan;
use gridlan::rm::alloc::ResourceRequest;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::table::{secs, Align, Table};
use gridlan::workload::trace::TraceJob;

fn trace() -> Vec<TraceJob> {
    (0..24)
        .map(|i| TraceJob {
            at: i as u64 * 120 * DUR_SEC,
            owner: format!("u{}", i % 4),
            request: ResourceRequest { nodes: 1, ppn: 1 + (i % 4) as u32 },
            compute: (300 + 120 * (i % 4) as u64) * DUR_SEC,
            walltime: 3600 * DUR_SEC,
            payload: gridlan::workload::trace::JobPayload::Synthetic,
        })
        .collect()
}

fn main() {
    let mut t = Table::new(&[
        "fault scale",
        "faults",
        "requeues",
        "wd restarts",
        "completed",
        "goodput",
        "makespan",
    ])
    .title("X1 — resilience under fault pressure (24 jobs, 8h horizon)")
    .align(&[
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for scale in [0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0] {
        let faults =
            if scale > 0.0 { FaultPlan::lab_default().scaled(scale) } else { FaultPlan::none() };
        let scenario = Scenario { horizon: 8 * 3600 * DUR_SEC, faults, ..Default::default() };
        let report = run_trace(Gridlan::build(Config::table1()), trace(), &scenario);
        let m = report.metrics;
        t.row(&[
            format!("{scale}x"),
            m.faults.to_string(),
            m.jobs_requeued.to_string(),
            m.watchdog_restarts.to_string(),
            format!("{}/24", m.jobs_completed),
            format!("{:.1}%", 100.0 * m.goodput()),
            secs(m.makespan as f64 / 1e9),
        ]);
    }
    print!("{}", t.render());
    println!("\nexpected shape: goodput decays and makespan stretches with fault scale,");
    println!("but completion stays 24/24 — the §4 script-folder + watchdog loop holds.");
}
