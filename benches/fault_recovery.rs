//! Bench X1: goodput and completion under increasing fault pressure — the
//! quantitative version of §2.6/§4's resilience story — plus the
//! partial-range EP recovery series (wasted/salvaged pairs, recovery
//! makespan, naive vs checkpointed) and the heterogeneous straggler
//! flood with and without range work stealing (DESIGN.md §11).
//!
//! Run: `cargo bench --bench fault_recovery`
//! Writes the deterministic series to `BENCH_fault_recovery.json`.

fn main() {
    gridlan::util::log::init_from_env();
    let h = gridlan::bench::suite::run_fault_recovery();
    let path = h.write().expect("write BENCH json");
    println!("\nwrote {}", path.display());
}
