//! Integration tests for `gridlan lint` (the determinism & invariant
//! static-analysis pass): the swept tree must be clean under
//! `--deny-warnings` semantics, every rule must fire on a seeded
//! violation fixture, and the pragma lifecycle (suppress / stale /
//! reasonless) must behave per DESIGN.md §9.
//!
//! Fixture sources are written to a per-test temp directory so rule
//! allowlists (matched by path suffix) cannot accidentally cover them.

use gridlan::analysis::lint_paths;
use std::path::{Path, PathBuf};

/// The crate's real source tree (what CI lints).
fn src_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust").join("src")
}

/// A unique scratch dir for one test; call `cleanup` when done.
fn fixture_dir(test: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("gridlan_lint_{}_{test}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create fixture dir");
    dir
}

fn write(dir: &Path, name: &str, contents: &str) {
    std::fs::write(dir.join(name), contents).expect("write fixture");
}

fn rules_fired(dir: &Path) -> Vec<(String, String)> {
    let report = lint_paths(&[dir.to_path_buf()]).expect("lint runs");
    report
        .findings
        .iter()
        .map(|f| {
            let file = Path::new(&f.path)
                .file_name()
                .map(|n| n.to_string_lossy().to_string())
                .unwrap_or_default();
            (file, f.rule.to_string())
        })
        .collect()
}

#[test]
fn swept_tree_is_clean_even_with_deny_warnings() {
    let report = lint_paths(&[src_root()]).expect("lint runs on the real tree");
    assert!(report.files_scanned > 30, "walked the whole tree: {}", report.files_scanned);
    assert_eq!(
        report.exit_code(true),
        0,
        "the swept tree must be violation-free:\n{}",
        report.render_human()
    );
}

#[test]
fn every_rule_fires_on_its_seeded_fixture() {
    let dir = fixture_dir("seeded");
    write(&dir, "wall.rs", "fn f() { let t = std::time::Instant::now(); }\n");
    write(&dir, "unordered.rs", "use std::collections::HashMap;\n");
    write(&dir, "spawn.rs", "fn f() { std::thread::spawn(|| {}); }\n");
    write(&dir, "random.rs", "fn f() -> u64 { rand::thread_rng().gen() }\n");
    write(&dir, "sleep.rs", "fn f(d: core::time::Duration) { std::thread::sleep(d); }\n");
    write(&dir, "exit.rs", "fn f() { std::process::exit(3); }\n");
    write(
        &dir,
        "handler.rs",
        "fn f() {\n    sim.schedule_in(5, move |s, w| {\n        w.nodes.get_mut(&c).unwrap();\n    });\n}\n",
    );
    write(&dir, "stale.rs", "// lint:allow(wall-clock): nothing here uses it\nfn f() {}\n");

    let fired = rules_fired(&dir);
    for (file, rule) in [
        ("wall.rs", "wall-clock"),
        ("unordered.rs", "unordered-collections"),
        ("spawn.rs", "thread-spawn"),
        ("random.rs", "ambient-random"),
        ("sleep.rs", "sleep"),
        ("exit.rs", "process-exit"),
        ("handler.rs", "panic-in-handler"),
        ("stale.rs", "stale-pragma"),
    ] {
        assert!(
            fired.iter().any(|(f, r)| f == file && r == rule),
            "expected {rule} to fire on {file}; got {fired:?}"
        );
    }

    // And the CLI contract: a tree with deny findings exits nonzero.
    let report = lint_paths(&[dir.clone()]).expect("lint runs");
    assert_eq!(report.exit_code(false), 1, "seeded violations must fail the gate");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn clean_fixture_stays_silent() {
    let dir = fixture_dir("clean");
    write(
        &dir,
        "clean.rs",
        concat!(
            "//! A well-behaved module: ordered maps, no ambient time.\n",
            "use std::collections::{BTreeMap, BTreeSet};\n",
            "pub fn f(m: &BTreeMap<String, u32>, s: &BTreeSet<u64>) -> usize {\n",
            "    m.len() + s.len()\n",
            "}\n",
            "// Mentions of Instant::now or thread::spawn in comments are fine.\n",
            "const DOC: &str = \"HashMap in a string is fine too\";\n",
        ),
    );
    let report = lint_paths(&[dir.clone()]).expect("lint runs");
    assert!(
        report.findings.is_empty(),
        "clean fixture produced findings:\n{}",
        report.render_human()
    );
    assert_eq!(report.exit_code(true), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn pragma_lifecycle_suppresses_stales_and_requires_reasons() {
    let dir = fixture_dir("pragma");
    // A pragma with a reason suppresses the finding on its own line or
    // the next line — no findings at all from this file.
    write(
        &dir,
        "suppressed.rs",
        concat!(
            "// lint:allow(wall-clock): fixture exercises the suppression path\n",
            "fn f() { let t = std::time::Instant::now(); }\n",
        ),
    );
    // A pragma that suppresses nothing is itself a deny finding.
    write(&dir, "stale.rs", "// lint:allow(sleep): left behind by a refactor\nfn f() {}\n");
    // A reasonless pragma never suppresses: the violation AND the pragma
    // are both reported.
    write(
        &dir,
        "reasonless.rs",
        "fn f() { let t = std::time::Instant::now(); } // lint:allow(wall-clock)\n",
    );

    let fired = rules_fired(&dir);
    assert!(
        !fired.iter().any(|(f, _)| f == "suppressed.rs"),
        "reasoned pragma must fully suppress: {fired:?}"
    );
    assert!(
        fired.iter().any(|(f, r)| f == "stale.rs" && r == "stale-pragma"),
        "unused pragma must be flagged stale: {fired:?}"
    );
    assert!(
        fired.iter().any(|(f, r)| f == "reasonless.rs" && r == "wall-clock"),
        "reasonless pragma must not suppress: {fired:?}"
    );
    assert!(
        fired.iter().any(|(f, r)| f == "reasonless.rs" && r == "stale-pragma"),
        "reasonless pragma is itself a finding: {fired:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn allowlisted_paths_are_exempt_only_for_their_rule() {
    let dir = fixture_dir("allowlist");
    let rt = dir.join("runtime");
    std::fs::create_dir_all(&rt).expect("mkdir runtime");
    // runtime/threaded.rs may spawn threads and read the wall clock (it
    // IS the host-side backend) but still may not use unordered maps.
    std::fs::write(
        rt.join("threaded.rs"),
        concat!(
            "fn f() { std::thread::scope(|s| {}); }\n",
            "fn g() { let t = std::time::Instant::now(); }\n",
            "use std::collections::HashMap;\n",
        ),
    )
    .expect("write fixture");
    let fired = rules_fired(&dir);
    assert!(
        !fired.iter().any(|(_, r)| r == "thread-spawn" || r == "wall-clock"),
        "allowlisted rules must stay quiet in runtime/threaded.rs: {fired:?}"
    );
    assert!(
        fired.iter().any(|(_, r)| r == "unordered-collections"),
        "non-allowlisted rules still apply: {fired:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn lint_report_is_deterministic_across_runs() {
    let a = lint_paths(&[src_root()]).expect("first run");
    let b = lint_paths(&[src_root()]).expect("second run");
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    assert_eq!(a.render_human(), b.render_human());
}
