//! Integration: the PJRT runtime against the AOT artifacts — real compute
//! through the whole L1→L2→HLO→runtime chain.  Skips (with a note) when
//! artifacts are absent; `make artifacts` produces them.

use gridlan::runtime::engine::EpEngine;
use gridlan::runtime::manifest::Manifest;
use gridlan::workload::ep::{ep_scalar, EpClass, EpJob, EpTally};

fn engine() -> Option<EpEngine> {
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        Some(EpEngine::load(&dir).expect("engine"))
    } else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        None
    }
}

#[test]
fn every_chunk_size_matches_the_scalar_oracle() {
    let Some(mut e) = engine() else { return };
    let manifest = Manifest::load(&Manifest::default_dir()).unwrap();
    for art in &manifest.artifacts {
        let t = e.run_pairs(0, art.total_pairs).unwrap();
        let s = ep_scalar(0, art.total_pairs);
        assert!(
            (t.sx - s.sx).abs() < 1e-7,
            "{}: sx {} vs {}",
            art.name,
            t.sx,
            s.sx
        );
        assert_eq!(t.nacc, s.nacc, "{}", art.name);
        assert_eq!(t.q, s.q, "{}", art.name);
    }
}

#[test]
fn sliced_class_s_verifies_like_the_paper_fig3_protocol() {
    // Split class S over 26 "processes" (the Fig. 3 protocol), run each
    // slice through PJRT, merge, verify against NPB constants.
    let Some(mut e) = engine() else { return };
    let job = EpJob::new(EpClass::S, 26);
    let mut total = EpTally::default();
    for s in job.slices() {
        total.merge(&e.run_pairs(s.pair_offset, s.pair_count).unwrap());
    }
    assert_eq!(total.pairs, EpClass::S.pairs());
    assert_eq!(total.verify(EpClass::S), Some(true), "sx={} sy={} nacc={}", total.sx, total.sy, total.nacc);
}

#[test]
fn slice_decomposition_invariant_to_proc_count() {
    let Some(mut e) = engine() else { return };
    // The same 1M-pair range split 1-way vs 7-way must tally identically.
    let whole = e.run_pairs(0, 1 << 20).unwrap();
    let mut parts = EpTally::default();
    let job = EpJob { class: EpClass::S, n_procs: 7 };
    let mut offset = 0u64;
    for s in job.slices().iter().take(7) {
        let count = (1u64 << 20) / 7 + if s.proc < ((1u64 << 20) % 7) as u32 { 1 } else { 0 };
        parts.merge(&e.run_pairs(offset, count).unwrap());
        offset += count;
    }
    assert_eq!(offset, 1 << 20);
    assert!((whole.sx - parts.sx).abs() < 1e-7);
    assert_eq!(whole.nacc, parts.nacc);
}

#[test]
fn throughput_is_sane() {
    let Some(mut e) = engine() else { return };
    e.run_pairs(0, 1 << 18).unwrap();
    let rate = e.measured_rate_mpairs().unwrap();
    // CPU PJRT on vectorized f64 EP: anywhere from 1 to 1000 Mpairs/s is
    // plausible; below 0.1 means the HLO path degenerated to scalar.
    assert!(rate > 0.1, "suspiciously slow: {rate} Mpairs/s");
}
