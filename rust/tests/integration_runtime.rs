//! Integration: the compute runtime end-to-end — REAL compute through the
//! `ComputeBackend` trait, in every build.
//!
//! The scalar backend needs no artifacts, no Python, and no network, so
//! nothing here skips.  (The PJRT artifact path is exercised separately
//! under `--features pjrt`.)

use gridlan::runtime::backend::{ComputeBackend, ScalarBackend};
use gridlan::runtime::engine::EpEngine;
use gridlan::runtime::threaded::ThreadedBackend;
use gridlan::workload::ep::{ep_scalar, EpClass, EpJob, EpTally};

#[test]
fn every_chunk_size_matches_the_scalar_oracle() {
    // The backend's chunked execution must be invisible: any chunk
    // geometry over the same range tallies identically to the oracle.
    let range = 150_001u64;
    let oracle = ep_scalar(0, range);
    for chunk in [1u64 << 10, 1 << 12, (1 << 14) + 17, 1 << 16, 1 << 18] {
        let mut e = EpEngine::with_backend(Box::new(ScalarBackend::with_chunk(chunk)));
        let t = e.run_pairs(0, range).unwrap();
        assert!((t.sx - oracle.sx).abs() < 1e-7, "chunk {chunk}: sx {} vs {}", t.sx, oracle.sx);
        assert_eq!(t.nacc, oracle.nacc, "chunk {chunk}");
        assert_eq!(t.q, oracle.q, "chunk {chunk}");
        assert_eq!(e.pairs_executed(), range, "chunk {chunk}");
    }
}

#[test]
fn threaded_backend_any_geometry_matches_the_oracle() {
    // Thread count and chunk size are execution details: any combination
    // over the same range must tally like the oracle (integer fields
    // exactly, sums to round-off).
    let range = 150_001u64;
    let oracle = ep_scalar(0, range);
    for (threads, chunk) in [(2usize, 1u64 << 12), (4, 1 << 16), (7, (1 << 14) + 17)] {
        let mut e = EpEngine::with_backend(Box::new(ThreadedBackend::with_chunk(threads, chunk)));
        let t = e.run_pairs(0, range).unwrap();
        assert_eq!(t.nacc, oracle.nacc, "threads {threads} chunk {chunk}");
        assert_eq!(t.q, oracle.q, "threads {threads} chunk {chunk}");
        assert!((t.sx - oracle.sx).abs() < 1e-7, "threads {threads} chunk {chunk}");
        assert_eq!(e.pairs_executed(), range);
    }
}

#[test]
fn sliced_class_s_verifies_like_the_paper_fig3_protocol() {
    // Split class S over 26 "processes" (the Fig. 3 protocol), run each
    // slice through the backend, merge, verify against NPB constants.
    let mut e = EpEngine::auto();
    let job = EpJob::new(EpClass::S, 26);
    let mut total = EpTally::default();
    for s in job.slices() {
        total.merge(&e.run_pairs(s.pair_offset, s.pair_count).unwrap());
    }
    assert_eq!(total.pairs, EpClass::S.pairs());
    assert_eq!(
        total.verify(EpClass::S),
        Some(true),
        "sx={} sy={} nacc={}",
        total.sx,
        total.sy,
        total.nacc
    );
}

#[test]
fn slice_decomposition_invariant_to_proc_count() {
    // The same 1M-pair range split 1-way vs 7-way must tally identically.
    let mut e = EpEngine::scalar();
    let whole = e.run_pairs(0, 1 << 20).unwrap();
    let mut parts = EpTally::default();
    let mut offset = 0u64;
    for p in 0..7u64 {
        let count = (1u64 << 20) / 7 + if p < ((1u64 << 20) % 7) { 1 } else { 0 };
        parts.merge(&e.run_pairs(offset, count).unwrap());
        offset += count;
    }
    assert_eq!(offset, 1 << 20);
    assert!((whole.sx - parts.sx).abs() < 1e-7);
    assert_eq!(whole.nacc, parts.nacc);
}

#[test]
fn throughput_is_sane() {
    let mut e = EpEngine::auto();
    e.run_pairs(0, 1 << 18).unwrap();
    let rate = e.measured_rate_mpairs().unwrap();
    // Even a debug-build scalar backend should clear 0.01 Mpairs/s; below
    // that something degenerated (e.g. per-pair jump-ahead reseeking).
    assert!(rate > 0.01, "suspiciously slow: {rate} Mpairs/s");
}

#[test]
fn backend_accounting_is_consistent() {
    let mut b = ScalarBackend::new();
    assert_eq!(b.pairs_executed(), 0);
    b.run_pairs(1_000, 2_000).unwrap();
    b.run_pairs(0, 500).unwrap();
    assert_eq!(b.pairs_executed(), 2_500);
    assert!(b.compute_secs() >= 0.0);
    assert_eq!(b.name(), "scalar");
}

#[cfg(feature = "pjrt")]
mod pjrt_feature {
    use gridlan::runtime::pjrt::PjrtBackend;

    #[test]
    fn pjrt_without_artifacts_reports_cleanly() {
        // In offline builds there are no artifacts (and no `xla` crate):
        // loading must fail with a diagnostic, never panic — callers fall
        // back to the scalar backend.
        let dir = std::path::Path::new("/nonexistent-gridlan-artifacts");
        let err = PjrtBackend::load(dir).unwrap_err();
        assert!(!err.is_empty());
    }
}
