//! Integration: the paper-reproduction acceptance suite — every table and
//! figure's *shape* must hold (DESIGN.md §4 experiment index).

use gridlan::bench::{fig3, mpilat, table1, table2};
use gridlan::config::Config;
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::perf::calibrate::Calibration;
use gridlan::perf::speedmodel::{ComparisonServer, GridlanPool};
use gridlan::runtime::engine::EpEngine;
use gridlan::workload::ep::{ep_scalar, EpClass};

#[test]
fn t1_inventory_reproduces_table1() {
    let rows = table1::inventory_rows(&Config::table1());
    let expect = [
        ("n01", "Xeon E5-2630", 12),
        ("n02", "Core i7-3930K", 6),
        ("n03", "Core i7-2920XM", 4),
        ("n04", "Core i7 960", 4),
    ];
    for ((node, cpu, cores), row) in expect.iter().zip(&rows) {
        assert_eq!(&row.0, node);
        assert_eq!(&row.1, cpu);
        assert_eq!(row.2, *cores);
    }
}

#[test]
fn t2_pings_match_paper_within_8pct() {
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let rows = table2::table2_rows(&mut g, 300);
    for r in &rows {
        let (_, ph, pv) = *table2::PAPER_TABLE2.iter().find(|p| p.0 == r.node).unwrap();
        let host_err = ((r.host_mean_us - ph) / ph).abs();
        let node_err = ((r.node_mean_us - pv) / pv).abs();
        assert!(host_err < 0.06, "{}: host {:.0} vs {}", r.node, r.host_mean_us, ph);
        assert!(node_err < 0.08, "{}: node {:.0} vs {}", r.node, r.node_mean_us, pv);
    }
    // "roughly 900 µs" overhead claim — accept 700-1000.
    let mean_ovh: f64 = rows.iter().map(|r| r.overhead_us()).sum::<f64>() / rows.len() as f64;
    assert!((700.0..1000.0).contains(&mean_ovh), "overhead {mean_ovh:.0}");
}

#[test]
fn m1_mpi_within_10pct_of_node_icmp() {
    let mut g = Gridlan::table1();
    g.boot_all(0);
    for r in mpilat::mpi_latency_rows(&mut g, 300) {
        let ratio = r.mpi_mean_us / r.icmp_node_mean_us;
        assert!((0.9..1.1).contains(&ratio), "{}: {ratio}", r.node);
    }
}

#[test]
fn f3_all_shape_checks() {
    let pool = GridlanPool::table1();
    for seed in [1u64, 7, 42] {
        let series = fig3::fig3_series(&pool, EpClass::D, 40, seed);
        for (name, ok) in fig3::shape_checks(&series) {
            assert!(ok, "seed {seed}: {name}");
        }
    }
}

#[test]
fn f3_crossover_is_robust_to_class() {
    // The who-wins story must not depend on problem size (EP is
    // communication-free, so it shouldn't).
    let pool = GridlanPool::table1();
    let server = ComparisonServer::opteron();
    for class in [EpClass::A, EpClass::C, EpClass::D] {
        let full = {
            let mut p = gridlan::perf::speedmodel::Placement::default();
            for c in &pool.clients {
                p.per_client.insert(c.name.clone(), c.cpu.cores);
            }
            pool.elapsed_secs(class.pairs(), &p)
        };
        let need = server.cores_to_match(class.pairs(), full).unwrap();
        assert!((34..=42).contains(&need), "class {:?}: {need}", class);
    }
}

#[test]
fn f3_gridlan_wins_at_every_core_count_up_to_26() {
    // "the Gridlan group of four computers outperforms the comparison
    // server for all tests up to the maximum number of Gridlan cores".
    let pool = GridlanPool::table1();
    let server = ComparisonServer::opteron();
    let mut rng = gridlan::util::rng::SplitMix64::new(3);
    for n in 1..=26u32 {
        // Even the WORST placement should win (check max over draws).
        let mut worst = 0.0f64;
        for _ in 0..50 {
            let t = pool.elapsed_secs(EpClass::D.pairs(), &pool.random_placement(n, &mut rng));
            worst = worst.max(t);
        }
        let s = server.elapsed_secs(EpClass::D.pairs(), n);
        assert!(worst < s, "n={n}: gridlan worst {worst:.0}s vs server {s:.0}s");
    }
}

#[test]
fn f3_protocol_runs_real_compute_on_the_backend() {
    // The Fig. 3 measurement protocol with REAL compute: scatter a pair
    // range over Fig. 3-style slices, execute each on the scalar
    // `ComputeBackend`, and check the merged physics against the oracle.
    let mut engine = EpEngine::scalar();
    let total_pairs = 1u64 << 18;
    let n_slices = 13u64;
    let mut merged = gridlan::workload::ep::EpTally::default();
    for p in 0..n_slices {
        let base = total_pairs / n_slices;
        let count = base + if p < total_pairs % n_slices { 1 } else { 0 };
        let offset = p * base + p.min(total_pairs % n_slices);
        merged.merge(&engine.run_pairs(offset, count).unwrap());
    }
    let oracle = ep_scalar(0, total_pairs);
    assert_eq!(merged.pairs, total_pairs);
    assert_eq!(merged.nacc, oracle.nacc);
    assert_eq!(merged.q, oracle.q);
    assert!((merged.sx - oracle.sx).abs() < 1e-7);
    // Acceptance ratio ~ pi/4, like the paper's EP verification.
    let rate = merged.nacc as f64 / merged.pairs as f64;
    assert!((rate - std::f64::consts::FRAC_PI_4).abs() < 0.01, "rate={rate}");
}

#[test]
fn measured_backend_rate_calibrates_the_speed_model() {
    // The perf model's calibration hook accepts a real measured rate from
    // the backend (what end_to_end does to extrapolate to class D).
    let mut engine = EpEngine::scalar();
    engine.run_pairs(0, 1 << 18).unwrap();
    let rate = engine.measured_rate_mpairs().unwrap();
    let cal = Calibration::new(rate);
    let secs = cal.secs_for(EpClass::D.pairs());
    assert!(secs > 0.0 && secs.is_finite());
    // Linear consistency: double the pairs, double the predicted time.
    assert!((cal.secs_for(2 << 20) / cal.secs_for(1 << 20) - 2.0).abs() < 1e-9);
}

#[test]
fn paper_212s_and_38_cores_headlines() {
    let pool = GridlanPool::table1();
    let series = fig3::fig3_series(&pool, EpClass::D, 20, 42);
    // 26 Gridlan cores ≈ 212 s (we accept 190-235).
    assert!(
        (190.0..235.0).contains(&series.full_pool_secs),
        "full pool {:.0}s",
        series.full_pool_secs
    );
    // Comparison server needs ≈38 cores.
    let need = series.server_cores_to_match.unwrap();
    assert!((34..=42).contains(&need), "{need} cores");
}
