//! Integration: the timing-wheel engine against the retired `BinaryHeap`
//! core (`sim::baseline`), which is kept in-tree precisely as an oracle.
//!
//! The contract under test is bit-level behavioural equality: identical
//! schedule/cancel/advance sequences must produce identical firing
//! orders, `now()` trajectories, `pending()` counts, `cancel` return
//! values, and `next_event_time()` peeks (tombstones included — the
//! wheel replicates the heap's run_until gating quirk exactly).

use gridlan::sim::baseline::HeapEventId;
use gridlan::sim::engine::EventId;
use gridlan::sim::{HeapSimulator, Simulator};
use gridlan::util::prop::{self, Outcome};
use gridlan::util::rng::SplitMix64;

/// Both engines plus the paired id map, driven in lockstep.
struct Pair {
    wheel: Simulator<Vec<u64>>,
    heap: HeapSimulator<Vec<u64>>,
    wheel_fired: Vec<u64>,
    heap_fired: Vec<u64>,
    ids: Vec<(EventId, HeapEventId)>,
}

impl Pair {
    fn new() -> Self {
        Self {
            wheel: Simulator::new(),
            heap: HeapSimulator::new(),
            wheel_fired: Vec::new(),
            heap_fired: Vec::new(),
            ids: Vec::new(),
        }
    }

    fn schedule(&mut self, delay: u64, key: u64) {
        let at = self.wheel.now().saturating_add(delay);
        let w = self.wheel.schedule_at(at, move |_s, f: &mut Vec<u64>| f.push(key));
        let h = self.heap.schedule_at(at, move |_s, f: &mut Vec<u64>| f.push(key));
        self.ids.push((w, h));
    }

    /// Cancel the nth issued pair; Err if the two engines disagree on
    /// whether the event was still live.
    fn cancel(&mut self, nth: usize) -> Result<(), String> {
        if self.ids.is_empty() {
            return Ok(());
        }
        let (w, h) = self.ids[nth % self.ids.len()];
        let cw = self.wheel.cancel(w);
        let ch = self.heap.cancel(h);
        if cw != ch {
            return Err(format!("cancel({nth}): wheel={cw} heap={ch}"));
        }
        Ok(())
    }

    fn advance(&mut self, dt: u64) {
        let until = self.wheel.now().saturating_add(dt);
        self.wheel.run_until(&mut self.wheel_fired, until);
        self.heap.run_until(&mut self.heap_fired, until);
    }

    fn drain(&mut self) {
        self.wheel.run_to_completion(&mut self.wheel_fired);
        self.heap.run_to_completion(&mut self.heap_fired);
    }

    /// Full lockstep comparison; Err with the first divergence.
    fn compare(&self, ctx: &str) -> Result<(), String> {
        if self.wheel.now() != self.heap.now() {
            return Err(format!("{ctx}: now {} vs {}", self.wheel.now(), self.heap.now()));
        }
        if self.wheel.executed() != self.heap.executed() {
            return Err(format!(
                "{ctx}: executed {} vs {}",
                self.wheel.executed(),
                self.heap.executed()
            ));
        }
        if self.wheel.pending() != self.heap.pending() {
            return Err(format!(
                "{ctx}: pending {} vs {}",
                self.wheel.pending(),
                self.heap.pending()
            ));
        }
        if self.wheel.next_event_time() != self.heap.next_event_time() {
            return Err(format!(
                "{ctx}: next_event_time {:?} vs {:?}",
                self.wheel.next_event_time(),
                self.heap.next_event_time()
            ));
        }
        if self.wheel_fired != self.heap_fired {
            return Err(format!(
                "{ctx}: firing order diverged at #{}",
                self.wheel_fired
                    .iter()
                    .zip(&self.heap_fired)
                    .position(|(a, b)| a != b)
                    .unwrap_or(self.wheel_fired.len().min(self.heap_fired.len()))
            ));
        }
        Ok(())
    }
}

#[test]
fn wheel_matches_the_heap_oracle_on_random_op_sequences() {
    prop::check(60, |g| {
        let n_ops = g.usize_in(1..120);
        let mut p = Pair::new();
        let mut key = 0u64;
        for op in 0..n_ops {
            match g.u64_in(0..10) {
                0..=4 => {
                    let delay = match g.u64_in(0..8) {
                        0 => 0,
                        // Same-tick collisions probe the FIFO tie-break.
                        1 => g.u64_in(0..4),
                        // Level boundaries and the 2^48 ns overflow edge.
                        2 => 1u64 << g.u64_in(40..52),
                        _ => g.u64_in(0..10_000_000),
                    };
                    p.schedule(delay, key);
                    key += 1;
                }
                5 | 6 => {
                    if let Err(e) = p.cancel(g.usize_in(0..4096)) {
                        return Outcome::Fail(format!("op {op}: {e}"));
                    }
                }
                _ => p.advance(g.u64_in(0..5_000_000)),
            }
            if let Err(e) = p.compare(&format!("op {op}")) {
                return Outcome::Fail(e);
            }
        }
        p.drain();
        match p.compare("after drain") {
            Ok(()) => Outcome::Pass,
            Err(e) => Outcome::Fail(e),
        }
    });
}

#[test]
fn large_storm_with_overflow_and_cancellations_matches_the_oracle() {
    // A bigger fixed-seed run than the shrinkable property above: 5k ops
    // deep enough to force cascades across wheel levels, overflow
    // promotion, slab slot reuse, and mid-drain cancellations.
    let mut rng = SplitMix64::new(0xD15C_0DE5);
    let mut p = Pair::new();
    for k in 0..5_000u64 {
        match rng.next_u64() % 10 {
            0..=5 => {
                let delay = if rng.next_u64() % 64 == 0 {
                    1u64 << 49 // past the wheel horizon → overflow level
                } else {
                    rng.next_u64() % 50_000_000
                };
                p.schedule(delay, k);
            }
            6 | 7 => p.cancel(rng.next_u64() as usize).expect("cancel parity"),
            _ => p.advance(rng.next_u64() % 10_000_000),
        }
    }
    p.compare("mid-storm").expect("lockstep parity");
    p.drain();
    p.compare("after drain").expect("lockstep parity");
    assert!(p.wheel.executed() > 1_000, "storm was supposed to fire thousands of events");
}

#[test]
fn batched_inserts_match_sequential_inserts_across_both_engines() {
    // schedule_batch must produce the same ids, order, and firing trace
    // as a sequential loop — and both must match the heap oracle.
    let times = [40u64, 10, 10, 30, 10, 20, 1 << 49, 0];
    let mut batched: Simulator<Vec<u64>> = Simulator::new();
    let ids = batched.schedule_batch(times.iter().enumerate().map(|(k, &t)| {
        let h: gridlan::sim::Handler<Vec<u64>> =
            Box::new(move |_s, f: &mut Vec<u64>| f.push(k as u64));
        (t, h)
    }));
    assert_eq!(ids.len(), times.len());

    let mut p = Pair::new();
    for (k, &t) in times.iter().enumerate() {
        p.schedule(t, k as u64);
    }
    assert_eq!(
        ids,
        p.ids.iter().map(|&(w, _)| w).collect::<Vec<_>>(),
        "batch ids must equal sequential ids"
    );
    let mut batched_fired: Vec<u64> = Vec::new();
    batched.run_to_completion(&mut batched_fired);
    p.drain();
    p.compare("after drain").expect("lockstep parity");
    assert_eq!(batched_fired, p.wheel_fired, "batch firing order must equal sequential");
    assert_eq!(batched_fired, vec![7, 1, 2, 4, 5, 3, 0, 6]);
}

#[test]
fn cancel_liveness_reports_agree_through_fire_and_reuse() {
    // cancel() returns whether the event was still live; the contract
    // must hold identically across both engines through firing, double
    // cancellation, and slab slot reuse.
    let mut p = Pair::new();
    p.schedule(10, 0);
    p.schedule(20, 1);
    p.cancel(0).expect("first cancel agrees (live)");
    p.cancel(0).expect("second cancel agrees (already dead)");
    p.advance(30);
    p.cancel(1).expect("cancel after firing agrees (dead)");
    // Slot reuse: the wheel recycles slot 0; the stale pair-0 id must
    // still report dead on both sides.
    p.schedule(40, 2);
    p.cancel(0).expect("stale id stays dead after slot reuse");
    p.drain();
    p.compare("after drain").expect("lockstep parity");
    assert_eq!(p.wheel_fired, vec![1, 2]);
}
