//! Integration: full job lifecycles across boot, VPN, RM, monitor and the
//! fault machinery — modules composed the way the paper's deployment is.

use gridlan::config::{Config, SchedPolicy};
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{run_ep_slices, run_scenario, run_trace, Scenario};
use gridlan::host::faults::FaultPlan;
use gridlan::rm::alloc::ResourceRequest;
use gridlan::rm::job::JobState;
use gridlan::rm::queue::NodePool;
use gridlan::rm::script::PbsScript;
use gridlan::runtime::engine::EpEngine;
use gridlan::sim::clock::DUR_SEC;
use gridlan::workload::ep::{ep_scalar, EpSlice};
use gridlan::workload::trace::{JobPayload, TraceGenerator, TraceJob};
use gridlan::util::rng::SplitMix64;

fn job(at_secs: u64, nodes: u32, ppn: u32, compute_secs: u64) -> TraceJob {
    TraceJob {
        at: at_secs * DUR_SEC,
        owner: "itest".into(),
        request: ResourceRequest { nodes, ppn },
        compute: compute_secs * DUR_SEC,
        walltime: compute_secs * 4 * DUR_SEC,
        payload: JobPayload::Synthetic,
    }
}

#[test]
fn paper_workflow_qsub_to_completion() {
    // The §2.4 procedure, steps 1-4, against a booted grid.
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let script = PbsScript::parse(
        "#PBS -N npb-ep\n#PBS -q gridlan\n#PBS -l nodes=4:ppn=4\n#PBS -l walltime=01:00:00\nmpirun ./ep\n",
    )
    .unwrap();
    let id = g.pbs.qsub(&script, "attila", "", 0).unwrap();
    let sched = g.scheduler();
    let started = g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), DUR_SEC);
    assert_eq!(started.len(), 1);
    let alloc = g.pbs.job(id).unwrap().allocation.clone().unwrap();
    assert_eq!(alloc.total_cores(), 16);
    // Every allocated node is an online gridlan node.
    for node in alloc.nodes() {
        assert!(g.nodes[node].state.is_running());
    }
    g.pbs.complete(id, 0, 3000 * DUR_SEC);
    assert!(g.pbs.job(id).unwrap().succeeded());
}

#[test]
fn qsub_slices_run_real_compute_through_the_backend() {
    // The full §2.4 user journey with an actual payload: EP slices are
    // qsub'd, scheduled onto booted nodes, and each slice's pair range is
    // executed for REAL on the scalar `ComputeBackend` before completion.
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let mut engine = EpEngine::scalar();
    let slices: Vec<EpSlice> = (0..8u32)
        .map(|p| EpSlice { proc: p, pair_offset: p as u64 * 32_768, pair_count: 32_768 })
        .collect();
    let total = run_ep_slices(&mut g, &mut engine, &slices, 0).unwrap();
    let oracle = ep_scalar(0, 8 * 32_768);
    assert_eq!(total.pairs, 8 * 32_768);
    assert_eq!(total.nacc, oracle.nacc, "backend compute drifted from the oracle");
    assert_eq!(total.q, oracle.q);
    assert!((total.sx - oracle.sx).abs() < 1e-7);
    assert_eq!(engine.pairs_executed(), 8 * 32_768, "all compute went through the backend");
    // Every slice job completed successfully in the resource manager.
    assert_eq!(g.pbs.jobs().filter(|j| j.succeeded()).count(), 8);
}

#[test]
fn multi_queue_isolation() {
    // The paper's "pre-existing cluster" coexistence: gridlan jobs never
    // land on cluster nodes and vice versa.
    let mut cfg = Config::table1();
    cfg.cluster_partition = Some(("opteron".into(), 1, 64));
    let mut g = Gridlan::build(cfg);
    g.boot_all(0);

    let grid_job = PbsScript::parse("#PBS -q gridlan\n#PBS -l nodes=1:ppn=8\n./a\n").unwrap();
    let batch_job = PbsScript::parse("#PBS -q batch\n#PBS -l nodes=1:ppn=32\n./b\n").unwrap();
    let gid = g.pbs.qsub(&grid_job, "u1", "", 0).unwrap();
    let bid = g.pbs.qsub(&batch_job, "u2", "", 0).unwrap();
    let sched = g.scheduler();
    g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), 1);
    g.pbs.schedule_cycle(NodePool::Cluster, sched.as_ref(), 1);
    let galloc = g.pbs.job(gid).unwrap().allocation.clone().unwrap();
    let balloc = g.pbs.job(bid).unwrap().allocation.clone().unwrap();
    assert!(galloc.nodes().all(|n| n.starts_with('n')));
    assert!(balloc.nodes().all(|n| n.starts_with("opteron")));
}

#[test]
fn requeued_job_reruns_elsewhere_or_later() {
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let script = PbsScript::parse("#PBS -q gridlan\n#PBS -l nodes=1:ppn=6\n./x\n").unwrap();
    let id = g.pbs.qsub(&script, "u", "", 0).unwrap();
    let sched = g.scheduler();
    g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), 1);
    let first = g.pbs.job(id).unwrap().allocation.clone().unwrap();
    let first_node = first.nodes().next().unwrap().clone();
    // Node dies; job requeued; node stays down.
    let victims = g.pbs.node_down(&first_node, 100 * DUR_SEC);
    assert_eq!(victims, vec![id]);
    assert_eq!(g.pbs.job(id).unwrap().state, JobState::Queued);
    // Next cycle must place it on a different (online) node if one fits.
    g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), 101 * DUR_SEC);
    let job = g.pbs.job(id).unwrap();
    if job.state == JobState::Running {
        let second = job.allocation.clone().unwrap();
        assert!(second.nodes().all(|n| *n != first_node));
    } else {
        // Only n01/n02 can host ppn=6; if it was n02 that died and n01 is
        // full this would queue — but the grid is empty, so Running is the
        // only acceptable state unless the dead node was the only fit.
        assert!(matches!(job.state, JobState::Queued));
        assert_eq!(first_node, "n01"); // ppn=6 fits n01 (12) and n02 (6)
    }
}

#[test]
fn scenario_scales_to_hundreds_of_jobs() {
    let gen = TraceGenerator { users: 12, ..TraceGenerator::lab_day() };
    let mut rng = SplitMix64::new(99);
    let trace = gen.generate(&mut rng);
    assert!(trace.len() > 80, "want a busy trace, got {}", trace.len());
    let n = trace.len() as u64;
    let scenario = Scenario { horizon: gen.horizon * 6, ..Default::default() };
    let report = run_trace(Gridlan::table1(), trace, &scenario);
    assert_eq!(report.metrics.jobs_completed + report.metrics.jobs_killed, n);
    assert!(report.metrics.jobs_completed as f64 / n as f64 > 0.95);
    assert!(report.events_executed > 1000);
}

#[test]
fn backfill_not_worse_than_fifo_on_wait() {
    let mk = |policy| {
        let mut cfg = Config::table1();
        cfg.sched = policy;
        let trace = vec![
            job(0, 3, 6, 1800), // wide head job (blocks once grid busy)
            job(1, 1, 6, 1800),
            job(2, 1, 1, 60),
            job(2, 1, 1, 60),
            job(2, 1, 1, 60),
        ];
        let scenario = Scenario { horizon: 6 * 3600 * DUR_SEC, ..Default::default() };
        run_trace(Gridlan::build(cfg), trace, &scenario).metrics
    };
    let fifo = mk(SchedPolicy::Fifo);
    let bf = mk(SchedPolicy::Backfill);
    assert_eq!(fifo.jobs_completed, 5);
    assert_eq!(bf.jobs_completed, 5);
    assert!(
        bf.mean_wait_secs() <= fifo.mean_wait_secs() + 1.0,
        "backfill {} vs fifo {}",
        bf.mean_wait_secs(),
        fifo.mean_wait_secs()
    );
}

#[test]
fn survives_extreme_fault_storm() {
    // Stress: MTBF minutes-scale — everything flaps constantly.
    let faults = FaultPlan {
        mtbf_power_off: 900 * DUR_SEC,
        mtbf_net_drop: 1200 * DUR_SEC,
        mtbf_vm_crash: 1500 * DUR_SEC,
        mean_outage: 120 * DUR_SEC,
    };
    let trace: Vec<TraceJob> = (0..10).map(|i| job(i * 60, 1, 2, 300)).collect();
    let scenario = Scenario { horizon: 12 * 3600 * DUR_SEC, faults, ..Default::default() };
    let report = run_trace(Gridlan::table1(), trace, &scenario);
    // No deadlock, no loss: every job eventually completes.
    assert_eq!(report.metrics.jobs_completed, 10, "{:?}", report.metrics);
    assert!(report.metrics.jobs_requeued > 0);
    assert!(report.metrics.goodput() < 1.0);
}

#[test]
fn mixed_trace_and_ep_jobs_survive_a_fault_storm_exactly() {
    // The tentpole scenario: synthetic trace jobs and real-compute EP
    // payload jobs coexist inside one event-driven run under a heavy
    // FaultPlan.  Requeues happen, yet the merged EP tally is exactly the
    // scalar oracle over the union pair range, and the whole report is
    // deterministic run-to-run.
    let run = || {
        let mut trace: Vec<TraceJob> = (0..8).map(|i| job(i * 120, 1, 2, 600)).collect();
        for i in 0..12u64 {
            trace.push(EpSlice {
                proc: i as u32,
                pair_offset: i * 250_000,
                pair_count: 250_000,
            }
            .trace_job((300 + i * 60) * DUR_SEC, 3600 * DUR_SEC));
        }
        let faults = FaultPlan {
            mtbf_power_off: 1800 * DUR_SEC,
            mtbf_net_drop: 0,
            mtbf_vm_crash: 2400 * DUR_SEC,
            mean_outage: 300 * DUR_SEC,
        };
        let scenario = Scenario { horizon: 6 * 3600 * DUR_SEC, faults, ..Default::default() };
        run_scenario(Gridlan::table1(), trace, &scenario, EpEngine::scalar())
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.report.metrics, r2.report.metrics, "reports must be deterministic");
    assert_eq!(r1.report.ep_tallies, r2.report.ep_tallies, "tallies must be deterministic");
    let m = &r1.report.metrics;
    assert_eq!(m.jobs_completed, 8 + 12, "{m:?}");
    assert_eq!(m.ep_jobs_completed, 12);
    assert!(m.faults > 0 && m.jobs_requeued > 0, "storm never hit running work: {m:?}");
    assert_eq!(m.ep_pairs_executed, 12 * 250_000);
    let total = r1.report.ep_total();
    let oracle = ep_scalar(0, 12 * 250_000);
    assert_eq!(total.nacc, oracle.nacc, "merged tally drifted from the oracle");
    assert_eq!(total.q, oracle.q);
    assert_eq!(total.pairs, oracle.pairs);
    assert!((total.sx - oracle.sx).abs() < 1e-7);
    assert!((total.sy - oracle.sy).abs() < 1e-7);
    // The engine executed each range exactly once per completion.
    assert_eq!(r1.engine.pairs_executed(), 12 * 250_000);
}

#[test]
fn script_folder_tracks_incomplete_jobs() {
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let script = PbsScript::parse("#PBS -N keep\n#PBS -q gridlan\n#PBS -l nodes=1:ppn=1\n./x\n").unwrap();
    let id1 = g.pbs.qsub(&script, "u", "", 0).unwrap();
    let id2 = g.pbs.qsub(&script, "u", "", 0).unwrap();
    g.folder.register(&mut g.server_fs, id1, &script);
    g.folder.register(&mut g.server_fs, id2, &script);
    assert_eq!(g.folder.pending_count(), 2);
    // id1 completes (its last command removes the script).
    let sched = g.scheduler();
    g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), 1);
    g.pbs.complete(id1, 0, 100);
    g.folder.job_completed(&mut g.server_fs, id1);
    // id2 is still pending -> it survives in the folder for recovery.
    let survivors = g.folder.survivors();
    assert_eq!(survivors.len(), 1);
    assert_eq!(survivors[0].0, id2);
}
