//! Integration: resource-manager behaviour across queue, scheduler, and
//! job-lifecycle layers — FIFO vs backfill ordering, submission-time
//! rejection against pool capacity, and job-state transitions.

use gridlan::rm::alloc::{match_request, Allocation, FreeNode, FreePool, ResourceRequest};
use gridlan::rm::job::{JobId, JobState};
use gridlan::rm::queue::{NodePool, Queue};
use gridlan::rm::sched::{BackfillScheduler, FifoScheduler, PendingJob, RunningJob, Scheduler};
use gridlan::rm::script::PbsScript;
use gridlan::rm::server::{NodePower, PbsServer};
use gridlan::sim::clock::DUR_SEC;

fn grid_server() -> PbsServer {
    let mut s = PbsServer::new();
    for (name, cores) in [("n01", 12), ("n02", 6), ("n03", 4), ("n04", 4)] {
        s.register_node(name, cores, NodePool::Gridlan);
        s.node_up(name);
    }
    s
}

fn pool_of(free: &[FreeNode]) -> FreePool {
    let mut p = FreePool::new();
    for f in free {
        p.set(&f.name, f.free_cores);
    }
    p
}

fn script(nodes: u32, ppn: u32, wall: &str) -> PbsScript {
    PbsScript::parse(&format!(
        "#PBS -q gridlan\n#PBS -l nodes={nodes}:ppn={ppn},walltime={wall}\n./job.x\n"
    ))
    .unwrap()
}

// ------------------------------------------------- FIFO vs backfill order

#[test]
fn fifo_blocks_at_head_where_backfill_overtakes() {
    // One running wide job; queue = [wider-than-free head, small shortie].
    // FIFO starts nothing; backfill starts exactly the shortie, and only
    // because it finishes before the head's shadow time.
    let running = vec![RunningJob {
        id: JobId(90),
        allocation: Allocation { cores: [("n01".to_string(), 10u32)].into_iter().collect() },
        expected_end: 7_200 * DUR_SEC,
    }];
    let free = vec![
        FreeNode { name: "n01".into(), free_cores: 2 },
        FreeNode { name: "n02".into(), free_cores: 6 },
    ];
    let pending = vec![
        PendingJob {
            id: JobId(1),
            request: ResourceRequest { nodes: 1, ppn: 10 },
            walltime: 3_600 * DUR_SEC,
            queue_priority: 0,
        },
        PendingJob {
            id: JobId(2),
            request: ResourceRequest { nodes: 1, ppn: 2 },
            walltime: 600 * DUR_SEC,
            queue_priority: 0,
        },
    ];
    let fifo = FifoScheduler.select(&pending, &mut pool_of(&free), &running, 0);
    assert!(fifo.is_empty(), "strict FIFO must not overtake the blocked head");
    let bf = BackfillScheduler::new().select(&pending, &mut pool_of(&free), &running, 0);
    assert_eq!(bf.len(), 1);
    assert_eq!(bf[0].0, JobId(2));
}

#[test]
fn backfill_respects_the_head_job_reservation() {
    // Same shape, but the backfill candidate would outlive the head's
    // shadow start: it must NOT start.
    let running = vec![RunningJob {
        id: JobId(90),
        allocation: Allocation { cores: [("n01".to_string(), 10u32)].into_iter().collect() },
        expected_end: 300 * DUR_SEC,
    }];
    let free = vec![FreeNode { name: "n01".into(), free_cores: 2 }];
    let pending = vec![
        PendingJob {
            id: JobId(1),
            request: ResourceRequest { nodes: 1, ppn: 10 },
            walltime: 3_600 * DUR_SEC,
            queue_priority: 0,
        },
        PendingJob {
            id: JobId(2),
            request: ResourceRequest { nodes: 1, ppn: 2 },
            walltime: 900 * DUR_SEC,
            queue_priority: 0,
        },
    ];
    let bf = BackfillScheduler::new().select(&pending, &mut pool_of(&free), &running, 0);
    assert!(bf.is_empty(), "backfill must not delay the head job");
}

#[test]
fn queue_priority_orders_the_pending_list() {
    // Two queues on the same pool: the higher-priority queue drains first
    // even when its jobs were submitted later.
    let mut s = grid_server();
    s.add_queue(Queue {
        name: "urgent".into(),
        pool: NodePool::Gridlan,
        max_running: 0,
        priority: 99,
        enabled: true,
    });
    let lo = s.qsub(&script(1, 4, "01:00:00"), "u", "", 0).unwrap();
    let mut urgent = script(1, 4, "01:00:00");
    urgent.queue = Some("urgent".into());
    let hi = s.qsub(&urgent, "u", "", 10).unwrap();
    let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 20);
    assert_eq!(d.len(), 2);
    assert_eq!(d[0].0, hi, "urgent queue scheduled first");
    assert_eq!(d[1].0, lo);
}

// -------------------------------------- rejection against pool capacity

#[test]
fn oversized_requests_are_rejected_at_submission() {
    let mut s = grid_server();
    // ppn exceeding every node: rejected even though the pool total fits.
    let err = s.qsub(&script(1, 13, "00:10:00"), "u", "", 0).unwrap_err();
    assert!(err.contains("ppn"), "{err}");
    // Total cores exceeding the pool (28 > 26): rejected.
    let err = s.qsub(&script(7, 4, "00:10:00"), "u", "", 0).unwrap_err();
    assert!(err.contains("capacity") || err.contains("exceeds"), "{err}");
    // Boundary: exactly the pool's widest node is accepted.
    assert!(s.qsub(&script(1, 12, "00:10:00"), "u", "", 0).is_ok());
    // Nothing rejected left residue in the job table.
    assert_eq!(s.qstat().len(), 1);
}

#[test]
fn match_request_never_splits_a_chunk_across_nodes() {
    // nodes=1:ppn=10 with 6+6 free must fail even though 12 cores exist.
    let free = vec![
        FreeNode { name: "a".into(), free_cores: 6 },
        FreeNode { name: "b".into(), free_cores: 6 },
    ];
    assert!(match_request(&ResourceRequest { nodes: 1, ppn: 10 }, &free).is_none());
    // But nodes=2:ppn=5 fits, one chunk per node.
    let a = match_request(&ResourceRequest { nodes: 2, ppn: 5 }, &free).unwrap();
    assert_eq!(a.total_cores(), 10);
    assert_eq!(a.node_count(), 2);
}

#[test]
fn offline_capacity_does_not_count() {
    let mut s = grid_server();
    s.set_node_power("n01", NodePower::Offline);
    // 16 cores requested; 26 registered but only 14 online.
    let id = s.qsub(&script(4, 4, "01:00:00"), "u", "", 0).unwrap();
    let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
    assert!(d.is_empty());
    assert_eq!(s.job(id).unwrap().state, JobState::Queued);
    s.node_up("n01");
    assert_eq!(s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 2).len(), 1);
}

// --------------------------------------------------- job-state lifecycle

#[test]
fn job_states_step_through_the_torque_alphabet() {
    let mut s = grid_server();
    let id = s.qsub(&script(1, 4, "01:00:00"), "u", "", 100).unwrap();
    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Queued);
    assert_eq!(job.state.letter(), 'Q');
    assert_eq!(job.submitted_at, 100);
    assert!(job.started_at.is_none() && job.allocation.is_none());

    s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 500);
    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Running);
    assert_eq!(job.started_at, Some(500));
    assert_eq!(job.allocation.as_ref().unwrap().total_cores(), 4);
    assert_eq!(job.wait_time(), Some(400));

    s.complete(id, 0, 2_500);
    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Completed);
    assert_eq!(job.run_time(), Some(2_000));
    assert_eq!(job.turnaround(), Some(2_400));
    assert!(job.succeeded());
}

#[test]
fn requeue_resets_lifecycle_and_counts() {
    let mut s = grid_server();
    let id = s.qsub(&script(1, 6, "01:00:00"), "u", "", 0).unwrap();
    s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 10);
    let node = s
        .job(id)
        .unwrap()
        .allocation
        .as_ref()
        .unwrap()
        .nodes()
        .next()
        .unwrap()
        .clone();
    let victims = s.node_down(&node, 50);
    assert_eq!(victims, vec![id]);
    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Queued);
    assert_eq!(job.requeues, 1);
    assert!(job.started_at.is_none());
    assert!(job.allocation.is_none());
    // A failed/killed job is never "succeeded", even once Completed.
    s.node_up(&node);
    s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 100);
    s.qdel(id, 200).unwrap();
    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Completed);
    assert_eq!(job.exit_code, None);
    assert!(!job.succeeded());
}

#[test]
fn nonzero_exit_completes_but_does_not_succeed() {
    let mut s = grid_server();
    let id = s.qsub(&script(1, 2, "00:30:00"), "u", "", 0).unwrap();
    s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
    s.complete(id, 1, 600);
    let job = s.job(id).unwrap();
    assert_eq!(job.state, JobState::Completed);
    assert_eq!(job.exit_code, Some(1));
    assert!(!job.succeeded());
    // Cores were released regardless of exit status.
    assert_eq!(s.pool_utilization(NodePool::Gridlan).0, 0);
}
