//! Integration tests for the observability pipeline: structured scenario
//! event logs (determinism + round-trip + rollup consistency), the bench
//! harness JSON contract, and the regression gate.

use gridlan::config::Config;
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::metrics::Metrics;
use gridlan::coordinator::scenario::{run_scenario_logged, Scenario};
use gridlan::host::faults::FaultPlan;
use gridlan::obs::event::{ScenarioEvent, ScenarioLogger};
use gridlan::obs::gate::{compare, DEFAULT_TOLERANCE};
use gridlan::obs::harness::{validate, BenchHarness};
use gridlan::obs::report::EventRollup;
use gridlan::rm::alloc::ResourceRequest;
use gridlan::runtime::engine::EpEngine;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::json::Json;
use gridlan::workload::trace::{JobPayload, TraceJob};

fn trace() -> Vec<TraceJob> {
    (0..8)
        .map(|i| TraceJob {
            at: i as u64 * 300 * DUR_SEC,
            owner: format!("u{}", i % 3),
            request: ResourceRequest { nodes: 1, ppn: 1 + (i % 3) as u32 },
            compute: (240 + 60 * (i % 3) as u64) * DUR_SEC,
            walltime: 3600 * DUR_SEC,
            payload: JobPayload::Synthetic,
        })
        .collect()
}

/// One faulty scenario run with a memory event sink; returns the JSONL
/// log and the live metrics.
fn run_logged() -> (String, Metrics) {
    let scenario = Scenario {
        horizon: 4 * 3600 * DUR_SEC,
        faults: FaultPlan::lab_default(),
        ..Default::default()
    };
    let run = run_scenario_logged(
        Gridlan::build(Config::table1()),
        trace(),
        &scenario,
        EpEngine::scalar(),
        ScenarioLogger::memory(),
    );
    (run.logger.to_jsonl(), run.report.metrics)
}

#[test]
fn same_seed_runs_emit_byte_identical_event_logs() {
    let (a, ma) = run_logged();
    let (b, mb) = run_logged();
    assert!(!a.is_empty(), "a faulty scenario must emit events");
    assert_eq!(a, b, "same-seed event logs must be byte-identical");
    assert_eq!(ma, mb, "same-seed metrics must match");
}

/// One seeded fault-storm run (lab fault rates scaled 5x): returns the
/// JSONL event log, the rendered report JSON, and the fault count.
fn run_storm() -> (String, String, u64) {
    let scenario = Scenario {
        horizon: 6 * 3600 * DUR_SEC,
        faults: FaultPlan::lab_default().scaled(5.0),
        ..Default::default()
    };
    let run = run_scenario_logged(
        Gridlan::build(Config::table1()),
        trace(),
        &scenario,
        EpEngine::scalar(),
        ScenarioLogger::memory(),
    );
    let faults = run.report.metrics.faults;
    (run.logger.to_jsonl(), run.report.to_json().to_pretty(), faults)
}

#[test]
fn fault_storm_replay_is_byte_identical() {
    // The determinism contract under stress: a heavy fault storm — power
    // cycles, VPN drops, VM crashes, watchdog restarts, requeues — run
    // twice from the same seed must reproduce the exact event log AND the
    // exact report JSON, byte for byte.  This is the invariant the whole
    // observability stack (BENCH baselines, regression gate, event
    // rollups) rests on, and what `gridlan lint` exists to protect.
    let (log_a, rep_a, faults_a) = run_storm();
    let (log_b, rep_b, faults_b) = run_storm();
    assert!(faults_a > 0, "the storm must actually inject faults");
    assert_eq!(faults_a, faults_b);
    assert_eq!(log_a, log_b, "storm event logs must be byte-identical");
    assert_eq!(rep_a, rep_b, "storm report JSON must be byte-identical");
    // The report JSON is well-formed and carries the metrics block.
    let doc = Json::parse(&rep_a).expect("report JSON parses");
    let metrics = doc.get("metrics").expect("metrics block");
    assert_eq!(
        metrics.get("faults").and_then(Json::as_u64),
        Some(faults_a),
        "report metrics mirror the live counters"
    );
}

#[test]
fn event_log_round_trips_and_rolls_up_consistently() {
    let (log, metrics) = run_logged();
    let events = ScenarioEvent::parse_jsonl(&log).expect("log parses");
    let reserialized: String = events.iter().map(|e| e.to_line() + "\n").collect();
    assert_eq!(log, reserialized, "parse -> serialize is byte-stable");

    let rollup = EventRollup::from_events(&events);
    assert!(rollup.consistent_with(&metrics));
    assert_eq!(rollup.submits, metrics.jobs_submitted);
    assert_eq!(rollup.completes, metrics.jobs_completed);
    assert_eq!(rollup.requeues, metrics.jobs_requeued);
    assert!(rollup.boots >= 4, "all four table-1 clients boot at least once");
    let mut last = 0;
    for ev in &events {
        assert!(ev.at >= last, "event timestamps are monotone");
        last = ev.at;
    }
}

#[test]
fn bench_harness_json_round_trips_through_util_json() {
    let mut h = BenchHarness::new("roundtrip", 7);
    h.param_u64("jobs", 8);
    h.param_str("mode", "test");
    h.sample("makespan", "s", 1234.5);
    h.sample("goodput", "frac", 0.875);
    h.sample("delta", "sum", -3.25e-4);
    let rendered = h.render_json();
    let doc = Json::parse(&rendered).expect("bench JSON parses");
    validate(&doc).expect("bench JSON passes schema validation");
    let re = doc.to_pretty() + "\n";
    assert_eq!(rendered, re, "parse -> pretty-print is byte-stable");
}

#[test]
fn gate_fails_on_injected_slowdown_and_passes_within_tolerance() {
    fn time_doc(mean: f64) -> Json {
        let mut h = BenchHarness::new("gate", 1);
        h.param_u64("jobs", 8);
        h.sample("makespan", "s", mean);
        h.to_json()
    }
    let base = time_doc(100.0);
    // 20% slower on a lower-is-better unit: the gate must fail.
    let slow = compare(&base, &time_doc(120.0), DEFAULT_TOLERANCE).unwrap();
    assert!(!slow.passed(), "20% slowdown must fail the gate");
    // 8% slower is inside the 15% tolerance.
    let ok = compare(&base, &time_doc(108.0), DEFAULT_TOLERANCE).unwrap();
    assert!(ok.passed(), "8% drift must pass the gate");
    // Getting faster is never a regression for time units.
    let fast = compare(&base, &time_doc(50.0), DEFAULT_TOLERANCE).unwrap();
    assert!(fast.passed());
}

#[test]
fn gate_direction_for_rates_is_higher_is_better() {
    fn rate_doc(mean: f64) -> Json {
        let mut h = BenchHarness::new("gate-rate", 1);
        h.sample("throughput", "Mpairs/s", mean);
        h.to_json()
    }
    let base = rate_doc(100.0);
    let drop = compare(&base, &rate_doc(80.0), DEFAULT_TOLERANCE).unwrap();
    assert!(!drop.passed(), "20% rate drop must fail the gate");
    let gain = compare(&base, &rate_doc(120.0), DEFAULT_TOLERANCE).unwrap();
    assert!(gain.passed(), "a rate gain is not a regression");
}

#[test]
fn suite_bench_is_deterministic_and_gates_against_itself() {
    let a = gridlan::bench::suite::run_fault_recovery();
    let b = gridlan::bench::suite::run_fault_recovery();
    assert_eq!(a.render_json(), b.render_json(), "same-seed BENCH json is byte-identical");
    let doc = Json::parse(&a.render_json()).unwrap();
    validate(&doc).expect("suite bench emits schema-valid JSON");
    let report = compare(&doc, &doc, DEFAULT_TOLERANCE).unwrap();
    assert!(report.passed(), "a bench never regresses against itself");
    assert_eq!(report.n_regressions(), 0);
}
