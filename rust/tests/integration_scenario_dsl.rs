//! Integration: the declarative scenario DSL and its chaos corpus.
//!
//! Holds the determinism contract for every committed file under
//! `scenarios/` — run twice, byte-identical event log and report JSON —
//! and proves the DSL subsumes the hand-coded scenario tests it
//! replaced (`Scenario::scripted_faults`, the mixed trace/EP storm).

use std::path::{Path, PathBuf};

use gridlan::config::Config;
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{run_scenario_logged, Scenario};
use gridlan::host::faults::{FaultEvent, FaultKind, FaultPlan};
use gridlan::obs::event::{ScenarioEvent, ScenarioLogger};
use gridlan::rm::alloc::ResourceRequest;
use gridlan::runtime::engine::EpEngine;
use gridlan::scenario_dsl::{corpus_files, load_file, run_compiled, run_file};
use gridlan::sim::clock::{DUR_MS, DUR_SEC};
use gridlan::workload::ep::EpSlice;
use gridlan::workload::trace::{JobPayload, TraceJob};

fn corpus_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios")
}

#[test]
fn corpus_has_at_least_ten_scenarios() {
    let files = corpus_files(&corpus_dir()).expect("committed corpus present");
    assert!(files.len() >= 10, "chaos corpus shrank to {} files", files.len());
}

#[test]
fn every_corpus_file_passes_expect_and_replays_byte_identically() {
    // The whole-corpus extension of integration_obs's fault-storm replay
    // test: each file runs twice — once through the file-path entry
    // point, once from its compiled form — and both the JSONL event log
    // and the pretty report JSON must match byte for byte.
    for path in corpus_files(&corpus_dir()).expect("corpus present") {
        let spec = load_file(&path).unwrap_or_else(|e| panic!("{e}"));
        let a = run_file(&path).unwrap_or_else(|e| panic!("{e}"));
        assert!(a.passed(), "{}:\n{}", path.display(), a.render_summary());
        assert!(
            !a.expect.checks.is_empty(),
            "{}: corpus files must assert something",
            path.display()
        );
        assert!(!a.events_jsonl.is_empty(), "{}: no events logged", path.display());
        let b = run_compiled(&spec.compile());
        assert_eq!(
            a.events_jsonl,
            b.events_jsonl,
            "{}: replay event logs must be byte-identical",
            path.display()
        );
        assert_eq!(
            a.report_json,
            b.report_json,
            "{}: replay report JSON must be byte-identical",
            path.display()
        );
        // The emitted log is a valid, round-trippable obs event stream.
        let events = ScenarioEvent::parse_jsonl(&a.events_jsonl).expect("log parses");
        let re: String = events.iter().map(|e| e.to_line() + "\n").collect();
        assert_eq!(a.events_jsonl, re, "{}: log round-trips", path.display());
    }
}

#[test]
fn dsl_subsumes_the_scripted_fault_scenario() {
    // scenarios/10_scripted_crash_requeue.json is the declarative twin of
    // the in-code `Scenario::scripted_faults` crash test: a pre-booted
    // Table-1 grid, one 2M-pair EP job at t=1000s, every client VM
    // crashed 200ms into the run.  The DSL path must emit the exact same
    // event log and report as the hand-built scenario — byte for byte.
    let file_out =
        run_file(&corpus_dir().join("10_scripted_crash_requeue.json")).expect("corpus file runs");

    let mut g = Gridlan::build(Config::table1());
    g.boot_all(0);
    let at = 1000 * DUR_SEC;
    let trace = vec![
        EpSlice { proc: 0, pair_offset: 5_000, pair_count: 2_000_000 }
            .trace_job(at, 3600 * DUR_SEC),
    ];
    let scripted: Vec<FaultEvent> = ["n01", "n02", "n03", "n04"]
        .iter()
        .map(|n| FaultEvent {
            at: at + 200 * DUR_MS,
            client: n.to_string(),
            kind: FaultKind::VmCrash,
            outage: 60 * DUR_SEC,
        })
        .collect();
    let scenario = Scenario {
        horizon: 2 * 3600 * DUR_SEC,
        scripted_faults: scripted,
        ..Default::default()
    };
    let run =
        run_scenario_logged(g, trace, &scenario, EpEngine::scalar(), ScenarioLogger::memory());

    assert_eq!(
        file_out.events_jsonl,
        run.logger.to_jsonl(),
        "DSL run and hand-coded scenario must emit identical event logs"
    );
    assert_eq!(file_out.report_json, run.report.to_json().to_pretty() + "\n");
    assert!(file_out.metrics.jobs_requeued >= 1, "{:?}", file_out.metrics);
    assert!(file_out.metrics.watchdog_restarts > 0);
}

#[test]
fn dsl_subsumes_the_mixed_trace_ep_storm() {
    // scenarios/09_mixed_trace_ep_storm.json re-expresses the
    // `mixed_trace_and_ep_jobs_survive_a_fault_storm_exactly` lifecycle
    // test: 8 synthetic jobs + 12 real-compute EP slices under a
    // power-off/VM-crash storm.  Metrics and merged tallies must match
    // the hand-built run exactly.
    let file_out =
        run_file(&corpus_dir().join("09_mixed_trace_ep_storm.json")).expect("corpus file runs");

    let mut trace: Vec<TraceJob> = (0..8u64)
        .map(|i| TraceJob {
            at: i * 120 * DUR_SEC,
            owner: "itest".into(),
            request: ResourceRequest { nodes: 1, ppn: 2 },
            compute: 600 * DUR_SEC,
            walltime: 2400 * DUR_SEC,
            payload: JobPayload::Synthetic,
        })
        .collect();
    for i in 0..12u64 {
        trace.push(
            EpSlice { proc: i as u32, pair_offset: i * 250_000, pair_count: 250_000 }
                .trace_job((300 + i * 60) * DUR_SEC, 3600 * DUR_SEC),
        );
    }
    let faults = FaultPlan {
        mtbf_power_off: 1800 * DUR_SEC,
        mtbf_net_drop: 0,
        mtbf_vm_crash: 2400 * DUR_SEC,
        mean_outage: 300 * DUR_SEC,
    };
    let scenario = Scenario { horizon: 6 * 3600 * DUR_SEC, faults, ..Default::default() };
    let run = run_scenario_logged(
        Gridlan::build(Config::table1()),
        trace,
        &scenario,
        EpEngine::scalar(),
        ScenarioLogger::memory(),
    );

    assert_eq!(file_out.metrics, run.report.metrics, "metrics must match the in-code twin");
    let twin_total = run.report.ep_total();
    assert_eq!(file_out.ep_total.nacc, twin_total.nacc);
    assert_eq!(file_out.ep_total.q, twin_total.q);
    assert_eq!(file_out.ep_total.pairs, twin_total.pairs);
    assert_eq!(file_out.metrics.jobs_completed, 20);
    assert_eq!(file_out.metrics.ep_pairs_executed, 12 * 250_000);
    assert!(file_out.metrics.faults > 0 && file_out.metrics.jobs_requeued > 0);
}

#[test]
fn file_errors_carry_the_path() {
    let missing = corpus_dir().join("no_such_scenario.json");
    let err = run_file(&missing).expect_err("missing file must error");
    assert!(err.contains("no_such_scenario.json"), "{err}");

    let dir = std::env::temp_dir().join("gridlan_dsl_itest");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{\n  \"seed\": 1,\n  \"faults\": [{\"kind\": \"meteor\"}]\n}").unwrap();
    let err = load_file(&bad).expect_err("bad fault kind must error");
    assert!(err.contains("bad.json"), "{err}");
    assert!(err.contains("faults[0].kind"), "{err}");
    assert!(err.contains("meteor"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
