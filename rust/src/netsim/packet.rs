//! Wire-format sizes and the simulated packet.
//!
//! Sizes matter because serialization delay = bytes * 8 / bandwidth, and
//! the VPN encapsulation grows every frame (part of the paper's ~900 µs
//! node-path overhead at 100 Mb/s links).

/// Ethernet header + FCS (no preamble).
pub const ETH_HEADER: u32 = 18;
/// IPv4 header (no options).
pub const IP_HEADER: u32 = 20;
/// UDP header.
pub const UDP_HEADER: u32 = 8;
/// ICMP echo header.
pub const ICMP_HEADER: u32 = 8;
/// OpenVPN-over-UDP encapsulation: outer IP+UDP+OpenVPN opcode/HMAC/IV.
/// (~69 bytes for the default cipher suite; we use the documented value.)
pub const VPN_HEADER: u32 = 69;

/// A simulated packet traversing the LAN.
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    /// Payload length in bytes (headers are added per-layer).
    pub payload: u32,
    /// Number of encapsulation layers already applied (0 = raw ethernet).
    pub layers: Vec<Layer>,
    /// Opaque tag for the receiver's dispatch (protocol, port...).
    pub tag: u64,
}

/// An encapsulation layer on a packet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Layer {
    Ipv4,
    Udp,
    Icmp,
    Vpn,
}

impl Packet {
    pub fn new(payload: u32, tag: u64) -> Self {
        Self { payload, layers: Vec::new(), tag }
    }

    /// Total on-wire bytes including all headers.
    pub fn wire_bytes(&self) -> u32 {
        let hdrs: u32 = self
            .layers
            .iter()
            .map(|l| match l {
                Layer::Ipv4 => IP_HEADER,
                Layer::Udp => UDP_HEADER,
                Layer::Icmp => ICMP_HEADER,
                Layer::Vpn => VPN_HEADER,
            })
            .sum();
        ETH_HEADER + hdrs + self.payload
    }

    pub fn push_layer(mut self, l: Layer) -> Self {
        self.layers.push(l);
        self
    }

    /// A standard 56-byte-payload ICMP echo (what the paper's ping sends).
    pub fn icmp_echo() -> Self {
        Packet::new(56, 0).push_layer(Layer::Ipv4).push_layer(Layer::Icmp)
    }

    /// The same echo encapsulated in the VPN tunnel.
    pub fn icmp_echo_tunneled() -> Self {
        Self::icmp_echo().push_layer(Layer::Vpn)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn icmp_echo_is_98_bytes_on_wire() {
        // 18 eth + 20 ip + 8 icmp + 56 payload = 102; the classic "64 bytes
        // from..." counts ip+icmp+payload = 84.  We count full ethernet.
        assert_eq!(Packet::icmp_echo().wire_bytes(), 102);
    }

    #[test]
    fn tunnel_adds_vpn_header() {
        let raw = Packet::icmp_echo().wire_bytes();
        let tun = Packet::icmp_echo_tunneled().wire_bytes();
        assert_eq!(tun - raw, VPN_HEADER);
    }

    #[test]
    fn layers_accumulate() {
        let p = Packet::new(100, 7)
            .push_layer(Layer::Ipv4)
            .push_layer(Layer::Udp)
            .push_layer(Layer::Vpn);
        assert_eq!(p.wire_bytes(), 18 + 20 + 8 + 69 + 100);
        assert_eq!(p.tag, 7);
    }
}
