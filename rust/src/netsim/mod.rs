//! Simulated LAN substrate.
//!
//! The paper's testbed is a building LAN: clients "a few switches or
//! routers away from the server ... linked via wired connections" (Fig. 1c).
//! This module models exactly what Table 2 is sensitive to:
//!
//! * per-link propagation + serialization delay,
//! * per-switch store-and-forward + processing delay,
//! * OS/NIC stack latency at each endpoint,
//! * gaussian jitter (the paper reports mean(std) over repeated pings).
//!
//! Topology is a device graph; paths are BFS shortest hop-count (LANs are
//! trees in practice).  Packet delivery is event-driven via
//! [`crate::sim::Simulator`]; latency-only queries use the analytic
//! [`Network::one_way_delay`], which the event path shares.

pub mod icmp;
pub mod packet;
pub mod topology;

pub use icmp::{ping_sweep, PingStats};
pub use packet::{Packet, ETH_HEADER, ICMP_HEADER, IP_HEADER, UDP_HEADER, VPN_HEADER};
pub use topology::{DeviceId, DeviceKind, LinkProfile, Network, PathDelayModel};
