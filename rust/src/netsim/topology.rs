//! Device graph and the path delay model.

use crate::sim::clock::{from_us_f64, SimTime};
use crate::util::rng::SplitMix64;
use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};

/// Sentinel in a BFS parent forest: not reached from the source.
const NO_PARENT: usize = usize::MAX;

/// Index of a device in the network graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

/// What a device is — affects per-hop processing cost.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceKind {
    /// End host: contributes OS+NIC stack latency at path endpoints.
    Host { stack_us: f64 },
    /// Store-and-forward switch/router.
    Switch { proc_us: f64 },
}

/// Physical link characteristics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkProfile {
    /// Propagation + cabling delay, one way (µs).
    pub latency_us: f64,
    /// Bandwidth in megabits/s (serialization delay = bytes*8/bw).
    pub bandwidth_mbps: f64,
}

impl LinkProfile {
    /// Typical building gigabit run.
    pub fn gigabit() -> Self {
        Self { latency_us: 3.0, bandwidth_mbps: 1000.0 }
    }

    /// Older 100 Mb/s segment (several of the paper's clients).
    pub fn fast_ethernet() -> Self {
        Self { latency_us: 5.0, bandwidth_mbps: 100.0 }
    }

    /// Serialization delay for `bytes` on this link, in µs.
    pub fn serialize_us(&self, bytes: u32) -> f64 {
        (bytes as f64 * 8.0) / self.bandwidth_mbps
    }
}

#[derive(Debug, Clone)]
struct Device {
    #[allow(dead_code)]
    name: String,
    kind: DeviceKind,
}

#[derive(Debug, Clone, Copy)]
struct Edge {
    to: usize,
    profile: LinkProfile,
}

/// Analytic delay decomposition for one path traversal (all µs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PathDelayModel {
    pub endpoint_stack_us: f64,
    pub propagation_us: f64,
    pub serialization_us: f64,
    pub switching_us: f64,
}

impl PathDelayModel {
    pub fn total_us(&self) -> f64 {
        self.endpoint_stack_us + self.propagation_us + self.serialization_us + self.switching_us
    }
}

/// The LAN graph.
#[derive(Debug, Clone, Default)]
pub struct Network {
    devices: Vec<Device>,
    adj: Vec<Vec<Edge>>,
    by_name: BTreeMap<String, usize>,
    /// Link profile per directed pair, mirroring `adj` (the first link
    /// wins on parallel edges, like the linear scan it replaces).  A star
    /// hub's adjacency list holds every client, so a per-window scan in
    /// `delay_model` would be O(clients) per packet.
    edge_idx: BTreeMap<(usize, usize), LinkProfile>,
    /// Memoized BFS parent forests keyed by source device.  One forest
    /// answers every `path`/`hops` query from that source in O(path);
    /// without it a 100k-node boot storm pays a full-graph BFS per boot.
    /// Cleared on any topology mutation.
    bfs_cache: RefCell<BTreeMap<usize, Vec<usize>>>,
    /// Per-path gaussian jitter sigma (µs) applied to one-way samples.
    pub jitter_sigma_us: f64,
}

impl Network {
    pub fn new() -> Self {
        Self { jitter_sigma_us: 7.0, ..Default::default() }
    }

    pub fn add_device(&mut self, name: &str, kind: DeviceKind) -> DeviceId {
        assert!(
            !self.by_name.contains_key(name),
            "duplicate device name {name}"
        );
        let id = self.devices.len();
        self.devices.push(Device { name: name.to_string(), kind });
        self.adj.push(Vec::new());
        self.by_name.insert(name.to_string(), id);
        self.bfs_cache.borrow_mut().clear();
        DeviceId(id)
    }

    pub fn add_host(&mut self, name: &str, stack_us: f64) -> DeviceId {
        self.add_device(name, DeviceKind::Host { stack_us })
    }

    pub fn add_switch(&mut self, name: &str, proc_us: f64) -> DeviceId {
        self.add_device(name, DeviceKind::Switch { proc_us })
    }

    pub fn lookup(&self, name: &str) -> Option<DeviceId> {
        self.by_name.get(name).map(|&i| DeviceId(i))
    }

    pub fn device_count(&self) -> usize {
        self.devices.len()
    }

    /// Bidirectional link.
    pub fn link(&mut self, a: DeviceId, b: DeviceId, profile: LinkProfile) {
        assert_ne!(a, b, "self-link");
        self.adj[a.0].push(Edge { to: b.0, profile });
        self.adj[b.0].push(Edge { to: a.0, profile });
        self.edge_idx.entry((a.0, b.0)).or_insert(profile);
        self.edge_idx.entry((b.0, a.0)).or_insert(profile);
        self.bfs_cache.borrow_mut().clear();
    }

    /// BFS shortest path (device ids, inclusive of endpoints).  The
    /// parent forest is memoized per source: the tree is identical to
    /// what an early-exit BFS would build (parents are fixed at first
    /// discovery), so the returned path is bit-identical to the
    /// uncached version.
    pub fn path(&self, from: DeviceId, to: DeviceId) -> Option<Vec<DeviceId>> {
        if from == to {
            return Some(vec![from]);
        }
        let mut cache = self.bfs_cache.borrow_mut();
        let parent = cache.entry(from.0).or_insert_with(|| self.bfs_parents(from.0));
        if to.0 >= parent.len() || parent[to.0] == NO_PARENT {
            return None;
        }
        let mut path = vec![to.0];
        let mut cur = to.0;
        while cur != from.0 {
            cur = parent[cur];
            path.push(cur);
        }
        path.reverse();
        Some(path.into_iter().map(DeviceId).collect())
    }

    /// Full BFS from `from`, same adjacency order as the query path.
    fn bfs_parents(&self, from: usize) -> Vec<usize> {
        let mut parent = vec![NO_PARENT; self.devices.len()];
        let mut seen = vec![false; self.devices.len()];
        let mut q = VecDeque::new();
        seen[from] = true;
        q.push_back(from);
        while let Some(u) = q.pop_front() {
            for e in &self.adj[u] {
                if !seen[e.to] {
                    seen[e.to] = true;
                    parent[e.to] = u;
                    q.push_back(e.to);
                }
            }
        }
        parent
    }

    fn edge_between(&self, a: usize, b: usize) -> Option<LinkProfile> {
        self.edge_idx.get(&(a, b)).copied()
    }

    /// Analytic one-way delay decomposition for `bytes` from `from` to `to`.
    /// Returns None if the devices are not connected.
    pub fn delay_model(&self, from: DeviceId, to: DeviceId, bytes: u32) -> Option<PathDelayModel> {
        let path = self.path(from, to)?;
        let mut m = PathDelayModel::default();
        for d in [&path[0], path.last().unwrap()] {
            if let DeviceKind::Host { stack_us } = self.devices[d.0].kind {
                m.endpoint_stack_us += stack_us;
            }
        }
        for w in path.windows(2) {
            let lp = self
                .edge_between(w[0].0, w[1].0)
                .expect("path uses nonexistent edge");
            m.propagation_us += lp.latency_us;
            m.serialization_us += lp.serialize_us(bytes);
        }
        // Interior devices: switching cost (store-and-forward already covered
        // by per-link serialization; proc_us is lookup+queueing).
        for d in &path[1..path.len().saturating_sub(1)] {
            if let DeviceKind::Switch { proc_us } = self.devices[d.0].kind {
                m.switching_us += proc_us;
            }
        }
        Some(m)
    }

    /// Mean one-way delay in µs.
    pub fn one_way_delay_us(&self, from: DeviceId, to: DeviceId, bytes: u32) -> Option<f64> {
        self.delay_model(from, to, bytes).map(|m| m.total_us())
    }

    /// One jittered one-way sample as SimTime.
    pub fn sample_one_way(
        &self,
        from: DeviceId,
        to: DeviceId,
        bytes: u32,
        rng: &mut SplitMix64,
    ) -> Option<SimTime> {
        let mean = self.one_way_delay_us(from, to, bytes)?;
        let jitter = rng.next_gaussian() * self.jitter_sigma_us;
        // Jitter can only delay below a floor of 80% of the mean — packets
        // don't arrive before light.
        Some(from_us_f64((mean + jitter).max(mean * 0.8)))
    }

    /// Hop count (number of links) between two devices.
    pub fn hops(&self, from: DeviceId, to: DeviceId) -> Option<usize> {
        self.path(from, to).map(|p| p.len().saturating_sub(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lan() -> (Network, DeviceId, DeviceId, DeviceId) {
        // server - sw1 - sw2 - client ; second client on sw1.
        let mut n = Network::new();
        let server = n.add_host("server", 50.0);
        let sw1 = n.add_switch("sw1", 20.0);
        let sw2 = n.add_switch("sw2", 20.0);
        let c1 = n.add_host("c1", 60.0);
        let c2 = n.add_host("c2", 60.0);
        let g = LinkProfile::gigabit();
        n.link(server, sw1, g);
        n.link(sw1, sw2, g);
        n.link(sw2, c1, g);
        n.link(sw1, c2, g);
        (n, server, c1, c2)
    }

    #[test]
    fn bfs_path_and_hops() {
        let (n, server, c1, c2) = lan();
        assert_eq!(n.hops(server, c1), Some(3));
        assert_eq!(n.hops(server, c2), Some(2));
        assert_eq!(n.hops(server, server), Some(0));
        let p = n.path(server, c1).unwrap();
        assert_eq!(p.len(), 4);
    }

    #[test]
    fn disconnected_is_none() {
        let mut n = Network::new();
        let a = n.add_host("a", 10.0);
        let b = n.add_host("b", 10.0);
        assert!(n.path(a, b).is_none());
        assert!(n.one_way_delay_us(a, b, 100).is_none());
    }

    #[test]
    fn delay_decomposition_adds_up() {
        let (n, server, c1, _) = lan();
        let m = n.delay_model(server, c1, 102).unwrap();
        // endpoints: 50 + 60; 3 links x 3µs prop; 3 links x 0.816µs ser;
        // 2 switches x 20µs.
        assert!((m.endpoint_stack_us - 110.0).abs() < 1e-9);
        assert!((m.propagation_us - 9.0).abs() < 1e-9);
        assert!((m.serialization_us - 3.0 * 102.0 * 8.0 / 1000.0).abs() < 1e-9);
        assert!((m.switching_us - 40.0).abs() < 1e-9);
        assert!((m.total_us() - (110.0 + 9.0 + 2.448 + 40.0)).abs() < 1e-6);
    }

    #[test]
    fn bigger_packets_take_longer() {
        let (n, server, c1, _) = lan();
        let small = n.one_way_delay_us(server, c1, 100).unwrap();
        let big = n.one_way_delay_us(server, c1, 1500).unwrap();
        assert!(big > small);
    }

    #[test]
    fn jittered_samples_scatter_around_mean() {
        let (n, server, c1, _) = lan();
        let mean = n.one_way_delay_us(server, c1, 102).unwrap();
        let mut rng = SplitMix64::new(5);
        let mut acc = 0.0;
        let k = 500;
        for _ in 0..k {
            acc += n.sample_one_way(server, c1, 102, &mut rng).unwrap() as f64 / 1e3;
        }
        let sample_mean = acc / k as f64;
        assert!((sample_mean - mean).abs() < 2.0, "{sample_mean} vs {mean}");
    }

    #[test]
    fn slower_links_dominate_serialization() {
        let mut n = Network::new();
        let a = n.add_host("a", 0.0);
        let b = n.add_host("b", 0.0);
        n.link(a, b, LinkProfile::fast_ethernet());
        let m = n.delay_model(a, b, 1500).unwrap();
        assert!((m.serialization_us - 120.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "duplicate device")]
    fn duplicate_names_panic() {
        let mut n = Network::new();
        n.add_host("x", 1.0);
        n.add_host("x", 1.0);
    }
}
