//! ICMP echo (ping) over the simulated LAN — the Table 2 measurement tool.
//!
//! `ping_sweep` reproduces the paper's methodology: repeated 56-byte
//! echoes, reported as mean(std) of the RTT.  The responder adds a small
//! processing delay (ICMP handled in-kernel).

use super::packet::Packet;
use super::topology::{DeviceId, Network};
use crate::util::rng::SplitMix64;
use crate::util::stats::Summary;

/// ICMP echo responder processing time (kernel fast path), µs.
pub const ECHO_PROC_US: f64 = 15.0;

/// Result of a ping sweep.
#[derive(Debug, Clone)]
pub struct PingStats {
    pub rtts_us: Summary,
    pub sent: usize,
    pub lost: usize,
}

impl PingStats {
    pub fn mean_us(&self) -> f64 {
        self.rtts_us.mean()
    }

    pub fn std_us(&self) -> f64 {
        self.rtts_us.std()
    }

    /// Paper-style string, e.g. "550(20)".
    pub fn paper(&self, round: f64) -> String {
        self.rtts_us.paper_format(round)
    }
}

/// One RTT sample (µs) for an un-tunneled ping, or None if unreachable.
pub fn ping_once(
    net: &Network,
    from: DeviceId,
    to: DeviceId,
    packet: &Packet,
    rng: &mut SplitMix64,
) -> Option<f64> {
    let fwd = net.sample_one_way(from, to, packet.wire_bytes(), rng)? as f64 / 1e3;
    let back = net.sample_one_way(to, from, packet.wire_bytes(), rng)? as f64 / 1e3;
    Some(fwd + ECHO_PROC_US + back)
}

/// `count` echo samples, like `ping -c count`.
pub fn ping_sweep(
    net: &Network,
    from: DeviceId,
    to: DeviceId,
    packet: &Packet,
    count: usize,
    rng: &mut SplitMix64,
) -> PingStats {
    let mut s = Summary::new();
    let mut lost = 0;
    for _ in 0..count {
        match ping_once(net, from, to, packet, rng) {
            Some(rtt) => s.push(rtt),
            None => lost += 1,
        }
    }
    PingStats { rtts_us: s, sent: count, lost }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::topology::LinkProfile;

    fn pair() -> (Network, DeviceId, DeviceId) {
        let mut n = Network::new();
        let a = n.add_host("server", 100.0);
        let sw = n.add_switch("sw", 25.0);
        let b = n.add_host("client", 120.0);
        n.link(a, sw, LinkProfile::gigabit());
        n.link(sw, b, LinkProfile::gigabit());
        (n, a, b)
    }

    #[test]
    fn rtt_is_roughly_twice_one_way() {
        let (n, a, b) = pair();
        let one_way = n.one_way_delay_us(a, b, Packet::icmp_echo().wire_bytes()).unwrap();
        let mut rng = SplitMix64::new(1);
        let stats = ping_sweep(&n, a, b, &Packet::icmp_echo(), 200, &mut rng);
        let expect = 2.0 * one_way + ECHO_PROC_US;
        assert!(
            (stats.mean_us() - expect).abs() < 5.0,
            "mean {} vs {}",
            stats.mean_us(),
            expect
        );
        assert_eq!(stats.lost, 0);
    }

    #[test]
    fn std_reflects_jitter() {
        let (mut n, a, b) = pair();
        n.jitter_sigma_us = 10.0;
        let mut rng = SplitMix64::new(2);
        let stats = ping_sweep(&n, a, b, &Packet::icmp_echo(), 300, &mut rng);
        // Two one-way samples per RTT: sigma_rtt ~ sqrt(2)*10.
        assert!(stats.std_us() > 5.0 && stats.std_us() < 30.0, "std={}", stats.std_us());
    }

    #[test]
    fn unreachable_counts_lost() {
        let mut n = Network::new();
        let a = n.add_host("a", 1.0);
        let b = n.add_host("b", 1.0);
        let mut rng = SplitMix64::new(3);
        let stats = ping_sweep(&n, a, b, &Packet::icmp_echo(), 5, &mut rng);
        assert_eq!(stats.lost, 5);
        assert!(stats.rtts_us.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let (n, a, b) = pair();
        let mut r1 = SplitMix64::new(7);
        let mut r2 = SplitMix64::new(7);
        let s1 = ping_sweep(&n, a, b, &Packet::icmp_echo(), 50, &mut r1);
        let s2 = ping_sweep(&n, a, b, &Packet::icmp_echo(), 50, &mut r2);
        assert_eq!(s1.mean_us(), s2.mean_us());
        assert_eq!(s1.std_us(), s2.std_us());
    }
}
