//! Monte Carlo campaigns (paper §4): "a statistical average of several
//! simulations of the same experiment must be performed" — each replica is
//! an independent job; the Gridlan's sweet spot.

use crate::rm::script::PbsScript;

/// A campaign of independent replicas.
#[derive(Debug, Clone)]
pub struct MonteCarloCampaign {
    pub name: String,
    pub replicas: u32,
    /// Pairs of EP-equivalent work per replica (we express MC work in the
    /// same currency the perf model speaks).
    pub pairs_per_replica: u64,
    pub queue: String,
}

impl MonteCarloCampaign {
    pub fn new(name: &str, replicas: u32, pairs_per_replica: u64) -> Self {
        Self { name: name.to_string(), replicas, pairs_per_replica, queue: "gridlan".into() }
    }

    /// One qsub script per replica, single core each (the §4 pattern).
    pub fn scripts(&self) -> Vec<PbsScript> {
        (0..self.replicas)
            .map(|i| {
                PbsScript::parse(&format!(
                    "#PBS -N {}-r{:03}\n#PBS -q {}\n#PBS -l nodes=1:ppn=1\n./mc.x --seed {}\n",
                    self.name, i, self.queue, i
                ))
                .expect("generated script parses")
            })
            .collect()
    }

    /// Payload string the coordinator hands the runtime for replica `i`:
    /// an EP pair range disjoint per replica.
    pub fn payload(&self, i: u32) -> String {
        format!("mc:{}:{}", i as u64 * self.pairs_per_replica, self.pairs_per_replica)
    }

    pub fn total_pairs(&self) -> u64 {
        self.replicas as u64 * self.pairs_per_replica
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripts_are_single_core_and_named() {
        let c = MonteCarloCampaign::new("ising", 8, 1 << 20);
        let scripts = c.scripts();
        assert_eq!(scripts.len(), 8);
        for (i, s) in scripts.iter().enumerate() {
            assert_eq!(s.request.total_cores(), 1);
            assert_eq!(s.queue.as_deref(), Some("gridlan"));
            assert!(s.name.as_ref().unwrap().contains(&format!("r{i:03}")));
        }
    }

    #[test]
    fn payloads_are_disjoint_ranges() {
        let c = MonteCarloCampaign::new("x", 3, 100);
        assert_eq!(c.payload(0), "mc:0:100");
        assert_eq!(c.payload(1), "mc:100:100");
        assert_eq!(c.payload(2), "mc:200:100");
        assert_eq!(c.total_pairs(), 300);
    }
}
