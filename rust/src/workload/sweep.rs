//! Parameter sweeps (paper §4): "the goal of the calculation is to
//! determine a curve from some simulation test, and each point of the
//! curve is independently obtained from other points using different
//! simulation parameters."

use crate::rm::script::PbsScript;

/// A 1-D parameter sweep producing one curve.
#[derive(Debug, Clone)]
pub struct ParameterSweep {
    pub name: String,
    pub param: String,
    pub values: Vec<f64>,
    /// EP-equivalent pairs of work per point; work may vary per point.
    pub pairs_per_point: Vec<u64>,
    pub cores_per_point: u32,
    pub queue: String,
}

impl ParameterSweep {
    /// Uniform-cost sweep over [lo, hi] with `n` points.
    pub fn linspace(name: &str, param: &str, lo: f64, hi: f64, n: usize, pairs: u64) -> Self {
        assert!(n >= 2);
        let values = (0..n)
            .map(|i| lo + (hi - lo) * i as f64 / (n - 1) as f64)
            .collect();
        Self {
            name: name.to_string(),
            param: param.to_string(),
            values,
            pairs_per_point: vec![pairs; n],
            cores_per_point: 1,
            queue: "gridlan".into(),
        }
    }

    pub fn n_points(&self) -> usize {
        self.values.len()
    }

    pub fn scripts(&self) -> Vec<PbsScript> {
        self.values
            .iter()
            .enumerate()
            .map(|(i, v)| {
                PbsScript::parse(&format!(
                    "#PBS -N {}-p{:03}\n#PBS -q {}\n#PBS -l nodes=1:ppn={}\n./sim.x --{}={}\n",
                    self.name, i, self.queue, self.cores_per_point, self.param, v
                ))
                .expect("generated script parses")
            })
            .collect()
    }

    /// Payload for point `i` (EP pair range, per-point size).
    pub fn payload(&self, i: usize) -> String {
        let offset: u64 = self.pairs_per_point[..i].iter().sum();
        format!("sweep:{}:{}", offset, self.pairs_per_point[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linspace_endpoints() {
        let s = ParameterSweep::linspace("visc", "nu", 0.1, 1.0, 10, 1 << 18);
        assert_eq!(s.n_points(), 10);
        assert!((s.values[0] - 0.1).abs() < 1e-12);
        assert!((s.values[9] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn scripts_embed_param_values() {
        let s = ParameterSweep::linspace("visc", "nu", 0.0, 1.0, 3, 100);
        let scripts = s.scripts();
        assert_eq!(scripts.len(), 3);
        assert!(scripts[1].commands[0].contains("--nu=0.5"));
    }

    #[test]
    fn payloads_tile_the_work() {
        let mut s = ParameterSweep::linspace("x", "p", 0.0, 1.0, 3, 0);
        s.pairs_per_point = vec![10, 20, 30];
        assert_eq!(s.payload(0), "sweep:0:10");
        assert_eq!(s.payload(1), "sweep:10:20");
        assert_eq!(s.payload(2), "sweep:30:30");
    }
}
