//! NPB suite communication-pattern models: the paper's §4/§6 suitability
//! analysis, quantified for the rest of the NAS Parallel Benchmarks.
//!
//! The paper's conclusion: the Gridlan fits (a) independent computations,
//! (b) tightly-coupled computations *within one node*, and (c) parallel
//! computations whose interconnect time is negligible.  This module models
//! each NPB kernel's per-iteration compute/message profile (classic
//! published characterizations, normalized per process) and classifies it
//! with [`crate::mpi::pattern::CommPattern`].

use crate::mpi::pattern::CommPattern;

/// An NPB kernel with its communication character.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NpbKernel {
    /// Embarrassingly Parallel — no communication.
    Ep,
    /// Integer Sort — all-to-all key exchange every iteration.
    Is,
    /// Conjugate Gradient — frequent small irregular messages.
    Cg,
    /// 3-D FFT — all-to-all transposes of large volumes.
    Ft,
    /// Multigrid — nearest-neighbour halo exchanges.
    Mg,
    /// Block tridiagonal solver — structured medium messages.
    Bt,
}

impl NpbKernel {
    pub fn all() -> [NpbKernel; 6] {
        [NpbKernel::Ep, NpbKernel::Is, NpbKernel::Cg, NpbKernel::Ft, NpbKernel::Mg, NpbKernel::Bt]
    }

    pub fn name(self) -> &'static str {
        match self {
            NpbKernel::Ep => "EP",
            NpbKernel::Is => "IS",
            NpbKernel::Cg => "CG",
            NpbKernel::Ft => "FT",
            NpbKernel::Mg => "MG",
            NpbKernel::Bt => "BT",
        }
    }

    /// Per-iteration, per-process profile at class-A-like scale on ~8
    /// processes (compute µs, messages/iter, bytes/message).  Values are
    /// order-of-magnitude characterizations from the NPB literature —
    /// what matters for the §4 analysis is their *ratios*.
    pub fn pattern(self) -> CommPattern {
        match self {
            NpbKernel::Ep => CommPattern { compute_us: 1.0e6, msgs_per_iter: 0.0, msg_bytes: 0 },
            NpbKernel::Is => CommPattern { compute_us: 9_000.0, msgs_per_iter: 8.0, msg_bytes: 2_000_000 },
            NpbKernel::Cg => CommPattern { compute_us: 3_500.0, msgs_per_iter: 24.0, msg_bytes: 16_000 },
            NpbKernel::Ft => CommPattern { compute_us: 50_000.0, msgs_per_iter: 8.0, msg_bytes: 4_000_000 },
            NpbKernel::Mg => CommPattern { compute_us: 8_000.0, msgs_per_iter: 12.0, msg_bytes: 16_000 },
            NpbKernel::Bt => CommPattern { compute_us: 30_000.0, msgs_per_iter: 12.0, msg_bytes: 160_000 },
        }
    }

    /// The paper's three-way verdict for a given interconnect.
    pub fn verdict(self, latency_us: f64, us_per_byte: f64) -> Suitability {
        let eff = self.pattern().efficiency(latency_us, us_per_byte);
        if eff >= 0.95 {
            Suitability::Ideal
        } else if eff >= 0.70 {
            Suitability::UserJudgement
        } else {
            Suitability::SingleNodeOnly
        }
    }
}

/// Where a job should run on the Gridlan (paper §6's three cases).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Suitability {
    /// Scatter freely across nodes.
    Ideal,
    /// The §4 "intermediate case": user decides (e.g. 70/30).
    UserJudgement,
    /// Keep all processes inside one node (case b of the conclusion).
    SingleNodeOnly,
}

/// Gridlan node-to-node interconnect figures (measured in M1/T2):
/// ~1400 µs RTT latency per message, gigabit wire underneath + VPN crypto.
pub const GRIDLAN_LAT_US: f64 = 1400.0;
pub const GRIDLAN_US_PER_BYTE: f64 = 0.014;

/// Conventional cluster interconnect for comparison.
pub const CLUSTER_LAT_US: f64 = 50.0;
pub const CLUSTER_US_PER_BYTE: f64 = 0.008;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ep_is_ideal_everywhere() {
        assert_eq!(NpbKernel::Ep.verdict(GRIDLAN_LAT_US, GRIDLAN_US_PER_BYTE), Suitability::Ideal);
        assert_eq!(NpbKernel::Ep.verdict(CLUSTER_LAT_US, CLUSTER_US_PER_BYTE), Suitability::Ideal);
    }

    #[test]
    fn communication_heavy_kernels_stay_single_node_on_gridlan() {
        for k in [NpbKernel::Is, NpbKernel::Cg] {
            assert_eq!(
                k.verdict(GRIDLAN_LAT_US, GRIDLAN_US_PER_BYTE),
                Suitability::SingleNodeOnly,
                "{}",
                k.name()
            );
        }
    }

    #[test]
    fn cluster_rescues_most_kernels() {
        // The same kernels are fine (or at least user-judgement) on a
        // proper cluster interconnect — the paper's point that this
        // analysis "should be performed regardless of the cluster".
        for k in NpbKernel::all() {
            let grid = k.pattern().efficiency(GRIDLAN_LAT_US, GRIDLAN_US_PER_BYTE);
            let clus = k.pattern().efficiency(CLUSTER_LAT_US, CLUSTER_US_PER_BYTE);
            assert!(clus >= grid, "{}: cluster {clus} < gridlan {grid}", k.name());
        }
        assert_ne!(
            NpbKernel::Mg.verdict(CLUSTER_LAT_US, CLUSTER_US_PER_BYTE),
            Suitability::SingleNodeOnly
        );
    }

    #[test]
    fn verdicts_monotone_in_latency() {
        for k in NpbKernel::all() {
            let lo = k.pattern().efficiency(10.0, 0.001);
            let hi = k.pattern().efficiency(10_000.0, 0.02);
            assert!(lo >= hi, "{}", k.name());
        }
    }
}
