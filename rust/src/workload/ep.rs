//! The NPB-EP benchmark as a Gridlan workload (paper §3.4).
//!
//! EP generates `2^(M+1)` uniform randoms with the NPB 46-bit LCG, forms
//! pairs, applies the Marsaglia polar acceptance test, and tallies the
//! accepted Gaussian deviates.  Zero communication: the ideal local-grid
//! job.  Work splits perfectly by pair ranges thanks to LCG jump-ahead.
//!
//! Verification: sums computed by the L1 kernel must match the class
//! constants (cross-checked against the official NPB values for class S
//! within the benchmark's 1e-8 relative tolerance — see EXPERIMENTS.md).

use crate::rm::alloc::ResourceRequest;
use crate::sim::clock::SimTime;
use crate::util::rng::{NpbLcg, NPB_MASK, NPB_SEED, R46};
use crate::workload::trace::{JobPayload, TraceJob};

/// EP observables, mergeable across slices/chunks.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct EpTally {
    pub sx: f64,
    pub sy: f64,
    pub q: [u64; 10],
    pub nacc: u64,
    pub pairs: u64,
}

impl EpTally {
    pub fn merge(&mut self, other: &EpTally) {
        self.sx += other.sx;
        self.sy += other.sy;
        for i in 0..10 {
            self.q[i] += other.q[i];
        }
        self.nacc += other.nacc;
        self.pairs += other.pairs;
    }

    /// NPB-style verification against class constants: relative error on
    /// the sums within 1e-8 and exact Gaussian-pair count.
    pub fn verify(&self, class: EpClass) -> Option<bool> {
        let (sx, sy, nacc) = class.verification()?;
        let rel = |a: f64, b: f64| ((a - b) / b).abs();
        Some(rel(self.sx, sx) < 1e-8 && rel(self.sy, sy) < 1e-8 && self.nacc == nacc)
    }
}

/// Exact scalar EP over `count` pairs starting at global pair `offset` —
/// the rust twin of the python gold oracle.  Used for sub-chunk remainders
/// in the runtime and as an independent check on the PJRT path.
pub fn ep_scalar(offset: u64, count: u64) -> EpTally {
    let lcg = NpbLcg::new(NPB_SEED).jumped(2 * offset);
    let mut t = EpTally { pairs: count, ..Default::default() };
    const A: u64 = crate::util::rng::NPB_A;
    let mut s = lcg.state;
    for _ in 0..count {
        s = s.wrapping_mul(A) & NPB_MASK;
        let x = 2.0 * (s as f64 * R46) - 1.0;
        s = s.wrapping_mul(A) & NPB_MASK;
        let y = 2.0 * (s as f64 * R46) - 1.0;
        let tt = x * x + y * y;
        if tt <= 1.0 {
            let f = (-2.0 * tt.ln() / tt).sqrt();
            let gx = x * f;
            let gy = y * f;
            let l = gx.abs().max(gy.abs()) as usize;
            if l < 10 {
                t.q[l] += 1;
            }
            t.sx += gx;
            t.sy += gy;
            t.nacc += 1;
        }
    }
    t
}

/// NPB problem classes: `pairs = 2^M`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EpClass {
    S,
    W,
    A,
    B,
    C,
    D,
}

impl EpClass {
    pub fn m(self) -> u32 {
        match self {
            EpClass::S => 24,
            EpClass::W => 25,
            EpClass::A => 28,
            EpClass::B => 30,
            EpClass::C => 32,
            EpClass::D => 36,
        }
    }

    pub fn pairs(self) -> u64 {
        1u64 << self.m()
    }

    pub fn name(self) -> &'static str {
        match self {
            EpClass::S => "S",
            EpClass::W => "W",
            EpClass::A => "A",
            EpClass::B => "B",
            EpClass::C => "C",
            EpClass::D => "D",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s.to_ascii_uppercase().as_str() {
            "S" => Some(EpClass::S),
            "W" => Some(EpClass::W),
            "A" => Some(EpClass::A),
            "B" => Some(EpClass::B),
            "C" => Some(EpClass::C),
            "D" => Some(EpClass::D),
            _ => None,
        }
    }

    /// Reference tallies (sx, sy, accepted pairs) where known.  S and W
    /// were computed with the verified L1 kernel/reference (the S values
    /// agree with the official NPB constants to ~1e-10 relative).
    pub fn verification(self) -> Option<(f64, f64, u64)> {
        match self {
            EpClass::S => Some((-3.247834652034633e3, -6.958407078382782e3, 13_176_389)),
            EpClass::W => Some((-2.863319731645753e3, -6.320053679109499e3, 26_354_769)),
            _ => None,
        }
    }
}

/// An EP job instance: one class, split over `n_procs` processes.
#[derive(Debug, Clone)]
pub struct EpJob {
    pub class: EpClass,
    pub n_procs: u32,
}

/// One process's slice of the pair space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpSlice {
    pub proc: u32,
    pub pair_offset: u64,
    pub pair_count: u64,
}

impl EpSlice {
    /// This slice as a single-core RM submission at time `at` — the
    /// Fig. 3 scatter-protocol job shape, carried as a real-compute
    /// [`JobPayload::Ep`] through the event-driven scenario.
    pub fn trace_job(&self, at: SimTime, walltime: SimTime) -> TraceJob {
        TraceJob {
            at,
            owner: "gridlan".into(),
            request: ResourceRequest { nodes: 1, ppn: 1 },
            compute: 0,
            walltime,
            payload: JobPayload::Ep { offset: self.pair_offset, count: self.pair_count },
        }
    }
}

impl EpJob {
    pub fn new(class: EpClass, n_procs: u32) -> Self {
        assert!(n_procs >= 1);
        Self { class, n_procs }
    }

    /// Contiguous near-equal split of the pair space (remainder spread over
    /// the first slices), matching how NPB-MPI partitions batches.
    pub fn slices(&self) -> Vec<EpSlice> {
        let total = self.class.pairs();
        let n = self.n_procs as u64;
        let base = total / n;
        let rem = total % n;
        let mut out = Vec::with_capacity(self.n_procs as usize);
        let mut offset = 0u64;
        for p in 0..n {
            let count = base + if p < rem { 1 } else { 0 };
            out.push(EpSlice { proc: p as u32, pair_offset: offset, pair_count: count });
            offset += count;
        }
        out
    }

    /// Lane seeds for executing one slice on the runtime's chunk geometry:
    /// `n_lanes` lanes each covering `pairs_per_lane` pairs starting at the
    /// slice offset (+ an intra-slice chunk offset).
    pub fn lane_seeds_for(slice: &EpSlice, chunk_offset: u64, n_lanes: usize, pairs_per_lane: u64) -> Vec<u64> {
        NpbLcg::ep_lane_seeds(n_lanes, pairs_per_lane, slice.pair_offset + chunk_offset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, expect};

    #[test]
    fn class_sizes() {
        assert_eq!(EpClass::S.pairs(), 1 << 24);
        assert_eq!(EpClass::D.pairs(), 1 << 36);
        assert_eq!(EpClass::from_name("d"), Some(EpClass::D));
        assert_eq!(EpClass::from_name("x"), None);
    }

    #[test]
    fn slices_partition_exactly() {
        for n in [1u32, 3, 7, 26] {
            let job = EpJob::new(EpClass::S, n);
            let slices = job.slices();
            assert_eq!(slices.len(), n as usize);
            let mut expected_offset = 0u64;
            let mut total = 0u64;
            for s in &slices {
                assert_eq!(s.pair_offset, expected_offset, "contiguous");
                expected_offset += s.pair_count;
                total += s.pair_count;
            }
            assert_eq!(total, EpClass::S.pairs());
        }
    }

    #[test]
    fn prop_slices_always_partition() {
        prop::check(100, |g| {
            let class = *g.choose(&[EpClass::S, EpClass::W, EpClass::A, EpClass::D]);
            let n = g.u64_in(1..200) as u32;
            let slices = EpJob::new(class, n).slices();
            let total: u64 = slices.iter().map(|s| s.pair_count).sum();
            let contiguous = slices.windows(2).all(|w| w[0].pair_offset + w[0].pair_count == w[1].pair_offset);
            let balanced = {
                let min = slices.iter().map(|s| s.pair_count).min().unwrap();
                let max = slices.iter().map(|s| s.pair_count).max().unwrap();
                max - min <= 1
            };
            expect(
                total == class.pairs() && contiguous && balanced,
                &format!("class={class:?} n={n}"),
            )
        });
    }

    #[test]
    fn lane_seeds_respect_offsets() {
        let job = EpJob::new(EpClass::S, 4);
        let slices = job.slices();
        let seeds = EpJob::lane_seeds_for(&slices[1], 0, 4, 16);
        // Lane 0 of slice 1 must equal the global stream state after
        // slices[1].pair_offset pairs.
        let direct = NpbLcg::new(crate::util::rng::NPB_SEED).jumped(2 * slices[1].pair_offset);
        assert_eq!(seeds[0], direct.state);
    }

    #[test]
    fn ep_scalar_matches_python_gold() {
        // python ref.ep_gold_scalar(1024) cross-check values are exercised
        // indirectly: scalar over 2 slices == scalar over the union.
        let whole = ep_scalar(0, 2048);
        let mut merged = ep_scalar(0, 1000);
        merged.merge(&ep_scalar(1000, 1048));
        assert!((whole.sx - merged.sx).abs() < 1e-9);
        assert!((whole.sy - merged.sy).abs() < 1e-9);
        assert_eq!(whole.q, merged.q);
        assert_eq!(whole.nacc, merged.nacc);
        assert_eq!(whole.pairs, merged.pairs);
    }

    #[test]
    fn ep_scalar_acceptance_near_pi_over_4() {
        let t = ep_scalar(0, 1 << 16);
        let rate = t.nacc as f64 / t.pairs as f64;
        assert!((rate - std::f64::consts::FRAC_PI_4).abs() < 0.01, "rate={rate}");
        assert_eq!(t.q.iter().sum::<u64>(), t.nacc);
    }

    #[test]
    fn prop_ep_scalar_merge_associative() {
        prop::check(30, |g| {
            let off = g.u64_in(0..10_000);
            let a = g.u64_in(1..2_000);
            let b = g.u64_in(1..2_000);
            let whole = ep_scalar(off, a + b);
            let mut parts = ep_scalar(off, a);
            parts.merge(&ep_scalar(off + a, b));
            expect(
                (whole.sx - parts.sx).abs() < 1e-9 && whole.nacc == parts.nacc,
                &format!("off={off} a={a} b={b}"),
            )
        });
    }

    #[test]
    fn verification_constants_present_for_small_classes() {
        assert!(EpClass::S.verification().is_some());
        assert!(EpClass::W.verification().is_some());
        assert!(EpClass::D.verification().is_none());
        let (_, _, nacc) = EpClass::S.verification().unwrap();
        // acceptance ratio ~ pi/4
        let ratio = nacc as f64 / EpClass::S.pairs() as f64;
        assert!((ratio - std::f64::consts::FRAC_PI_4).abs() < 1e-3);
    }
}
