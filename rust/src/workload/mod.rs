//! Workloads the paper's users run on the Gridlan.
//!
//! * [`ep`] — the NPB "Embarrassingly Parallel" benchmark (the paper's
//!   Fig. 3 workload): class definitions, work accounting, verification
//!   sums, and the splitting of a job into per-core process work;
//! * [`montecarlo`] — Monte Carlo campaigns (§4's first example use-case);
//! * [`sweep`] — parameter-sweep curves (§4's second example);
//! * [`trace`] — synthetic multi-user job traces for the scheduler
//!   ablation (A1).

pub mod ep;
pub mod montecarlo;
pub mod npb;
pub mod sweep;
pub mod trace;

pub use ep::{EpClass, EpJob};
pub use montecarlo::MonteCarloCampaign;
pub use npb::{NpbKernel, Suitability};
pub use sweep::ParameterSweep;
pub use trace::TraceGenerator;
