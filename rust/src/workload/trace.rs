//! Synthetic multi-user job traces for the scheduler ablation (A1).
//!
//! Mimics a small lab's submission pattern: bursts of small jobs (students
//! iterating), occasional wide jobs (someone's big run), submitted over a
//! working day.

use crate::rm::alloc::ResourceRequest;
use crate::sim::clock::{SimTime, DUR_SEC};
use crate::util::rng::SplitMix64;

/// What a submitted job actually runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum JobPayload {
    /// Synthetic work: occupies the allocation for [`TraceJob::compute`]
    /// (rescaled by the speed model); no real computation happens.
    #[default]
    Synthetic,
    /// Real EP compute over global pairs `[offset, offset + count)`:
    /// the duration comes from the speed model (pairs over the slowest
    /// allocated core's rate) and the range is executed for REAL on the
    /// scenario's `ComputeBackend` at completion time.
    Ep { offset: u64, count: u64 },
}

impl JobPayload {
    /// The opaque payload string the RM carries (`trace:<ns>` /
    /// `ep:<offset>:<count>`).
    pub fn encode(&self, compute: SimTime) -> String {
        match self {
            JobPayload::Synthetic => format!("trace:{compute}"),
            JobPayload::Ep { offset, count } => format!("ep:{offset}:{count}"),
        }
    }
}

/// One synthetic submission.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceJob {
    pub at: SimTime,
    pub owner: String,
    pub request: ResourceRequest,
    /// Actual compute duration (what the workload would take on one
    /// reference core; the perf model rescales per placement).  Ignored
    /// for [`JobPayload::Ep`], whose duration derives from its pair count.
    pub compute: SimTime,
    /// The walltime the user *requested* (over-estimate, like real users).
    pub walltime: SimTime,
    /// What the job runs (synthetic occupancy or real EP compute).
    pub payload: JobPayload,
}

/// Trace generator.
#[derive(Debug, Clone)]
pub struct TraceGenerator {
    pub users: u32,
    pub horizon: SimTime,
    /// Mean inter-arrival per user.
    pub mean_gap: SimTime,
    /// P(wide job) vs small job.
    pub wide_fraction: f64,
}

impl TraceGenerator {
    pub fn lab_day() -> Self {
        Self {
            users: 5,
            horizon: 8 * 3600 * DUR_SEC,
            mean_gap: 1800 * DUR_SEC,
            wide_fraction: 0.15,
        }
    }

    pub fn generate(&self, rng: &mut SplitMix64) -> Vec<TraceJob> {
        let mut jobs = Vec::new();
        for u in 0..self.users {
            let mut t: SimTime = (rng.next_f64() * self.mean_gap as f64) as SimTime;
            while t < self.horizon {
                let wide = rng.next_f64() < self.wide_fraction;
                let (request, compute_secs) = if wide {
                    (
                        ResourceRequest { nodes: 2 + rng.gen_range(3) as u32, ppn: 4 },
                        1200.0 + rng.next_f64() * 2400.0,
                    )
                } else {
                    (
                        ResourceRequest { nodes: 1, ppn: 1 + rng.gen_range(4) as u32 },
                        120.0 + rng.next_f64() * 900.0,
                    )
                };
                let compute = (compute_secs * DUR_SEC as f64) as SimTime;
                // Users over-request walltime 1.5-4x.
                let walltime = (compute as f64 * (1.5 + 2.5 * rng.next_f64())) as SimTime;
                jobs.push(TraceJob {
                    at: t,
                    owner: format!("user{u:02}"),
                    request,
                    compute,
                    walltime,
                    payload: JobPayload::Synthetic,
                });
                t += (rng.next_f64() * 2.0 * self.mean_gap as f64) as SimTime + DUR_SEC;
            }
        }
        jobs.sort_by_key(|j| j.at);
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sorted() {
        let gen = TraceGenerator::lab_day();
        let a = gen.generate(&mut SplitMix64::new(5));
        let b = gen.generate(&mut SplitMix64::new(5));
        assert_eq!(a, b);
        assert!(!a.is_empty());
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn jobs_within_horizon_and_sane() {
        let gen = TraceGenerator::lab_day();
        let jobs = gen.generate(&mut SplitMix64::new(6));
        for j in &jobs {
            assert!(j.at < gen.horizon);
            assert!(j.walltime >= j.compute, "users over-estimate");
            assert!(j.request.total_cores() >= 1);
        }
    }

    #[test]
    fn payload_encoding() {
        assert_eq!(JobPayload::Synthetic.encode(5), "trace:5");
        assert_eq!(JobPayload::Ep { offset: 10, count: 20 }.encode(999), "ep:10:20");
    }

    #[test]
    fn mix_of_wide_and_narrow() {
        let gen = TraceGenerator { users: 20, ..TraceGenerator::lab_day() };
        let jobs = gen.generate(&mut SplitMix64::new(7));
        let wide = jobs.iter().filter(|j| j.request.nodes > 1).count();
        assert!(wide > 0 && wide < jobs.len());
    }
}
