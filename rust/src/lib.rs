//! # Gridlan — a multi-purpose local grid computing framework
//!
//! Reproduction of Rodrigues & Costa (2016): turn underused lab
//! workstations into a cluster-like local grid via VPN + virtualized,
//! remote-booted nodes + a Torque-like resource manager.
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — the Gridlan coordinator and every substrate it
//!   needs, on a deterministic discrete-event simulation;
//! * **runtime** — real EP compute for simulated jobs behind the
//!   [`runtime::backend::ComputeBackend`] trait: the pure-Rust scalar
//!   backend (zero external dependencies; bit-deterministic), the
//!   multi-threaded backend (`std::thread` fan-out with an exact merge;
//!   the default on multi-core hosts), or the optional PJRT artifact
//!   path (`--features pjrt`);
//! * **L2/L1 (python, build-time only, optional)** — the NPB-EP compute
//!   payload as a JAX graph wrapping a Pallas kernel, AOT-lowered to HLO
//!   text for the PJRT backend.

pub mod analysis;
pub mod bench;
pub mod boot;
pub mod config;
pub mod coordinator;
pub mod host;
pub mod monitor;
pub mod mpi;
pub mod netsim;
pub mod obs;
pub mod perf;
pub mod rm;
pub mod runtime;
pub mod scenario_dsl;
pub mod sim;
pub mod util;
pub mod vm;
pub mod vpn;
pub mod workload;
