//! Virtualized Gridlan nodes (paper §2.2).
//!
//! Each client workstation runs one VM — "the Gridlan node" — so the
//! compute environment is homogeneous regardless of the host OS.  Three
//! concerns live here:
//!
//! * [`cpu`] — the physical CPU performance model, including the Turbo
//!   Boost / Turbo Core clock-vs-active-cores behaviour that makes the
//!   paper's Fig. 3 deviate from ideal speed-up;
//! * [`hypervisor`] — QEMU/KVM, VirtualBox, pure-QEMU (TCG) and VMware
//!   profiles: CPU efficiency and virtio network overhead;
//! * [`node`] — the VM lifecycle state machine (Off → PXE → TFTP →
//!   NFS-root → Up) driven by the `boot` protocols.

pub mod cpu;
pub mod hypervisor;
pub mod node;

pub use cpu::CpuModel;
pub use hypervisor::{Hypervisor, HypervisorKind};
pub use node::{NodeState, VmNode};
