//! Physical CPU performance model with dynamic clock scaling.
//!
//! The paper (Fig. 3 discussion): "the results do not agree with the ideal
//! speed-up ... due to the technology implemented in the processors whereby
//! the core's clocks are dynamically changed ... (Turbo Boost by Intel and
//! Turbo Core by AMD)".  A single active core runs at max turbo; a fully
//! loaded chip runs near base.  Measured t1 is therefore *better* than
//! t(n)*n, putting every multi-core point above the ideal t1/n line.
//!
//! `ep_rate_mpairs` converts clocks to NPB-EP throughput via a per-µarch
//! pairs-per-cycle factor (calibrated in DESIGN.md §5 so the Fig. 3 shape —
//! 26 Gridlan cores ≈ 212 s, comparison server needs ≈ 38 cores — holds).

/// A physical CPU package (or a multi-socket aggregate for the comparison
/// server, which behaves symmetrically for EP).
#[derive(Debug, Clone, PartialEq)]
pub struct CpuModel {
    pub name: String,
    /// Schedulable cores as the paper counts them (Table 1).
    pub cores: u32,
    /// Base (all-core sustained, no turbo headroom) clock, GHz.
    pub base_ghz: f64,
    /// Max single-core turbo, GHz.
    pub max_turbo_ghz: f64,
    /// All-core turbo (sustained clock with every core busy), GHz.
    pub all_core_ghz: f64,
    /// NPB-EP pairs per cycle per core (µarch efficiency).
    pub pairs_per_cycle: f64,
}

impl CpuModel {
    /// Clock (GHz) with `active` busy cores: linear interpolation from max
    /// single-core turbo down to the all-core clock, clamped.
    pub fn clock_ghz(&self, active: u32) -> f64 {
        if active == 0 {
            return self.max_turbo_ghz;
        }
        let active = active.min(self.cores);
        if self.cores == 1 {
            return self.max_turbo_ghz;
        }
        let frac = (active - 1) as f64 / (self.cores - 1) as f64;
        self.max_turbo_ghz + frac * (self.all_core_ghz - self.max_turbo_ghz)
    }

    /// EP throughput of ONE core (Mpairs/s) when `active` cores are busy.
    pub fn ep_rate_mpairs(&self, active: u32) -> f64 {
        self.clock_ghz(active) * 1e3 * self.pairs_per_cycle
    }

    /// Aggregate EP throughput (Mpairs/s) with `active` busy cores.
    pub fn ep_rate_total_mpairs(&self, active: u32) -> f64 {
        let active = active.min(self.cores);
        active as f64 * self.ep_rate_mpairs(active)
    }

    // ------------------------------------------------ paper's Table 1 SKUs

    /// Intel Xeon E5-2630 (n01, counted as 12 cores in Table 1).
    pub fn xeon_e5_2630() -> Self {
        Self {
            name: "Xeon E5-2630".into(),
            cores: 12,
            base_ghz: 2.3,
            max_turbo_ghz: 2.8,
            all_core_ghz: 2.5,
            pairs_per_cycle: 0.0052,
        }
    }

    /// Intel Core i7-3930K (n02, 6 cores).
    pub fn i7_3930k() -> Self {
        Self {
            name: "Core i7-3930K".into(),
            cores: 6,
            base_ghz: 3.2,
            max_turbo_ghz: 3.8,
            all_core_ghz: 3.5,
            pairs_per_cycle: 0.0050,
        }
    }

    /// Intel Core i7-2920XM (n03, 4 cores, mobile).
    pub fn i7_2920xm() -> Self {
        Self {
            name: "Core i7-2920XM".into(),
            cores: 4,
            base_ghz: 2.5,
            max_turbo_ghz: 3.5,
            all_core_ghz: 3.2,
            pairs_per_cycle: 0.0050,
        }
    }

    /// Intel Core i7 960 (n04, 4 cores, Nehalem).
    pub fn i7_960() -> Self {
        Self {
            name: "Core i7 960".into(),
            cores: 4,
            base_ghz: 3.2,
            max_turbo_ghz: 3.46,
            all_core_ghz: 3.33,
            pairs_per_cycle: 0.0042,
        }
    }

    /// 4 x AMD Opteron 6376 (the 64-core comparison server). Piledriver
    /// modules share FPUs, so per-core EP throughput is low — this is why
    /// the paper's server needs ~38 cores to match 26 Gridlan cores.
    pub fn opteron_6376_quad() -> Self {
        Self {
            name: "4x Opteron 6376".into(),
            cores: 64,
            base_ghz: 2.3,
            max_turbo_ghz: 3.2,
            all_core_ghz: 2.6,
            pairs_per_cycle: 0.0030,
        }
    }

    /// The Gridlan server's own CPU.  NOT part of the 26-core pool: the
    /// paper's Table 1 rows sum to 26 (12+6+4+4) even though the caption
    /// says 24 — Fig. 3 sweeps 1..26 cores, so we follow the rows.
    pub fn server_cpu() -> Self {
        Self {
            name: "Server (2 cores)".into(),
            cores: 2,
            base_ghz: 3.0,
            max_turbo_ghz: 3.4,
            all_core_ghz: 3.2,
            pairs_per_cycle: 0.0046,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_decreases_with_active_cores() {
        let cpu = CpuModel::xeon_e5_2630();
        assert!((cpu.clock_ghz(1) - 2.8).abs() < 1e-12);
        assert!((cpu.clock_ghz(12) - 2.5).abs() < 1e-12);
        let mut prev = cpu.clock_ghz(1);
        for a in 2..=12 {
            let c = cpu.clock_ghz(a);
            assert!(c <= prev);
            prev = c;
        }
    }

    #[test]
    fn active_clamped_to_core_count() {
        let cpu = CpuModel::i7_960();
        assert_eq!(cpu.clock_ghz(100), cpu.clock_ghz(4));
    }

    #[test]
    fn aggregate_rate_increases_with_cores_despite_turbo() {
        // Adding cores must still add throughput (sublinearly).
        for cpu in [CpuModel::xeon_e5_2630(), CpuModel::opteron_6376_quad()] {
            let mut prev = 0.0;
            for a in 1..=cpu.cores {
                let r = cpu.ep_rate_total_mpairs(a);
                assert!(r > prev, "{}: a={a}", cpu.name);
                prev = r;
            }
        }
    }

    #[test]
    fn per_core_rate_at_full_load_below_single() {
        let cpu = CpuModel::i7_2920xm();
        assert!(cpu.ep_rate_mpairs(4) < cpu.ep_rate_mpairs(1));
        // i7-2920XM has a big turbo window: >= 8% gap.
        assert!(cpu.ep_rate_mpairs(1) / cpu.ep_rate_mpairs(4) > 1.08);
    }

    #[test]
    fn intel_beats_amd_per_core() {
        // The crux of Fig 3's crossover.
        let intel = CpuModel::xeon_e5_2630();
        let amd = CpuModel::opteron_6376_quad();
        assert!(intel.ep_rate_mpairs(12) > amd.ep_rate_mpairs(64) * 1.2);
    }

    #[test]
    fn table1_core_total_is_26() {
        // Table 1 rows sum to 26 (the caption's "24" contradicts both the
        // rows and Fig. 3's 1..26 sweep; we follow the rows).
        let total: u32 = [
            CpuModel::xeon_e5_2630(),
            CpuModel::i7_3930k(),
            CpuModel::i7_2920xm(),
            CpuModel::i7_960(),
        ]
        .iter()
        .map(|c| c.cores)
        .sum();
        assert_eq!(total, 26);
    }
}
