//! Gridlan node (VM) lifecycle state machine.
//!
//! Paper §2.5 boot sequence: client connects VPN → starts VM → VM sends
//! DHCP through the tunnel → server answers with boot files (TFTP) → VM
//! mounts `/` over NFS → boot completes.  The `boot` module drives these
//! transitions on the event engine; this type enforces legal ordering and
//! records per-phase timestamps (used by the boot-storm bench).

use crate::sim::clock::SimTime;

/// Boot lifecycle states, in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum NodeState {
    Off,
    PoweringOn,
    Dhcp,
    Tftp,
    NfsMount,
    Up,
    /// Terminal until restarted by the watchdog.
    Crashed,
}

impl NodeState {
    pub fn is_running(self) -> bool {
        self == NodeState::Up
    }
}

/// A virtual machine acting as a Gridlan node.
#[derive(Debug, Clone)]
pub struct VmNode {
    /// Node name as the resource manager sees it (n01, n02...).
    pub name: String,
    /// Host client this VM runs on.
    pub client: String,
    /// vCPUs exposed to the guest (paper: all host cores).
    pub vcpus: u32,
    pub state: NodeState,
    /// (state entered, sim time) history for diagnostics/benches.
    pub history: Vec<(NodeState, SimTime)>,
    /// Completed boots (watchdog restarts increment this).
    pub boot_count: u32,
}

impl VmNode {
    pub fn new(name: &str, client: &str, vcpus: u32) -> Self {
        Self {
            name: name.to_string(),
            client: client.to_string(),
            vcpus,
            state: NodeState::Off,
            history: vec![(NodeState::Off, 0)],
            boot_count: 0,
        }
    }

    /// Legal next states from the current one.
    fn legal_next(&self) -> &'static [NodeState] {
        use NodeState::*;
        match self.state {
            Off => &[PoweringOn],
            PoweringOn => &[Dhcp, Crashed, Off],
            Dhcp => &[Tftp, Crashed, Off],
            Tftp => &[NfsMount, Crashed, Off],
            NfsMount => &[Up, Crashed, Off],
            Up => &[Crashed, Off],
            Crashed => &[PoweringOn, Off],
        }
    }

    /// Transition; panics on illegal transitions (a simulation bug, not a
    /// runtime condition).
    pub fn advance(&mut self, next: NodeState, now: SimTime) {
        assert!(
            self.legal_next().contains(&next),
            "illegal node transition {:?} -> {next:?} ({})",
            self.state,
            self.name
        );
        if next == NodeState::Up {
            self.boot_count += 1;
        }
        self.state = next;
        self.history.push((next, now));
    }

    /// Duration of the last completed boot (PoweringOn → Up), if any.
    pub fn last_boot_duration(&self) -> Option<SimTime> {
        let mut up_at = None;
        for &(s, t) in self.history.iter().rev() {
            match s {
                NodeState::Up if up_at.is_none() => up_at = Some(t),
                NodeState::PoweringOn => {
                    if let Some(u) = up_at {
                        return Some(u - t);
                    }
                }
                _ => {}
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boot(node: &mut VmNode, t0: SimTime) {
        use NodeState::*;
        node.advance(PoweringOn, t0);
        node.advance(Dhcp, t0 + 1_000_000);
        node.advance(Tftp, t0 + 2_000_000);
        node.advance(NfsMount, t0 + 50_000_000);
        node.advance(Up, t0 + 80_000_000);
    }

    #[test]
    fn full_boot_sequence() {
        let mut n = VmNode::new("n01", "client01", 12);
        boot(&mut n, 100);
        assert!(n.state.is_running());
        assert_eq!(n.boot_count, 1);
        assert_eq!(n.last_boot_duration(), Some(80_000_000));
    }

    #[test]
    #[should_panic(expected = "illegal node transition")]
    fn cannot_skip_states() {
        let mut n = VmNode::new("n01", "c", 4);
        n.advance(NodeState::Up, 0);
    }

    #[test]
    fn crash_and_watchdog_restart() {
        let mut n = VmNode::new("n02", "c", 6);
        boot(&mut n, 0);
        n.advance(NodeState::Crashed, 200_000_000);
        assert!(!n.state.is_running());
        boot_from_crash(&mut n, 300_000_000);
        assert_eq!(n.boot_count, 2);
    }

    fn boot_from_crash(n: &mut VmNode, t0: SimTime) {
        use NodeState::*;
        n.advance(PoweringOn, t0);
        n.advance(Dhcp, t0 + 1);
        n.advance(Tftp, t0 + 2);
        n.advance(NfsMount, t0 + 3);
        n.advance(Up, t0 + 4);
    }

    #[test]
    fn power_off_from_any_running_state() {
        let mut n = VmNode::new("n03", "c", 4);
        n.advance(NodeState::PoweringOn, 0);
        n.advance(NodeState::Dhcp, 1);
        n.advance(NodeState::Off, 2); // user shut the client down mid-boot
        assert_eq!(n.state, NodeState::Off);
    }
}
