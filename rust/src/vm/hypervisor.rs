//! Hypervisor profiles (paper §3.2 and §5).
//!
//! The paper uses QEMU/KVM on GNU/Linux hosts and VirtualBox (headless) on
//! Windows hosts, notes VMware as an alternative, and discusses replacing
//! VirtualBox with *pure QEMU* (TCG emulation) to fix the SYSTEM-user
//! issue — "although this is at the cost of a drop in performance".
//!
//! Two effects matter to the experiments:
//! * `cpu_efficiency` — guest compute throughput vs bare metal (Fig. 3);
//! * `vnet_one_way_us` — virtio/NAT network path overhead per direction
//!   (Table 2: the node ping includes the VM's network stack).

/// Which hypervisor runs the node.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HypervisorKind {
    /// QEMU with KVM acceleration (Linux hosts).
    QemuKvm,
    /// VirtualBox headless (Windows hosts in the paper).
    VirtualBox,
    /// Pure QEMU TCG emulation — no VT-x needed, big slowdown (paper §5).
    PureQemu,
    /// VMware Workstation/Player (paper's listed alternative).
    Vmware,
}

/// Performance profile of a hypervisor.
#[derive(Debug, Clone, PartialEq)]
pub struct Hypervisor {
    pub kind: HypervisorKind,
    /// Guest compute throughput as a fraction of bare metal.
    pub cpu_efficiency: f64,
    /// Added one-way network latency through the virtual NIC, µs.
    pub vnet_one_way_us: f64,
    /// Time for the hypervisor to create/power-on the VM, ms.
    pub power_on_ms: f64,
}

impl Hypervisor {
    pub fn new(kind: HypervisorKind) -> Self {
        match kind {
            // Calibration note (Table 2): node-vs-host overhead is split
            // between the VPN (~210 µs RTT) and the virtio path; per-node
            // profile tweaks live in the cluster config.
            HypervisorKind::QemuKvm => Self {
                kind,
                cpu_efficiency: 0.97,
                vnet_one_way_us: 165.0,
                power_on_ms: 900.0,
            },
            HypervisorKind::VirtualBox => Self {
                kind,
                cpu_efficiency: 0.93,
                vnet_one_way_us: 240.0,
                power_on_ms: 2_300.0,
            },
            HypervisorKind::PureQemu => Self {
                kind,
                cpu_efficiency: 0.12, // TCG: order-of-magnitude drop
                vnet_one_way_us: 260.0,
                power_on_ms: 1_200.0,
            },
            HypervisorKind::Vmware => Self {
                kind,
                cpu_efficiency: 0.95,
                vnet_one_way_us: 185.0,
                power_on_ms: 1_800.0,
            },
        }
    }

    /// Guest EP throughput for one core (Mpairs/s) given the host rate.
    pub fn guest_rate(&self, host_rate_mpairs: f64) -> f64 {
        host_rate_mpairs * self.cpu_efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kvm_is_near_native() {
        let h = Hypervisor::new(HypervisorKind::QemuKvm);
        assert!(h.cpu_efficiency > 0.95);
    }

    #[test]
    fn pure_qemu_is_an_order_of_magnitude_slower() {
        let kvm = Hypervisor::new(HypervisorKind::QemuKvm);
        let tcg = Hypervisor::new(HypervisorKind::PureQemu);
        assert!(kvm.cpu_efficiency / tcg.cpu_efficiency > 5.0);
    }

    #[test]
    fn guest_rate_scales() {
        let h = Hypervisor::new(HypervisorKind::VirtualBox);
        assert!((h.guest_rate(100.0) - 93.0).abs() < 1e-9);
    }

    #[test]
    fn all_profiles_have_positive_overheads() {
        for k in [
            HypervisorKind::QemuKvm,
            HypervisorKind::VirtualBox,
            HypervisorKind::PureQemu,
            HypervisorKind::Vmware,
        ] {
            let h = Hypervisor::new(k);
            assert!(h.vnet_one_way_us > 0.0 && h.power_on_ms > 0.0);
            assert!(h.cpu_efficiency > 0.0 && h.cpu_efficiency <= 1.0);
        }
    }
}
