//! System assembly and the direct (non-event) measurement APIs.

use crate::boot::nfs::NfsExport;
use crate::boot::pxe::{BootParams, BootPlan};
use crate::boot::tftp::TftpServer;
use crate::boot::fsimage::FsImage;
use crate::config::{ClientConfig, Config, SchedPolicy};
use crate::host::client::ClientAgent;
use crate::monitor::pinger::Pinger;
use crate::monitor::resilience::ScriptFolder;
use crate::monitor::statusd::StatusService;
use crate::netsim::icmp::{ping_sweep, PingStats, ECHO_PROC_US};
use crate::netsim::packet::Packet;
use crate::netsim::topology::{DeviceId, LinkProfile, Network};
use crate::perf::speedmodel::GridlanPool;
use crate::rm::queue::NodePool;
use crate::rm::sched::{BackfillScheduler, FifoScheduler, Scheduler};
use crate::rm::server::PbsServer;
use crate::util::rng::SplitMix64;
use crate::util::stats::Summary;
use crate::vm::node::{NodeState, VmNode};
use crate::vpn::hub::VpnHub;
use crate::vpn::tunnel::TunnelCost;
use std::collections::BTreeMap;

/// The assembled system.
pub struct Gridlan {
    pub config: Config,
    pub net: Network,
    pub server_dev: DeviceId,
    pub hub: VpnHub,
    pub clients: Vec<ClientAgent>,
    /// Name → position in `clients` (and `config.clients`, which share
    /// insertion order).  Keeps per-client lookups O(log n): at 100k-node
    /// scenarios a linear `find` per boot/poll turns quadratic.
    client_idx: BTreeMap<String, usize>,
    pub client_dev: BTreeMap<String, DeviceId>,
    pub nodes: BTreeMap<String, VmNode>,
    pub pbs: PbsServer,
    pub pinger: Pinger,
    pub status: StatusService,
    pub folder: ScriptFolder,
    pub server_fs: FsImage,
    pub tftp: TftpServer,
    pub nfs: NfsExport,
    pub rng: SplitMix64,
}

impl Gridlan {
    /// Build the whole system from a config. Nodes start Off/offline;
    /// call [`boot_all`] (or run a scenario) to bring them up.
    pub fn build(config: Config) -> Gridlan {
        let mut rng = SplitMix64::new(config.seed);
        // ---- network: server - backbone switch chain - clients
        let mut net = Network::new();
        net.jitter_sigma_us = config.jitter_us;
        let server_dev = net.add_host("server", config.server_stack_us);
        let backbone = LinkProfile { latency_us: 3.0, bandwidth_mbps: config.backbone_mbps };
        // Shared first switch; per-client extra hops as private chains.
        let sw0 = net.add_switch("sw0", config.switch_proc_us);
        net.link(server_dev, sw0, backbone);
        let mut client_dev = BTreeMap::new();
        for c in &config.clients {
            let mut prev = sw0;
            for h in 1..c.switch_hops {
                let sw = net.add_switch(&format!("sw-{}-{h}", c.name), config.switch_proc_us);
                net.link(prev, sw, backbone);
                prev = sw;
            }
            let dev = net.add_host(&c.name, c.stack_us);
            net.link(prev, dev, LinkProfile { latency_us: 3.0, bandwidth_mbps: c.link_mbps });
            client_dev.insert(c.name.clone(), dev);
        }
        // ---- VPN hub + client agents + VM nodes
        let hub = VpnHub::new(server_dev, rng.next_u64());
        let mut clients = Vec::new();
        let mut client_idx = BTreeMap::new();
        let mut nodes = BTreeMap::new();
        for c in &config.clients {
            let mut agent = ClientAgent::new(&c.name, c.os, c.cpu.clone());
            if let Some(hv) = c.hypervisor {
                agent = agent.with_hypervisor(hv);
            }
            nodes.insert(c.name.clone(), VmNode::new(&c.name, &c.name, c.cpu.cores));
            // `or_insert` keeps the first occurrence, matching the old
            // linear `find` on a (malformed) duplicate-name config.
            client_idx.entry(c.name.clone()).or_insert(clients.len());
            clients.push(agent);
        }
        // ---- resource manager
        let mut pbs = PbsServer::new();
        for c in &config.clients {
            pbs.register_node(&c.name, c.cpu.cores, NodePool::Gridlan);
        }
        if let Some((name, n, cores)) = &config.cluster_partition {
            for i in 0..*n {
                let node = format!("{name}-{i:02}");
                pbs.register_node(&node, *cores, NodePool::Cluster);
                pbs.node_up(&node);
            }
        }
        // ---- monitoring
        let node_names: Vec<String> = config.clients.iter().map(|c| c.name.clone()).collect();
        let pinger = Pinger::new(&node_names);
        let mut status = StatusService::new();
        for c in &config.clients {
            status.bind(&c.name, &c.name);
        }
        let mut server_fs = FsImage::new();
        server_fs.mkdir_p("/var/spool/gridlan");
        let folder = ScriptFolder::new("/var/spool/gridlan");
        Gridlan {
            config,
            net,
            server_dev,
            hub,
            clients,
            client_idx,
            client_dev,
            nodes,
            pbs,
            pinger,
            status,
            folder,
            server_fs,
            tftp: TftpServer::new(512),
            nfs: NfsExport::debian(),
            rng,
        }
    }

    /// The paper's testbed.
    pub fn table1() -> Gridlan {
        Self::build(Config::table1())
    }

    pub fn scheduler(&self) -> Box<dyn Scheduler> {
        match self.config.sched {
            SchedPolicy::Fifo => Box::new(FifoScheduler),
            SchedPolicy::Backfill => Box::new(BackfillScheduler::new()),
        }
    }

    pub fn client(&self, name: &str) -> Option<&ClientAgent> {
        self.client_idx.get(name).map(|&i| &self.clients[i])
    }

    pub fn client_mut(&mut self, name: &str) -> Option<&mut ClientAgent> {
        self.client_idx.get(name).map(|&i| &mut self.clients[i])
    }

    fn client_config(&self, name: &str) -> &ClientConfig {
        let i = *self.client_idx.get(name).expect("unknown client");
        &self.config.clients[i]
    }

    /// Speed-model pool view of this deployment.
    pub fn pool(&self) -> GridlanPool {
        GridlanPool { clients: self.clients.clone() }
    }

    // ------------------------------------------------------------- boot

    /// VPN-connect a client (OS start-up step 1). Errors if no key.
    pub fn connect_client(&mut self, name: &str) -> Result<(), String> {
        let dev = *self.client_dev.get(name).ok_or("unknown client")?;
        let key = self.hub.provision(name); // admin pre-provisioned
        self.hub.connect(name, &key, dev, TunnelCost::default())?;
        if let Some(c) = self.client_mut(name) {
            c.vpn_connected = true;
        }
        Ok(())
    }

    /// Boot parameters for a client's node (latency through VPN+virtio).
    pub fn boot_params(&mut self, name: &str) -> BootParams {
        let one_way = self.node_one_way_us(name).unwrap_or(700.0);
        let mbps = self.client_config(name).link_mbps;
        BootParams {
            one_way_us: one_way,
            us_per_byte: 8.0 / mbps,
            ..BootParams::default()
        }
    }

    /// Compute the node's boot plan.
    pub fn boot_plan(&mut self, name: &str) -> BootPlan {
        let hv = self.client(name).expect("client").hypervisor.clone();
        let params = self.boot_params(name);
        BootPlan::compute(&hv, &self.tftp, &self.nfs, &params)
    }

    /// Bring every node up immediately (fast-forward boot; the event-driven
    /// path lives in `scenario`).  Returns the slowest boot duration.
    pub fn boot_all(&mut self, now: crate::sim::SimTime) -> crate::sim::SimTime {
        let names: Vec<String> = self.config.clients.iter().map(|c| c.name.clone()).collect();
        let mut slowest = 0;
        for name in names {
            self.connect_client(&name).expect("provisioned key");
            let plan = self.boot_plan(&name);
            let node = self.nodes.get_mut(&name).unwrap();
            let mut t = now;
            for &(state, dur) in &plan.phases {
                node.advance(state, t);
                t += dur;
            }
            // BootPlan ends with (Up, 0) so node is Up at t.
            debug_assert_eq!(node.state, NodeState::Up);
            slowest = slowest.max(plan.total());
            self.pbs.node_up(&name);
        }
        let up: Vec<String> = self.nodes.keys().cloned().collect();
        self.pinger.sweep(now + slowest, |n| up.iter().any(|u| u == n));
        slowest
    }

    // ----------------------------------------------------- measurements

    /// Mean one-way node path (server↔VM) in µs: tunnel + virtio.
    pub fn node_one_way_us(&mut self, name: &str) -> Option<f64> {
        let p = Packet::icmp_echo();
        let vnet = self.client(name)?.hypervisor.vnet_one_way_us;
        let mut rng = self.rng.fork();
        let tunnel = self.hub.server_to_client_us(&self.net, name, &p, &mut rng)?;
        Some(tunnel + vnet)
    }

    /// Table-2 host ping: server → client host, `count` echoes.
    pub fn ping_host(&mut self, name: &str, count: usize) -> Option<PingStats> {
        let dev = *self.client_dev.get(name)?;
        let mut rng = self.rng.fork();
        Some(ping_sweep(&self.net, self.server_dev, dev, &Packet::icmp_echo(), count, &mut rng))
    }

    /// Table-2 node ping: server → VM through VPN + virtio.
    pub fn ping_node(&mut self, name: &str, count: usize) -> Option<PingStats> {
        if !self.hub.is_connected(name) {
            return None;
        }
        let vnet = self.client(name)?.hypervisor.vnet_one_way_us;
        let p = Packet::icmp_echo();
        let mut rng = self.rng.fork();
        let mut s = Summary::new();
        for _ in 0..count {
            let fwd = self.hub.server_to_client_us(&self.net, name, &p, &mut rng)?;
            let back = self.hub.server_to_client_us(&self.net, name, &p, &mut rng)?;
            s.push(fwd + back + 2.0 * vnet + ECHO_PROC_US);
        }
        Some(PingStats { rtts_us: s, sent: count, lost: 0 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_boots_table1() {
        let mut g = Gridlan::table1();
        assert_eq!(g.clients.len(), 4);
        let slowest = g.boot_all(0);
        assert!(slowest > 0);
        for node in g.nodes.values() {
            assert!(node.state.is_running());
        }
        // All nodes online in the RM.
        let (busy, total) = g.pbs.pool_utilization(NodePool::Gridlan);
        assert_eq!((busy, total), (0, 26));
        // Monitor saw them.
        assert_eq!(g.pinger.on_nodes().len(), 4);
    }

    #[test]
    fn table2_host_pings_in_paper_range() {
        let mut g = Gridlan::table1();
        g.boot_all(0);
        let expected = [("n01", 550.0), ("n02", 660.0), ("n03", 750.0), ("n04", 610.0)];
        for (name, target) in expected {
            let s = g.ping_host(name, 200).unwrap();
            let m = s.mean_us();
            assert!(
                (m - target).abs() < target * 0.05,
                "{name}: host ping {m:.0} vs paper {target}"
            );
        }
    }

    #[test]
    fn table2_node_pings_in_paper_range() {
        let mut g = Gridlan::table1();
        g.boot_all(0);
        let expected = [("n01", 1250.0), ("n02", 1500.0), ("n03", 1650.0), ("n04", 1400.0)];
        for (name, target) in expected {
            let s = g.ping_node(name, 200).unwrap();
            let m = s.mean_us();
            assert!(
                (m - target).abs() < target * 0.08,
                "{name}: node ping {m:.0} vs paper {target}"
            );
        }
    }

    #[test]
    fn overhead_is_roughly_900us() {
        // Paper: "The additional overhead provided by the Gridlan is
        // roughly 900 µs."
        let mut g = Gridlan::table1();
        g.boot_all(0);
        let mut overheads = Vec::new();
        for name in ["n01", "n02", "n03", "n04"] {
            let host = g.ping_host(name, 200).unwrap().mean_us();
            let node = g.ping_node(name, 200).unwrap().mean_us();
            overheads.push(node - host);
        }
        let mean = overheads.iter().sum::<f64>() / overheads.len() as f64;
        assert!((700.0..1000.0).contains(&mean), "mean overhead {mean:.0} µs");
    }

    #[test]
    fn unconnected_node_unpingable() {
        let mut g = Gridlan::table1();
        assert!(g.ping_node("n01", 5).is_none());
        assert!(g.ping_host("nope", 5).is_none());
    }

    #[test]
    fn cluster_partition_registers_nodes() {
        let mut cfg = Config::table1();
        cfg.cluster_partition = Some(("opteron".into(), 1, 64));
        let g = Gridlan::build(cfg);
        let (_, total) = g.pbs.pool_utilization(NodePool::Cluster);
        assert_eq!(total, 64);
    }
}
