//! The Gridlan coordinator: assembles server + clients + VPN + boot + RM +
//! monitor into one system and drives end-to-end scenarios.
//!
//! * [`gridlan`] — construction from a [`crate::config::Config`], node
//!   boot, Table-2 measurements, EP job helpers;
//! * [`scenario`] — the event-driven runner: job traces, monitor sweeps,
//!   watchdog polls, fault injection and real EP compute on the DES
//!   engine;
//! * [`metrics`] — utilization/goodput accounting.

pub mod gridlan;
pub mod metrics;
pub mod scenario;

pub use gridlan::Gridlan;
pub use metrics::Metrics;
pub use scenario::{run_scenario, run_trace, Scenario, ScenarioReport, ScenarioRun};
