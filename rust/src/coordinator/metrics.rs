//! Utilization and goodput accounting for scenario runs.

use crate::sim::clock::SimTime;
use crate::util::json::{obj, Json};

/// Aggregated counters from one scenario run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Metrics {
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_requeued: u64,
    pub jobs_killed: u64,
    /// Core-seconds of useful compute delivered.
    pub core_secs_useful: f64,
    /// Core-seconds wasted (work lost to node failures).
    pub core_secs_wasted: f64,
    /// Total wait time across completed jobs.
    pub total_wait: SimTime,
    /// Scenario makespan (last completion).
    pub makespan: SimTime,
    /// Faults injected.
    pub faults: u64,
    /// Watchdog restarts triggered.
    pub watchdog_restarts: u64,
    /// Real-compute (EP payload) jobs completed.
    pub ep_jobs_completed: u64,
    /// Real-compute jobs whose backend execution failed (exit != 0).
    pub ep_jobs_failed: u64,
    /// EP pairs *executed* on the compute backend, including any wasted
    /// re-execution after faults.  The merged logical range lives in
    /// `ScenarioReport::ep_tallies`; `executed - logical` is the wasted
    /// pair count (zero on clean runs and under salvage recovery).
    pub ep_pairs_executed: u64,
    /// Sub-span checkpoints recorded for running EP jobs.
    pub ep_checkpoints: u64,
    /// EP pairs salvaged across fault requeues (checkpointed sub-spans
    /// whose tallies were banked instead of re-executed).
    pub ep_pairs_salvaged: u64,
    /// Straggler range-steal operations (child jobs spawned).
    pub ep_steals: u64,
}

impl Metrics {
    /// Goodput fraction: useful / (useful + wasted).
    pub fn goodput(&self) -> f64 {
        let total = self.core_secs_useful + self.core_secs_wasted;
        if total <= 0.0 {
            return 1.0;
        }
        self.core_secs_useful / total
    }

    pub fn mean_wait_secs(&self) -> f64 {
        if self.jobs_completed == 0 {
            return 0.0;
        }
        self.total_wait as f64 / 1e9 / self.jobs_completed as f64
    }

    pub fn completion_rate(&self) -> f64 {
        if self.jobs_submitted == 0 {
            return 1.0;
        }
        self.jobs_completed as f64 / self.jobs_submitted as f64
    }

    /// Stable JSON rendering (fixed key order) — the replay-determinism
    /// tests compare this byte-for-byte across same-seed runs.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("jobs_submitted", Json::Num(self.jobs_submitted as f64)),
            ("jobs_completed", Json::Num(self.jobs_completed as f64)),
            ("jobs_requeued", Json::Num(self.jobs_requeued as f64)),
            ("jobs_killed", Json::Num(self.jobs_killed as f64)),
            ("core_secs_useful", Json::Num(self.core_secs_useful)),
            ("core_secs_wasted", Json::Num(self.core_secs_wasted)),
            ("goodput", Json::Num(self.goodput())),
            ("mean_wait_secs", Json::Num(self.mean_wait_secs())),
            ("makespan_ns", Json::Num(self.makespan as f64)),
            ("faults", Json::Num(self.faults as f64)),
            ("watchdog_restarts", Json::Num(self.watchdog_restarts as f64)),
            ("ep_jobs_completed", Json::Num(self.ep_jobs_completed as f64)),
            ("ep_jobs_failed", Json::Num(self.ep_jobs_failed as f64)),
            ("ep_pairs_executed", Json::Num(self.ep_pairs_executed as f64)),
            ("ep_checkpoints", Json::Num(self.ep_checkpoints as f64)),
            ("ep_pairs_salvaged", Json::Num(self.ep_pairs_salvaged as f64)),
            ("ep_steals", Json::Num(self.ep_steals as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn goodput_bounds() {
        let mut m = Metrics::default();
        assert_eq!(m.goodput(), 1.0);
        m.core_secs_useful = 80.0;
        m.core_secs_wasted = 20.0;
        assert!((m.goodput() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn wait_and_completion() {
        let m = Metrics {
            jobs_submitted: 10,
            jobs_completed: 8,
            total_wait: 16 * 1_000_000_000,
            ..Default::default()
        };
        assert!((m.mean_wait_secs() - 2.0).abs() < 1e-12);
        assert!((m.completion_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn json_rendering_is_stable_and_parseable() {
        let m = Metrics {
            jobs_submitted: 10,
            jobs_completed: 8,
            core_secs_useful: 80.0,
            core_secs_wasted: 20.0,
            ..Default::default()
        };
        let a = m.to_json().to_string();
        let b = m.to_json().to_string();
        assert_eq!(a, b, "same metrics render to identical bytes");
        let doc = crate::util::json::Json::parse(&a).expect("metrics JSON parses");
        assert_eq!(doc.get("jobs_completed").and_then(|j| j.as_u64()), Some(8));
        assert_eq!(doc.get("goodput").and_then(|j| j.as_f64()), Some(0.8));
    }
}
