//! Event-driven scenario runner: job traces + monitor sweeps + watchdog
//! polls + fault injection + REAL compute, all on the DES engine.
//!
//! This is where the paper's §2.6 feedback loop actually closes: the
//! 5-minute server pinger marks nodes on/off, the client watchdog asks the
//! status service and restarts dead VMs, pbs_server requeues the jobs that
//! were running there (the §4 script-folder technique), and the scheduler
//! re-places them once nodes return.
//!
//! Compute-bearing jobs are first-class citizens: a
//! [`JobPayload::Ep`] trace entry is scheduled by the RM like any other
//! job, its duration comes from the speed model (pairs over the slowest
//! allocated core's EP rate), and its pair range is executed for REAL on
//! the scenario's [`EpEngine`] at completion time.  A fault that kills a
//! running EP job loses the attempt — the requeued job re-executes the
//! same pair range later, and because ranges address the global NPB
//! stream, the re-executed tally is bit-identical and the merged result
//! stays exact.

use super::gridlan::Gridlan;
use super::metrics::Metrics;
use crate::host::faults::{FaultEvent, FaultKind, FaultPlan};
use crate::obs::event::{EventKind, ScenarioLogger};
use crate::host::watchdog::{Watchdog, WatchdogAction};
use crate::rm::job::JobId;
use crate::rm::mom::Mom;
use crate::rm::queue::NodePool;
use crate::rm::sched::Scheduler;
use crate::rm::script::PbsScript;
use crate::runtime::engine::EpEngine;
use crate::sim::clock::{SimTime, DUR_SEC};
use crate::sim::{Handler, Simulator};
use crate::vm::node::NodeState;
use crate::workload::ep::{EpClass, EpJob, EpSlice, EpTally};
use crate::workload::trace::{JobPayload, TraceJob};
use std::collections::{BTreeMap, BTreeSet};

/// Reference core rate used to normalize trace job compute times
/// (Mpairs/s; a mid-range Table-1 core).
const REF_RATE_MPAIRS: f64 = 15.0;

/// Partial-range recovery & work-stealing policy for EP jobs.
///
/// As a running EP job's sub-spans complete on the DES clock, their
/// tallies are banked and a checkpoint event is logged.  With `salvage`
/// on, a fault requeues only the unexecuted remainder (`ep:<cursor>:<rest>`)
/// and the banked spans merge into the final tally — the exact-merge
/// pair-range protocol makes any partition bit-identical.  With `steal`
/// on, the scheduler splits a straggler's remainder onto idle cores at a
/// sub-span boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryPolicy {
    /// Bank completed sub-span tallies across a fault and requeue only
    /// the unexecuted remainder (`false` = naive full-range re-execution).
    pub salvage: bool,
    /// Sub-span checkpoint interval in pairs; 0 = auto (~`count/16`,
    /// clamped to `[1024, 4194304]`).
    pub checkpoint_interval: u64,
    /// Split stragglers' remaining ranges onto idle cores.
    pub steal: bool,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        Self { salvage: true, checkpoint_interval: 0, steal: false }
    }
}

/// Scenario parameters.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub horizon: SimTime,
    /// Scheduler cycle period (Torque's scheduler iteration).
    pub sched_period: SimTime,
    pub faults: FaultPlan,
    /// Deterministic, hand-placed fault events applied in addition to the
    /// generated plan (tests use these to hit exact race windows).
    pub scripted_faults: Vec<FaultEvent>,
    /// EP checkpoint/salvage/steal policy.
    pub recovery: RecoveryPolicy,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            horizon: 12 * 3600 * DUR_SEC,
            sched_period: 10 * DUR_SEC,
            faults: FaultPlan::none(),
            scripted_faults: Vec::new(),
            recovery: RecoveryPolicy::default(),
        }
    }
}

/// What a run produced.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub metrics: Metrics,
    pub events_executed: u64,
    pub final_time: SimTime,
    /// Per-job EP tallies, recorded at each compute job's completion.
    /// These are *logical*: banked salvaged sub-spans merge with the
    /// re-executed remainder, so each pair of a job's range appears
    /// exactly once (unlike [`Metrics::ep_pairs_executed`], which counts
    /// executions including waste).
    pub ep_tallies: BTreeMap<JobId, EpTally>,
    /// Range-steal lineage: child job → the parent it was split from.
    pub steal_lineage: BTreeMap<JobId, JobId>,
}

impl ScenarioReport {
    /// Merge of all per-job EP tallies, in job-id order (deterministic).
    pub fn ep_total(&self) -> EpTally {
        let mut total = EpTally::default();
        for t in self.ep_tallies.values() {
            total.merge(t);
        }
        total
    }

    /// Stable JSON rendering: metrics plus run totals, fixed key order.
    /// Same-seed runs must produce byte-identical output (the replay
    /// determinism tests hold this line).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let total = self.ep_total();
        obj(vec![
            ("metrics", self.metrics.to_json()),
            ("events_executed", Json::Num(self.events_executed as f64)),
            ("final_time_ns", Json::Num(self.final_time as f64)),
            ("ep_jobs_tallied", Json::Num(self.ep_tallies.len() as f64)),
            ("ep_pairs_total", Json::Num(total.pairs as f64)),
            ("ep_nacc_total", Json::Num(total.nacc as f64)),
            (
                "ep_pairs_wasted",
                Json::Num(self.metrics.ep_pairs_executed.saturating_sub(total.pairs) as f64),
            ),
            ("steal_lineage", {
                let mut lin = crate::util::json::JsonObj::new();
                for (child, parent) in &self.steal_lineage {
                    lin.insert(&child.0.to_string(), Json::Num(parent.0 as f64));
                }
                Json::Obj(lin)
            }),
        ])
    }
}

/// A finished scenario run: the report plus the system, engine, and event
/// logger handed back to the caller (for post-run inspection of RM state,
/// backend accounting, node histories, event-log aggregation...).
pub struct ScenarioRun {
    pub report: ScenarioReport,
    pub gridlan: Gridlan,
    pub engine: EpEngine,
    /// The sink passed to [`run_scenario_logged`] (a null sink for plain
    /// [`run_scenario`] callers); a memory sink carries the typed records.
    pub logger: ScenarioLogger,
}

/// Live sub-span execution state for one EP job attempt.  Created when
/// the attempt starts, dropped on completion or fault.  Timing constants
/// (`attempt_pairs`, `compute_total`) are frozen at attempt start so
/// checkpoint instants stay fixed even when a steal truncates `end`.
#[derive(Debug, Clone)]
struct EpRun {
    /// Next unexecuted absolute pair index (advances span by span).
    cursor: u64,
    /// Exclusive end of the attempt's range (shrinks on a steal).
    end: u64,
    /// First pair of this attempt (the payload offset at start time).
    attempt_offset: u64,
    /// Pairs in the attempt at start time — the timing denominator.
    attempt_pairs: u64,
    /// Sub-span checkpoint interval in pairs.
    interval: u64,
    /// Instant compute began (start + MOM prologue).
    compute_t0: SimTime,
    /// Pure-compute duration of the attempt range at start time.
    compute_total: SimTime,
    /// A sub-span execution failed; completion reports exit 1.
    failed: bool,
}

/// Simulated instant at which the attempt's cursor reaches `cursor`
/// (linear interpolation over the attempt range, integer-exact at both
/// ends so a clean run completes at `start + wrap_runtime(compute)`).
fn checkpoint_time(run: &EpRun, cursor: u64) -> SimTime {
    let done = (cursor - run.attempt_offset) as u128;
    let total = run.attempt_pairs.max(1) as u128;
    run.compute_t0 + (run.compute_total as u128 * done / total) as SimTime
}

/// Default sub-span checkpoint interval for a `count`-pair range:
/// ~`count/16`, clamped so tiny ranges stay single-span and huge ranges
/// still checkpoint at least every 4M pairs.
fn default_checkpoint_interval(count: u64) -> u64 {
    (count / 16).clamp(1024, 1 << 22)
}

/// First sub-span boundary strictly after `cursor`.  Boundaries sit at
/// `attempt_offset + k*interval`; the result is clamped to `end`, so the
/// last (possibly short) span ends exactly at the range end.
fn next_boundary(cursor: u64, attempt_offset: u64, interval: u64, end: u64) -> u64 {
    let k = (cursor - attempt_offset) / interval + 1;
    attempt_offset.saturating_add(k.saturating_mul(interval)).min(end)
}

struct World {
    g: Gridlan,
    /// Policy object built once per run: the cached backfill scheduler
    /// carries its shadow memo across cycles.
    sched: Box<dyn Scheduler>,
    m: Metrics,
    engine: EpEngine,
    watchdogs: BTreeMap<String, Watchdog>,
    /// Per-job start generation guard for completion events.
    started_gen: BTreeMap<JobId, SimTime>,
    /// Per-node boot generation: bumped whenever a boot begins or the
    /// node dies, so in-flight boot-completion events land stale.
    boot_gen: BTreeMap<String, u64>,
    /// Per-job EP tallies (recorded at completion).
    ep_tallies: BTreeMap<JobId, EpTally>,
    /// EP checkpoint/salvage/steal policy for this run.
    recovery: RecoveryPolicy,
    /// Live sub-span state per running EP attempt.
    ep_runs: BTreeMap<JobId, EpRun>,
    /// Banked tallies of executed sub-spans; survive salvage requeues and
    /// become the job's logical tally at completion.
    ep_banked: BTreeMap<JobId, EpTally>,
    /// Each EP job's logical range (original offset, current count) — the
    /// count shrinks when a steal splits the range off.
    ep_logical: BTreeMap<JobId, (u64, u64)>,
    /// Steal lineage: child job → parent it stole from.
    lineage: BTreeMap<JobId, JobId>,
    /// Structured event sink (+ human mirror via `GRIDLAN_LOG`).
    logger: ScenarioLogger,
}

/// Run a trace of jobs through the Gridlan under a fault plan, with real
/// compute on `engine` for [`JobPayload::Ep`] entries.  Nodes still `Off`
/// boot event-driven from t=0 (already-booted grids keep their state);
/// jobs are submitted at their trace times; the run ends when the horizon
/// passes AND the queue drains (or a hard cap of 4x horizon).
pub fn run_scenario(
    g: Gridlan,
    trace: Vec<TraceJob>,
    scenario: &Scenario,
    engine: EpEngine,
) -> ScenarioRun {
    run_scenario_logged(g, trace, scenario, engine, ScenarioLogger::null())
}

/// [`run_scenario`] with a structured event sink: every lifecycle
/// transition (boot, submit, schedule, start, complete, fault, requeue)
/// lands in `logger` as a typed record, timestamped in simulated ns, and
/// is mirrored through [`crate::util::log`] at the record's level.  The
/// sink comes back on [`ScenarioRun::logger`].
pub fn run_scenario_logged(
    g: Gridlan,
    trace: Vec<TraceJob>,
    scenario: &Scenario,
    engine: EpEngine,
    logger: ScenarioLogger,
) -> ScenarioRun {
    let mut sim: Simulator<World> = Simulator::new();
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
    let watchdogs = names.iter().map(|n| (n.clone(), Watchdog::new(n))).collect();
    let sched = g.scheduler();
    let mut world = World {
        g,
        sched,
        m: Metrics::default(),
        engine,
        watchdogs,
        started_gen: BTreeMap::new(),
        boot_gen: BTreeMap::new(),
        ep_tallies: BTreeMap::new(),
        recovery: scenario.recovery.clone(),
        ep_runs: BTreeMap::new(),
        ep_banked: BTreeMap::new(),
        ep_logical: BTreeMap::new(),
        lineage: BTreeMap::new(),
        logger,
    };

    // --- initial boots (event-driven: an Off node comes up after its
    // plan; a grid pre-booted via `boot_all` keeps its Up nodes).
    for name in &names {
        if world.g.nodes[name].state == NodeState::Off {
            world.g.connect_client(name).expect("provisioned");
            world.g.nodes.get_mut(name).unwrap().advance(NodeState::PoweringOn, 0);
            begin_boot(&mut sim, &mut world, name);
        }
    }

    // --- job submissions (batched: one slab reserve for the whole trace).
    world.m.jobs_submitted += trace.len() as u64;
    sim.schedule_batch(trace.into_iter().enumerate().map(|(i, tj)| {
        let at = tj.at;
        let h: Handler<World> = Box::new(move |s, w| submit(s, w, &tj, i));
        (at, h)
    }));

    // --- periodic machinery.
    let period = scenario.sched_period;
    sim.schedule_at(period, move |s, w| sched_tick(s, w, period));
    sim.schedule_at(300 * DUR_SEC, monitor_sweep);
    for (i, name) in names.iter().enumerate() {
        let n = name.clone();
        // Stagger watchdogs so they don't all fire in one instant.
        sim.schedule_at(300 * DUR_SEC + (i as u64 + 1) * DUR_SEC, move |s, w| {
            watchdog_poll(s, w, &n);
        });
    }

    // --- faults (generated plan + scripted extras).
    let mut frng = world.g.rng.fork();
    let mut faults = scenario.faults.generate(&names, scenario.horizon, &mut frng);
    faults.extend(scenario.scripted_faults.iter().cloned());
    world.m.faults += faults.len() as u64;
    sim.schedule_batch(faults.into_iter().map(|ev| {
        let at = ev.at;
        let h: Handler<World> =
            Box::new(move |s, w| apply_fault(s, w, &ev.client, ev.kind, ev.outage));
        (at, h)
    }));

    // --- run: until horizon, then drain (cap at 4x horizon).
    sim.run_until(&mut world, scenario.horizon);
    let cap = scenario.horizon.saturating_mul(4);
    while world.g.pbs.jobs().any(|j| !matches!(j.state, crate::rm::job::JobState::Completed))
        && sim.now() < cap
    {
        if !sim.step(&mut world) {
            break;
        }
    }
    let report = ScenarioReport {
        metrics: world.m,
        events_executed: sim.executed(),
        final_time: sim.now(),
        ep_tallies: world.ep_tallies,
        steal_lineage: world.lineage,
    };
    ScenarioRun { report, gridlan: world.g, engine: world.engine, logger: world.logger }
}

/// [`run_scenario`] with a scalar engine, keeping only the report — the
/// deterministic workhorse for benches and ablations.
pub fn run_trace(g: Gridlan, trace: Vec<TraceJob>, scenario: &Scenario) -> ScenarioReport {
    run_scenario(g, trace, scenario, EpEngine::scalar()).report
}

// ------------------------------------------------------ real EP compute

/// Run a set of EP slices as single-core jobs through the resource
/// manager on the event-driven scenario path: each slice is submitted
/// with an `ep:<offset>:<count>` payload, scheduled by the RM (booting
/// any still-Off nodes first), timed by the speed model, and executed for
/// REAL on the engine's [`crate::runtime::backend::ComputeBackend`] at
/// completion — the paper's Fig. 3 scatter protocol with the compute
/// payload attached.
pub fn run_ep_slices(
    g: &mut Gridlan,
    engine: &mut EpEngine,
    slices: &[EpSlice],
    now: SimTime,
) -> Result<EpTally, String> {
    let trace: Vec<TraceJob> = slices.iter().map(|s| s.trace_job(now, 3600 * DUR_SEC)).collect();
    let scenario = Scenario {
        horizon: now.saturating_add(3600 * DUR_SEC),
        ..Default::default()
    };
    // Leave a minimal (clientless) placeholder in *g while the real
    // instance runs the scenario; it is overwritten right after.
    let mut placeholder_cfg = g.config.clone();
    placeholder_cfg.clients.clear();
    placeholder_cfg.cluster_partition = None;
    let g_owned = std::mem::replace(g, Gridlan::build(placeholder_cfg));
    let engine_owned = std::mem::replace(engine, EpEngine::scalar());
    let run = run_scenario(g_owned, trace, &scenario, engine_owned);
    *g = run.gridlan;
    *engine = run.engine;
    let done = run.report.ep_tallies.len();
    if done < slices.len() {
        // Distinguish a backend failure (job completed with exit != 0, no
        // tally) from a scheduling stall — counted per-run, so failures
        // left in the job table by earlier calls don't misattribute.
        let failed = run.report.metrics.ep_jobs_failed;
        if failed > 0 {
            return Err(format!(
                "compute backend failed on {failed} of {} slices",
                slices.len()
            ));
        }
        return Err(format!(
            "scheduler stalled with {} of {} slices incomplete (pool too narrow or nodes never booted)",
            slices.len() - done,
            slices.len()
        ));
    }
    Ok(run.report.ep_total())
}

/// [`run_ep_slices`] for a whole NPB class split `n_procs` ways (the
/// Fig. 3 protocol: class S over 26 single-core processes).
pub fn run_ep_job(
    g: &mut Gridlan,
    engine: &mut EpEngine,
    class: EpClass,
    n_procs: u32,
    now: SimTime,
) -> Result<EpTally, String> {
    run_ep_slices(g, engine, &EpJob::new(class, n_procs).slices(), now)
}

/// Parse an `ep:<offset>:<count>` / `mc:...` / `sweep:...` payload into
/// its pair range.
pub fn parse_pair_range(payload: &str) -> Option<(u64, u64)> {
    let mut parts = payload.split(':');
    let _tag = parts.next()?;
    let offset = parts.next()?.parse().ok()?;
    let count = parts.next()?.parse().ok()?;
    if parts.next().is_some() {
        return None;
    }
    Some((offset, count))
}

// ---------------------------------------------------------------- events

/// Arm a boot-completion event for `name` under a fresh boot generation.
/// Any later crash/power-off bumps the generation, so a completion event
/// scheduled before the fault lands stale and leaves the node alone.
fn begin_boot(sim: &mut Simulator<World>, w: &mut World, name: &str) {
    let gen = {
        let e = w.boot_gen.entry(name.to_string()).or_insert(0);
        *e += 1;
        *e
    };
    let total = w.g.boot_plan(name).total();
    let n = name.to_string();
    sim.schedule_in(total, move |s, w| node_up(w, &n, gen, s.now()));
}

fn node_up(w: &mut World, name: &str, gen: u64, now: SimTime) {
    // Stale boot completion: the node crashed or powered off (bumping the
    // generation) after this boot started.  Regression guard — the old
    // code broke out of the state walk at `Crashed` and still marked the
    // node schedulable.
    if w.boot_gen.get(name).copied().unwrap_or(0) != gen {
        return;
    }
    let node = w.g.nodes.get_mut(name).unwrap();
    use NodeState::*;
    if !matches!(node.state, PoweringOn | Dhcp | Tftp | NfsMount) {
        return; // only a mid-boot node can complete a boot
    }
    // Jump through remaining boot states (plan time already elapsed).
    while node.state != Up {
        let next = match node.state {
            PoweringOn => Dhcp,
            Dhcp => Tftp,
            Tftp => NfsMount,
            _ => Up,
        };
        let t = node.history.last().map(|&(_, t)| t).unwrap_or(0);
        node.advance(next, t);
    }
    w.g.pbs.node_up(name);
    w.logger.log(now, EventKind::Boot { client: name.to_string(), generation: gen });
}

fn submit(sim: &mut Simulator<World>, w: &mut World, tj: &TraceJob, i: usize) {
    let kind = match tj.payload {
        JobPayload::Synthetic => "trace",
        JobPayload::Ep { .. } => "ep",
    };
    let script = PbsScript {
        name: Some(format!("{kind}-{i:04}")),
        queue: Some("gridlan".into()),
        request: tj.request,
        walltime: Some(tj.walltime),
        commands: vec!["./work.x".into()],
    };
    let payload = tj.payload.encode(tj.compute);
    match w.g.pbs.qsub(&script, &tj.owner, &payload, sim.now()) {
        Ok(id) => {
            w.g.folder.register(&mut w.g.server_fs, id, &script);
            w.logger.log(
                sim.now(),
                EventKind::Submit {
                    job: id.0,
                    owner: tj.owner.clone(),
                    nodes: tj.request.nodes,
                    ppn: tj.request.ppn,
                    kind: kind.to_string(),
                },
            );
            // Nudge the scheduler.
            sim.schedule_in(DUR_SEC, |s, w| run_sched(s, w));
        }
        Err(_) => {
            w.m.jobs_killed += 1; // rejected at submission
        }
    }
}

fn sched_tick(sim: &mut Simulator<World>, w: &mut World, period: SimTime) {
    run_sched(sim, w);
    sim.schedule_in(period, move |s, w| sched_tick(s, w, period));
}

fn run_sched(sim: &mut Simulator<World>, w: &mut World) {
    let now = sim.now();
    let decisions = w.g.pbs.schedule_cycle(NodePool::Gridlan, w.sched.as_ref(), now);
    for (id, alloc) in decisions {
        let payload = w.g.pbs.job(id).map(|j| j.payload.clone()).unwrap_or_default();
        w.logger.log(
            now,
            EventKind::Schedule {
                job: id.0,
                alloc: alloc.cores.iter().map(|(n, c)| (n.clone(), *c)).collect(),
            },
        );
        // Slowest allocated core rate (Turbo + hypervisor aware).
        let mut min_rate = f64::INFINITY;
        for (node, cores) in &alloc.cores {
            let busy = w.g.pbs.node(node).map(|n| n.busy_cores).unwrap_or(*cores);
            let rate = w.g.client(node).map(|c| c.guest_ep_rate(busy)).unwrap_or(REF_RATE_MPAIRS);
            min_rate = min_rate.min(rate);
        }
        if !min_rate.is_finite() {
            min_rate = REF_RATE_MPAIRS;
        }
        let compute: SimTime = if let Some((_offset, count)) = parse_pair_range(&payload) {
            // Real-compute payload: pairs at the slowest core's EP rate.
            (count as f64 * 1e3 / min_rate.max(1e-6)) as SimTime
        } else {
            // Synthetic payload: trace compute normalized to the slowest
            // allocated client.
            let base: SimTime = payload
                .strip_prefix("trace:")
                .and_then(|c| c.parse().ok())
                .unwrap_or(60 * DUR_SEC);
            (base as f64 * (REF_RATE_MPAIRS / min_rate).max(0.1)) as SimTime
        };
        let duration = Mom::wrap_runtime(compute);
        w.logger.log(now, EventKind::Start { job: id.0, run_ns: duration });
        w.started_gen.insert(id, now);
        // EP attempts execute sub-span by sub-span as the DES advances: a
        // chain of checkpoint events runs each span on the engine and
        // banks its tally, so a mid-range fault salvages completed spans.
        // The final span lands at exactly `compute_t0 + compute`, so a
        // clean run completes at the same instant as the old single-event
        // path (`start + wrap_runtime(compute)`).
        let chained = match parse_pair_range(&payload) {
            Some((po, pc)) if pc > 0 => {
                let interval = if w.recovery.checkpoint_interval > 0 {
                    w.recovery.checkpoint_interval
                } else {
                    default_checkpoint_interval(pc)
                };
                w.ep_logical.entry(id).or_insert((po, pc));
                let run = EpRun {
                    cursor: po,
                    end: po + pc,
                    attempt_offset: po,
                    attempt_pairs: pc,
                    interval,
                    compute_t0: now + crate::rm::mom::PROLOGUE,
                    compute_total: compute,
                    failed: false,
                };
                let first = next_boundary(po, po, interval, po + pc);
                let at = checkpoint_time(&run, first);
                w.ep_runs.insert(id, run);
                sim.schedule_at(at, move |s, w| ep_progress(s, w, id, now, first));
                true
            }
            _ => false,
        };
        if !chained {
            sim.schedule_in(duration, move |s, w| job_done(s, w, id, now));
        }
    }
    if w.recovery.steal {
        try_steal(sim, w, now);
    }
}

/// One link of an EP attempt's checkpoint chain: execute the sub-span
/// `[cursor, target)` on the engine, bank its tally, and schedule either
/// the next checkpoint or (past the last span) the MOM epilogue +
/// completion.  Staleness is guarded exactly like `job_done`: a requeue
/// removes the start generation, so in-flight links land dead.
fn ep_progress(sim: &mut Simulator<World>, w: &mut World, id: JobId, started: SimTime, target: u64) {
    if w.started_gen.get(&id) != Some(&started) {
        return;
    }
    let Some(run) = w.ep_runs.get(&id).cloned() else { return };
    // A steal may have truncated the range to exactly this boundary.
    let target = target.min(run.end);
    let mut failed = run.failed;
    if !failed && target > run.cursor {
        let span = target - run.cursor;
        match w.engine.run_pairs(run.cursor, span) {
            Ok(t) => {
                w.ep_banked.entry(id).or_default().merge(&t);
                w.m.ep_pairs_executed += span;
            }
            Err(_) => failed = true,
        }
    }
    if let Some(r) = w.ep_runs.get_mut(&id) {
        r.cursor = target;
        r.failed = failed;
    }
    if target < run.end {
        w.m.ep_checkpoints += 1;
        w.logger.log(
            sim.now(),
            EventKind::Checkpoint {
                job: id.0,
                cursor: target,
                pairs_done: target - run.attempt_offset,
            },
        );
        let next = next_boundary(target, run.attempt_offset, run.interval, run.end);
        let at = checkpoint_time(&run, next);
        sim.schedule_at(at, move |s, w| ep_progress(s, w, id, started, next));
    } else {
        sim.schedule_in(crate::rm::mom::EPILOGUE, move |s, w| job_done(s, w, id, started));
    }
}

/// Straggler work stealing: with idle cores and an empty queue, split the
/// slowest-finishing EP attempt's remainder at a sub-span boundary into a
/// new single-core child job.  The projected finish times come from the
/// per-node speed model (the attempt's compute rate was fixed by the
/// slowest allocated core), so heterogeneous grids steal from slow nodes
/// first; candidate order and tie-breaks are job-id deterministic.
fn try_steal(sim: &mut Simulator<World>, w: &mut World, now: SimTime) {
    use crate::rm::alloc::ResourceRequest;
    let (busy, total) = w.g.pbs.pool_utilization(NodePool::Gridlan);
    if total == 0 || busy >= total {
        return;
    }
    // Don't steal while real work waits for those cores.
    if w.g.pbs.jobs().any(|j| j.state == crate::rm::job::JobState::Queued && j.queue == "gridlan")
    {
        return;
    }
    // Best idle core's EP rate under the speed model.
    let mut best_rate = 0.0f64;
    for n in w.g.pbs.nodes() {
        if n.free_cores() == 0 {
            continue;
        }
        if let Some(c) = w.g.client(&n.name) {
            best_rate = best_rate.max(c.guest_ep_rate(n.busy_cores + 1));
        }
    }
    if best_rate <= 0.0 {
        return;
    }
    // Victim: the attempt with the latest projected finish whose stolen
    // half would complete on an idle core before the straggler finishes.
    let mut victim: Option<(JobId, u64, u64, SimTime)> = None;
    for (id, run) in &w.ep_runs {
        if run.failed {
            continue;
        }
        let rem = run.end.saturating_sub(run.cursor);
        if rem < 2 * run.interval {
            continue; // remainder must span at least two sub-spans
        }
        // Sub-span boundary nearest the middle of the remainder.
        let mid = run.cursor + rem / 2;
        let k = (mid - run.attempt_offset).div_ceil(run.interval);
        let split = run.attempt_offset.saturating_add(k.saturating_mul(run.interval));
        if split <= run.cursor || split >= run.end {
            continue;
        }
        let stolen = run.end - split;
        let parent_finish = checkpoint_time(run, run.end) + crate::rm::mom::EPILOGUE;
        let child_est = now
            + DUR_SEC
            + crate::rm::mom::PROLOGUE
            + crate::rm::mom::EPILOGUE
            + (stolen as f64 * 1e3 / best_rate) as SimTime;
        if child_est >= parent_finish {
            continue; // not worth moving
        }
        match victim {
            Some((_, _, _, best_finish)) if parent_finish <= best_finish => {}
            _ => victim = Some((*id, split, stolen, parent_finish)),
        }
    }
    let Some((pid, split, stolen, _)) = victim else { return };
    let Some(parent) = w.g.pbs.job(pid) else { return };
    let owner = parent.owner.clone();
    let walltime = parent.walltime;
    let (po, _) = match parse_pair_range(&parent.payload) {
        Some(r) => r,
        None => return,
    };
    let script = PbsScript {
        name: Some(format!("steal-{}", pid.0)),
        queue: Some("gridlan".into()),
        request: ResourceRequest { nodes: 1, ppn: 1 },
        walltime,
        commands: vec!["./work.x".into()],
    };
    // Submit the child first; only a successful admission truncates the
    // parent, so a rejected qsub can never lose part of the range.
    let child_payload = format!("ep:{split}:{stolen}");
    let Ok(cid) = w.g.pbs.qsub(&script, &owner, &child_payload, now) else { return };
    w.g.pbs
        .set_payload(pid, &format!("ep:{po}:{}", split - po))
        .expect("steal victim is a live job");
    if let Some(r) = w.ep_runs.get_mut(&pid) {
        r.end = split;
    }
    if let Some(l) = w.ep_logical.get_mut(&pid) {
        l.1 = split - l.0;
    }
    w.m.jobs_submitted += 1;
    w.m.ep_steals += 1;
    w.g.folder.register(&mut w.g.server_fs, cid, &script);
    w.lineage.insert(cid, pid);
    w.logger.log(
        now,
        EventKind::Submit { job: cid.0, owner, nodes: 1, ppn: 1, kind: "ep".to_string() },
    );
    w.logger.log(
        now,
        EventKind::Steal { parent: pid.0, child: cid.0, offset: split, count: stolen },
    );
    sim.schedule_in(DUR_SEC, |s, w| run_sched(s, w));
}

fn job_done(sim: &mut Simulator<World>, w: &mut World, id: JobId, started: SimTime) {
    // Stale completion (job was requeued since): ignore.
    if w.started_gen.get(&id) != Some(&started) {
        return;
    }
    let Some(job) = w.g.pbs.job(id) else { return };
    if job.state != crate::rm::job::JobState::Running || job.started_at != Some(started) {
        return;
    }
    // EP compute already happened span by span along the checkpoint
    // chain; completion just promotes the banked tally to the job's
    // logical result.  Banked spans cover the job's logical range exactly
    // once (salvaged spans + re-executed remainder), so the merge is
    // bit-identical to a single scalar pass over the range.
    let payload = job.payload.clone();
    let mut exit_code = 0;
    if let Some((_offset, count)) = parse_pair_range(&payload) {
        let run = w.ep_runs.remove(&id);
        if run.as_ref().map(|r| r.failed).unwrap_or(false) {
            w.ep_banked.remove(&id);
            w.ep_logical.remove(&id);
            w.m.ep_jobs_failed += 1;
            exit_code = 1;
        } else {
            let tally = w.ep_banked.remove(&id).unwrap_or_default();
            let logical = w.ep_logical.remove(&id).map(|(_, c)| c).unwrap_or(count);
            assert_eq!(
                tally.pairs, logical,
                "banked sub-spans must cover job {id}'s logical range exactly"
            );
            w.ep_tallies.insert(id, tally);
            w.m.ep_jobs_completed += 1;
        }
    }
    let rec = w.g.pbs.complete(id, exit_code, sim.now());
    w.logger.log(
        sim.now(),
        EventKind::Complete { job: id.0, exit: exit_code, wait_ns: rec.wait },
    );
    w.g.folder.job_completed(&mut w.g.server_fs, id);
    w.m.jobs_completed += 1;
    w.m.total_wait += rec.wait;
    w.m.core_secs_useful += rec.allocation.total_cores() as f64 * (sim.now() - started) as f64 / 1e9;
    w.m.makespan = w.m.makespan.max(sim.now());
    sim.schedule_in(DUR_SEC, |s, w| run_sched(s, w));
}

fn monitor_sweep(sim: &mut Simulator<World>, w: &mut World) {
    let now = sim.now();
    // A node answers if its VM is Up, the tunnel is connected, and the
    // client has power.  Set lookup, not a linear scan: the sweep calls
    // the probe once per tracked node, and at 100k-node scenarios an
    // O(n) probe would make each sweep quadratic.
    let mut responding = BTreeSet::new();
    for c in &w.g.clients {
        let node_up = w.g.nodes.get(&c.name).map(|n| n.state.is_running()).unwrap_or(false);
        if c.powered && w.g.hub.is_connected(&c.name) && node_up {
            responding.insert(c.name.clone());
        }
    }
    w.g.pinger.sweep(now, |n| responding.contains(n));
    sim.schedule_in(300 * DUR_SEC, monitor_sweep);
}

fn watchdog_poll(sim: &mut Simulator<World>, w: &mut World, name: &str) {
    let now = sim.now();
    let powered = w.g.client(name).map(|c| c.powered).unwrap_or(false);
    let reachable = powered && w.g.hub.is_connected(name);
    let node_on = if reachable { w.g.status.is_node_on(&w.g.pinger, name) } else { None };
    let action = w.watchdogs.get_mut(name).unwrap().poll(now, reachable, node_on);
    match action {
        WatchdogAction::RestartVm if powered => {
            let node = w.g.nodes.get_mut(name).unwrap();
            if matches!(node.state, NodeState::Crashed | NodeState::Off) {
                node.advance(NodeState::PoweringOn, now);
                w.m.watchdog_restarts += 1;
                begin_boot(sim, w, name);
            }
        }
        WatchdogAction::ReconnectVpn if powered => {
            let _ = w.g.connect_client(name);
        }
        _ => {}
    }
    let n = name.to_string();
    sim.schedule_in(300 * DUR_SEC, move |s, w| watchdog_poll(s, w, &n));
}

/// Stable wire name for a fault kind in the event log.
fn fault_kind_name(kind: FaultKind) -> &'static str {
    match kind {
        FaultKind::ClientPowerOff => "power_off",
        FaultKind::NetworkDrop => "net_drop",
        FaultKind::VmCrash => "vm_crash",
    }
}

fn apply_fault(
    sim: &mut Simulator<World>,
    w: &mut World,
    client: &str,
    kind: FaultKind,
    outage: SimTime,
) {
    let now = sim.now();
    w.logger.log(
        now,
        EventKind::Fault {
            client: client.to_string(),
            kind: fault_kind_name(kind).to_string(),
            outage_ns: outage,
        },
    );
    // Account wasted work + requeue running jobs on this node.
    let waste_and_requeue = |w: &mut World, now: SimTime| {
        // Capture wasted core-seconds before node_down clears started_at.
        let wasted: f64 = w
            .g
            .pbs
            .jobs()
            .filter(|j| {
                j.state == crate::rm::job::JobState::Running
                    && j.allocation.as_ref().map(|a| a.cores.contains_key(client)).unwrap_or(false)
            })
            .map(|j| {
                let cores = j.allocation.as_ref().map(|a| a.total_cores()).unwrap_or(0);
                cores as f64 * (now.saturating_sub(j.started_at.unwrap_or(now))) as f64 / 1e9
            })
            .sum();
        let victims = w.g.pbs.node_down(client, now);
        for id in &victims {
            w.m.jobs_requeued += 1;
            w.started_gen.remove(id);
            // Partial-range recovery: bank the attempt's checkpointed
            // sub-spans and requeue only the unexecuted remainder.  In
            // naive mode (or after a backend failure) the bank is
            // discarded and the full payload range re-executes.
            if let Some(run) = w.ep_runs.remove(id) {
                if run.failed || !w.recovery.salvage {
                    w.ep_banked.remove(id);
                } else {
                    let salvaged = run.cursor - run.attempt_offset;
                    if salvaged > 0 {
                        w.m.ep_pairs_salvaged += salvaged;
                        let rest = run.end - run.cursor;
                        w.g.pbs
                            .set_payload(*id, &format!("ep:{}:{rest}", run.cursor))
                            .expect("requeued EP job is in the job table");
                    }
                }
            }
            w.logger.log(now, EventKind::Requeue { job: id.0, client: client.to_string() });
        }
        w.m.core_secs_wasted += wasted;
        victims.len()
    };
    // The node is about to die: invalidate any in-flight boot completion.
    let kill_boot_gen = |w: &mut World| {
        *w.boot_gen.entry(client.to_string()).or_insert(0) += 1;
    };
    match kind {
        FaultKind::ClientPowerOff => {
            if let Some(c) = w.g.client_mut(client) {
                if !c.powered {
                    return; // already down
                }
                c.powered = false;
                c.vpn_connected = false;
            }
            w.g.hub.disconnect(client);
            kill_boot_gen(w);
            let node = w.g.nodes.get_mut(client).unwrap();
            if node.state != NodeState::Off {
                node.advance(NodeState::Off, now);
            }
            waste_and_requeue(w, now);
            // Owner turns it back on after the outage; VM boots again.
            let c = client.to_string();
            sim.schedule_in(outage, move |s, w: &mut World| {
                if let Some(cl) = w.g.client_mut(&c) {
                    cl.powered = true;
                }
                let _ = w.g.connect_client(&c);
                let node = w.g.nodes.get_mut(&c).expect("powered client has a node");
                if node.state == NodeState::Off {
                    node.advance(NodeState::PoweringOn, s.now());
                    begin_boot(s, w, &c);
                }
            });
        }
        FaultKind::NetworkDrop => {
            w.g.hub.disconnect(client);
            if let Some(c) = w.g.client_mut(client) {
                c.vpn_connected = false;
            }
            waste_and_requeue(w, now);
            let c = client.to_string();
            sim.schedule_in(outage, move |s, w: &mut World| {
                let _ = w.g.connect_client(&c);
                // Node was running all along; RM can use it again.
                if w.g.nodes.get(&c).map(|n| n.state.is_running()).unwrap_or(false) {
                    w.g.pbs.node_up(&c);
                }
                let _ = s;
            });
        }
        FaultKind::VmCrash => {
            kill_boot_gen(w);
            let node = w.g.nodes.get_mut(client).unwrap();
            if !matches!(node.state, NodeState::Off | NodeState::Crashed) {
                node.advance(NodeState::Crashed, now);
            }
            waste_and_requeue(w, now);
            // Recovery path: monitor marks Off; watchdog restarts the VM.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::rm::alloc::ResourceRequest;
    use crate::rm::server::NodePower;
    use crate::sim::clock::DUR_MS;
    use crate::workload::ep::ep_scalar;

    fn quick_trace(n: usize, cores: u32, compute_secs: u64) -> Vec<TraceJob> {
        (0..n)
            .map(|i| TraceJob {
                at: (i as u64) * DUR_SEC,
                owner: "u".into(),
                request: ResourceRequest { nodes: 1, ppn: cores },
                compute: compute_secs * DUR_SEC,
                walltime: compute_secs * 3 * DUR_SEC,
                payload: JobPayload::Synthetic,
            })
            .collect()
    }

    #[test]
    fn clean_run_completes_all_jobs() {
        let g = Gridlan::build(Config::table1());
        let scenario = Scenario { horizon: 2 * 3600 * DUR_SEC, ..Default::default() };
        let report = run_trace(g, quick_trace(10, 2, 120), &scenario);
        assert_eq!(report.metrics.jobs_completed, 10);
        assert_eq!(report.metrics.jobs_requeued, 0);
        assert!(report.metrics.goodput() > 0.999);
        assert!(report.metrics.makespan > 0);
    }

    #[test]
    fn jobs_wait_for_boot() {
        // Submitted at t=1s, but nodes take minutes to PXE-boot: the first
        // completion must come after the fastest boot.
        let mut g = Gridlan::build(Config::table1());
        let boot_min = {
            let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
            names
                .iter()
                .map(|n| {
                    g.connect_client(n).unwrap();
                    let t = g.boot_plan(n).total();
                    g.hub.disconnect(n);
                    t
                })
                .min()
                .unwrap()
        };
        let g = Gridlan::build(Config::table1());
        let scenario = Scenario { horizon: 3600 * DUR_SEC, ..Default::default() };
        let report = run_trace(g, quick_trace(1, 1, 10), &scenario);
        assert_eq!(report.metrics.jobs_completed, 1);
        assert!(
            report.metrics.makespan > boot_min,
            "makespan {} <= boot {}",
            report.metrics.makespan,
            boot_min
        );
    }

    #[test]
    fn faulty_run_requeues_and_recovers() {
        let g = Gridlan::build(Config::table1());
        // Heavy faults: power-offs every ~20 min per client.
        let faults = FaultPlan {
            mtbf_power_off: 1200 * DUR_SEC,
            mtbf_net_drop: 0,
            mtbf_vm_crash: 0,
            mean_outage: 300 * DUR_SEC,
        };
        let scenario =
            Scenario { horizon: 4 * 3600 * DUR_SEC, faults, ..Default::default() };
        // Long jobs so faults hit running work.
        let report = run_trace(g, quick_trace(12, 4, 900), &scenario);
        assert!(report.metrics.faults > 0, "no faults injected");
        assert!(report.metrics.jobs_requeued > 0, "faults never hit a running job");
        // The resilience machinery must still finish everything.
        assert_eq!(report.metrics.jobs_completed, 12, "{:?}", report.metrics);
        assert!(report.metrics.goodput() < 1.0);
    }

    #[test]
    fn vm_crash_recovered_by_watchdog() {
        let g = Gridlan::build(Config::table1());
        let faults = FaultPlan {
            mtbf_power_off: 0,
            mtbf_net_drop: 0,
            mtbf_vm_crash: 1800 * DUR_SEC,
            mean_outage: 60 * DUR_SEC,
        };
        let scenario = Scenario { horizon: 6 * 3600 * DUR_SEC, faults, ..Default::default() };
        let report = run_trace(g, quick_trace(8, 2, 600), &scenario);
        assert!(report.metrics.faults > 0);
        assert!(report.metrics.watchdog_restarts > 0, "watchdog never fired");
        assert_eq!(report.metrics.jobs_completed, 8);
    }

    #[test]
    fn stale_boot_completion_after_crash_stays_offline() {
        // Regression (the `_gen` guard was unused): a boot-completion
        // event scheduled before a VmCrash must not mark the crashed node
        // schedulable when it fires afterward.
        let mut sim: Simulator<World> = Simulator::new();
        let g = Gridlan::build(Config::table1());
        let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
        let watchdogs = names.iter().map(|n| (n.clone(), Watchdog::new(n))).collect();
        let sched = g.scheduler();
        let mut w = World {
            g,
            sched,
            m: Metrics::default(),
            engine: EpEngine::scalar(),
            watchdogs,
            started_gen: BTreeMap::new(),
            boot_gen: BTreeMap::new(),
            ep_tallies: BTreeMap::new(),
            recovery: RecoveryPolicy::default(),
            ep_runs: BTreeMap::new(),
            ep_banked: BTreeMap::new(),
            ep_logical: BTreeMap::new(),
            lineage: BTreeMap::new(),
            logger: ScenarioLogger::null(),
        };
        w.g.connect_client("n01").unwrap();
        let total = w.g.boot_plan("n01").total();
        w.g.nodes.get_mut("n01").unwrap().advance(NodeState::PoweringOn, 0);
        begin_boot(&mut sim, &mut w, "n01");
        // Crash strictly inside the boot window; no watchdog is armed, so
        // nothing may legitimately bring the node back.
        sim.schedule_at(total / 2, |s, w| {
            apply_fault(s, w, "n01", FaultKind::VmCrash, 60 * DUR_SEC);
        });
        sim.run_until(&mut w, total * 2);
        assert_eq!(w.g.nodes["n01"].state, NodeState::Crashed);
        assert_eq!(
            w.g.pbs.node("n01").unwrap().power,
            NodePower::Offline,
            "stale boot completion marked a crashed node schedulable"
        );
    }

    #[test]
    fn ep_payload_jobs_compute_for_real_in_a_scenario() {
        // EP payload entries inside run_trace: scheduled by the RM, timed
        // by the speed model, executed on the engine at completion.
        let g = Gridlan::build(Config::table1());
        let trace: Vec<TraceJob> = (0..6)
            .map(|i| {
                EpSlice { proc: i, pair_offset: i as u64 * 40_000, pair_count: 40_000 }
                    .trace_job((i as u64) * DUR_SEC, 3600 * DUR_SEC)
            })
            .collect();
        let scenario = Scenario { horizon: 3600 * DUR_SEC, ..Default::default() };
        let run = run_scenario(g, trace, &scenario, EpEngine::scalar());
        assert_eq!(run.report.metrics.jobs_completed, 6);
        assert_eq!(run.report.metrics.ep_jobs_completed, 6);
        assert_eq!(run.report.metrics.ep_pairs_executed, 240_000);
        assert_eq!(run.engine.pairs_executed(), 240_000);
        let total = run.report.ep_total();
        let oracle = ep_scalar(0, 240_000);
        assert_eq!(total.nacc, oracle.nacc);
        assert_eq!(total.q, oracle.q);
        assert!((total.sx - oracle.sx).abs() < 1e-7);
        // EP jobs waited for the event-driven PXE boots like everyone.
        assert!(run.report.metrics.makespan > 60 * DUR_SEC);
    }

    #[test]
    fn ep_slices_through_rm_match_the_oracle() {
        // Real compute through qsub -> schedule -> backend -> complete:
        // the merged tally equals the scalar oracle over the union range.
        let mut g = Gridlan::build(Config::table1());
        g.boot_all(0);
        let mut engine = EpEngine::scalar();
        let slices: Vec<EpSlice> = (0..4)
            .map(|i| EpSlice { proc: i, pair_offset: i as u64 * 50_000, pair_count: 50_000 })
            .collect();
        let total = run_ep_slices(&mut g, &mut engine, &slices, 0).unwrap();
        let oracle = crate::workload::ep::ep_scalar(0, 200_000);
        assert_eq!(total.nacc, oracle.nacc);
        assert_eq!(total.q, oracle.q);
        assert!((total.sx - oracle.sx).abs() < 1e-7);
        assert_eq!(engine.pairs_executed(), 200_000);
        // Every slice ran to successful completion in the RM.
        assert_eq!(g.pbs.jobs().filter(|j| j.succeeded()).count(), 4);
    }

    #[test]
    fn ep_job_wider_than_the_pool_still_completes() {
        // 40 single-core slices on a 26-core pool: needs multiple
        // scheduling cycles; the merge must still be exact.
        let mut g = Gridlan::build(Config::table1());
        g.boot_all(0);
        let mut engine = EpEngine::scalar();
        let slices: Vec<EpSlice> = (0..40)
            .map(|i| EpSlice { proc: i, pair_offset: i as u64 * 4_096, pair_count: 4_096 })
            .collect();
        let total = run_ep_slices(&mut g, &mut engine, &slices, 0).unwrap();
        let oracle = crate::workload::ep::ep_scalar(0, 40 * 4_096);
        assert_eq!(total.nacc, oracle.nacc);
        assert_eq!(total.pairs, 40 * 4_096);
    }

    #[test]
    fn unbooted_grid_boots_event_driven_for_ep_slices() {
        // run_ep_slices on a cold grid now PXE-boots the nodes as part of
        // the scenario instead of stalling.
        let mut g = Gridlan::build(Config::table1());
        let mut engine = EpEngine::scalar();
        let slices = [EpSlice { proc: 0, pair_offset: 0, pair_count: 1024 }];
        let total = run_ep_slices(&mut g, &mut engine, &slices, 0).unwrap();
        assert_eq!(total.nacc, ep_scalar(0, 1024).nacc);
        assert!(g.nodes.values().any(|n| n.state.is_running()), "boot never happened");
    }

    #[test]
    fn pair_range_payloads_parse() {
        assert_eq!(parse_pair_range("ep:0:1024"), Some((0, 1024)));
        assert_eq!(parse_pair_range("mc:65536:131072"), Some((65536, 131072)));
        assert_eq!(parse_pair_range("sweep:10:20"), Some((10, 20)));
        assert_eq!(parse_pair_range("trace:5"), None);
        assert_eq!(parse_pair_range("ep:1:2:3"), None);
        assert_eq!(parse_pair_range("ep:x:2"), None);
    }

    #[test]
    fn logged_run_records_consistent_events() {
        let g = Gridlan::build(Config::table1());
        let scenario = Scenario { horizon: 2 * 3600 * DUR_SEC, ..Default::default() };
        let run = run_scenario_logged(
            g,
            quick_trace(6, 2, 120),
            &scenario,
            EpEngine::scalar(),
            ScenarioLogger::memory(),
        );
        let events = run.logger.events();
        assert!(!events.is_empty());
        // DES delivery order: timestamps are monotone.
        assert!(events.windows(2).all(|w| w[0].at <= w[1].at));
        let rollup = crate::obs::report::EventRollup::from_events(events);
        assert!(
            rollup.consistent_with(&run.report.metrics),
            "{rollup:?} vs {:?}",
            run.report.metrics
        );
        assert_eq!(rollup.submits, run.report.metrics.jobs_submitted);
        assert_eq!(rollup.boots, 4, "all four Table-1 nodes boot exactly once");
        assert_eq!(rollup.starts, rollup.schedules);
    }

    #[test]
    fn deterministic_reports() {
        let s = Scenario { horizon: 3600 * DUR_SEC, ..Default::default() };
        let r1 = run_trace(Gridlan::build(Config::table1()), quick_trace(5, 2, 60), &s);
        let r2 = run_trace(Gridlan::build(Config::table1()), quick_trace(5, 2, 60), &s);
        assert_eq!(r1.metrics, r2.metrics);
        assert_eq!(r1.events_executed, r2.events_executed);
    }

    #[test]
    fn scripted_fault_requeues_an_ep_job_and_tally_stays_exact() {
        // A VmCrash storm placed precisely inside the EP job's MOM
        // prologue: the first attempt dies before computing anything, the
        // requeued attempt re-executes the whole range after the watchdog
        // resurrects the grid, and the recorded tally is still exact.
        let mut g = Gridlan::build(Config::table1());
        g.boot_all(0);
        let (offset, count) = (5_000u64, 2_000_000u64);
        let at = 1000 * DUR_SEC;
        let trace =
            vec![EpSlice { proc: 0, pair_offset: offset, pair_count: count }.trace_job(at, 3600 * DUR_SEC)];
        // The sched tick at t=1000s starts the job (submission lands first
        // at the same timestamp); MOM's prologue alone lasts 350 ms, so a
        // crash of every client 200 ms in is strictly inside the run.
        let scripted: Vec<FaultEvent> = ["n01", "n02", "n03", "n04"]
            .iter()
            .map(|n| FaultEvent {
                at: at + 200 * DUR_MS,
                client: n.to_string(),
                kind: FaultKind::VmCrash,
                outage: 60 * DUR_SEC,
            })
            .collect();
        let scenario =
            Scenario { horizon: 2 * 3600 * DUR_SEC, scripted_faults: scripted, ..Default::default() };
        let run = run_scenario(g, trace, &scenario, EpEngine::scalar());
        let m = &run.report.metrics;
        assert_eq!(m.jobs_completed, 1, "{m:?}");
        assert!(m.jobs_requeued >= 1, "crash must interrupt the running EP job: {m:?}");
        assert!(m.watchdog_restarts > 0, "watchdog must resurrect the grid");
        let job = run.gridlan.pbs.jobs().find(|j| j.requeues > 0).expect("requeued job");
        assert!(job.succeeded());
        // Killed attempt computed nothing; the final attempt computed the
        // range exactly once.
        assert_eq!(run.engine.pairs_executed(), count);
        let tally = run.report.ep_tallies.values().next().unwrap();
        let oracle = ep_scalar(offset, count);
        assert_eq!(tally.nacc, oracle.nacc);
        assert_eq!(tally.q, oracle.q);
        assert_eq!(tally.pairs, oracle.pairs);
        assert!((tally.sx - oracle.sx).abs() < 1e-7);
    }

    #[test]
    fn checkpoint_interval_and_boundary_arithmetic() {
        // Auto interval: ~count/16, clamped to [1024, 4M].
        assert_eq!(default_checkpoint_interval(100), 1024);
        assert_eq!(default_checkpoint_interval(16 * 1024), 1024);
        assert_eq!(default_checkpoint_interval(262_144), 16_384);
        assert_eq!(default_checkpoint_interval(1 << 30), 1 << 22);
        // Boundaries sit at attempt_offset + k*interval, clamped to end.
        assert_eq!(next_boundary(0, 0, 1024, 4096), 1024);
        assert_eq!(next_boundary(1024, 0, 1024, 4096), 2048);
        assert_eq!(next_boundary(3072, 0, 1024, 4096), 4096, "last span ends at end");
        assert_eq!(next_boundary(3072, 0, 1024, 4000), 4000, "short tail clamps to end");
        assert_eq!(next_boundary(500, 0, 1024, 4096), 1024, "mid-span cursor rounds up");
        // Non-zero attempt offset (a salvage-requeued remainder).
        assert_eq!(next_boundary(5000, 5000, 1024, 8000), 6024);
        assert_eq!(next_boundary(7900, 5000, 1024, 8000), 8000);
        // checkpoint_time is integer-exact at both range ends and monotone.
        let run = EpRun {
            cursor: 0,
            end: 1000,
            attempt_offset: 0,
            attempt_pairs: 1000,
            interval: 100,
            compute_t0: 500,
            compute_total: 777,
            failed: false,
        };
        assert_eq!(checkpoint_time(&run, 0), 500);
        assert_eq!(checkpoint_time(&run, 1000), 500 + 777, "clean run ends exactly on time");
        let mut prev = 0;
        for k in 0..=10 {
            let t = checkpoint_time(&run, k * 100);
            assert!(t >= prev, "checkpoint instants must be monotone");
            prev = t;
        }
    }

    #[test]
    fn checkpoint_chain_preserves_legacy_completion_instant() {
        // A clean run must complete at start + wrap_runtime(compute)
        // regardless of how many sub-spans the range is cut into: the
        // single-span chain (interval >= count) is the legacy path, and
        // the auto-interval 16-span chain must land on the same instant.
        let run_with = |interval: u64| {
            let mut g = Gridlan::build(Config::table1());
            g.boot_all(0);
            let trace = vec![EpSlice { proc: 0, pair_offset: 0, pair_count: 100_000 }
                .trace_job(0, 3600 * DUR_SEC)];
            let scenario = Scenario {
                horizon: 3600 * DUR_SEC,
                recovery: RecoveryPolicy { checkpoint_interval: interval, ..Default::default() },
                ..Default::default()
            };
            run_scenario(g, trace, &scenario, EpEngine::scalar()).report
        };
        let single = run_with(100_000);
        let chained = run_with(0);
        assert_eq!(single.metrics.makespan, chained.metrics.makespan);
        assert_eq!(single.metrics.ep_checkpoints, 0, "one span logs no checkpoints");
        assert_eq!(chained.metrics.ep_checkpoints, 15, "16 spans log 15 checkpoints");
        assert_eq!(single.ep_total(), chained.ep_total(), "partition must not change the tally");
    }

    /// Prebooted Table-1 grid, one EP job at t=1000s, every client crashed
    /// `crash_ms` after the start instant.  Returns the finished run.
    fn crash_one_ep_job(offset: u64, count: u64, crash_ms: u64, salvage: bool) -> ScenarioRun {
        let mut g = Gridlan::build(Config::table1());
        g.boot_all(0);
        let at = 1000 * DUR_SEC;
        let trace =
            vec![EpSlice { proc: 0, pair_offset: offset, pair_count: count }
                .trace_job(at, 3600 * DUR_SEC)];
        let scripted: Vec<FaultEvent> = ["n01", "n02", "n03", "n04"]
            .iter()
            .map(|n| FaultEvent {
                at: at + crash_ms * DUR_MS,
                client: n.to_string(),
                kind: FaultKind::VmCrash,
                outage: 60 * DUR_SEC,
            })
            .collect();
        let scenario = Scenario {
            horizon: 2 * 3600 * DUR_SEC,
            scripted_faults: scripted,
            recovery: RecoveryPolicy { salvage, ..Default::default() },
            ..Default::default()
        };
        run_scenario(g, trace, &scenario, EpEngine::scalar())
    }

    #[test]
    fn mid_range_crash_salvages_checkpointed_spans() {
        // Crash 400 ms after start: 350 ms of MOM prologue plus ~50 ms of
        // compute — several sub-spans are checkpointed on every Table-1
        // core speed.  Salvage banks them, the requeue carries only the
        // remainder, and every logical pair executes exactly once.
        let (offset, count) = (5_000u64, 2_000_000u64);
        let run = crash_one_ep_job(offset, count, 400, true);
        let m = &run.report.metrics;
        assert_eq!(m.jobs_completed, 1, "{m:?}");
        assert!(m.jobs_requeued >= 1, "crash must interrupt the running job: {m:?}");
        assert!(m.ep_checkpoints > 0, "no sub-span ever checkpointed: {m:?}");
        assert!(m.ep_pairs_salvaged > 0, "mid-compute crash must salvage spans: {m:?}");
        assert!(m.ep_pairs_salvaged < count, "the whole range cannot be salvaged: {m:?}");
        // The salvage invariant: executed == logical, zero waste.
        assert_eq!(m.ep_pairs_executed, count, "salvage must not re-execute pairs");
        assert_eq!(run.engine.pairs_executed(), count);
        let tally = run.report.ep_tallies.values().next().expect("job tallied");
        let oracle = ep_scalar(offset, count);
        assert_eq!(tally.nacc, oracle.nacc);
        assert_eq!(tally.q, oracle.q);
        assert_eq!(tally.pairs, oracle.pairs);
        assert!((tally.sx - oracle.sx).abs() < 1e-7);
        assert!((tally.sy - oracle.sy).abs() < 1e-7);
    }

    #[test]
    fn naive_mode_wastes_what_salvage_keeps() {
        // Same crash, salvage off: the bank is discarded, the requeued
        // attempt re-executes the full range, and the waste shows up as
        // executed - logical.  The tally must still be exact — waste is a
        // cost, never a correctness leak.
        let (offset, count) = (5_000u64, 2_000_000u64);
        let naive = crash_one_ep_job(offset, count, 400, false);
        let m = &naive.report.metrics;
        assert_eq!(m.jobs_completed, 1, "{m:?}");
        assert_eq!(m.ep_pairs_salvaged, 0, "naive mode banks nothing across faults");
        assert!(
            m.ep_pairs_executed > count,
            "naive re-execution must waste pairs: executed {} <= logical {count}",
            m.ep_pairs_executed
        );
        let wasted = m.ep_pairs_executed - count;
        assert!(wasted > 0);
        let tally = naive.report.ep_tallies.values().next().expect("job tallied");
        let oracle = ep_scalar(offset, count);
        assert_eq!(tally.nacc, oracle.nacc);
        assert_eq!(tally.pairs, oracle.pairs);
        // Salvage eliminates that waste entirely at the same crash point.
        let salvaged = crash_one_ep_job(offset, count, 400, true);
        assert_eq!(
            salvaged.report.metrics.ep_pairs_executed - count,
            0,
            "salvage must waste nothing"
        );
    }

    /// A two-node grid with a 20x-slow single-core straggler: flat clocks
    /// (base == turbo == all-core) so every rate is exact, one slice lands
    /// on the slow core, and the steal window is wide.
    fn straggler_config() -> Config {
        use crate::config::ClientConfig;
        use crate::host::client::ClientOs;
        use crate::vm::cpu::CpuModel;
        let mk = |name: &str, cores: u32, ppc: f64| ClientConfig {
            name: name.into(),
            os: ClientOs::Linux,
            cpu: CpuModel {
                name: format!("flat-{name}"),
                cores,
                base_ghz: 3.0,
                max_turbo_ghz: 3.0,
                all_core_ghz: 3.0,
                pairs_per_cycle: ppc,
            },
            hypervisor: None,
            switch_hops: 2,
            stack_us: 120.0,
            link_mbps: 1000.0,
        };
        let mut cfg = Config::table1();
        cfg.clients = vec![mk("fast", 4, 0.004), mk("slow", 1, 0.00002)];
        cfg
    }

    fn run_straggler_flood(steal: bool) -> ScenarioRun {
        let mut g = Gridlan::build(straggler_config());
        g.boot_all(0);
        let trace: Vec<TraceJob> = (0..5)
            .map(|i| {
                EpSlice { proc: i, pair_offset: i as u64 * 200_000, pair_count: 200_000 }
                    .trace_job(0, 3600 * DUR_SEC)
            })
            .collect();
        let scenario = Scenario {
            horizon: 3600 * DUR_SEC,
            recovery: RecoveryPolicy { steal, ..Default::default() },
            ..Default::default()
        };
        run_scenario(g, trace, &scenario, EpEngine::scalar())
    }

    #[test]
    fn steal_splits_the_straggler_and_beats_no_steal_makespan() {
        let baseline = run_straggler_flood(false);
        assert_eq!(baseline.report.metrics.ep_steals, 0);
        assert!(baseline.report.steal_lineage.is_empty());

        let stolen = run_straggler_flood(true);
        let m = &stolen.report.metrics;
        assert!(m.ep_steals >= 1, "idle fast cores must steal from the straggler: {m:?}");
        assert_eq!(m.jobs_completed, 5 + m.ep_steals, "every child job completes");
        assert!(!stolen.report.steal_lineage.is_empty());
        for (child, parent) in &stolen.report.steal_lineage {
            assert_ne!(child, parent);
            assert!(
                stolen.report.ep_tallies.contains_key(child)
                    && stolen.report.ep_tallies.contains_key(parent),
                "both halves of a split must complete and tally"
            );
        }
        // Stealing moves work, it never duplicates it.
        assert_eq!(m.ep_pairs_executed, 1_000_000, "no pair executes twice under stealing");
        assert_eq!(stolen.engine.pairs_executed(), 1_000_000);
        let total = stolen.report.ep_total();
        let oracle = ep_scalar(0, 1_000_000);
        assert_eq!(total.nacc, oracle.nacc);
        assert_eq!(total.q, oracle.q);
        assert_eq!(total.pairs, oracle.pairs);
        assert!((total.sx - oracle.sx).abs() < 1e-7);
        // The point of the exercise: the straggler's tail shrinks.
        assert!(
            m.makespan < baseline.report.metrics.makespan,
            "steal makespan {} must beat no-steal {}",
            m.makespan,
            baseline.report.metrics.makespan
        );
    }

    #[test]
    fn steal_threshold_honors_the_speed_model() {
        // With stealing on but every node equally fast and busy, the
        // profit test (child must finish before the straggler would) finds
        // no victim: a short remainder is never worth the MOM overheads.
        let mut g = Gridlan::build(Config::table1());
        g.boot_all(0);
        let trace = vec![EpSlice { proc: 0, pair_offset: 0, pair_count: 500_000 }
            .trace_job(0, 3600 * DUR_SEC)];
        let scenario = Scenario {
            horizon: 3600 * DUR_SEC,
            recovery: RecoveryPolicy { steal: true, ..Default::default() },
            ..Default::default()
        };
        let run = run_scenario(g, trace, &scenario, EpEngine::scalar());
        let m = &run.report.metrics;
        // ~35 ms of compute against ~1.55 s of steal overhead: no steal.
        assert_eq!(m.ep_steals, 0, "unprofitable steal must be rejected: {m:?}");
        assert_eq!(m.jobs_completed, 1);
        assert_eq!(m.ep_pairs_executed, 500_000);
    }

    #[test]
    fn prop_random_crash_schedules_keep_tallies_exact() {
        use crate::util::prop::{check, expect, Outcome};
        // Random crash instants (prologue, mid-compute, epilogue, or after
        // completion) under both recovery modes: the merged tally must
        // equal the scalar oracle bit-for-bit on counters, salvage must
        // never re-execute a pair, and naive mode may only over-execute.
        check(6, |g| {
            let offset = g.u64_in(0..10_000);
            let count = g.u64_in(1_000_000..3_000_000);
            let crash_ms = g.u64_in(0..800);
            let salvage = g.u64_in(0..2) == 0;
            let run = crash_one_ep_job(offset, count, crash_ms, salvage);
            let m = &run.report.metrics;
            if m.jobs_completed != 1 {
                return Outcome::Fail(format!(
                    "offset={offset} count={count} crash_ms={crash_ms} salvage={salvage}: \
                     completed {} != 1",
                    m.jobs_completed
                ));
            }
            let tally = run.report.ep_tallies.values().next().expect("job tallied").clone();
            let oracle = ep_scalar(offset, count);
            if tally.nacc != oracle.nacc
                || tally.q != oracle.q
                || tally.pairs != oracle.pairs
                || (tally.sx - oracle.sx).abs() >= 1e-7
            {
                return Outcome::Fail(format!(
                    "offset={offset} count={count} crash_ms={crash_ms} salvage={salvage}: \
                     tally diverged from oracle (nacc {} vs {})",
                    tally.nacc, oracle.nacc
                ));
            }
            if run.engine.pairs_executed() != m.ep_pairs_executed {
                return Outcome::Fail(format!(
                    "engine executed {} but metrics counted {}",
                    run.engine.pairs_executed(),
                    m.ep_pairs_executed
                ));
            }
            if salvage {
                expect(
                    m.ep_pairs_executed == count,
                    &format!(
                        "salvage re-executed pairs: executed {} != logical {count} \
                         (offset={offset} crash_ms={crash_ms})",
                        m.ep_pairs_executed
                    ),
                )
            } else {
                expect(
                    m.ep_pairs_executed >= count,
                    &format!(
                        "executed {} < logical {count} — pairs went missing \
                         (offset={offset} crash_ms={crash_ms})",
                        m.ep_pairs_executed
                    ),
                )
            }
        });
    }
}
