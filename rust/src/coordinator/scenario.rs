//! Event-driven scenario runner: job traces + monitor sweeps + watchdog
//! polls + fault injection, all on the DES engine.
//!
//! This is where the paper's §2.6 feedback loop actually closes: the
//! 5-minute server pinger marks nodes on/off, the client watchdog asks the
//! status service and restarts dead VMs, pbs_server requeues the jobs that
//! were running there (the §4 script-folder technique), and the scheduler
//! re-places them once nodes return.

use super::gridlan::Gridlan;
use super::metrics::Metrics;
use crate::host::faults::{FaultKind, FaultPlan};
use crate::host::watchdog::{Watchdog, WatchdogAction};
use crate::rm::job::JobId;
use crate::rm::mom::Mom;
use crate::rm::queue::NodePool;
use crate::rm::script::PbsScript;
use crate::runtime::engine::EpEngine;
use crate::sim::clock::{SimTime, DUR_SEC};
use crate::sim::Simulator;
use crate::vm::node::NodeState;
use crate::workload::ep::{EpClass, EpJob, EpSlice, EpTally};
use crate::workload::trace::TraceJob;
use std::collections::BTreeMap;

/// Reference core rate used to normalize trace job compute times
/// (Mpairs/s; a mid-range Table-1 core).
const REF_RATE_MPAIRS: f64 = 15.0;

/// Scenario parameters.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub horizon: SimTime,
    /// Scheduler cycle period (Torque's scheduler iteration).
    pub sched_period: SimTime,
    pub faults: FaultPlan,
}

impl Default for Scenario {
    fn default() -> Self {
        Self {
            horizon: 12 * 3600 * DUR_SEC,
            sched_period: 10 * DUR_SEC,
            faults: FaultPlan::none(),
        }
    }
}

/// What a run produced.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub metrics: Metrics,
    pub events_executed: u64,
    pub final_time: SimTime,
}

struct World {
    g: Gridlan,
    m: Metrics,
    watchdogs: BTreeMap<String, Watchdog>,
    /// Per-job start generation guard for completion events.
    started_gen: BTreeMap<JobId, SimTime>,
}

/// Run a trace of jobs through the Gridlan under a fault plan.
/// Nodes boot event-driven at t=0; jobs are submitted at their trace
/// times; the run ends when the horizon passes AND the queue drains (or a
/// hard cap of 4x horizon).
pub fn run_trace(mut g: Gridlan, trace: Vec<TraceJob>, scenario: &Scenario) -> ScenarioReport {
    let mut sim: Simulator<World> = Simulator::new();
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();

    // --- initial boots (event-driven: node comes up after its plan).
    for name in &names {
        g.connect_client(name).expect("provisioned");
        let plan = g.boot_plan(name);
        let total = plan.total();
        g.nodes.get_mut(name).unwrap().advance(NodeState::PoweringOn, 0);
        let n = name.clone();
        sim.schedule_at(total, move |_s, w: &mut World| {
            node_up(w, &n, 0);
        });
    }

    let watchdogs = names.iter().map(|n| (n.clone(), Watchdog::new(n))).collect();
    let mut world = World { g, m: Metrics::default(), watchdogs, started_gen: BTreeMap::new() };

    // --- job submissions.
    for (i, tj) in trace.iter().enumerate() {
        let tj = tj.clone();
        world.m.jobs_submitted += 1;
        sim.schedule_at(tj.at, move |s, w: &mut World| {
            submit(s, w, &tj, i);
        });
    }

    // --- periodic machinery.
    let period = scenario.sched_period;
    sim.schedule_at(period, move |s, w| sched_tick(s, w, period));
    sim.schedule_at(300 * DUR_SEC, monitor_sweep);
    for (i, name) in names.iter().enumerate() {
        let n = name.clone();
        // Stagger watchdogs so they don't all fire in one instant.
        sim.schedule_at(300 * DUR_SEC + (i as u64 + 1) * DUR_SEC, move |s, w| {
            watchdog_poll(s, w, &n);
        });
    }

    // --- faults.
    let mut frng = world.g.rng.fork();
    for ev in scenario.faults.generate(&names, scenario.horizon, &mut frng) {
        world.m.faults += 1;
        sim.schedule_at(ev.at, move |s, w: &mut World| {
            apply_fault(s, w, &ev.client, ev.kind, ev.outage);
        });
    }

    // --- run: until horizon, then drain (cap at 4x horizon).
    sim.run_until(&mut world, scenario.horizon);
    let cap = scenario.horizon.saturating_mul(4);
    while world.g.pbs.jobs().any(|j| !matches!(j.state, crate::rm::job::JobState::Completed))
        && sim.now() < cap
    {
        if !sim.step(&mut world) {
            break;
        }
    }
    ScenarioReport {
        metrics: world.m,
        events_executed: sim.executed(),
        final_time: sim.now(),
    }
}

// ------------------------------------------------------ real EP compute

/// Run a set of EP slices as single-core jobs through the resource
/// manager, executing each slice's pair range for REAL on the engine's
/// [`crate::runtime::backend::ComputeBackend`].  The grid must be booted
/// (`Gridlan::boot_all` or a scenario) or the scheduler will stall.
///
/// Slices are submitted with `ep:<offset>:<count>` payloads, scheduled in
/// as many cycles as the pool width requires, executed, and completed —
/// the paper's Fig. 3 scatter protocol with the compute payload attached.
pub fn run_ep_slices(
    g: &mut Gridlan,
    engine: &mut EpEngine,
    slices: &[EpSlice],
    now: SimTime,
) -> Result<EpTally, String> {
    let mut ids = Vec::with_capacity(slices.len());
    for s in slices {
        let script = PbsScript::parse(&format!(
            "#PBS -N ep-slice-{:03}\n#PBS -q gridlan\n#PBS -l nodes=1:ppn=1\n./ep.x\n",
            s.proc
        ))
        .map_err(|e| e.to_string())?;
        let payload = format!("ep:{}:{}", s.pair_offset, s.pair_count);
        let id = g.pbs.qsub(&script, "gridlan", &payload, now).map_err(|e| e.to_string())?;
        ids.push(id);
    }
    let sched = g.scheduler();
    let mut total = EpTally::default();
    let mut done = 0usize;
    let mut t = now;
    while done < ids.len() {
        t += DUR_SEC;
        let started = g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), t);
        if started.is_empty() {
            return Err(format!(
                "scheduler stalled with {} of {} slices unplaced (is the grid booted?)",
                ids.len() - done,
                ids.len()
            ));
        }
        for (id, _alloc) in started {
            let payload = g.pbs.job(id).ok_or("scheduled job vanished")?.payload.clone();
            let (offset, count) =
                parse_pair_range(&payload).ok_or_else(|| format!("bad payload '{payload}'"))?;
            total.merge(&engine.run_pairs(offset, count)?);
            t += DUR_SEC;
            g.pbs.complete(id, 0, t);
            done += 1;
        }
    }
    Ok(total)
}

/// [`run_ep_slices`] for a whole NPB class split `n_procs` ways (the
/// Fig. 3 protocol: class S over 26 single-core processes).
pub fn run_ep_job(
    g: &mut Gridlan,
    engine: &mut EpEngine,
    class: EpClass,
    n_procs: u32,
    now: SimTime,
) -> Result<EpTally, String> {
    run_ep_slices(g, engine, &EpJob::new(class, n_procs).slices(), now)
}

/// Parse an `ep:<offset>:<count>` / `mc:...` / `sweep:...` payload into
/// its pair range.
pub fn parse_pair_range(payload: &str) -> Option<(u64, u64)> {
    let mut parts = payload.split(':');
    let _tag = parts.next()?;
    let offset = parts.next()?.parse().ok()?;
    let count = parts.next()?.parse().ok()?;
    if parts.next().is_some() {
        return None;
    }
    Some((offset, count))
}

// ---------------------------------------------------------------- events

fn node_up(w: &mut World, name: &str, _gen: u64) {
    let node = w.g.nodes.get_mut(name).unwrap();
    if node.state == NodeState::Up || node.state == NodeState::Off {
        return; // crashed-then-recovered races resolve harmlessly
    }
    // Jump through remaining boot states (plan time already elapsed).
    use NodeState::*;
    while node.state != Up {
        let next = match node.state {
            PoweringOn => Dhcp,
            Dhcp => Tftp,
            Tftp => NfsMount,
            NfsMount => Up,
            Crashed | Off | Up => break,
        };
        let t = node.history.last().map(|&(_, t)| t).unwrap_or(0);
        node.advance(next, t);
    }
    w.g.pbs.node_up(name);
}

fn submit(sim: &mut Simulator<World>, w: &mut World, tj: &TraceJob, i: usize) {
    let script = PbsScript {
        name: Some(format!("trace-{i:04}")),
        queue: Some("gridlan".into()),
        request: tj.request,
        walltime: Some(tj.walltime),
        commands: vec!["./work.x".into()],
    };
    let payload = format!("trace:{}", tj.compute);
    match w.g.pbs.qsub(&script, &tj.owner, &payload, sim.now()) {
        Ok(id) => {
            w.g.folder.register(&mut w.g.server_fs, id, &script);
            // Nudge the scheduler.
            sim.schedule_in(DUR_SEC, |s, w| run_sched(s, w));
        }
        Err(_) => {
            w.m.jobs_killed += 1; // rejected at submission
        }
    }
}

fn sched_tick(sim: &mut Simulator<World>, w: &mut World, period: SimTime) {
    run_sched(sim, w);
    sim.schedule_in(period, move |s, w| sched_tick(s, w, period));
}

fn run_sched(sim: &mut Simulator<World>, w: &mut World) {
    let scheduler = w.g.scheduler();
    let now = sim.now();
    let decisions = w.g.pbs.schedule_cycle(NodePool::Gridlan, scheduler.as_ref(), now);
    for (id, alloc) in decisions {
        // Duration: trace compute normalized by the slowest allocated
        // client (Turbo + hypervisor), plus MOM prologue/epilogue.
        let compute: SimTime = w
            .g
            .pbs
            .job(id)
            .and_then(|j| j.payload.strip_prefix("trace:").and_then(|c| c.parse().ok()))
            .unwrap_or(60 * DUR_SEC);
        let mut worst_factor: f64 = 0.0;
        for (node, cores) in &alloc.cores {
            let busy = w.g.pbs.node(node).map(|n| n.busy_cores).unwrap_or(*cores);
            let rate = w.g.client(node).map(|c| c.guest_ep_rate(busy)).unwrap_or(REF_RATE_MPAIRS);
            worst_factor = worst_factor.max(REF_RATE_MPAIRS / rate);
        }
        let duration = Mom::wrap_runtime((compute as f64 * worst_factor.max(0.1)) as SimTime);
        w.started_gen.insert(id, now);
        sim.schedule_in(duration, move |s, w| job_done(s, w, id, now));
    }
}

fn job_done(sim: &mut Simulator<World>, w: &mut World, id: JobId, started: SimTime) {
    // Stale completion (job was requeued since): ignore.
    if w.started_gen.get(&id) != Some(&started) {
        return;
    }
    let Some(job) = w.g.pbs.job(id) else { return };
    if job.state != crate::rm::job::JobState::Running || job.started_at != Some(started) {
        return;
    }
    let cores = job.allocation.as_ref().map(|a| a.total_cores()).unwrap_or(0);
    let wait = job.wait_time().unwrap_or(0);
    w.g.pbs.complete(id, 0, sim.now());
    w.g.folder.job_completed(&mut w.g.server_fs, id);
    w.m.jobs_completed += 1;
    w.m.total_wait += wait;
    w.m.core_secs_useful += cores as f64 * (sim.now() - started) as f64 / 1e9;
    w.m.makespan = w.m.makespan.max(sim.now());
    sim.schedule_in(DUR_SEC, |s, w| run_sched(s, w));
}

fn monitor_sweep(sim: &mut Simulator<World>, w: &mut World) {
    let now = sim.now();
    // A node answers if its VM is Up, the tunnel is connected, and the
    // client has power.
    let mut responding = Vec::new();
    for c in &w.g.clients {
        let node_up = w.g.nodes.get(&c.name).map(|n| n.state.is_running()).unwrap_or(false);
        if c.powered && w.g.hub.is_connected(&c.name) && node_up {
            responding.push(c.name.clone());
        }
    }
    w.g.pinger.sweep(now, |n| responding.iter().any(|r| r == n));
    sim.schedule_in(300 * DUR_SEC, monitor_sweep);
}

fn watchdog_poll(sim: &mut Simulator<World>, w: &mut World, name: &str) {
    let now = sim.now();
    let powered = w.g.client(name).map(|c| c.powered).unwrap_or(false);
    let reachable = powered && w.g.hub.is_connected(name);
    let node_on = if reachable { w.g.status.is_node_on(&w.g.pinger, name) } else { None };
    let action = w.watchdogs.get_mut(name).unwrap().poll(now, reachable, node_on);
    match action {
        WatchdogAction::RestartVm if powered => {
            let node = w.g.nodes.get_mut(name).unwrap();
            if matches!(node.state, NodeState::Crashed | NodeState::Off) {
                node.advance(NodeState::PoweringOn, now);
                w.m.watchdog_restarts += 1;
                let plan = w.g.boot_plan(name);
                let n = name.to_string();
                sim.schedule_in(plan.total(), move |_s, w| node_up(w, &n, 0));
            }
        }
        WatchdogAction::ReconnectVpn if powered => {
            let _ = w.g.connect_client(name);
        }
        _ => {}
    }
    let n = name.to_string();
    sim.schedule_in(300 * DUR_SEC, move |s, w| watchdog_poll(s, w, &n));
}

fn apply_fault(
    sim: &mut Simulator<World>,
    w: &mut World,
    client: &str,
    kind: FaultKind,
    outage: SimTime,
) {
    let now = sim.now();
    // Account wasted work + requeue running jobs on this node.
    let waste_and_requeue = |w: &mut World, now: SimTime| {
        // Capture wasted core-seconds before node_down clears started_at.
        let wasted: f64 = w
            .g
            .pbs
            .jobs()
            .filter(|j| {
                j.state == crate::rm::job::JobState::Running
                    && j.allocation.as_ref().map(|a| a.cores.contains_key(client)).unwrap_or(false)
            })
            .map(|j| {
                let cores = j.allocation.as_ref().map(|a| a.total_cores()).unwrap_or(0);
                cores as f64 * (now.saturating_sub(j.started_at.unwrap_or(now))) as f64 / 1e9
            })
            .sum();
        let victims = w.g.pbs.node_down(client, now);
        for id in &victims {
            w.m.jobs_requeued += 1;
            w.started_gen.remove(id);
        }
        w.m.core_secs_wasted += wasted;
        victims.len()
    };
    match kind {
        FaultKind::ClientPowerOff => {
            if let Some(c) = w.g.clients.iter_mut().find(|c| c.name == client) {
                if !c.powered {
                    return; // already down
                }
                c.powered = false;
                c.vpn_connected = false;
            }
            w.g.hub.disconnect(client);
            let node = w.g.nodes.get_mut(client).unwrap();
            if node.state != NodeState::Off {
                node.advance(NodeState::Off, now);
            }
            waste_and_requeue(w, now);
            // Owner turns it back on after the outage; VM boots again.
            let c = client.to_string();
            sim.schedule_in(outage, move |s, w: &mut World| {
                if let Some(cl) = w.g.clients.iter_mut().find(|cl| cl.name == c) {
                    cl.powered = true;
                }
                let _ = w.g.connect_client(&c);
                let node = w.g.nodes.get_mut(&c).unwrap();
                if node.state == NodeState::Off {
                    node.advance(NodeState::PoweringOn, s.now());
                    let plan = w.g.boot_plan(&c);
                    let c2 = c.clone();
                    s.schedule_in(plan.total(), move |_s, w| node_up(w, &c2, 0));
                }
            });
        }
        FaultKind::NetworkDrop => {
            w.g.hub.disconnect(client);
            if let Some(c) = w.g.clients.iter_mut().find(|c| c.name == client) {
                c.vpn_connected = false;
            }
            waste_and_requeue(w, now);
            let c = client.to_string();
            sim.schedule_in(outage, move |s, w: &mut World| {
                let _ = w.g.connect_client(&c);
                // Node was running all along; RM can use it again.
                if w.g.nodes.get(&c).map(|n| n.state.is_running()).unwrap_or(false) {
                    w.g.pbs.node_up(&c);
                }
                let _ = s;
            });
        }
        FaultKind::VmCrash => {
            let node = w.g.nodes.get_mut(client).unwrap();
            if !matches!(node.state, NodeState::Off | NodeState::Crashed) {
                node.advance(NodeState::Crashed, now);
            }
            waste_and_requeue(w, now);
            // Recovery path: monitor marks Off; watchdog restarts the VM.
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::rm::alloc::ResourceRequest;

    fn quick_trace(n: usize, cores: u32, compute_secs: u64) -> Vec<TraceJob> {
        (0..n)
            .map(|i| TraceJob {
                at: (i as u64) * DUR_SEC,
                owner: "u".into(),
                request: ResourceRequest { nodes: 1, ppn: cores },
                compute: compute_secs * DUR_SEC,
                walltime: compute_secs * 3 * DUR_SEC,
            })
            .collect()
    }

    #[test]
    fn clean_run_completes_all_jobs() {
        let g = Gridlan::build(Config::table1());
        let scenario = Scenario { horizon: 2 * 3600 * DUR_SEC, ..Default::default() };
        let report = run_trace(g, quick_trace(10, 2, 120), &scenario);
        assert_eq!(report.metrics.jobs_completed, 10);
        assert_eq!(report.metrics.jobs_requeued, 0);
        assert!(report.metrics.goodput() > 0.999);
        assert!(report.metrics.makespan > 0);
    }

    #[test]
    fn jobs_wait_for_boot() {
        // Submitted at t=1s, but nodes take minutes to PXE-boot: the first
        // completion must come after the fastest boot.
        let mut g = Gridlan::build(Config::table1());
        let boot_min = {
            let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
            names
                .iter()
                .map(|n| {
                    g.connect_client(n).unwrap();
                    let t = g.boot_plan(n).total();
                    g.hub.disconnect(n);
                    t
                })
                .min()
                .unwrap()
        };
        let g = Gridlan::build(Config::table1());
        let scenario = Scenario { horizon: 3600 * DUR_SEC, ..Default::default() };
        let report = run_trace(g, quick_trace(1, 1, 10), &scenario);
        assert_eq!(report.metrics.jobs_completed, 1);
        assert!(
            report.metrics.makespan > boot_min,
            "makespan {} <= boot {}",
            report.metrics.makespan,
            boot_min
        );
    }

    #[test]
    fn faulty_run_requeues_and_recovers() {
        let g = Gridlan::build(Config::table1());
        // Heavy faults: power-offs every ~20 min per client.
        let faults = FaultPlan {
            mtbf_power_off: 1200 * DUR_SEC,
            mtbf_net_drop: 0,
            mtbf_vm_crash: 0,
            mean_outage: 300 * DUR_SEC,
        };
        let scenario =
            Scenario { horizon: 4 * 3600 * DUR_SEC, faults, ..Default::default() };
        // Long jobs so faults hit running work.
        let report = run_trace(g, quick_trace(12, 4, 900), &scenario);
        assert!(report.metrics.faults > 0, "no faults injected");
        assert!(report.metrics.jobs_requeued > 0, "faults never hit a running job");
        // The resilience machinery must still finish everything.
        assert_eq!(report.metrics.jobs_completed, 12, "{:?}", report.metrics);
        assert!(report.metrics.goodput() < 1.0);
    }

    #[test]
    fn vm_crash_recovered_by_watchdog() {
        let g = Gridlan::build(Config::table1());
        let faults = FaultPlan {
            mtbf_power_off: 0,
            mtbf_net_drop: 0,
            mtbf_vm_crash: 1800 * DUR_SEC,
            mean_outage: 60 * DUR_SEC,
        };
        let scenario = Scenario { horizon: 6 * 3600 * DUR_SEC, faults, ..Default::default() };
        let report = run_trace(g, quick_trace(8, 2, 600), &scenario);
        assert!(report.metrics.faults > 0);
        assert!(report.metrics.watchdog_restarts > 0, "watchdog never fired");
        assert_eq!(report.metrics.jobs_completed, 8);
    }

    #[test]
    fn ep_slices_through_rm_match_the_oracle() {
        // Real compute through qsub -> schedule -> backend -> complete:
        // the merged tally equals the scalar oracle over the union range.
        let mut g = Gridlan::build(Config::table1());
        g.boot_all(0);
        let mut engine = EpEngine::scalar();
        let slices: Vec<EpSlice> = (0..4)
            .map(|i| EpSlice { proc: i, pair_offset: i as u64 * 50_000, pair_count: 50_000 })
            .collect();
        let total = run_ep_slices(&mut g, &mut engine, &slices, 0).unwrap();
        let oracle = crate::workload::ep::ep_scalar(0, 200_000);
        assert_eq!(total.nacc, oracle.nacc);
        assert_eq!(total.q, oracle.q);
        assert!((total.sx - oracle.sx).abs() < 1e-7);
        assert_eq!(engine.pairs_executed(), 200_000);
        // Every slice ran to successful completion in the RM.
        assert_eq!(g.pbs.jobs().filter(|j| j.succeeded()).count(), 4);
    }

    #[test]
    fn ep_job_wider_than_the_pool_still_completes() {
        // 40 single-core slices on a 26-core pool: needs multiple
        // scheduling cycles; the merge must still be exact.
        let mut g = Gridlan::build(Config::table1());
        g.boot_all(0);
        let mut engine = EpEngine::scalar();
        let slices: Vec<EpSlice> = (0..40)
            .map(|i| EpSlice { proc: i, pair_offset: i as u64 * 4_096, pair_count: 4_096 })
            .collect();
        let total = run_ep_slices(&mut g, &mut engine, &slices, 0).unwrap();
        let oracle = crate::workload::ep::ep_scalar(0, 40 * 4_096);
        assert_eq!(total.nacc, oracle.nacc);
        assert_eq!(total.pairs, 40 * 4_096);
    }

    #[test]
    fn unbooted_grid_reports_a_stall() {
        let mut g = Gridlan::build(Config::table1());
        let mut engine = EpEngine::scalar();
        let slices = [EpSlice { proc: 0, pair_offset: 0, pair_count: 1024 }];
        let err = run_ep_slices(&mut g, &mut engine, &slices, 0).unwrap_err();
        assert!(err.contains("stalled"), "{err}");
    }

    #[test]
    fn pair_range_payloads_parse() {
        assert_eq!(parse_pair_range("ep:0:1024"), Some((0, 1024)));
        assert_eq!(parse_pair_range("mc:65536:131072"), Some((65536, 131072)));
        assert_eq!(parse_pair_range("sweep:10:20"), Some((10, 20)));
        assert_eq!(parse_pair_range("trace:5"), None);
        assert_eq!(parse_pair_range("ep:1:2:3"), None);
        assert_eq!(parse_pair_range("ep:x:2"), None);
    }

    #[test]
    fn deterministic_reports() {
        let s = Scenario { horizon: 3600 * DUR_SEC, ..Default::default() };
        let r1 = run_trace(Gridlan::build(Config::table1()), quick_trace(5, 2, 60), &s);
        let r2 = run_trace(Gridlan::build(Config::table1()), quick_trace(5, 2, 60), &s);
        assert_eq!(r1.metrics, r2.metrics);
        assert_eq!(r1.events_executed, r2.events_executed);
    }
}
