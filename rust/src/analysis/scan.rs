//! Comment/string-aware Rust source scanner.
//!
//! The rule engine must never fire on text inside a comment, a string
//! literal, or a char literal — otherwise the lint's own fixtures (and any
//! doc sentence mentioning `HashMap`) would light up.  This pass walks the
//! source once with a small state machine and produces a *blanked* copy:
//! byte-for-byte the same line structure, but every comment, string, and
//! char literal replaced by spaces.  Pattern rules then match on the
//! blanked text with plain substring search.
//!
//! The same pass extracts suppression pragmas from line comments:
//!
//! ```text
//! // lint:allow(rule-name): reason the exception is legitimate
//! ```
//!
//! A pragma suppresses findings for `rule-name` on its own line and on the
//! line directly below it.  Pragmas are only recognized in `//` line
//! comments (not block comments), and the reason clause is mandatory —
//! [`crate::analysis::rules`] rejects reasonless or unknown-rule pragmas
//! and flags pragmas that suppressed nothing as stale.

/// One `lint:allow` pragma as written in the source.
#[derive(Debug, Clone, PartialEq)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// The rule name between the parentheses (not validated here).
    pub rule: String,
    /// The free-text justification after the closing `):` (may be empty —
    /// the rule engine treats an empty reason as a violation).
    pub reason: String,
}

/// One scanned source file: blanked code lines plus extracted pragmas.
#[derive(Debug, Clone)]
pub struct ScannedFile {
    /// Display path (normalized to forward slashes).
    pub path: String,
    /// Source lines with comments/strings/chars blanked to spaces.
    /// Line `code[i]` corresponds to source line `i + 1`.
    pub code: Vec<String>,
    /// Pragmas in source order.
    pub pragmas: Vec<Pragma>,
}

/// Lexer state for the blanking pass.
enum State {
    Code,
    LineComment,
    /// Nested block comments: the value is the nesting depth.
    BlockComment(u32),
    /// Ordinary string literal (escapes honored).
    Str,
    /// Raw string literal terminated by `"` plus this many `#`s.
    RawStr(u32),
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Scan one source file.  `path` is used for display only.
pub fn scan_source(path: &str, text: &str) -> ScannedFile {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(text.len());
    let mut pragmas: Vec<Pragma> = Vec::new();
    let mut comment_buf = String::new();
    let mut line = 1usize;
    let mut state = State::Code;
    let mut i = 0usize;

    // Push a blanked char: newlines survive (line structure is the whole
    // point), everything else becomes a space.
    fn blank(out: &mut String, c: char) {
        out.push(if c == '\n' { '\n' } else { ' ' });
    }

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    state = State::LineComment;
                    comment_buf.clear();
                    blank(&mut out, c);
                    blank(&mut out, '/');
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(1);
                    blank(&mut out, c);
                    blank(&mut out, '*');
                    i += 2;
                } else if c == '"' {
                    state = State::Str;
                    blank(&mut out, c);
                    i += 1;
                } else if (c == 'r' || c == 'b')
                    && (i == 0 || !is_ident(chars[i - 1]))
                    && raw_string_hashes(&chars, i).is_some()
                {
                    // r"...", r#"..."#, br#"..."# — blank the prefix and
                    // enter the raw string after its opening quote.
                    let (hashes, body_start) = raw_string_hashes(&chars, i).expect("checked");
                    for &pc in &chars[i..body_start] {
                        blank(&mut out, pc);
                    }
                    state = State::RawStr(hashes);
                    i = body_start;
                } else if c == 'b'
                    && (i == 0 || !is_ident(chars[i - 1]))
                    && next == Some('\'')
                {
                    // Byte char literal b'x': blank the b and let the '
                    // branch below consume the literal on the next round.
                    blank(&mut out, c);
                    i += 1;
                } else if c == '\'' {
                    // Char literal or lifetime.  `'\...'` and `'x'` are
                    // literals; anything else (`'a` in `<'a>`) is a
                    // lifetime and stays as code.
                    if next == Some('\\') {
                        blank(&mut out, c);
                        i += 1;
                        // Skip the escape sequence up to the closing quote.
                        while i < n {
                            let e = chars[i];
                            if e == '\n' {
                                line += 1;
                            }
                            blank(&mut out, e);
                            if e == '\\' && i + 1 < n {
                                blank(&mut out, chars[i + 1]);
                                i += 2;
                                continue;
                            }
                            i += 1;
                            if e == '\'' {
                                break;
                            }
                        }
                    } else if next.is_some() && chars.get(i + 2).copied() == Some('\'') {
                        blank(&mut out, c);
                        blank(&mut out, chars[i + 1]);
                        blank(&mut out, '\'');
                        i += 3;
                    } else {
                        out.push(c); // lifetime tick — harmless as code
                        i += 1;
                    }
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                if c == '\n' {
                    if let Some(p) = parse_pragma(&comment_buf, line - 1) {
                        pragmas.push(p);
                    }
                    out.push('\n');
                    state = State::Code;
                } else {
                    comment_buf.push(c);
                    blank(&mut out, c);
                }
                i += 1;
            }
            State::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    blank(&mut out, c);
                    blank(&mut out, '/');
                    i += 2;
                    state = if depth <= 1 { State::Code } else { State::BlockComment(depth - 1) };
                } else if c == '/' && next == Some('*') {
                    blank(&mut out, c);
                    blank(&mut out, '*');
                    i += 2;
                    state = State::BlockComment(depth + 1);
                } else {
                    blank(&mut out, c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' && i + 1 < n {
                    if chars[i + 1] == '\n' {
                        line += 1;
                    }
                    blank(&mut out, c);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                } else {
                    blank(&mut out, c);
                    i += 1;
                    if c == '"' {
                        state = State::Code;
                    }
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && closes_raw(&chars, i, hashes) {
                    blank(&mut out, c);
                    for k in 0..hashes as usize {
                        blank(&mut out, chars[i + 1 + k]);
                    }
                    i += 1 + hashes as usize;
                    state = State::Code;
                } else {
                    blank(&mut out, c);
                    i += 1;
                }
            }
        }
    }
    // Pragma on the file's last line (no trailing newline).
    if let State::LineComment = state {
        if let Some(p) = parse_pragma(&comment_buf, line) {
            pragmas.push(p);
        }
    }

    ScannedFile {
        path: path.replace('\\', "/"),
        code: out.split('\n').map(str::to_string).collect(),
        pragmas,
    }
}

/// If `chars[i..]` starts a raw string prefix (`r`, `br`, `r#`, `br##`...
/// followed by `"`), return (hash count, index of the first body char).
fn raw_string_hashes(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// Does the `"` at `chars[i]` close a raw string with `hashes` hashes?
fn closes_raw(chars: &[char], i: usize, hashes: u32) -> bool {
    (0..hashes as usize).all(|k| chars.get(i + 1 + k) == Some(&'#'))
}

/// Parse `lint:allow(rule): reason` out of one line comment's text.
fn parse_pragma(comment: &str, line: usize) -> Option<Pragma> {
    let start = comment.find("lint:allow(")?;
    let rest = &comment[start + "lint:allow(".len()..];
    let close = rest.find(')')?;
    let rule = rest[..close].trim().to_string();
    let after = rest[close + 1..].trim_start();
    let reason = after.strip_prefix(':').map(|r| r.trim().to_string()).unwrap_or_default();
    Some(Pragma { line, rule, reason })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(text: &str) -> Vec<String> {
        scan_source("t.rs", text).code
    }

    #[test]
    fn line_comments_are_blanked() {
        let code = code_of("let x = 1; // HashMap in a comment\nlet y = 2;\n");
        assert!(code[0].contains("let x = 1;"));
        assert!(!code[0].contains("HashMap"));
        assert!(code[1].contains("let y = 2;"));
    }

    #[test]
    fn block_comments_nest_and_preserve_lines() {
        let code = code_of("a /* one /* two */ still comment */ b\n/* multi\nline */ c\n");
        assert!(code[0].starts_with('a'));
        assert!(code[0].ends_with('b'));
        assert!(!code[0].contains("comment"));
        assert!(!code[1].contains("multi"));
        assert!(code[2].contains('c'));
    }

    #[test]
    fn strings_are_blanked_with_escapes() {
        let code = code_of(r#"let s = "HashMap \" still string"; let t = 1;"#);
        assert!(!code[0].contains("HashMap"));
        assert!(!code[0].contains("still"));
        assert!(code[0].contains("let t = 1;"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let text = "let s = r#\"Instant::now() \" not closed \"#; let u = 2;\n";
        let code = code_of(text);
        assert!(!code[0].contains("Instant"));
        assert!(code[0].contains("let u = 2;"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let code = code_of("let c = 'x'; let nl = '\\n'; fn f<'a>(v: &'a str) {}");
        assert!(!code[0].contains('x'), "{}", code[0]);
        assert!(code[0].contains("fn f<'a>(v: &'a str)"));
    }

    #[test]
    fn identifier_ending_in_r_is_not_raw_string() {
        let code = code_of("let var = other\"x\";\n");
        assert!(code[0].contains("let var = other"));
        assert!(!code[0].contains('x'));
    }

    #[test]
    fn pragma_is_extracted_with_rule_and_reason() {
        let f = scan_source("t.rs", "x();\n// lint:allow(wall-clock): CLI timer\ny();\n");
        assert_eq!(f.pragmas.len(), 1);
        assert_eq!(f.pragmas[0].line, 2);
        assert_eq!(f.pragmas[0].rule, "wall-clock");
        assert_eq!(f.pragmas[0].reason, "CLI timer");
    }

    #[test]
    fn trailing_pragma_on_code_line_and_missing_reason() {
        let f = scan_source(
            "t.rs",
            "foo(); // lint:allow(thread-spawn): worker pool\nbar(); // lint:allow(sleep)\n",
        );
        assert_eq!(f.pragmas.len(), 2);
        assert_eq!(f.pragmas[0].line, 1);
        assert_eq!(f.pragmas[0].rule, "thread-spawn");
        assert_eq!(f.pragmas[1].rule, "sleep");
        assert_eq!(f.pragmas[1].reason, "");
    }

    #[test]
    fn pragma_on_last_line_without_newline() {
        let f = scan_source("t.rs", "x();\n// lint:allow(sleep): last line");
        assert_eq!(f.pragmas.len(), 1);
        assert_eq!(f.pragmas[0].line, 2);
    }

    #[test]
    fn line_count_matches_source() {
        let text = "a\nb\n\"two\nline string\"\nc\n";
        let code = code_of(text);
        assert_eq!(code.len(), text.split('\n').count());
        assert!(code[4].contains('c'));
    }
}
