//! The determinism & invariant rule set.
//!
//! Every rule exists to defend one contract: **same-seed scenario runs are
//! bit-identical** (DESIGN.md §6/§9).  Wall-clock reads, hasher-ordered
//! iteration, ambient threads, and unseeded entropy are exactly the ways a
//! Rust codebase silently loses that property; panicking DES handlers and
//! library-side `process::exit` are the ways it loses robustness.
//!
//! Two escape hatches, both explicit and auditable:
//! * a per-rule **file allowlist** for whole files that are host-side by
//!   design (the compute backends time real work; the bench suite reports
//!   wall throughput);
//! * a `// lint:allow(rule): reason` **pragma** for a single legitimate
//!   site.  Pragmas that stop suppressing anything are themselves errors
//!   (`stale-pragma`), so the annotation layer cannot rot.

use super::scan::ScannedFile;

/// Finding severity: `Deny` fails the lint; `Warn` fails only under
/// `--deny-warnings`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Warn,
    Deny,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        }
    }
}

/// One rule violation (or stale/invalid pragma).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    pub message: String,
}

/// A substring-pattern rule over blanked code lines.
struct PatternRule {
    name: &'static str,
    severity: Severity,
    /// Any of these substrings on a code line fires the rule.
    patterns: &'static [&'static str],
    /// Path suffixes the rule does not apply to (host-side by design).
    allow_paths: &'static [&'static str],
    /// Short rationale, embedded in the finding message.
    why: &'static str,
}

/// Rule name for the schedule-closure panic check (special-cased: it needs
/// call-span tracking, not line patterns).
pub const PANIC_IN_HANDLER: &str = "panic-in-handler";
/// Rule name for stale/invalid pragma findings.
pub const STALE_PRAGMA: &str = "stale-pragma";

const PATTERN_RULES: &[PatternRule] = &[
    PatternRule {
        name: "wall-clock",
        severity: Severity::Deny,
        patterns: &["Instant::now", "SystemTime"],
        // Host-side by design: the compute backends time real execution,
        // the bench suite reports wall throughput alongside sim series,
        // and the logger is allowed to stamp host time if it ever wants to.
        allow_paths: &[
            "bench/suite.rs",
            "runtime/backend.rs",
            "runtime/threaded.rs",
            "runtime/pjrt.rs",
            "util/log.rs",
        ],
        why: "wall-clock reads make same-seed runs diverge; sim code must use Simulator::now()",
    },
    PatternRule {
        name: "unordered-collections",
        severity: Severity::Deny,
        patterns: &["HashMap", "HashSet", "hash_map::", "hash_set::"],
        allow_paths: &[],
        why: "iteration order depends on hasher state; use BTreeMap/BTreeSet or sort first",
    },
    PatternRule {
        name: "thread-spawn",
        severity: Severity::Deny,
        patterns: &["thread::spawn", "thread::scope", "thread::Builder"],
        allow_paths: &["runtime/threaded.rs"],
        why: "ambient threads interleave nondeterministically; only the threaded backend may fan out",
    },
    PatternRule {
        name: "ambient-random",
        severity: Severity::Deny,
        patterns: &["RandomState", "thread_rng", "from_entropy", "rand::", "getrandom"],
        allow_paths: &["util/rng.rs"],
        why: "unseeded entropy breaks replay; all randomness must flow from util::rng seeds",
    },
    PatternRule {
        name: "sleep",
        severity: Severity::Deny,
        patterns: &["thread::sleep", "sleep_ms"],
        allow_paths: &[],
        why: "wall-clock waiting has no place in a discrete-event simulation",
    },
    PatternRule {
        name: "process-exit",
        severity: Severity::Deny,
        patterns: &["process::exit", "process::abort"],
        allow_paths: &["main.rs"],
        why: "library code must return errors; only the CLI decides the process exit code",
    },
];

/// Names of every rule a pragma may reference.
pub fn rule_names() -> Vec<&'static str> {
    let mut names: Vec<&'static str> = PATTERN_RULES.iter().map(|r| r.name).collect();
    names.push(PANIC_IN_HANDLER);
    names.push(STALE_PRAGMA);
    names
}

/// Patterns that may not appear inside a DES handler closure (the `Warn`
/// tier): a panicking handler tears down the whole scenario instead of
/// surfacing a job-level failure.  `.expect("reason")` is deliberately NOT
/// flagged — a documented invariant is the sanctioned form.
const HANDLER_PANIC_PATTERNS: &[&str] =
    &["panic!", ".unwrap()", "unreachable!", "todo!", "unimplemented!"];

/// The DES scheduling entry points whose closure arguments count as
/// event-handler scope.
const HANDLER_CALLS: &[&str] = &["schedule_at(", "schedule_in(", "schedule_batch("];

/// Run every rule over one scanned file.  Pragmas on the finding's line or
/// the line above suppress it; each suppression marks the pragma used, and
/// unused/invalid pragmas come back as `stale-pragma` findings.
pub fn check_file(file: &ScannedFile) -> Vec<Finding> {
    let mut raw: Vec<Finding> = Vec::new();

    for rule in PATTERN_RULES {
        if rule.allow_paths.iter().any(|suffix| file.path.ends_with(suffix)) {
            continue;
        }
        for (idx, code) in file.code.iter().enumerate() {
            if let Some(pat) = rule.patterns.iter().find(|p| code.contains(**p)) {
                raw.push(Finding {
                    rule: rule.name,
                    severity: rule.severity,
                    path: file.path.clone(),
                    line: idx + 1,
                    message: format!("`{pat}`: {}", rule.why),
                });
            }
        }
    }

    raw.extend(check_handler_panics(file));

    // Pragma suppression: a pragma covers its own line and the next line.
    let mut used = vec![false; file.pragmas.len()];
    let mut findings: Vec<Finding> = Vec::new();
    for f in raw {
        let suppressed = file.pragmas.iter().enumerate().any(|(pi, p)| {
            let covers = p.rule == f.rule && (p.line == f.line || p.line + 1 == f.line);
            if covers && !p.reason.is_empty() {
                used[pi] = true;
            }
            covers && !p.reason.is_empty()
        });
        if !suppressed {
            findings.push(f);
        }
    }

    // Pragma hygiene: unknown rule, missing reason, or nothing suppressed.
    let known = rule_names();
    for (pi, p) in file.pragmas.iter().enumerate() {
        if !known.contains(&p.rule.as_str()) {
            findings.push(Finding {
                rule: STALE_PRAGMA,
                severity: Severity::Deny,
                path: file.path.clone(),
                line: p.line,
                message: format!(
                    "pragma names unknown rule `{}` (known: {})",
                    p.rule,
                    known.join(", ")
                ),
            });
        } else if p.reason.is_empty() {
            findings.push(Finding {
                rule: STALE_PRAGMA,
                severity: Severity::Deny,
                path: file.path.clone(),
                line: p.line,
                message: format!(
                    "pragma for `{}` has no reason; write `// lint:allow({}): why`",
                    p.rule, p.rule
                ),
            });
        } else if !used[pi] {
            findings.push(Finding {
                rule: STALE_PRAGMA,
                severity: Severity::Deny,
                path: file.path.clone(),
                line: p.line,
                message: format!(
                    "pragma for `{}` suppresses nothing here — delete it",
                    p.rule
                ),
            });
        }
    }

    findings.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    findings
}

/// Find `panic!`/`.unwrap()`-style calls lexically inside the closure
/// argument of `schedule_at(...)` / `schedule_in(...)` /
/// `schedule_batch(...)`.  Tracking is by
/// parenthesis depth from the call's opening paren, so multi-line closures
/// are covered; named handler functions called *from* a closure are not
/// (they are ordinary code and may assert their own invariants).
fn check_handler_panics(file: &ScannedFile) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut depth = 0u32; // 0 = outside any handler call span
    for (idx, code) in file.code.iter().enumerate() {
        let chars: Vec<char> = code.chars().collect();
        let mut i = 0usize;
        let mut span_start: Option<usize> = if depth > 0 { Some(0) } else { None };
        while i < chars.len() {
            if depth == 0 {
                match next_handler_call(&chars, i) {
                    Some(after_open) => {
                        depth = 1;
                        i = after_open;
                        span_start = Some(i);
                    }
                    None => break,
                }
            } else {
                match chars[i] {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            let start = span_start.take().unwrap_or(0);
                            check_span(file, idx, &chars[start..i], &mut findings);
                        }
                    }
                    _ => {}
                }
                i += 1;
            }
        }
        if depth > 0 {
            let start = span_start.unwrap_or(0);
            check_span(file, idx, &chars[start.min(chars.len())..], &mut findings);
        }
    }
    findings
}

/// Earliest handler-call open paren at or after `from`; returns the index
/// just past the `(`.
fn next_handler_call(chars: &[char], from: usize) -> Option<usize> {
    let hay: String = chars[from..].iter().collect();
    let mut best: Option<usize> = None;
    for call in HANDLER_CALLS {
        if let Some(off) = hay.find(call) {
            // `find` returns a byte offset; convert to a char count so the
            // caller's index stays valid on non-ASCII lines.
            let after = from + hay[..off].chars().count() + call.chars().count();
            best = Some(best.map_or(after, |b: usize| b.min(after)));
        }
    }
    best
}

/// Flag panic patterns within one in-span slice of a line.
fn check_span(file: &ScannedFile, line_idx: usize, span: &[char], findings: &mut Vec<Finding>) {
    let text: String = span.iter().collect();
    for pat in HANDLER_PANIC_PATTERNS {
        if text.contains(pat) {
            findings.push(Finding {
                rule: PANIC_IN_HANDLER,
                severity: Severity::Warn,
                path: file.path.clone(),
                line: line_idx + 1,
                message: format!(
                    "`{pat}` inside a DES handler closure: a panicking handler kills the whole \
                     scenario; return/record the failure or use .expect(\"invariant\")"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::scan_source;

    fn findings_for(path: &str, src: &str) -> Vec<Finding> {
        check_file(&scan_source(path, src))
    }

    fn rules_of(fs: &[Finding]) -> Vec<&'static str> {
        fs.iter().map(|f| f.rule).collect()
    }

    #[test]
    fn wall_clock_fires_and_allowlist_exempts() {
        let bad = "fn f() { let t = std::time::Instant::now(); }\n";
        assert_eq!(rules_of(&findings_for("sim/engine.rs", bad)), vec!["wall-clock"]);
        assert!(findings_for("runtime/threaded.rs", bad).is_empty());
        let sys = "use std::time::SystemTime;\n";
        assert_eq!(rules_of(&findings_for("rm/sched.rs", sys)), vec!["wall-clock"]);
    }

    #[test]
    fn wall_clock_silent_on_clean_code() {
        let clean = "fn f(now: u64) -> u64 { now + 1 }\n";
        assert!(findings_for("sim/engine.rs", clean).is_empty());
    }

    #[test]
    fn unordered_collections_fire_everywhere() {
        let bad = "use std::collections::HashMap;\nlet m: HashMap<u32, u32> = HashMap::new();\n";
        let fs = findings_for("rm/sched.rs", bad);
        assert_eq!(rules_of(&fs), vec!["unordered-collections", "unordered-collections"]);
        let clean = "use std::collections::BTreeMap;\nlet h = std::hash::Hash;\n";
        assert!(findings_for("rm/sched.rs", clean).is_empty());
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let src = "// HashMap would be wrong here\nlet s = \"Instant::now\";\n";
        assert!(findings_for("sim/engine.rs", src).is_empty());
    }

    #[test]
    fn thread_spawn_only_in_threaded_backend() {
        let bad = "std::thread::spawn(|| {});\n";
        assert_eq!(rules_of(&findings_for("monitor/pinger.rs", bad)), vec!["thread-spawn"]);
        assert!(findings_for("runtime/threaded.rs", bad).is_empty());
        let scope = "std::thread::scope(|s| {});\n";
        assert_eq!(rules_of(&findings_for("vpn/hub.rs", scope)), vec!["thread-spawn"]);
    }

    #[test]
    fn ambient_random_fires_outside_rng() {
        let bad = "use std::collections::hash_map::RandomState;\n";
        let fs = findings_for("rm/queue.rs", bad);
        // RandomState trips ambient-random; hash_map:: trips the
        // unordered-collections rule too — both are real hazards.
        assert!(fs.iter().any(|f| f.rule == "ambient-random"), "{fs:?}");
        assert!(findings_for("util/rng.rs", bad).is_empty());
    }

    #[test]
    fn sleep_and_process_exit() {
        assert_eq!(
            rules_of(&findings_for("rm/mom.rs", "std::thread::sleep(d);\n")),
            vec!["sleep"]
        );
        assert_eq!(
            rules_of(&findings_for("rm/server.rs", "std::process::exit(1);\n")),
            vec!["process-exit"]
        );
        assert!(findings_for("main.rs", "std::process::exit(1);\n").is_empty());
    }

    #[test]
    fn panic_in_handler_is_warn_tier_and_span_scoped() {
        let bad = "sim.schedule_at(10, move |s, w| {\n    w.jobs.get(&id).unwrap();\n});\n";
        let fs = findings_for("coordinator/scenario.rs", bad);
        assert_eq!(rules_of(&fs), vec![PANIC_IN_HANDLER]);
        assert_eq!(fs[0].severity, Severity::Warn);
        assert_eq!(fs[0].line, 2);
        // The same unwrap outside any handler span is fine.
        let outside = "let x = w.jobs.get(&id).unwrap();\nsim.schedule_at(10, tick);\n";
        assert!(findings_for("coordinator/scenario.rs", outside).is_empty());
        // .expect with a reason is the sanctioned form.
        let expected =
            "sim.schedule_in(5, move |s, w| {\n    w.jobs.get(&id).expect(\"armed above\");\n});\n";
        assert!(findings_for("coordinator/scenario.rs", expected).is_empty());
    }

    #[test]
    fn handler_span_closes_with_parens() {
        // After the call's closing paren the rule stops applying.
        let src = "sim.schedule_at(10, |s, w| w.tick());\nlet y = x.unwrap();\n";
        assert!(findings_for("coordinator/scenario.rs", src).is_empty());
        // panic! in a nested call inside the span still fires.
        let nested = "sim.schedule_at(t, move |s, w| { if bad { panic!(\"no\") } });\n";
        assert_eq!(
            rules_of(&findings_for("coordinator/scenario.rs", nested)),
            vec![PANIC_IN_HANDLER]
        );
    }

    #[test]
    fn pragma_suppresses_same_and_next_line() {
        let same = "let t = Instant::now(); // lint:allow(wall-clock): CLI-facing timer\n";
        assert!(findings_for("main.rs", same).is_empty());
        let above =
            "// lint:allow(wall-clock): CLI-facing timer\nlet t = Instant::now();\n";
        assert!(findings_for("main.rs", above).is_empty());
    }

    #[test]
    fn stale_pragma_is_a_deny_finding() {
        let stale = "// lint:allow(wall-clock): nothing here needs it\nlet x = 1;\n";
        let fs = findings_for("main.rs", stale);
        assert_eq!(rules_of(&fs), vec![STALE_PRAGMA]);
        assert_eq!(fs[0].severity, Severity::Deny);
        assert_eq!(fs[0].line, 1);
    }

    #[test]
    fn reasonless_and_unknown_pragmas_are_rejected() {
        let no_reason = "let t = Instant::now(); // lint:allow(wall-clock)\n";
        let fs = findings_for("main.rs", no_reason);
        // The finding is NOT suppressed and the pragma is flagged.
        assert!(fs.iter().any(|f| f.rule == "wall-clock"));
        assert!(fs.iter().any(|f| f.rule == STALE_PRAGMA));

        let unknown = "// lint:allow(no-such-rule): whatever\nlet x = 1;\n";
        let fs = findings_for("main.rs", unknown);
        assert_eq!(rules_of(&fs), vec![STALE_PRAGMA]);
        assert!(fs[0].message.contains("unknown rule"));
    }

    #[test]
    fn multiline_handler_span_tracks_depth() {
        let src = "sim.schedule_in(delay, move |s, w| {\n    let a = f(1, (2));\n    \
                   w.x.todo_marker();\n    if a { unreachable!() }\n});\nx.unwrap();\n";
        let fs = findings_for("coordinator/scenario.rs", src);
        assert_eq!(rules_of(&fs), vec![PANIC_IN_HANDLER]);
        assert_eq!(fs[0].line, 4);
    }
}
