//! In-tree static analysis: the `gridlan lint` determinism & invariant
//! pass (DESIGN.md §9).
//!
//! Zero-dependency, in the spirit of `util/json.rs`: a comment/string-aware
//! source scanner ([`scan`]) feeds a small rule engine ([`rules`]) that
//! enforces the contracts every replayable artifact in this repo rests on —
//! scenario event logs, `BENCH_*.json` baselines, and the regression gate
//! are only meaningful while same-seed runs stay bit-identical.
//!
//! Entry points: [`lint_paths`] walks `.rs` files under the given roots
//! (deterministic order, `target/` skipped) and returns a [`LintReport`];
//! the CLI front end is `gridlan lint [--format json|human]
//! [--deny-warnings] [PATH...]`, which defaults to scanning `rust/src`.

pub mod rules;
pub mod scan;

pub use rules::{Finding, Severity};

use crate::util::json::{Json, JsonObj};
use std::path::{Path, PathBuf};

/// Outcome of one lint run.
#[derive(Debug, Clone)]
pub struct LintReport {
    /// All unsuppressed findings, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    pub fn errors(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Deny).count()
    }

    pub fn warnings(&self) -> usize {
        self.findings.iter().filter(|f| f.severity == Severity::Warn).count()
    }

    /// Exit code under the CLI contract: deny findings always fail;
    /// warnings fail only when `deny_warnings` is set.
    pub fn exit_code(&self, deny_warnings: bool) -> i32 {
        if self.errors() > 0 || (deny_warnings && self.warnings() > 0) {
            1
        } else {
            0
        }
    }

    /// Compiler-style one-line-per-finding text plus a summary line.
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}: {}:{}: [{}] {}\n",
                f.severity.name(),
                f.path,
                f.line,
                f.rule,
                f.message
            ));
        }
        out.push_str(&format!(
            "lint: {} file(s), {} error(s), {} warning(s)\n",
            self.files_scanned,
            self.errors(),
            self.warnings()
        ));
        out
    }

    /// Machine-readable form (stable key order, deterministic).
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("files_scanned", Json::Num(self.files_scanned as f64));
        o.insert("errors", Json::Num(self.errors() as f64));
        o.insert("warnings", Json::Num(self.warnings() as f64));
        let findings = self
            .findings
            .iter()
            .map(|f| {
                let mut fo = JsonObj::new();
                fo.insert("severity", Json::Str(f.severity.name().to_string()));
                fo.insert("rule", Json::Str(f.rule.to_string()));
                fo.insert("path", Json::Str(f.path.clone()));
                fo.insert("line", Json::Num(f.line as f64));
                fo.insert("message", Json::Str(f.message.clone()));
                Json::Obj(fo)
            })
            .collect();
        o.insert("findings", Json::Arr(findings));
        Json::Obj(o)
    }
}

/// Lint every `.rs` file under the given roots (files are scanned
/// directly; directories are walked recursively, `target/` and hidden
/// directories skipped).  File order — and therefore finding order — is
/// sorted, so output is deterministic across filesystems.
pub fn lint_paths(roots: &[PathBuf]) -> Result<LintReport, String> {
    let mut files: Vec<PathBuf> = Vec::new();
    for root in roots {
        if root.is_dir() {
            collect_rs_files(root, &mut files)?;
        } else if root.is_file() {
            files.push(root.clone());
        } else {
            return Err(format!("lint: no such path: {}", root.display()));
        }
    }
    files.sort();
    files.dedup();

    let mut findings: Vec<Finding> = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("lint: cannot read {}: {e}", path.display()))?;
        let scanned = scan::scan_source(&path.to_string_lossy(), &text);
        findings.extend(rules::check_file(&scanned));
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Ok(LintReport { findings, files_scanned: files.len() })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("lint: cannot read dir {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("lint: {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name().to_string_lossy().to_string();
        if path.is_dir() {
            if name == "target" || name.starts_with('.') {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report_from(snippets: &[(&str, &str)]) -> LintReport {
        let mut findings = Vec::new();
        for (path, src) in snippets {
            findings.extend(rules::check_file(&scan::scan_source(path, src)));
        }
        findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
        LintReport { findings, files_scanned: snippets.len() }
    }

    #[test]
    fn exit_code_contract() {
        let clean = report_from(&[("a.rs", "fn main() {}\n")]);
        assert_eq!(clean.exit_code(false), 0);
        assert_eq!(clean.exit_code(true), 0);

        let warn_only = report_from(&[(
            "coordinator/scenario.rs",
            "sim.schedule_at(1, |s, w| w.x.unwrap());\n",
        )]);
        assert_eq!(warn_only.errors(), 0);
        assert_eq!(warn_only.warnings(), 1);
        assert_eq!(warn_only.exit_code(false), 0);
        assert_eq!(warn_only.exit_code(true), 1);

        let deny = report_from(&[("sim/engine.rs", "let t = Instant::now();\n")]);
        assert_eq!(deny.exit_code(false), 1);
    }

    #[test]
    fn human_render_names_rule_file_line() {
        let r = report_from(&[("sim/engine.rs", "let t = Instant::now();\n")]);
        let text = r.render_human();
        assert!(text.contains("deny: sim/engine.rs:1: [wall-clock]"), "{text}");
        assert!(text.contains("1 error(s), 0 warning(s)"), "{text}");
    }

    #[test]
    fn json_render_is_stable_and_parseable() {
        let r = report_from(&[("sim/engine.rs", "use std::collections::HashMap;\n")]);
        let text = r.to_json().to_string();
        let back = Json::parse(&text).expect("lint JSON parses");
        assert_eq!(back.get("errors").and_then(Json::as_u64), Some(1));
        let findings = back.get("findings").and_then(Json::as_arr).expect("array");
        assert_eq!(findings.len(), 1);
        assert_eq!(
            findings[0].get("rule").and_then(Json::as_str),
            Some("unordered-collections")
        );
        // Deterministic: rendering twice is byte-identical.
        assert_eq!(text, r.to_json().to_string());
    }

    #[test]
    fn findings_sort_by_path_then_line() {
        let r = report_from(&[
            ("b.rs", "let t = Instant::now();\n"),
            ("a.rs", "x;\nlet m: HashSet<u32> = x;\n"),
        ]);
        let keys: Vec<(String, usize)> =
            r.findings.iter().map(|f| (f.path.clone(), f.line)).collect();
        let mut sorted = keys.clone();
        sorted.sort();
        assert_eq!(keys, sorted);
    }
}
