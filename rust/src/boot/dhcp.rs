//! DHCP on the VPN subnet (paper §2.5 step 3–4: "The virtual machine sends
//! the DHCP requests through the VPN's tunnel ... The cluster server
//! responds ... and sends the appropriate files").
//!
//! Lease bookkeeping plus the DORA (Discover/Offer/Request/Ack) timing
//! model: four messages, i.e. two round trips through the tunnel.

use std::collections::BTreeMap;

/// A granted lease.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lease {
    pub mac: String,
    pub ip: String,
    /// Lease duration in seconds (bookkeeping only).
    pub lease_secs: u64,
}

/// The server-side DHCP service bound to the VPN subnet.
#[derive(Debug, Clone)]
pub struct DhcpServer {
    subnet_prefix: String,
    pool_start: u8,
    pool_end: u8,
    next: u8,
    by_mac: BTreeMap<String, Lease>,
    taken: BTreeMap<String, String>, // ip -> mac
}

impl DhcpServer {
    /// Pool `prefix.start ..= prefix.end`, e.g. ("10.8.1", 10, 250).
    pub fn new(subnet_prefix: &str, start: u8, end: u8) -> Self {
        assert!(start <= end);
        Self {
            subnet_prefix: subnet_prefix.to_string(),
            pool_start: start,
            pool_end: end,
            next: start,
            by_mac: BTreeMap::new(),
            taken: BTreeMap::new(),
        }
    }

    /// Full DORA for `mac`. Re-requests return the same lease (DHCP
    /// affinity — nodes keep their address across reboots).
    pub fn dora(&mut self, mac: &str) -> Option<Lease> {
        if let Some(l) = self.by_mac.get(mac) {
            return Some(l.clone());
        }
        // Find a free address starting from `next`.
        let span = (self.pool_end - self.pool_start + 1) as usize;
        for _ in 0..span {
            let candidate = format!("{}.{}", self.subnet_prefix, self.next);
            let cur = self.next;
            self.next = if cur >= self.pool_end { self.pool_start } else { cur + 1 };
            if !self.taken.contains_key(&candidate) {
                let lease = Lease { mac: mac.to_string(), ip: candidate.clone(), lease_secs: 86_400 };
                self.taken.insert(candidate, mac.to_string());
                self.by_mac.insert(mac.to_string(), lease.clone());
                return Some(lease);
            }
        }
        None // pool exhausted
    }

    /// Release a lease (VM destroyed).
    pub fn release(&mut self, mac: &str) {
        if let Some(l) = self.by_mac.remove(mac) {
            self.taken.remove(&l.ip);
        }
    }

    pub fn active_leases(&self) -> usize {
        self.by_mac.len()
    }

    /// DORA wall time given one-way tunnel delay (µs): 4 messages = 2 RTT,
    /// plus server-side processing per exchange.
    pub fn dora_duration_us(one_way_us: f64) -> f64 {
        const SERVER_PROC_US: f64 = 120.0; // lease lookup + config render
        4.0 * one_way_us + 2.0 * SERVER_PROC_US
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn leases_are_unique() {
        let mut d = DhcpServer::new("10.8.1", 10, 20);
        let a = d.dora("aa:00").unwrap();
        let b = d.dora("bb:00").unwrap();
        assert_ne!(a.ip, b.ip);
        assert_eq!(d.active_leases(), 2);
    }

    #[test]
    fn rerequest_returns_same_ip() {
        let mut d = DhcpServer::new("10.8.1", 10, 20);
        let a1 = d.dora("aa:00").unwrap();
        let a2 = d.dora("aa:00").unwrap();
        assert_eq!(a1.ip, a2.ip);
        assert_eq!(d.active_leases(), 1);
    }

    #[test]
    fn pool_exhaustion_returns_none() {
        let mut d = DhcpServer::new("10.8.1", 10, 12);
        assert!(d.dora("a").is_some());
        assert!(d.dora("b").is_some());
        assert!(d.dora("c").is_some());
        assert!(d.dora("d").is_none());
    }

    #[test]
    fn release_recycles_address() {
        let mut d = DhcpServer::new("10.8.1", 10, 10);
        let a = d.dora("a").unwrap();
        assert!(d.dora("b").is_none());
        d.release("a");
        let b = d.dora("b").unwrap();
        assert_eq!(a.ip, b.ip);
    }

    #[test]
    fn dora_timing_is_two_rtts_plus_processing() {
        let t = DhcpServer::dora_duration_us(500.0);
        assert!((t - (2000.0 + 240.0)).abs() < 1e-9);
    }
}
