//! NFSv3 root-filesystem model (paper §2.3 "nfsroot").
//!
//! Unlike TFTP, NFS reads pipeline: the client keeps several READ RPCs in
//! flight (Linux nfsroot default wsize/rsize 32 KiB, up to `slots`
//! concurrent slots), so effective throughput ≈ slots × rsize / RTT,
//! capped by link bandwidth.

use super::fsimage::FsImage;

/// An NFS export backed by a shared [`FsImage`].
#[derive(Debug, Clone)]
pub struct NfsExport {
    pub root: FsImage,
    /// READ/WRITE RPC payload size (bytes).
    pub rsize: u32,
    /// Concurrent RPC slots the client keeps in flight.
    pub slots: u32,
    /// Server-side per-RPC cost, µs.
    pub per_rpc_server_us: f64,
}

impl NfsExport {
    pub fn debian() -> Self {
        Self {
            root: FsImage::debian_nfsroot(),
            rsize: 32 * 1024,
            slots: 16,
            per_rpc_server_us: 35.0,
        }
    }

    /// MOUNT + PORTMAP + FSINFO handshake duration (µs): 3 round trips.
    pub fn mount_duration_us(&self, one_way_us: f64) -> f64 {
        3.0 * (2.0 * one_way_us + self.per_rpc_server_us)
    }

    /// Time (µs) to read `bytes` sequentially with pipelining, given the
    /// per-packet one-way delay and per-byte serialization (µs/byte).
    pub fn read_duration_us(&self, bytes: u64, one_way_us: f64, us_per_byte: f64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let rpcs = (bytes + self.rsize as u64 - 1) / self.rsize as u64;
        let rtt = 2.0 * one_way_us + self.per_rpc_server_us;
        // With `slots` RPCs pipelined, the RTT cost is amortized slots-fold;
        // serialization of the payload is not parallelizable on one link.
        let latency_cost = rpcs as f64 * rtt / self.slots as f64;
        let wire_cost = bytes as f64 * us_per_byte;
        latency_cost.max(wire_cost) + rtt // + first-RPC fill
    }

    /// Boot-time read volume: kernel userland working set, not the whole
    /// image (page cache reads on demand). ~1/3 of the base bundle.
    pub fn boot_read_bytes(&self) -> u64 {
        self.root.du("/") / 3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mount_is_three_round_trips() {
        let nfs = NfsExport::debian();
        let d = nfs.mount_duration_us(500.0);
        assert!((d - 3.0 * (1000.0 + 35.0)).abs() < 1e-9);
    }

    #[test]
    fn pipelining_beats_lockstep() {
        let nfs = NfsExport::debian();
        let bytes = 10_000_000u64;
        // Gigabit serialization (0.008 µs/B) so latency, not wire, is the
        // contended resource.
        let pipelined = nfs.read_duration_us(bytes, 700.0, 0.008);
        // Lock-step equivalent: every RPC pays full RTT.
        let rpcs = (bytes / nfs.rsize as u64 + 1) as f64;
        let lockstep = rpcs * (1400.0 + nfs.per_rpc_server_us);
        assert!(pipelined < lockstep / 4.0, "pipelined={pipelined} lockstep={lockstep}");
    }

    #[test]
    fn zero_read_is_free() {
        assert_eq!(NfsExport::debian().read_duration_us(0, 500.0, 0.1), 0.0);
    }

    #[test]
    fn wire_bandwidth_caps_throughput() {
        let nfs = NfsExport::debian();
        // Very low latency: wire cost dominates.
        let bytes = 100_000_000u64;
        let d = nfs.read_duration_us(bytes, 10.0, 0.08);
        assert!(d >= bytes as f64 * 0.08);
    }

    #[test]
    fn shared_root_install_changes_boot_volume() {
        let mut nfs = NfsExport::debian();
        let before = nfs.boot_read_bytes();
        nfs.root.chroot_install("openfoam", 300_000_000);
        assert!(nfs.boot_read_bytes() > before);
    }
}
