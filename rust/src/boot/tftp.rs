//! TFTP transfer model (paper §3.2: "Transmission of the node kernel
//! through the network: TFTP").
//!
//! TFTP (RFC 1350) is lock-step: every DATA block waits for its ACK, so
//! transfer time ≈ n_blocks × (RTT + per-block serialization + server
//! read).  That makes kernel+initramfs transfer the dominant boot phase
//! over a high-latency tunnel — which is why the boot-storm bench (A3) is
//! interesting and why the paper mentions iPXE/HTTP as an alternative.

/// Classic TFTP block size.
pub const BLKSIZE_DEFAULT: u32 = 512;
/// RFC 2348 negotiated block size typically used by PXE ROMs.
pub const BLKSIZE_PXE: u32 = 1432;

/// Server-side file registry + transfer timing.
#[derive(Debug, Clone)]
pub struct TftpServer {
    files: super::fsimage::FsImage,
    pub blksize: u32,
    /// Per-block server read+send overhead, µs.
    pub per_block_server_us: f64,
}

impl TftpServer {
    pub fn new(blksize: u32) -> Self {
        Self {
            files: super::fsimage::FsImage::tftp_dir(),
            blksize,
            per_block_server_us: 20.0,
        }
    }

    pub fn files(&self) -> &super::fsimage::FsImage {
        &self.files
    }

    pub fn files_mut(&mut self) -> &mut super::fsimage::FsImage {
        &mut self.files
    }

    /// Number of DATA blocks for a file of `bytes` (last block may be
    /// short; an exact multiple still needs a final empty block).
    pub fn n_blocks(&self, bytes: u64) -> u64 {
        bytes / self.blksize as u64 + 1
    }

    /// RRQ transfer duration (µs) for `path`, given per-packet one-way
    /// delay and per-byte serialization cost (µs/byte) on the path.
    /// Returns None for missing files.
    pub fn transfer_duration_us(
        &self,
        path: &str,
        one_way_us: f64,
        us_per_byte: f64,
    ) -> Option<f64> {
        let bytes = self.files.file_size(path)?;
        let blocks = self.n_blocks(bytes) as f64;
        // Each block: server read/send + DATA flight + payload
        // serialization + ACK flight (ACK serialization negligible).
        let per_block =
            self.per_block_server_us + one_way_us + self.blksize as f64 * us_per_byte + one_way_us;
        Some(blocks * per_block)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_count_includes_terminator() {
        let t = TftpServer::new(512);
        assert_eq!(t.n_blocks(0), 1);
        assert_eq!(t.n_blocks(511), 1);
        assert_eq!(t.n_blocks(512), 2); // full block then empty terminator
        assert_eq!(t.n_blocks(1025), 3);
    }

    #[test]
    fn lockstep_dominated_by_rtt() {
        let t = TftpServer::new(512);
        let fast = t.transfer_duration_us("/srv/tftp/vmlinuz", 100.0, 0.01).unwrap();
        let slow = t.transfer_duration_us("/srv/tftp/vmlinuz", 1000.0, 0.01).unwrap();
        // 10x RTT ≈ ~9x transfer time when RTT dominates.
        assert!(slow / fast > 5.0, "slow={slow} fast={fast}");
    }

    #[test]
    fn bigger_blksize_fewer_blocks_faster() {
        let t512 = TftpServer::new(BLKSIZE_DEFAULT);
        let t1432 = TftpServer::new(BLKSIZE_PXE);
        let d512 = t512.transfer_duration_us("/srv/tftp/initrd.img", 600.0, 0.08).unwrap();
        let d1432 = t1432.transfer_duration_us("/srv/tftp/initrd.img", 600.0, 0.08).unwrap();
        assert!(d1432 < d512 * 0.6, "d1432={d1432} d512={d512}");
    }

    #[test]
    fn missing_file_is_none() {
        let t = TftpServer::new(512);
        assert!(t.transfer_duration_us("/srv/tftp/nope", 100.0, 0.01).is_none());
    }

    #[test]
    fn kernel_transfer_over_tunnel_takes_tens_of_seconds() {
        // Sanity against the paper's setup: ~700µs one-way node path,
        // 5.2MB kernel, 512B blocks -> tens of seconds.  (Why PXE boot over
        // WAN-ish latency hurts, and why blksize negotiation matters.)
        let t = TftpServer::new(512);
        let d = t.transfer_duration_us("/srv/tftp/vmlinuz", 700.0, 0.08).unwrap();
        let secs = d / 1e6;
        assert!(secs > 10.0 && secs < 60.0, "secs={secs}");
    }
}
