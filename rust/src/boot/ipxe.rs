//! iPXE/HTTP boot — the paper's §3.2 alternative to TFTP ("An alternative
//! is iPxe, which can be configured to use an HTTP connection"),
//! implemented as an extension and benchmarked in A3.
//!
//! HTTP over TCP streams with a congestion window instead of TFTP's
//! lock-step: after slow start, the transfer is bandwidth-bound rather
//! than RTT-bound — which is exactly why the paper suggests it.

use super::fsimage::FsImage;

/// An HTTP boot file server.
#[derive(Debug, Clone)]
pub struct IpxeServer {
    files: FsImage,
    /// TCP maximum segment size (bytes).
    pub mss: u32,
    /// Initial congestion window (segments, RFC 6928).
    pub init_cwnd: u32,
    /// Per-request server overhead, µs.
    pub per_request_us: f64,
}

impl IpxeServer {
    pub fn new() -> Self {
        Self { files: FsImage::tftp_dir(), mss: 1460, init_cwnd: 10, per_request_us: 400.0 }
    }

    pub fn files(&self) -> &FsImage {
        &self.files
    }

    pub fn files_mut(&mut self) -> &mut FsImage {
        &mut self.files
    }

    /// HTTP GET duration (µs) for `path`: TCP handshake + slow start
    /// until the pipe fills, then bandwidth-bound streaming.
    pub fn transfer_duration_us(
        &self,
        path: &str,
        one_way_us: f64,
        us_per_byte: f64,
    ) -> Option<f64> {
        let bytes = self.files.file_size(path)?;
        let rtt = 2.0 * one_way_us;
        // Handshake (SYN/SYNACK/ACK ~ 1.5 RTT) + request/first byte (1 RTT).
        let mut t = 2.5 * rtt + self.per_request_us;
        // Slow start: cwnd doubles each RTT until the window covers the
        // bandwidth-delay product (or the file ends).
        let bdp_bytes = (rtt / us_per_byte.max(1e-9)).max(self.mss as f64);
        let mut cwnd_bytes = (self.init_cwnd * self.mss) as f64;
        let mut sent = 0.0;
        while sent < bytes as f64 && cwnd_bytes < bdp_bytes {
            sent += cwnd_bytes;
            t += rtt;
            cwnd_bytes *= 2.0;
        }
        // Remainder streams at line rate.
        if sent < bytes as f64 {
            t += (bytes as f64 - sent) * us_per_byte;
        }
        Some(t)
    }
}

impl Default for IpxeServer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boot::tftp::TftpServer;

    #[test]
    fn http_beats_lockstep_tftp_on_high_latency() {
        // The §5 claim quantified: on the Gridlan's ~700 µs one-way node
        // path, HTTP boot is dramatically faster than TFTP 512.
        let ipxe = IpxeServer::new();
        let tftp = TftpServer::new(512);
        let http = ipxe.transfer_duration_us("/srv/tftp/initrd.img", 700.0, 0.008).unwrap();
        let lock = tftp.transfer_duration_us("/srv/tftp/initrd.img", 700.0, 0.008).unwrap();
        assert!(http < lock / 10.0, "http={http} tftp={lock}");
    }

    #[test]
    fn low_latency_converges_to_line_rate() {
        let ipxe = IpxeServer::new();
        let bytes = ipxe.files().file_size("/srv/tftp/initrd.img").unwrap();
        let d = ipxe.transfer_duration_us("/srv/tftp/initrd.img", 20.0, 0.008).unwrap();
        let line = bytes as f64 * 0.008;
        assert!(d < line * 1.3, "d={d} line={line}");
    }

    #[test]
    fn missing_file_none() {
        assert!(IpxeServer::new().transfer_duration_us("/nope", 100.0, 0.01).is_none());
    }

    #[test]
    fn slow_start_visible_on_small_files() {
        // Small file: handshake+slow-start dominated; roughly independent
        // of file size below one window.
        let ipxe = IpxeServer::new();
        let a = ipxe.transfer_duration_us("/srv/tftp/pxelinux.0", 700.0, 0.008).unwrap();
        assert!(a < 10.0 * 1e3 + 5_000.0, "a={a}");
    }
}
