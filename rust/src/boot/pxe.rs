//! PXE boot orchestration: compose hypervisor power-on, DHCP, TFTP, kernel
//! init and nfsroot mount into a per-node [`BootPlan`] (paper §2.5).
//!
//! The plan is computed analytically from the node's tunnel latency and
//! link profile, then applied on the event engine by the coordinator; this
//! keeps the protocol models decoupled from the world type.

use super::dhcp::DhcpServer;
use super::nfs::NfsExport;
use super::tftp::TftpServer;
use crate::sim::clock::{from_us_f64, SimTime};
use crate::vm::hypervisor::Hypervisor;
use crate::vm::node::NodeState;

/// Per-node inputs to the boot time model.
#[derive(Debug, Clone, Copy)]
pub struct BootParams {
    /// One-way packet delay node↔server through VPN+virtio, µs.
    pub one_way_us: f64,
    /// Serialization cost on the bottleneck link, µs per byte.
    pub us_per_byte: f64,
    /// Kernel + initramfs decompress/init time, guest side, ms — scaled by
    /// hypervisor cpu efficiency.
    pub kernel_init_ms: f64,
}

impl Default for BootParams {
    fn default() -> Self {
        Self { one_way_us: 700.0, us_per_byte: 0.008, kernel_init_ms: 2_800.0 }
    }
}

/// The phases of one node boot, with durations.
#[derive(Debug, Clone)]
pub struct BootPlan {
    /// (state entered, phase duration) in order; the node is Up after the
    /// last phase completes.
    pub phases: Vec<(NodeState, SimTime)>,
}

impl BootPlan {
    /// Compute the plan for one node.
    pub fn compute(
        hv: &Hypervisor,
        tftp: &TftpServer,
        nfs: &NfsExport,
        params: &BootParams,
    ) -> Self {
        let power_on = from_us_f64(hv.power_on_ms * 1e3);

        let dhcp = from_us_f64(DhcpServer::dora_duration_us(params.one_way_us));

        let kernel = tftp
            .transfer_duration_us("/srv/tftp/vmlinuz", params.one_way_us, params.us_per_byte)
            .expect("kernel in tftp dir");
        let initrd = tftp
            .transfer_duration_us("/srv/tftp/initrd.img", params.one_way_us, params.us_per_byte)
            .expect("initrd in tftp dir");
        let pxelinux = tftp
            .transfer_duration_us("/srv/tftp/pxelinux.0", params.one_way_us, params.us_per_byte)
            .expect("pxelinux in tftp dir");
        let tftp_total = from_us_f64(kernel + initrd + pxelinux);

        let kernel_init = from_us_f64(params.kernel_init_ms * 1e3 / hv.cpu_efficiency.max(0.01));
        let mount = nfs.mount_duration_us(params.one_way_us);
        let userland =
            nfs.read_duration_us(nfs.boot_read_bytes(), params.one_way_us, params.us_per_byte);
        let nfs_total = from_us_f64(mount + userland) + kernel_init;

        Self {
            phases: vec![
                (NodeState::PoweringOn, power_on),
                (NodeState::Dhcp, dhcp),
                (NodeState::Tftp, tftp_total),
                (NodeState::NfsMount, nfs_total),
                (NodeState::Up, 0),
            ],
        }
    }

    /// Total boot duration.
    pub fn total(&self) -> SimTime {
        self.phases.iter().map(|&(_, d)| d).sum()
    }

    /// Duration of a named phase.
    pub fn phase(&self, s: NodeState) -> Option<SimTime> {
        self.phases.iter().find(|&&(p, _)| p == s).map(|&(_, d)| d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vm::hypervisor::HypervisorKind;

    fn plan(kind: HypervisorKind, one_way_us: f64) -> BootPlan {
        let hv = Hypervisor::new(kind);
        let tftp = TftpServer::new(512);
        let nfs = NfsExport::debian();
        BootPlan::compute(
            &hv,
            &tftp,
            &nfs,
            &BootParams { one_way_us, ..Default::default() },
        )
    }

    #[test]
    fn phases_in_lifecycle_order() {
        let p = plan(HypervisorKind::QemuKvm, 700.0);
        let states: Vec<NodeState> = p.phases.iter().map(|&(s, _)| s).collect();
        assert_eq!(
            states,
            vec![
                NodeState::PoweringOn,
                NodeState::Dhcp,
                NodeState::Tftp,
                NodeState::NfsMount,
                NodeState::Up
            ]
        );
    }

    #[test]
    fn tftp_dominates_on_high_latency_path() {
        let p = plan(HypervisorKind::QemuKvm, 700.0);
        let tftp = p.phase(NodeState::Tftp).unwrap();
        assert!(tftp > p.phase(NodeState::Dhcp).unwrap() * 100);
        assert!(tftp as f64 > p.total() as f64 * 0.4, "tftp share too small");
    }

    #[test]
    fn boot_time_plausible_minutes_scale() {
        // Paper-scale tunnel: boot takes on the order of a minute or two —
        // acceptable because nodes boot once and stay up.
        let p = plan(HypervisorKind::QemuKvm, 700.0);
        let secs = p.total() as f64 / 1e9;
        assert!(secs > 20.0 && secs < 300.0, "secs={secs}");
    }

    #[test]
    fn lower_latency_boots_faster() {
        let fast = plan(HypervisorKind::QemuKvm, 200.0);
        let slow = plan(HypervisorKind::QemuKvm, 900.0);
        assert!(fast.total() < slow.total());
    }

    #[test]
    fn pure_qemu_pays_kernel_init_penalty() {
        let kvm = plan(HypervisorKind::QemuKvm, 700.0);
        let tcg = plan(HypervisorKind::PureQemu, 700.0);
        assert!(
            tcg.phase(NodeState::NfsMount).unwrap() > kvm.phase(NodeState::NfsMount).unwrap() * 3
        );
    }
}
