//! Remote boot substrate (paper §2.3): PXE → DHCP → TFTP → nfsroot.
//!
//! "the node will request (using a DHCP request from the PXE) the necessary
//! files from the Gridlan server to boot.  After booting the Linux kernel
//! and the initramfs, the virtual machine will mount the root filesystem
//! via NFS."
//!
//! * [`fsimage`] — the server's `/nfsroot` shared root filesystem and the
//!   TFTP directory; centralized admin (`chroot apt-get install`) operates
//!   on it;
//! * [`dhcp`] — lease management on the VPN subnet + the DORA exchange;
//! * [`tftp`] — lock-step block transfer timing (kernel + initramfs);
//! * [`nfs`] — mount + RPC read model for the root filesystem;
//! * [`pxe`] — composes the above into a per-node [`pxe::BootPlan`].

pub mod dhcp;
pub mod fsimage;
pub mod ipxe;
pub mod nfs;
pub mod pxe;
pub mod tftp;

pub use dhcp::DhcpServer;
pub use ipxe::IpxeServer;
pub use fsimage::FsImage;
pub use nfs::NfsExport;
pub use pxe::{BootParams, BootPlan};
pub use tftp::TftpServer;
