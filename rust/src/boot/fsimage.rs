//! In-memory filesystem image: the server's `/nfsroot` and `/srv/tftp`.
//!
//! Paper §2.3: "All virtualized computing nodes share the same root
//! filesystem ... To install new software in the nodes, the administrator
//! must change the nodes' system in the folder /nfsroot, with the command
//! `chroot /nfsroot apt-get install package`".
//!
//! The image tracks paths and sizes (contents are irrelevant to timing);
//! `chroot_install` models the admin operation and makes the new software
//! instantly visible to every node — the centralized-maintenance property.

use std::collections::BTreeMap;

/// One entry in the image.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Entry {
    Dir,
    File { bytes: u64 },
}

/// A filesystem tree keyed by absolute path ("/" separated).
#[derive(Debug, Clone, Default)]
pub struct FsImage {
    entries: BTreeMap<String, Entry>,
}

impl FsImage {
    pub fn new() -> Self {
        let mut fs = Self::default();
        fs.mkdir_p("/");
        fs
    }

    /// A Debian-8-ish nfsroot: enough structure for the boot model and the
    /// admin workflows. Sizes approximate a minimal netboot install.
    pub fn debian_nfsroot() -> Self {
        let mut fs = Self::new();
        for d in ["/bin", "/etc", "/lib", "/usr", "/usr/bin", "/usr/lib", "/var", "/home", "/opt"] {
            fs.mkdir_p(d);
        }
        fs.write("/bin/busybox", 1_100_000);
        fs.write("/etc/fstab", 400);
        fs.write("/etc/hostname", 8);
        fs.write("/lib/libc-2.19.so", 1_700_000);
        fs.write("/usr/lib/base.bundle", 380_000_000); // aggregate userland
        fs
    }

    /// TFTP directory with the netboot artifacts (paper: kernel updates =
    /// copy a new kernel into the TFTP directory).
    pub fn tftp_dir() -> Self {
        let mut fs = Self::new();
        fs.mkdir_p("/srv/tftp");
        fs.write("/srv/tftp/vmlinuz", 5_200_000);
        fs.write("/srv/tftp/initrd.img", 18_500_000);
        fs.write("/srv/tftp/pxelinux.0", 42_000);
        fs
    }

    fn normalize(path: &str) -> String {
        let p = path.trim_end_matches('/');
        if p.is_empty() {
            "/".to_string()
        } else {
            p.to_string()
        }
    }

    pub fn mkdir_p(&mut self, path: &str) {
        let path = Self::normalize(path);
        let mut cur = String::new();
        for seg in path.split('/').filter(|s| !s.is_empty()) {
            cur.push('/');
            cur.push_str(seg);
            self.entries.entry(cur.clone()).or_insert(Entry::Dir);
        }
        self.entries.entry("/".to_string()).or_insert(Entry::Dir);
    }

    /// Create/overwrite a file; parents are created.
    pub fn write(&mut self, path: &str, bytes: u64) {
        let path = Self::normalize(path);
        if let Some(parent) = path.rfind('/') {
            if parent > 0 {
                self.mkdir_p(&path[..parent]);
            }
        }
        self.entries.insert(path, Entry::File { bytes });
    }

    pub fn get(&self, path: &str) -> Option<&Entry> {
        self.entries.get(&Self::normalize(path))
    }

    pub fn file_size(&self, path: &str) -> Option<u64> {
        match self.get(path)? {
            Entry::File { bytes } => Some(*bytes),
            Entry::Dir => None,
        }
    }

    pub fn exists(&self, path: &str) -> bool {
        self.get(path).is_some()
    }

    pub fn remove(&mut self, path: &str) -> bool {
        let path = Self::normalize(path);
        // Remove the subtree.
        let keys: Vec<String> = self
            .entries
            .range(path.clone()..)
            .take_while(|(k, _)| **k == path || k.starts_with(&format!("{path}/")))
            .map(|(k, _)| k.clone())
            .collect();
        for k in &keys {
            self.entries.remove(k);
        }
        !keys.is_empty()
    }

    /// List direct children of a directory.
    pub fn ls(&self, dir: &str) -> Vec<String> {
        let dir = Self::normalize(dir);
        let prefix = if dir == "/" { "/".to_string() } else { format!("{dir}/") };
        self.entries
            .keys()
            .filter(|k| {
                k.starts_with(&prefix)
                    && *k != &dir
                    && !k[prefix.len()..].contains('/')
                    && !k[prefix.len()..].is_empty()
            })
            .cloned()
            .collect()
    }

    /// Total bytes under a path.
    pub fn du(&self, path: &str) -> u64 {
        let path = Self::normalize(path);
        self.entries
            .iter()
            .filter(|(k, _)| **k == path || k.starts_with(&format!("{path}/")) || path == "/")
            .map(|(_, e)| match e {
                Entry::File { bytes } => *bytes,
                Entry::Dir => 0,
            })
            .sum()
    }

    /// The paper's admin operation: `chroot /nfsroot apt-get install pkg`.
    /// Adds the package payload under /usr; every node sees it immediately
    /// because they share this image.
    pub fn chroot_install(&mut self, package: &str, bytes: u64) {
        self.write(&format!("/usr/lib/{package}.pkg"), bytes);
        self.write(&format!("/usr/bin/{package}"), bytes / 50 + 1024);
        self.write(&format!("/var/lib/dpkg/info/{package}.list"), 2_000);
    }

    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_creates_parents() {
        let mut fs = FsImage::new();
        fs.write("/a/b/c.txt", 10);
        assert_eq!(fs.get("/a"), Some(&Entry::Dir));
        assert_eq!(fs.get("/a/b"), Some(&Entry::Dir));
        assert_eq!(fs.file_size("/a/b/c.txt"), Some(10));
    }

    #[test]
    fn ls_lists_direct_children_only() {
        let mut fs = FsImage::new();
        fs.write("/x/one", 1);
        fs.write("/x/two", 2);
        fs.write("/x/sub/three", 3);
        let ls = fs.ls("/x");
        assert_eq!(ls, vec!["/x/one", "/x/sub", "/x/two"]);
    }

    #[test]
    fn du_sums_subtree() {
        let mut fs = FsImage::new();
        fs.write("/x/a", 100);
        fs.write("/x/s/b", 50);
        fs.write("/y/c", 7);
        assert_eq!(fs.du("/x"), 150);
        assert_eq!(fs.du("/"), 157);
    }

    #[test]
    fn remove_subtree() {
        let mut fs = FsImage::new();
        fs.write("/x/a", 1);
        fs.write("/x/b/c", 2);
        assert!(fs.remove("/x"));
        assert!(!fs.exists("/x/a"));
        assert!(!fs.exists("/x"));
        assert!(!fs.remove("/x"));
    }

    #[test]
    fn chroot_install_visible_in_shared_root() {
        let mut fs = FsImage::debian_nfsroot();
        let before = fs.du("/");
        fs.chroot_install("gromacs", 85_000_000);
        assert!(fs.exists("/usr/bin/gromacs"));
        assert!(fs.du("/") > before + 85_000_000);
    }

    #[test]
    fn tftp_dir_has_boot_artifacts() {
        let fs = FsImage::tftp_dir();
        assert!(fs.file_size("/srv/tftp/vmlinuz").unwrap() > 1_000_000);
        assert!(fs.file_size("/srv/tftp/initrd.img").unwrap() > 10_000_000);
    }

    #[test]
    fn kernel_update_is_a_copy_into_tftp() {
        // Paper: "To update a kernel, a new one must be compiled and copied
        // to the TFTP directory."
        let mut fs = FsImage::tftp_dir();
        let old = fs.file_size("/srv/tftp/vmlinuz").unwrap();
        fs.write("/srv/tftp/vmlinuz", old + 300_000);
        assert_eq!(fs.file_size("/srv/tftp/vmlinuz").unwrap(), old + 300_000);
    }
}
