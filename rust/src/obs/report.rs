//! Fold a structured scenario event log back into report-style rollups.
//!
//! This is the offline half of the observability pipeline: given a JSONL
//! log (from a [`crate::obs::event::ScenarioLogger`] memory/writer sink or
//! a file on disk), [`EventRollup`] reproduces the counters a live
//! [`crate::coordinator::metrics::Metrics`] would have accumulated — the
//! `gridlan report <events.jsonl>` CLI mode renders it.

use crate::coordinator::metrics::Metrics;
use crate::obs::event::{EventKind, ScenarioEvent};
use crate::sim::clock::SimTime;
use crate::util::stats::Summary;
use crate::util::table::{secs, Align, Table};

/// Aggregates computed from an event log.
#[derive(Debug, Clone, Default)]
pub struct EventRollup {
    pub boots: u64,
    pub submits: u64,
    pub schedules: u64,
    pub starts: u64,
    pub completes: u64,
    /// Completions with exit code 0.
    pub completed_ok: u64,
    pub faults: u64,
    pub requeues: u64,
    /// Sub-span checkpoints recorded for running EP jobs.
    pub checkpoints: u64,
    /// Straggler range-steal operations.
    pub steals: u64,
    /// Per-completion queue wait, in seconds.
    pub wait_secs: Summary,
    /// Timestamp of the last record (sim ns).
    pub last_t: SimTime,
}

impl EventRollup {
    pub fn from_events(events: &[ScenarioEvent]) -> Self {
        let mut r = EventRollup::default();
        for ev in events {
            r.last_t = r.last_t.max(ev.at);
            match &ev.kind {
                EventKind::Boot { .. } => r.boots += 1,
                EventKind::Submit { .. } => r.submits += 1,
                EventKind::Schedule { .. } => r.schedules += 1,
                EventKind::Start { .. } => r.starts += 1,
                EventKind::Complete { exit, wait_ns, .. } => {
                    r.completes += 1;
                    if *exit == 0 {
                        r.completed_ok += 1;
                    }
                    r.wait_secs.push(*wait_ns as f64 / 1e9);
                }
                EventKind::Fault { .. } => r.faults += 1,
                EventKind::Requeue { .. } => r.requeues += 1,
                EventKind::Checkpoint { .. } => r.checkpoints += 1,
                EventKind::Steal { .. } => r.steals += 1,
            }
        }
        r
    }

    pub fn from_jsonl(text: &str) -> Result<Self, String> {
        Ok(Self::from_events(&ScenarioEvent::parse_jsonl(text)?))
    }

    pub fn mean_wait_secs(&self) -> f64 {
        self.wait_secs.mean()
    }

    /// Completions per submission (1.0 when nothing was submitted).
    pub fn completion_rate(&self) -> f64 {
        if self.submits == 0 {
            return 1.0;
        }
        self.completes as f64 / self.submits as f64
    }

    /// The rollup agrees with a live [`Metrics`] on the counters both
    /// sides observe exactly: completions, requeues, and total wait.
    /// (Submissions rejected at qsub and faults scheduled past the end of
    /// the run are visible to only one side, so they are not compared.)
    pub fn consistent_with(&self, m: &Metrics) -> bool {
        let wait_total_ns = (self.wait_secs.mean() * self.wait_secs.len() as f64 * 1e9).round();
        self.completes == m.jobs_completed
            && self.requeues == m.jobs_requeued
            && (wait_total_ns - m.total_wait as f64).abs() < 1e3
    }

    /// Human-readable rollup table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["metric", "value"])
            .title("scenario event-log rollup")
            .align(&[Align::Left, Align::Right]);
        t.row(&["boots".into(), self.boots.to_string()]);
        t.row(&["submits".into(), self.submits.to_string()]);
        t.row(&["schedules".into(), self.schedules.to_string()]);
        t.row(&["starts".into(), self.starts.to_string()]);
        t.row(&["completes".into(), self.completes.to_string()]);
        t.row(&["completed ok".into(), self.completed_ok.to_string()]);
        t.row(&["faults".into(), self.faults.to_string()]);
        t.row(&["requeues".into(), self.requeues.to_string()]);
        t.row(&["checkpoints".into(), self.checkpoints.to_string()]);
        t.row(&["steals".into(), self.steals.to_string()]);
        t.row(&["mean wait".into(), secs(self.mean_wait_secs())]);
        t.row(&["p99 wait".into(), secs(self.wait_secs.p99())]);
        t.row(&["completion rate".into(), format!("{:.3}", self.completion_rate())]);
        t.row(&["log span".into(), secs(self.last_t as f64 / 1e9)]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log() -> Vec<ScenarioEvent> {
        vec![
            ScenarioEvent::new(10, EventKind::Boot { client: "n01".into(), generation: 1 }),
            ScenarioEvent::new(
                20,
                EventKind::Submit {
                    job: 1,
                    owner: "u".into(),
                    nodes: 1,
                    ppn: 2,
                    kind: "trace".into(),
                },
            ),
            ScenarioEvent::new(
                30,
                EventKind::Schedule { job: 1, alloc: vec![("n01".into(), 2)] },
            ),
            ScenarioEvent::new(30, EventKind::Start { job: 1, run_ns: 100 }),
            ScenarioEvent::new(
                50,
                EventKind::Fault {
                    client: "n01".into(),
                    kind: "net_drop".into(),
                    outage_ns: 5,
                },
            ),
            ScenarioEvent::new(50, EventKind::Requeue { job: 1, client: "n01".into() }),
            ScenarioEvent::new(
                45,
                EventKind::Checkpoint { job: 1, cursor: 4_096, pairs_done: 4_096 },
            ),
            ScenarioEvent::new(
                60,
                EventKind::Steal { parent: 1, child: 2, offset: 4_096, count: 1_024 },
            ),
            ScenarioEvent::new(
                90,
                EventKind::Schedule { job: 1, alloc: vec![("n02".into(), 2)] },
            ),
            ScenarioEvent::new(90, EventKind::Start { job: 1, run_ns: 100 }),
            ScenarioEvent::new(
                200,
                EventKind::Complete { job: 1, exit: 0, wait_ns: 3_000_000_000 },
            ),
        ]
    }

    #[test]
    fn counts_every_kind() {
        let r = EventRollup::from_events(&log());
        assert_eq!(r.boots, 1);
        assert_eq!(r.submits, 1);
        assert_eq!(r.schedules, 2);
        assert_eq!(r.starts, 2);
        assert_eq!(r.completes, 1);
        assert_eq!(r.completed_ok, 1);
        assert_eq!(r.faults, 1);
        assert_eq!(r.requeues, 1);
        assert_eq!(r.checkpoints, 1);
        assert_eq!(r.steals, 1);
        assert_eq!(r.last_t, 200);
        assert!((r.mean_wait_secs() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn from_jsonl_matches_from_events() {
        let events = log();
        let text: String = events.iter().map(|e| e.to_line() + "\n").collect();
        let a = EventRollup::from_events(&events);
        let b = EventRollup::from_jsonl(&text).unwrap();
        assert_eq!(a.completes, b.completes);
        assert_eq!(a.requeues, b.requeues);
        assert_eq!(a.last_t, b.last_t);
    }

    #[test]
    fn consistency_against_metrics() {
        let r = EventRollup::from_events(&log());
        let m = Metrics {
            jobs_submitted: 1,
            jobs_completed: 1,
            jobs_requeued: 1,
            total_wait: 3_000_000_000,
            faults: 1,
            ..Default::default()
        };
        assert!(r.consistent_with(&m));
        let wrong = Metrics { jobs_completed: 2, ..m };
        assert!(!r.consistent_with(&wrong));
    }

    #[test]
    fn render_mentions_key_counters() {
        let out = EventRollup::from_events(&log()).render();
        assert!(out.contains("completes"));
        assert!(out.contains("requeues"));
        assert!(out.contains("completion rate"));
    }

    #[test]
    fn empty_log_is_total() {
        let r = EventRollup::from_events(&[]);
        assert_eq!(r.completion_rate(), 1.0);
        assert_eq!(r.mean_wait_secs(), 0.0);
        assert!(!r.render().is_empty());
    }
}
