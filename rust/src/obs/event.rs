//! Typed, structured scenario event log.
//!
//! `run_scenario` emits one record per lifecycle transition — boot,
//! submit, schedule, start, complete, fault, requeue — through a
//! [`ScenarioLogger`] sink.  Records serialize to JSONL (one compact JSON
//! object per line, `{"t": <sim-ns>, "ev": "<kind>", ...}`), parse back
//! losslessly, and mirror through [`crate::util::log`] so `GRIDLAN_LOG`
//! controls a human-readable view of the same stream.
//!
//! Timestamps are *simulated* nanoseconds, so same-seed runs produce
//! byte-identical logs.

use std::io::Write;

use crate::sim::clock::SimTime;
use crate::util::json::{Json, JsonObj};
use crate::util::log::{self, Level};

/// What happened.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// A node finished its PXE boot and became schedulable.
    Boot { client: String, generation: u64 },
    /// A job was accepted by the resource manager.
    Submit { job: u64, owner: String, nodes: u32, ppn: u32, kind: String },
    /// The scheduler placed a job; `alloc` is node -> cores, sorted.
    Schedule { job: u64, alloc: Vec<(String, u32)> },
    /// The job's MOM began executing it (planned runtime from the model).
    Start { job: u64, run_ns: u64 },
    /// The job completed (exit 0) or failed (exit != 0).
    Complete { job: u64, exit: i32, wait_ns: u64 },
    /// An injected fault hit a client.
    Fault { client: String, kind: String, outage_ns: u64 },
    /// A running job was thrown back in the queue by a node loss.
    Requeue { job: u64, client: String },
    /// A running EP job finished a sub-span: `cursor` is the absolute
    /// pair index execution has reached, `pairs_done` the pairs banked
    /// so far this attempt.
    Checkpoint { job: u64, cursor: u64, pairs_done: u64 },
    /// A straggler's remaining range `[offset, offset+count)` was split
    /// off `parent` into new job `child`.
    Steal { parent: u64, child: u64, offset: u64, count: u64 },
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Boot { .. } => "boot",
            EventKind::Submit { .. } => "submit",
            EventKind::Schedule { .. } => "schedule",
            EventKind::Start { .. } => "start",
            EventKind::Complete { .. } => "complete",
            EventKind::Fault { .. } => "fault",
            EventKind::Requeue { .. } => "requeue",
            EventKind::Checkpoint { .. } => "checkpoint",
            EventKind::Steal { .. } => "steal",
        }
    }

    /// Log level for the human-readable mirror.
    pub fn level(&self) -> Level {
        match self {
            EventKind::Fault { .. } | EventKind::Requeue { .. } => Level::Warn,
            _ => Level::Info,
        }
    }
}

/// One timestamped record.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioEvent {
    /// Simulated nanoseconds since scenario start.
    pub at: SimTime,
    pub kind: EventKind,
}

impl ScenarioEvent {
    pub fn new(at: SimTime, kind: EventKind) -> Self {
        Self { at, kind }
    }

    /// The record as a JSON object (key order is the wire format).
    pub fn to_json(&self) -> Json {
        let mut o = JsonObj::new();
        o.insert("t", Json::Num(self.at as f64));
        o.insert("ev", Json::Str(self.kind.name().to_string()));
        match &self.kind {
            EventKind::Boot { client, generation } => {
                o.insert("client", Json::Str(client.clone()));
                o.insert("gen", Json::Num(*generation as f64));
            }
            EventKind::Submit { job, owner, nodes, ppn, kind } => {
                o.insert("job", Json::Num(*job as f64));
                o.insert("owner", Json::Str(owner.clone()));
                o.insert("nodes", Json::Num(*nodes as f64));
                o.insert("ppn", Json::Num(*ppn as f64));
                o.insert("kind", Json::Str(kind.clone()));
            }
            EventKind::Schedule { job, alloc } => {
                o.insert("job", Json::Num(*job as f64));
                let mut a = JsonObj::new();
                for (node, cores) in alloc {
                    a.insert(node, Json::Num(*cores as f64));
                }
                o.insert("alloc", Json::Obj(a));
            }
            EventKind::Start { job, run_ns } => {
                o.insert("job", Json::Num(*job as f64));
                o.insert("run_ns", Json::Num(*run_ns as f64));
            }
            EventKind::Complete { job, exit, wait_ns } => {
                o.insert("job", Json::Num(*job as f64));
                o.insert("exit", Json::Num(*exit as f64));
                o.insert("wait_ns", Json::Num(*wait_ns as f64));
            }
            EventKind::Fault { client, kind, outage_ns } => {
                o.insert("client", Json::Str(client.clone()));
                o.insert("kind", Json::Str(kind.clone()));
                o.insert("outage_ns", Json::Num(*outage_ns as f64));
            }
            EventKind::Requeue { job, client } => {
                o.insert("job", Json::Num(*job as f64));
                o.insert("client", Json::Str(client.clone()));
            }
            EventKind::Checkpoint { job, cursor, pairs_done } => {
                o.insert("job", Json::Num(*job as f64));
                o.insert("cursor", Json::Num(*cursor as f64));
                o.insert("pairs_done", Json::Num(*pairs_done as f64));
            }
            EventKind::Steal { parent, child, offset, count } => {
                o.insert("parent", Json::Num(*parent as f64));
                o.insert("child", Json::Num(*child as f64));
                o.insert("offset", Json::Num(*offset as f64));
                o.insert("count", Json::Num(*count as f64));
            }
        }
        Json::Obj(o)
    }

    /// One JSONL line (no trailing newline).
    pub fn to_line(&self) -> String {
        self.to_json().to_string()
    }

    /// Human-readable form for the `GRIDLAN_LOG` mirror.
    pub fn human(&self) -> String {
        match &self.kind {
            EventKind::Boot { client, generation } => {
                format!("node {client} up (boot generation {generation})")
            }
            EventKind::Submit { job, owner, nodes, ppn, kind } => {
                format!("job {job} submitted by {owner} ({nodes}x{ppn} {kind})")
            }
            EventKind::Schedule { job, alloc } => {
                let placed: Vec<String> =
                    alloc.iter().map(|(n, c)| format!("{n}:{c}")).collect();
                format!("job {job} scheduled on {}", placed.join(","))
            }
            EventKind::Start { job, run_ns } => {
                format!("job {job} started (planned runtime {:.2}s)", *run_ns as f64 / 1e9)
            }
            EventKind::Complete { job, exit, wait_ns } => {
                format!("job {job} completed exit={exit} wait={:.1}s", *wait_ns as f64 / 1e9)
            }
            EventKind::Fault { client, kind, outage_ns } => {
                format!("fault {kind} on {client} (outage {:.0}s)", *outage_ns as f64 / 1e9)
            }
            EventKind::Requeue { job, client } => {
                format!("job {job} requeued off {client}")
            }
            EventKind::Checkpoint { job, cursor, pairs_done } => {
                format!("job {job} checkpointed at pair {cursor} ({pairs_done} done)")
            }
            EventKind::Steal { parent, child, offset, count } => {
                format!("job {child} stole [{offset},+{count}) from job {parent}")
            }
        }
    }

    /// Parse one JSONL line back into a typed record.
    pub fn parse_line(line: &str) -> Result<ScenarioEvent, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        let at = req_u64(&j, "t")?;
        let ev = req_str(&j, "ev")?;
        let kind = match ev.as_str() {
            "boot" => EventKind::Boot {
                client: req_str(&j, "client")?,
                generation: req_u64(&j, "gen")?,
            },
            "submit" => EventKind::Submit {
                job: req_u64(&j, "job")?,
                owner: req_str(&j, "owner")?,
                nodes: req_u64(&j, "nodes")? as u32,
                ppn: req_u64(&j, "ppn")? as u32,
                kind: req_str(&j, "kind")?,
            },
            "schedule" => {
                let alloc_obj = j
                    .get("alloc")
                    .and_then(Json::as_obj)
                    .ok_or("schedule record missing object \"alloc\"")?;
                let mut alloc = Vec::new();
                for (node, cores) in alloc_obj.iter() {
                    let c = cores
                        .as_u64()
                        .ok_or_else(|| format!("alloc[{node}] is not an integer"))?;
                    alloc.push((node.clone(), c as u32));
                }
                EventKind::Schedule { job: req_u64(&j, "job")?, alloc }
            }
            "start" => EventKind::Start {
                job: req_u64(&j, "job")?,
                run_ns: req_u64(&j, "run_ns")?,
            },
            "complete" => EventKind::Complete {
                job: req_u64(&j, "job")?,
                exit: req_i64(&j, "exit")? as i32,
                wait_ns: req_u64(&j, "wait_ns")?,
            },
            "fault" => EventKind::Fault {
                client: req_str(&j, "client")?,
                kind: req_str(&j, "kind")?,
                outage_ns: req_u64(&j, "outage_ns")?,
            },
            "requeue" => EventKind::Requeue {
                job: req_u64(&j, "job")?,
                client: req_str(&j, "client")?,
            },
            "checkpoint" => EventKind::Checkpoint {
                job: req_u64(&j, "job")?,
                cursor: req_u64(&j, "cursor")?,
                pairs_done: req_u64(&j, "pairs_done")?,
            },
            "steal" => EventKind::Steal {
                parent: req_u64(&j, "parent")?,
                child: req_u64(&j, "child")?,
                offset: req_u64(&j, "offset")?,
                count: req_u64(&j, "count")?,
            },
            other => return Err(format!("unknown event kind {other:?}")),
        };
        Ok(ScenarioEvent { at, kind })
    }

    /// Parse a whole JSONL document (blank lines skipped).
    pub fn parse_jsonl(text: &str) -> Result<Vec<ScenarioEvent>, String> {
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            out.push(
                ScenarioEvent::parse_line(line).map_err(|e| format!("line {}: {e}", i + 1))?,
            );
        }
        Ok(out)
    }
}

fn req_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| format!("missing integer field {key:?}"))
}

fn req_i64(j: &Json, key: &str) -> Result<i64, String> {
    j.get(key)
        .and_then(Json::as_i64)
        .ok_or_else(|| format!("missing integer field {key:?}"))
}

fn req_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string field {key:?}"))
}

/// Where scenario events go.
///
/// Every record is also mirrored (human-readable) through
/// [`crate::util::log::emit`] at the kind's level, so `GRIDLAN_LOG=info`
/// narrates any scenario regardless of the sink.
pub enum ScenarioLogger {
    /// Drop records (mirror only) — the default for existing callers.
    Null,
    /// Keep typed records in memory for post-run aggregation.
    Memory(Vec<ScenarioEvent>),
    /// Stream JSONL lines to a writer as they happen.
    Writer(Box<dyn Write + Send>),
}

impl ScenarioLogger {
    pub fn null() -> Self {
        ScenarioLogger::Null
    }

    pub fn memory() -> Self {
        ScenarioLogger::Memory(Vec::new())
    }

    pub fn writer(w: Box<dyn Write + Send>) -> Self {
        ScenarioLogger::Writer(w)
    }

    pub fn is_null(&self) -> bool {
        matches!(self, ScenarioLogger::Null)
    }

    /// Record one event: mirror to the leveled log, then sink.
    pub fn log(&mut self, at: SimTime, kind: EventKind) {
        let ev = ScenarioEvent::new(at, kind);
        if log::enabled(ev.kind.level()) {
            log::emit(ev.kind.level(), ev.at, "scenario", &ev.human());
        }
        match self {
            ScenarioLogger::Null => {}
            ScenarioLogger::Memory(events) => events.push(ev),
            ScenarioLogger::Writer(w) => {
                let _ = writeln!(w, "{}", ev.to_line());
            }
        }
    }

    /// Recorded events (empty unless this is a memory sink).
    pub fn events(&self) -> &[ScenarioEvent] {
        match self {
            ScenarioLogger::Memory(events) => events,
            _ => &[],
        }
    }

    /// The memory sink's records as a JSONL document.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.events() {
            out.push_str(&ev.to_line());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_of_each() -> Vec<ScenarioEvent> {
        vec![
            ScenarioEvent::new(
                100,
                EventKind::Boot { client: "n01".into(), generation: 1 },
            ),
            ScenarioEvent::new(
                200,
                EventKind::Submit {
                    job: 1,
                    owner: "user00".into(),
                    nodes: 2,
                    ppn: 4,
                    kind: "trace".into(),
                },
            ),
            ScenarioEvent::new(
                300,
                EventKind::Schedule {
                    job: 1,
                    alloc: vec![("n01".into(), 4), ("n02".into(), 4)],
                },
            ),
            ScenarioEvent::new(300, EventKind::Start { job: 1, run_ns: 5_000_000_000 }),
            ScenarioEvent::new(
                400,
                EventKind::Complete { job: 1, exit: 0, wait_ns: 100 },
            ),
            ScenarioEvent::new(
                500,
                EventKind::Fault {
                    client: "n02".into(),
                    kind: "vm_crash".into(),
                    outage_ns: 60_000_000_000,
                },
            ),
            ScenarioEvent::new(500, EventKind::Requeue { job: 1, client: "n02".into() }),
            ScenarioEvent::new(
                550,
                EventKind::Checkpoint { job: 1, cursor: 12_288, pairs_done: 8_192 },
            ),
            ScenarioEvent::new(
                600,
                EventKind::Steal { parent: 1, child: 9, offset: 12_288, count: 4_096 },
            ),
        ]
    }

    #[test]
    fn every_kind_round_trips() {
        for ev in one_of_each() {
            let line = ev.to_line();
            let back = ScenarioEvent::parse_line(&line).unwrap();
            assert_eq!(back, ev, "line: {line}");
            assert_eq!(back.to_line(), line);
        }
    }

    #[test]
    fn jsonl_round_trips() {
        let events = one_of_each();
        let mut logger = ScenarioLogger::memory();
        for ev in &events {
            logger.log(ev.at, ev.kind.clone());
        }
        let text = logger.to_jsonl();
        assert_eq!(text.lines().count(), events.len());
        let back = ScenarioEvent::parse_jsonl(&text).unwrap();
        assert_eq!(back, events);
    }

    #[test]
    fn wire_format_is_stable() {
        let ev = ScenarioEvent::new(
            42,
            EventKind::Complete { job: 7, exit: 1, wait_ns: 1500 },
        );
        assert_eq!(ev.to_line(), r#"{"t":42,"ev":"complete","job":7,"exit":1,"wait_ns":1500}"#);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ScenarioEvent::parse_line("not json").is_err());
        assert!(ScenarioEvent::parse_line(r#"{"t":1,"ev":"warp"}"#).is_err());
        assert!(ScenarioEvent::parse_line(r#"{"ev":"boot","client":"n01","gen":1}"#).is_err());
        let multi = "{\"t\":1,\"ev\":\"boot\",\"client\":\"n01\",\"gen\":1}\n\nbroken\n";
        let err = ScenarioEvent::parse_jsonl(multi).unwrap_err();
        assert!(err.starts_with("line 3:"), "{err}");
    }

    #[test]
    fn null_and_writer_sinks() {
        let mut null = ScenarioLogger::null();
        assert!(null.is_null());
        null.log(1, EventKind::Start { job: 1, run_ns: 2 });
        assert!(null.events().is_empty());

        let mut sink = ScenarioLogger::writer(Box::new(Vec::new()));
        sink.log(1, EventKind::Start { job: 1, run_ns: 2 });
        if let ScenarioLogger::Writer(w) = &sink {
            let _ = w; // bytes went to the boxed Vec; shape checked via memory sink
        }
    }

    #[test]
    fn levels_route_faults_to_warn() {
        assert_eq!(
            EventKind::Fault { client: "n01".into(), kind: "vm_crash".into(), outage_ns: 0 }
                .level(),
            Level::Warn
        );
        assert_eq!(EventKind::Start { job: 1, run_ns: 0 }.level(), Level::Info);
    }
}
