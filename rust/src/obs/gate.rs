//! Perf-regression gate: compare a freshly produced `BENCH_*.json`
//! against the committed baseline and fail on a >15% mean regression.
//!
//! Direction is inferred from the series unit:
//!
//! * units ending in `/s` (rates) — higher is better;
//! * time units (`ns`, `us`/`µs`, `ms`, `s`, `min`) — lower is better;
//! * anything else (counts, fractions, ratios) — two-sided: any drift
//!   beyond the tolerance fails, because those series are deterministic
//!   model outputs that should not move at all.

use crate::obs::harness;
use crate::util::json::Json;
use crate::util::table::{Align, Table};

/// Relative tolerance on the series mean before the gate trips.
pub const DEFAULT_TOLERANCE: f64 = 0.15;

/// Which way a series is allowed to drift.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    HigherIsBetter,
    LowerIsBetter,
    TwoSided,
}

/// Infer comparison direction from a unit string.
pub fn direction_for_unit(unit: &str) -> Direction {
    let u = unit.trim();
    if u.ends_with("/s") {
        return Direction::HigherIsBetter;
    }
    match u {
        "ns" | "us" | "µs" | "ms" | "s" | "sec" | "secs" | "seconds" | "min" => {
            Direction::LowerIsBetter
        }
        _ => Direction::TwoSided,
    }
}

/// Outcome for one compared series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateStatus {
    Ok,
    Regression,
    MissingInFresh,
    UnitMismatch,
}

/// One series' comparison result.
#[derive(Debug, Clone)]
pub struct GateFinding {
    pub label: String,
    pub unit: String,
    pub direction: Direction,
    pub baseline_mean: f64,
    pub fresh_mean: f64,
    pub status: GateStatus,
}

impl GateFinding {
    /// Relative change fresh vs baseline (0.2 = fresh is 20% above).
    pub fn rel_change(&self) -> f64 {
        if self.baseline_mean == 0.0 {
            if self.fresh_mean == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (self.fresh_mean - self.baseline_mean) / self.baseline_mean.abs()
        }
    }
}

/// Result of gating one bench document.
#[derive(Debug, Clone)]
pub struct GateReport {
    pub bench: String,
    pub findings: Vec<GateFinding>,
    /// Non-fatal notes (e.g. new series absent from the baseline).
    pub warnings: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.findings.iter().all(|f| f.status == GateStatus::Ok)
    }

    pub fn n_regressions(&self) -> usize {
        self.findings.iter().filter(|f| f.status != GateStatus::Ok).count()
    }

    /// Human-readable verdict table.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["series", "unit", "baseline", "fresh", "change", "verdict"])
            .title(&format!("gate: {}", self.bench))
            .align(&[
                Align::Left,
                Align::Left,
                Align::Right,
                Align::Right,
                Align::Right,
                Align::Left,
            ]);
        for f in &self.findings {
            let verdict = match f.status {
                GateStatus::Ok => "ok",
                GateStatus::Regression => "REGRESSION",
                GateStatus::MissingInFresh => "MISSING",
                GateStatus::UnitMismatch => "UNIT MISMATCH",
            };
            let change = if f.rel_change().is_finite() {
                format!("{:+.1}%", f.rel_change() * 100.0)
            } else {
                "n/a".to_string()
            };
            t.row(&[
                f.label.clone(),
                f.unit.clone(),
                format!("{:.6}", f.baseline_mean),
                format!("{:.6}", f.fresh_mean),
                change,
                verdict.to_string(),
            ]);
        }
        let mut out = t.render();
        for w in &self.warnings {
            out.push_str(&format!("note: {w}\n"));
        }
        out
    }
}

fn series_fields(entry: &Json) -> Option<(&str, &str, f64)> {
    let label = entry.get("label")?.as_str()?;
    let unit = entry.get("unit")?.as_str()?;
    let mean = entry.get("mean")?.as_f64()?;
    Some((label, unit, mean))
}

/// Compare `fresh` against `baseline` (both full `BENCH_*.json` documents)
/// with the given relative tolerance on each series mean.
///
/// Hard errors (`Err`) are non-comparable documents: schema violations,
/// different bench names, or a seed/params drift (the baseline must be
/// re-minted, not compared).  Per-series regressions land as findings in
/// the returned [`GateReport`].
pub fn compare(baseline: &Json, fresh: &Json, tolerance: f64) -> Result<GateReport, String> {
    harness::validate(baseline).map_err(|e| format!("baseline: {e}"))?;
    harness::validate(fresh).map_err(|e| format!("fresh: {e}"))?;
    let name = baseline.get("name").unwrap().as_str().unwrap().to_string();
    let fresh_name = fresh.get("name").unwrap().as_str().unwrap();
    if name != fresh_name {
        return Err(format!("bench name mismatch: baseline={name:?} fresh={fresh_name:?}"));
    }
    let b_seed = baseline.get("seed").unwrap().as_u64().unwrap();
    let f_seed = fresh.get("seed").unwrap().as_u64().unwrap();
    if b_seed != f_seed {
        return Err(format!(
            "seed mismatch for {name}: baseline={b_seed} fresh={f_seed} — re-mint the baseline"
        ));
    }
    let b_params = baseline.get("params").unwrap().to_string();
    let f_params = fresh.get("params").unwrap().to_string();
    if b_params != f_params {
        return Err(format!(
            "params mismatch for {name}: baseline={b_params} fresh={f_params} — re-mint the baseline"
        ));
    }

    let b_series = baseline.get("series").unwrap().as_arr().unwrap();
    let f_series = fresh.get("series").unwrap().as_arr().unwrap();
    let mut findings = Vec::new();
    let mut warnings = Vec::new();

    for entry in b_series {
        let (label, unit, b_mean) = series_fields(entry).ok_or("unreachable: validated")?;
        let direction = direction_for_unit(unit);
        let fresh_entry = f_series
            .iter()
            .find(|e| e.get("label").and_then(Json::as_str) == Some(label));
        let (status, f_mean) = match fresh_entry.and_then(series_fields) {
            None => (GateStatus::MissingInFresh, 0.0),
            Some((_, f_unit, f_mean)) if f_unit != unit => (GateStatus::UnitMismatch, f_mean),
            Some((_, _, f_mean)) => {
                let regressed = if b_mean == 0.0 {
                    f_mean.abs() > 1e-9
                } else {
                    let rel = (f_mean - b_mean) / b_mean.abs();
                    match direction {
                        Direction::HigherIsBetter => rel < -tolerance,
                        Direction::LowerIsBetter => rel > tolerance,
                        Direction::TwoSided => rel.abs() > tolerance,
                    }
                };
                (if regressed { GateStatus::Regression } else { GateStatus::Ok }, f_mean)
            }
        };
        findings.push(GateFinding {
            label: label.to_string(),
            unit: unit.to_string(),
            direction,
            baseline_mean: b_mean,
            fresh_mean: f_mean,
            status,
        });
    }

    for entry in f_series {
        if let Some((label, _, _)) = series_fields(entry) {
            let known = b_series
                .iter()
                .any(|e| e.get("label").and_then(Json::as_str) == Some(label));
            if !known {
                warnings.push(format!(
                    "series {label:?} is new (absent from baseline) — commit a refreshed baseline"
                ));
            }
        }
    }

    Ok(GateReport { bench: name, findings, warnings })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::harness::BenchHarness;
    use crate::util::stats::Summary;

    fn doc(means: &[(&str, &str, f64)]) -> Json {
        let mut h = BenchHarness::new("t", 9);
        h.param_u64("size", 64);
        for (label, unit, mean) in means {
            h.series(label, unit, Summary::from_slice(&[*mean]));
        }
        h.to_json()
    }

    #[test]
    fn unit_direction_inference() {
        assert_eq!(direction_for_unit("Mpairs/s"), Direction::HigherIsBetter);
        assert_eq!(direction_for_unit("events/s"), Direction::HigherIsBetter);
        assert_eq!(direction_for_unit("µs"), Direction::LowerIsBetter);
        assert_eq!(direction_for_unit("us"), Direction::LowerIsBetter);
        assert_eq!(direction_for_unit("s"), Direction::LowerIsBetter);
        assert_eq!(direction_for_unit("count"), Direction::TwoSided);
        assert_eq!(direction_for_unit("frac"), Direction::TwoSided);
    }

    #[test]
    fn identical_documents_pass() {
        let base = doc(&[("lat", "µs", 100.0), ("rate", "jobs/s", 5.0)]);
        let report = compare(&base, &base, DEFAULT_TOLERANCE).unwrap();
        assert!(report.passed());
        assert_eq!(report.findings.len(), 2);
    }

    #[test]
    fn injected_20pct_slowdown_fails() {
        let base = doc(&[("lat", "µs", 100.0)]);
        let slow = doc(&[("lat", "µs", 120.0)]);
        let report = compare(&base, &slow, DEFAULT_TOLERANCE).unwrap();
        assert!(!report.passed());
        assert_eq!(report.findings[0].status, GateStatus::Regression);
        assert!(report.render().contains("REGRESSION"));
    }

    #[test]
    fn ten_pct_drift_passes() {
        let base = doc(&[("lat", "µs", 100.0)]);
        let a_bit_slower = doc(&[("lat", "µs", 110.0)]);
        assert!(compare(&base, &a_bit_slower, DEFAULT_TOLERANCE).unwrap().passed());
    }

    #[test]
    fn rate_drop_fails_rate_gain_passes() {
        let base = doc(&[("rate", "Mpairs/s", 100.0)]);
        let slower = doc(&[("rate", "Mpairs/s", 80.0)]);
        let faster = doc(&[("rate", "Mpairs/s", 200.0)]);
        assert!(!compare(&base, &slower, DEFAULT_TOLERANCE).unwrap().passed());
        assert!(compare(&base, &faster, DEFAULT_TOLERANCE).unwrap().passed());
    }

    #[test]
    fn faster_latency_passes_two_sided_drift_fails() {
        let base = doc(&[("lat", "µs", 100.0), ("jobs", "count", 10.0)]);
        let better = doc(&[("lat", "µs", 50.0), ("jobs", "count", 10.0)]);
        assert!(compare(&base, &better, DEFAULT_TOLERANCE).unwrap().passed());
        let drifted = doc(&[("lat", "µs", 100.0), ("jobs", "count", 13.0)]);
        assert!(!compare(&base, &drifted, DEFAULT_TOLERANCE).unwrap().passed());
    }

    #[test]
    fn missing_series_fails_new_series_warns() {
        let base = doc(&[("a", "s", 1.0), ("b", "s", 2.0)]);
        let missing = doc(&[("a", "s", 1.0)]);
        let report = compare(&base, &missing, DEFAULT_TOLERANCE).unwrap();
        assert!(!report.passed());
        assert!(report
            .findings
            .iter()
            .any(|f| f.label == "b" && f.status == GateStatus::MissingInFresh));
        let extra_report = compare(&missing, &base, DEFAULT_TOLERANCE).unwrap();
        assert!(extra_report.passed());
        assert_eq!(extra_report.warnings.len(), 1);
    }

    #[test]
    fn seed_or_params_mismatch_is_hard_error() {
        let base = doc(&[("a", "s", 1.0)]);
        let mut h = BenchHarness::new("t", 10);
        h.param_u64("size", 64);
        h.series("a", "s", Summary::from_slice(&[1.0]));
        assert!(compare(&base, &h.to_json(), DEFAULT_TOLERANCE).is_err());
        let mut h2 = BenchHarness::new("t", 9);
        h2.param_u64("size", 65);
        h2.series("a", "s", Summary::from_slice(&[1.0]));
        assert!(compare(&base, &h2.to_json(), DEFAULT_TOLERANCE).is_err());
    }

    #[test]
    fn zero_baseline_requires_zero_fresh() {
        let base = doc(&[("lost", "count", 0.0)]);
        assert!(compare(&base, &base, DEFAULT_TOLERANCE).unwrap().passed());
        let nonzero = doc(&[("lost", "count", 1.0)]);
        assert!(!compare(&base, &nonzero, DEFAULT_TOLERANCE).unwrap().passed());
    }
}
