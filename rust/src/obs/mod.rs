//! Observability pipeline: machine-readable perf trajectory.
//!
//! Three pieces, one contract:
//!
//! * [`harness`] — every bench registers sample series with a
//!   [`harness::BenchHarness`] and writes a deterministic
//!   `BENCH_<name>.json` (no wall-clock fields; same-seed runs are
//!   byte-identical).
//! * [`event`] / [`report`] — `run_scenario` emits typed JSONL lifecycle
//!   records through a [`event::ScenarioLogger`], and
//!   [`report::EventRollup`] folds a log back into report-style metrics.
//! * [`gate`] — `gridlan bench --check` compares fresh bench JSON against
//!   the committed baselines and fails on a >15% mean regression.

pub mod event;
pub mod gate;
pub mod harness;
pub mod report;

pub use event::{EventKind, ScenarioEvent, ScenarioLogger};
pub use gate::{compare, GateReport, DEFAULT_TOLERANCE};
pub use harness::BenchHarness;
pub use report::EventRollup;
