//! Shared benchmark harness: every bench registers named sample series
//! here and the harness writes one deterministic `BENCH_<name>.json`.
//!
//! Schema (the machine-readable perf-trajectory contract):
//!
//! ```json
//! {
//!   "name": "boot_storm",
//!   "params": { "fleet_sizes": [1, 4], "...": "bench-specific" },
//!   "seed": 28189,
//!   "series": [
//!     { "label": "boot_window_n4", "n": 4, "mean": 41.2, "sd": 0.4,
//!       "p50": 41.1, "p99": 41.9, "unit": "s" }
//!   ]
//! }
//! ```
//!
//! There are deliberately **no wall-clock fields**: only deterministic
//! sim-derived metrics (simulated durations, model predictions, event
//! counts, EP tallies) enter the JSON, so two same-seed runs produce
//! byte-identical files.  Wall-clock measurements stay on stdout.

use std::io;
use std::path::{Path, PathBuf};

use crate::util::json::{Json, JsonObj};
use crate::util::stats::Summary;

/// True when `GRIDLAN_BENCH_QUICK=1` (or `true`/`yes`): benches shrink
/// their *wall-clock-only* stdout loops for CI.  Quick mode must never
/// change what goes into the JSON — baselines are mode-invariant.
pub fn quick() -> bool {
    matches!(
        std::env::var("GRIDLAN_BENCH_QUICK").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

/// Pick the full-size or quick-mode value for a wall-clock-only loop.
pub fn pick<T>(full: T, quick_value: T) -> T {
    if quick() {
        quick_value
    } else {
        full
    }
}

/// Accumulates one bench's parameters and sample series, then renders the
/// deterministic `BENCH_<name>.json` document.
#[derive(Debug, Clone)]
pub struct BenchHarness {
    name: String,
    seed: u64,
    params: JsonObj,
    series: Vec<(String, String, Summary)>,
}

impl BenchHarness {
    pub fn new(name: &str, seed: u64) -> Self {
        Self { name: name.to_string(), seed, params: JsonObj::new(), series: Vec::new() }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Record a bench parameter (problem size, sweep axis, policy list…).
    /// Parameters are part of the gate contract: a baseline with different
    /// params is not comparable and the gate fails loudly.
    pub fn param(&mut self, key: &str, value: Json) {
        self.params.insert(key, value);
    }

    pub fn param_u64(&mut self, key: &str, v: u64) {
        self.param(key, Json::Num(v as f64));
    }

    pub fn param_f64(&mut self, key: &str, v: f64) {
        self.param(key, Json::Num(v));
    }

    pub fn param_str(&mut self, key: &str, v: &str) {
        self.param(key, Json::Str(v.to_string()));
    }

    /// Register a complete series under `label`.  Labels must be unique
    /// within a bench — a duplicate is a bug in the bench, so it panics.
    pub fn series(&mut self, label: &str, unit: &str, summary: Summary) {
        assert!(
            !self.series.iter().any(|(l, _, _)| l == label),
            "duplicate bench series label {label:?}"
        );
        self.series.push((label.to_string(), unit.to_string(), summary));
    }

    /// Append one sample to the series `label`, creating it on first use.
    pub fn sample(&mut self, label: &str, unit: &str, x: f64) {
        if let Some((_, u, s)) = self.series.iter_mut().find(|(l, _, _)| l == label) {
            assert_eq!(u, unit, "series {label:?} unit changed");
            s.push(x);
        } else {
            self.series.push((label.to_string(), unit.to_string(), Summary::from_slice(&[x])));
        }
    }

    pub fn n_series(&self) -> usize {
        self.series.len()
    }

    /// The full document: `{name, params, seed, series: [...]}`.
    pub fn to_json(&self) -> Json {
        let mut doc = JsonObj::new();
        doc.insert("name", Json::Str(self.name.clone()));
        doc.insert("params", Json::Obj(self.params.clone()));
        doc.insert("seed", Json::Num(self.seed as f64));
        let series: Vec<Json> = self
            .series
            .iter()
            .map(|(label, unit, summary)| {
                let mut entry = JsonObj::new();
                entry.insert("label", Json::Str(label.clone()));
                if let Json::Obj(stats) = summary.to_json() {
                    for (k, v) in stats.iter() {
                        entry.insert(k, v.clone());
                    }
                }
                entry.insert("unit", Json::Str(unit.clone()));
                Json::Obj(entry)
            })
            .collect();
        doc.insert("series", Json::Arr(series));
        Json::Obj(doc)
    }

    /// Pretty-printed document with a trailing newline — the exact bytes
    /// [`BenchHarness::write_to`] puts on disk.
    pub fn render_json(&self) -> String {
        let mut s = self.to_json().to_pretty();
        s.push('\n');
        s
    }

    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.name)
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path written.
    pub fn write_to(&self, dir: &Path) -> io::Result<PathBuf> {
        let path = dir.join(self.file_name());
        std::fs::write(&path, self.render_json())?;
        Ok(path)
    }

    /// Write into the current directory (the repo root by convention).
    pub fn write(&self) -> io::Result<PathBuf> {
        self.write_to(Path::new("."))
    }
}

/// Schema check for a `BENCH_*.json` document (used by the gate before
/// comparing, and by the round-trip tests).
pub fn validate(doc: &Json) -> Result<(), String> {
    let obj = doc.as_obj().ok_or("document is not an object")?;
    let name = obj
        .get("name")
        .and_then(Json::as_str)
        .ok_or("missing string field \"name\"")?;
    if name.is_empty() {
        return Err("\"name\" is empty".into());
    }
    obj.get("params")
        .and_then(Json::as_obj)
        .ok_or("missing object field \"params\"")?;
    obj.get("seed")
        .and_then(Json::as_u64)
        .ok_or("missing integer field \"seed\"")?;
    let series = obj
        .get("series")
        .and_then(Json::as_arr)
        .ok_or("missing array field \"series\"")?;
    if series.is_empty() {
        return Err("\"series\" is empty".into());
    }
    for (i, entry) in series.iter().enumerate() {
        let e = entry.as_obj().ok_or_else(|| format!("series[{i}] is not an object"))?;
        let label = e
            .get("label")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("series[{i}] missing string \"label\""))?;
        for key in ["n", "mean", "sd", "p50", "p99"] {
            let v = e
                .get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("series[{i}] ({label}) missing number \"{key}\""))?;
            if !v.is_finite() {
                return Err(format!("series[{i}] ({label}) field \"{key}\" is not finite"));
            }
        }
        e.get("unit")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("series[{i}] ({label}) missing string \"unit\""))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_harness() -> BenchHarness {
        let mut h = BenchHarness::new("demo", 42);
        h.param_u64("iters", 100);
        h.param_str("mode", "full");
        h.sample("lat", "µs", 10.0);
        h.sample("lat", "µs", 12.0);
        h.series("rate", "Mpairs/s", Summary::from_slice(&[5.0]));
        h
    }

    #[test]
    fn document_shape() {
        let h = sample_harness();
        let doc = h.to_json();
        validate(&doc).unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("demo"));
        assert_eq!(doc.get("seed").unwrap().as_u64(), Some(42));
        let series = doc.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].get("label").unwrap().as_str(), Some("lat"));
        assert_eq!(series[0].get("n").unwrap().as_u64(), Some(2));
        assert_eq!(series[0].get("unit").unwrap().as_str(), Some("µs"));
        // field order is part of the byte-identity contract
        let keys: Vec<&str> = series[0]
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, _)| k.as_str())
            .collect();
        assert_eq!(keys, ["label", "n", "mean", "sd", "p50", "p99", "unit"]);
    }

    #[test]
    fn render_is_deterministic() {
        assert_eq!(sample_harness().render_json(), sample_harness().render_json());
        assert!(sample_harness().render_json().ends_with('\n'));
    }

    #[test]
    fn json_round_trips_through_parser() {
        let h = sample_harness();
        let text = h.render_json();
        let parsed = Json::parse(&text).unwrap();
        validate(&parsed).unwrap();
        assert_eq!(parsed, h.to_json());
        // re-rendering the parsed document reproduces the bytes exactly
        let mut again = parsed.to_pretty();
        again.push('\n');
        assert_eq!(again, text);
    }

    #[test]
    fn validate_rejects_bad_documents() {
        assert!(validate(&Json::Null).is_err());
        let mut h = BenchHarness::new("x", 1);
        h.sample("a", "s", 1.0);
        let good = h.to_json();
        validate(&good).unwrap();
        // strip the series -> invalid
        let empty = Json::parse(r#"{"name":"x","params":{},"seed":1,"series":[]}"#).unwrap();
        assert!(validate(&empty).is_err());
        let missing =
            Json::parse(r#"{"name":"x","params":{},"seed":1,"series":[{"label":"a"}]}"#).unwrap();
        assert!(validate(&missing).is_err());
    }

    #[test]
    #[should_panic]
    fn duplicate_series_label_panics() {
        let mut h = BenchHarness::new("x", 1);
        h.series("a", "s", Summary::new());
        h.series("a", "s", Summary::new());
    }

    #[test]
    fn file_name_convention() {
        assert_eq!(BenchHarness::new("ep_throughput", 0).file_name(), "BENCH_ep_throughput.json");
    }

    #[test]
    fn pick_respects_env() {
        // not parallel-safe to mutate the env here; just exercise the
        // non-quick path (tests run without GRIDLAN_BENCH_QUICK set).
        if !quick() {
            assert_eq!(pick(100u64, 5), 100);
        }
    }
}
