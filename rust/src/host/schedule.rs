//! Client availability schedules — the paper's §5 future-work feature,
//! implemented as an extension (experiment X-sched).
//!
//! "Clients could then be tagged and the administrator could set a schedule
//! specifying when jobs may be received from particular groups of clients.
//! One example is a user who offers his computer for use by the local grid
//! at nighttime and weekends."
//!
//! Time is simulation time; we anchor t=0 at Monday 00:00 and use
//! 7×24-hour weeks.

use crate::sim::clock::{SimTime, DUR_SEC};

const HOUR: SimTime = 3600 * DUR_SEC;
const DAY: SimTime = 24 * HOUR;
const WEEK: SimTime = 7 * DAY;

/// A weekly availability calendar: allowed [start_hour, end_hour) windows
/// per weekday (0 = Monday).
#[derive(Debug, Clone, PartialEq)]
pub struct AvailabilitySchedule {
    /// (weekday 0-6, start hour 0-24, end hour 0-24); end may be <= start
    /// for "never this day" (empty window).
    windows: Vec<(u8, u8, u8)>,
}

impl AvailabilitySchedule {
    /// Always available (the paper's default behaviour today).
    pub fn always() -> Self {
        Self { windows: (0..7).map(|d| (d, 0, 24)).collect() }
    }

    /// The paper's example: nights (20:00–08:00) and all weekend.
    pub fn nights_and_weekends() -> Self {
        let mut windows = Vec::new();
        for d in 0..5u8 {
            windows.push((d, 20, 24));
            windows.push((d, 0, 8));
        }
        windows.push((5, 0, 24));
        windows.push((6, 0, 24));
        Self { windows }
    }

    /// Custom schedule from windows.
    pub fn from_windows(windows: Vec<(u8, u8, u8)>) -> Self {
        for &(d, s, e) in &windows {
            assert!(d < 7 && s <= 24 && e <= 24, "bad window ({d},{s},{e})");
        }
        Self { windows }
    }

    fn decompose(at: SimTime) -> (u8, f64) {
        let in_week = at % WEEK;
        let day = (in_week / DAY) as u8;
        let hour = (in_week % DAY) as f64 / HOUR as f64;
        (day, hour)
    }

    /// May the grid run jobs on this client at simulated time `at`?
    pub fn available_at(&self, at: SimTime) -> bool {
        let (day, hour) = Self::decompose(at);
        self.windows
            .iter()
            .any(|&(d, s, e)| d == day && (s as f64) <= hour && hour < e as f64)
    }

    /// Next time ≥ `at` when the client becomes available (None if never).
    pub fn next_available(&self, at: SimTime) -> Option<SimTime> {
        if self.available_at(at) {
            return Some(at);
        }
        // Scan hour boundaries for up to one week.
        let mut t = at - (at % HOUR) + HOUR;
        for _ in 0..(7 * 24 + 1) {
            if self.available_at(t) {
                return Some(t);
            }
            t += HOUR;
        }
        None
    }

    /// Time remaining in the current window (0 if unavailable) — the
    /// scheduler uses this to freeze jobs before the window closes.
    pub fn window_remaining(&self, at: SimTime) -> SimTime {
        if !self.available_at(at) {
            return 0;
        }
        let mut t = at;
        let step = HOUR / 60; // minute resolution
        while self.available_at(t) {
            t += step;
            if t - at > WEEK {
                return WEEK; // effectively always-on
            }
        }
        t - at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn always_is_always() {
        let s = AvailabilitySchedule::always();
        for h in [0u64, 5, 13, 23] {
            assert!(s.available_at(h * HOUR + 3 * DAY));
        }
        assert_eq!(s.window_remaining(0), WEEK);
    }

    #[test]
    fn nights_and_weekends_pattern() {
        let s = AvailabilitySchedule::nights_and_weekends();
        // Monday 10:00 — owner is working.
        assert!(!s.available_at(10 * HOUR));
        // Monday 21:00 — night window.
        assert!(s.available_at(21 * HOUR));
        // Monday 03:00 — early morning window.
        assert!(s.available_at(3 * HOUR));
        // Saturday noon — weekend.
        assert!(s.available_at(5 * DAY + 12 * HOUR));
    }

    #[test]
    fn next_available_from_weekday_morning() {
        let s = AvailabilitySchedule::nights_and_weekends();
        // Monday 09:00 -> next window opens Monday 20:00.
        let next = s.next_available(9 * HOUR).unwrap();
        assert_eq!(next, 20 * HOUR);
    }

    #[test]
    fn window_remaining_shrinks() {
        let s = AvailabilitySchedule::nights_and_weekends();
        let at_2100 = 21 * HOUR;
        let at_2200 = 22 * HOUR;
        assert!(s.window_remaining(at_2100) > s.window_remaining(at_2200));
    }

    #[test]
    fn weeks_repeat() {
        let s = AvailabilitySchedule::nights_and_weekends();
        let t = 21 * HOUR;
        assert_eq!(s.available_at(t), s.available_at(t + WEEK));
        assert_eq!(s.available_at(t), s.available_at(t + 52 * WEEK));
    }

    #[test]
    #[should_panic(expected = "bad window")]
    fn invalid_window_panics() {
        AvailabilitySchedule::from_windows(vec![(7, 0, 24)]);
    }
}
