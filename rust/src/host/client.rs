//! The Gridlan client: a graduate student's workstation.
//!
//! Invisibility requirement (paper §1): "The installed software must not
//! disrupt the usual work of ordinary users of the machine or impose any
//! specific operating system" — hence Windows clients run VirtualBox and
//! Linux clients run QEMU/KVM (Table 1), and everything happens at OS
//! start-up without user interaction.

use crate::vm::hypervisor::{Hypervisor, HypervisorKind};
use crate::vm::cpu::CpuModel;

/// Host operating system (Table 1 column "Client OS").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientOs {
    Linux,
    Windows,
}

impl ClientOs {
    /// The hypervisor the paper deploys on this OS.
    pub fn default_hypervisor(self) -> HypervisorKind {
        match self {
            ClientOs::Linux => HypervisorKind::QemuKvm,
            ClientOs::Windows => HypervisorKind::VirtualBox,
        }
    }
}

/// A client workstation and its agent state.
#[derive(Debug, Clone)]
pub struct ClientAgent {
    pub name: String,
    pub os: ClientOs,
    pub cpu: CpuModel,
    pub hypervisor: Hypervisor,
    /// Whether the workstation is powered on.
    pub powered: bool,
    /// Whether the VPN tunnel is up.
    pub vpn_connected: bool,
    /// Interactive (owner) load, in busy cores — the VM competes with it.
    pub interactive_load_cores: f64,
}

impl ClientAgent {
    pub fn new(name: &str, os: ClientOs, cpu: CpuModel) -> Self {
        Self {
            name: name.to_string(),
            os,
            hypervisor: Hypervisor::new(os.default_hypervisor()),
            cpu,
            powered: true,
            vpn_connected: false,
            interactive_load_cores: 0.0,
        }
    }

    /// Replace the hypervisor (paper §5: swap VirtualBox for pure QEMU).
    pub fn with_hypervisor(mut self, kind: HypervisorKind) -> Self {
        self.hypervisor = Hypervisor::new(kind);
        self
    }

    /// Cores the VM can use without disturbing the owner.
    pub fn vm_cores(&self) -> u32 {
        (self.cpu.cores as f64 - self.interactive_load_cores).floor().max(0.0) as u32
    }

    /// Guest EP rate (Mpairs/s) of one vCPU when `active` vCPUs are busy
    /// on this client.
    pub fn guest_ep_rate(&self, active: u32) -> f64 {
        self.hypervisor.guest_rate(self.cpu.ep_rate_mpairs(active))
    }

    /// Paper Table 1 clients, exactly.
    pub fn table1() -> Vec<ClientAgent> {
        vec![
            ClientAgent::new("n01", ClientOs::Linux, CpuModel::xeon_e5_2630()),
            ClientAgent::new("n02", ClientOs::Windows, CpuModel::i7_3930k()),
            ClientAgent::new("n03", ClientOs::Windows, CpuModel::i7_2920xm()),
            ClientAgent::new("n04", ClientOs::Windows, CpuModel::i7_960()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let clients = ClientAgent::table1();
        assert_eq!(clients.len(), 4);
        let cores: Vec<u32> = clients.iter().map(|c| c.cpu.cores).collect();
        assert_eq!(cores, vec![12, 6, 4, 4]);
        assert_eq!(cores.iter().sum::<u32>(), 26);
        assert_eq!(clients[0].os, ClientOs::Linux);
        assert!(clients[1..].iter().all(|c| c.os == ClientOs::Windows));
    }

    #[test]
    fn os_selects_hypervisor() {
        assert_eq!(ClientOs::Linux.default_hypervisor(), HypervisorKind::QemuKvm);
        assert_eq!(ClientOs::Windows.default_hypervisor(), HypervisorKind::VirtualBox);
    }

    #[test]
    fn interactive_load_reduces_vm_cores() {
        let mut c = ClientAgent::new("x", ClientOs::Linux, CpuModel::xeon_e5_2630());
        assert_eq!(c.vm_cores(), 12);
        c.interactive_load_cores = 2.5;
        assert_eq!(c.vm_cores(), 9);
    }

    #[test]
    fn windows_guest_rate_below_linux_guest_rate() {
        // Same CPU, different hypervisor efficiency.
        let cpu = CpuModel::i7_960();
        let lin = ClientAgent::new("l", ClientOs::Linux, cpu.clone());
        let win = ClientAgent::new("w", ClientOs::Windows, cpu);
        assert!(win.guest_ep_rate(4) < lin.guest_ep_rate(4));
    }

    #[test]
    fn hypervisor_swap() {
        let c = ClientAgent::new("x", ClientOs::Windows, CpuModel::i7_960())
            .with_hypervisor(HypervisorKind::PureQemu);
        assert!(c.guest_ep_rate(1) < 5.0); // TCG is painfully slow
    }
}
