//! Client-side host agents (paper §2.5, §2.6, §5).
//!
//! * [`client`] — the workstation agent: connects the VPN at OS start-up
//!   and launches the node VM;
//! * [`watchdog`] — "A script in the client machine asks the server if the
//!   virtual machine is on.  If the status is 'off', then a script to
//!   restart the node is executed";
//! * [`faults`] — fault injector: inadvertent power-off, network drop,
//!   VM crash (the events §2.6 defends against);
//! * [`schedule`] — the §5 future-work client availability calendar
//!   ("a user who offers his computer ... at nighttime and weekends"),
//!   implemented here as an extension.

pub mod client;
pub mod faults;
pub mod schedule;
pub mod watchdog;

pub use client::{ClientAgent, ClientOs};
pub use faults::{FaultKind, FaultPlan};
pub use schedule::AvailabilitySchedule;
pub use watchdog::Watchdog;
