//! Fault injection (paper §2.6, §4).
//!
//! "The computer clients are unreliable, since they can be inadvertently
//! turned off or can be victims of a network connection fault" ... "a
//! system crash, or ... interruptions to the electrical power supply or
//! network events."
//!
//! A [`FaultPlan`] generates a deterministic schedule of fault events from
//! per-kind rates; the coordinator applies them to clients/nodes, and the
//! fault-recovery bench measures job goodput under increasing fault rates.

use crate::sim::clock::{SimTime, DUR_SEC};
use crate::util::rng::SplitMix64;

/// Kinds of client/node failure, mirroring the paper's list.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// Owner turns the workstation off (client + node die, later reboot).
    ClientPowerOff,
    /// Network drop (VPN falls, node unreachable; machine keeps running).
    NetworkDrop,
    /// Guest VM crash (host fine; watchdog restarts).
    VmCrash,
}

/// One scheduled fault.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub at: SimTime,
    pub client: String,
    pub kind: FaultKind,
    /// How long the condition lasts before repair begins (e.g. machine
    /// stays off this long).
    pub outage: SimTime,
}

/// Poisson-ish fault generator: per-kind mean-time-between-failures.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub mtbf_power_off: SimTime,
    pub mtbf_net_drop: SimTime,
    pub mtbf_vm_crash: SimTime,
    pub mean_outage: SimTime,
}

impl FaultPlan {
    /// A lab-like profile: a power-off every ~8h per client, net blip every
    /// ~12h, VM crash every ~24h; outages average 10 min.
    pub fn lab_default() -> Self {
        Self {
            mtbf_power_off: 8 * 3600 * DUR_SEC,
            mtbf_net_drop: 12 * 3600 * DUR_SEC,
            mtbf_vm_crash: 24 * 3600 * DUR_SEC,
            mean_outage: 600 * DUR_SEC,
        }
    }

    /// No faults (clean-run baseline).
    pub fn none() -> Self {
        Self { mtbf_power_off: 0, mtbf_net_drop: 0, mtbf_vm_crash: 0, mean_outage: 0 }
    }

    /// Scale all rates by `factor` (>1 = more faults). MTBFs shrink.
    pub fn scaled(&self, factor: f64) -> Self {
        let s = |t: SimTime| {
            if t == 0 || factor <= 0.0 {
                0
            } else {
                ((t as f64 / factor) as u64).max(1)
            }
        };
        Self {
            mtbf_power_off: s(self.mtbf_power_off),
            mtbf_net_drop: s(self.mtbf_net_drop),
            mtbf_vm_crash: s(self.mtbf_vm_crash),
            mean_outage: self.mean_outage,
        }
    }

    fn draw_exponential(rng: &mut SplitMix64, mean: SimTime) -> SimTime {
        let u = rng.next_f64().max(1e-12);
        (-(u.ln()) * mean as f64) as SimTime
    }

    /// Generate all fault events for `clients` over `[0, horizon)`.
    /// Deterministic for a given rng seed; sorted by time.
    pub fn generate(
        &self,
        clients: &[String],
        horizon: SimTime,
        rng: &mut SplitMix64,
    ) -> Vec<FaultEvent> {
        let mut events = Vec::new();
        for client in clients {
            for (kind, mtbf) in [
                (FaultKind::ClientPowerOff, self.mtbf_power_off),
                (FaultKind::NetworkDrop, self.mtbf_net_drop),
                (FaultKind::VmCrash, self.mtbf_vm_crash),
            ] {
                if mtbf == 0 {
                    continue;
                }
                let mut t = Self::draw_exponential(rng, mtbf);
                while t < horizon {
                    let outage = Self::draw_exponential(rng, self.mean_outage.max(1));
                    events.push(FaultEvent { at: t, client: client.clone(), kind, outage });
                    t += Self::draw_exponential(rng, mtbf).max(DUR_SEC);
                }
            }
        }
        events.sort_by_key(|e| e.at);
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn clients() -> Vec<String> {
        vec!["n01".into(), "n02".into(), "n03".into(), "n04".into()]
    }

    #[test]
    fn none_plan_generates_nothing() {
        let mut rng = SplitMix64::new(1);
        let ev = FaultPlan::none().generate(&clients(), 24 * 3600 * DUR_SEC, &mut rng);
        assert!(ev.is_empty());
    }

    #[test]
    fn rate_scales_event_count() {
        let mut r1 = SplitMix64::new(2);
        let mut r2 = SplitMix64::new(2);
        let horizon = 7 * 24 * 3600 * DUR_SEC;
        let base = FaultPlan::lab_default().generate(&clients(), horizon, &mut r1);
        let heavy = FaultPlan::lab_default().scaled(5.0).generate(&clients(), horizon, &mut r2);
        assert!(heavy.len() > base.len() * 2, "{} vs {}", heavy.len(), base.len());
    }

    #[test]
    fn events_sorted_and_within_horizon() {
        let mut rng = SplitMix64::new(3);
        let horizon = 3 * 24 * 3600 * DUR_SEC;
        let ev = FaultPlan::lab_default().generate(&clients(), horizon, &mut rng);
        assert!(!ev.is_empty());
        for w in ev.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(ev.iter().all(|e| e.at < horizon));
    }

    #[test]
    fn deterministic_per_seed() {
        let horizon = 24 * 3600 * DUR_SEC;
        let a = FaultPlan::lab_default().generate(&clients(), horizon, &mut SplitMix64::new(7));
        let b = FaultPlan::lab_default().generate(&clients(), horizon, &mut SplitMix64::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn expected_rate_roughly_matches_mtbf() {
        // One client, MTBF 1h over 100h -> ~100 power-off events (+-40%).
        let plan = FaultPlan {
            mtbf_power_off: 3600 * DUR_SEC,
            mtbf_net_drop: 0,
            mtbf_vm_crash: 0,
            mean_outage: 60 * DUR_SEC,
        };
        let mut rng = SplitMix64::new(11);
        let ev = plan.generate(&["c".into()], 100 * 3600 * DUR_SEC, &mut rng);
        assert!(
            (60..=140).contains(&ev.len()),
            "got {} events",
            ev.len()
        );
    }
}
