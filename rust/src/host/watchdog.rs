//! Client-side watchdog (paper §2.6).
//!
//! "A script in the client machine asks the server if the virtual machine
//! (the Gridlan node) is on.  If the status is 'off,' then a script to
//! restart the node is executed."
//!
//! The watchdog polls the server's status service on its own period and
//! decides whether to trigger a VM restart.  It is intentionally dumb —
//! all intelligence (ping sweeps, state table) is server-side in
//! `monitor`; the split matches the paper's design.

use crate::sim::clock::{SimTime, DUR_SEC};

/// What the watchdog decided on one poll.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WatchdogAction {
    /// Node reported on — nothing to do.
    None,
    /// Node reported off — restart the VM.
    RestartVm,
    /// Could not reach the server (VPN down) — reconnect first.
    ReconnectVpn,
}

/// Per-client watchdog state.
#[derive(Debug, Clone)]
pub struct Watchdog {
    pub client: String,
    /// Poll period (the paper pairs this with the server's 5-minute pinger).
    pub period: SimTime,
    /// Restarts triggered so far.
    pub restarts: u32,
    /// Back-off: after a restart, skip this many polls before acting again
    /// (a VM boot takes minutes over TFTP; don't restart a booting VM).
    pub cooldown_polls: u32,
    cooldown_left: u32,
    pub last_action: Option<(SimTime, WatchdogAction)>,
}

impl Watchdog {
    pub fn new(client: &str) -> Self {
        Self {
            client: client.to_string(),
            period: 300 * DUR_SEC,
            restarts: 0,
            cooldown_polls: 2,
            cooldown_left: 0,
            last_action: None,
        }
    }

    /// One poll: `server_reachable` is whether the status query got an
    /// answer; `node_reported_on` is the server's answer (None when
    /// unreachable).
    pub fn poll(
        &mut self,
        now: SimTime,
        server_reachable: bool,
        node_reported_on: Option<bool>,
    ) -> WatchdogAction {
        let action = if !server_reachable {
            WatchdogAction::ReconnectVpn
        } else if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
            WatchdogAction::None
        } else {
            match node_reported_on {
                Some(true) => WatchdogAction::None,
                Some(false) | None => {
                    self.restarts += 1;
                    self.cooldown_left = self.cooldown_polls;
                    WatchdogAction::RestartVm
                }
            }
        };
        self.last_action = Some((now, action));
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn healthy_node_no_action() {
        let mut w = Watchdog::new("n01");
        assert_eq!(w.poll(0, true, Some(true)), WatchdogAction::None);
        assert_eq!(w.restarts, 0);
    }

    #[test]
    fn off_node_triggers_restart() {
        let mut w = Watchdog::new("n01");
        assert_eq!(w.poll(0, true, Some(false)), WatchdogAction::RestartVm);
        assert_eq!(w.restarts, 1);
    }

    #[test]
    fn cooldown_suppresses_thrashing() {
        let mut w = Watchdog::new("n01");
        assert_eq!(w.poll(0, true, Some(false)), WatchdogAction::RestartVm);
        // Node still booting, server still says off: cooldown holds.
        assert_eq!(w.poll(300, true, Some(false)), WatchdogAction::None);
        assert_eq!(w.poll(600, true, Some(false)), WatchdogAction::None);
        // Cooldown expired and node still off: restart again.
        assert_eq!(w.poll(900, true, Some(false)), WatchdogAction::RestartVm);
        assert_eq!(w.restarts, 2);
    }

    #[test]
    fn unreachable_server_reconnects_vpn() {
        let mut w = Watchdog::new("n01");
        assert_eq!(w.poll(0, false, None), WatchdogAction::ReconnectVpn);
        assert_eq!(w.restarts, 0);
    }

    #[test]
    fn recovery_resets_nothing_but_acts_sane() {
        let mut w = Watchdog::new("n01");
        w.poll(0, true, Some(false));
        w.poll(300, true, Some(true)); // cooldown tick, node back
        w.poll(600, true, Some(true));
        assert_eq!(w.poll(900, true, Some(true)), WatchdogAction::None);
        assert_eq!(w.restarts, 1);
    }
}
