//! Resource requests and allocations: matching `nodes=X:ppn=Y` against the
//! node registry.

use std::collections::BTreeMap;

/// What a job asks for (`#PBS -l nodes=X:ppn=Y`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceRequest {
    pub nodes: u32,
    pub ppn: u32,
}

impl Default for ResourceRequest {
    fn default() -> Self {
        Self { nodes: 1, ppn: 1 }
    }
}

impl ResourceRequest {
    pub fn total_cores(&self) -> u32 {
        self.nodes * self.ppn
    }
}

/// Cores granted per node (node name → core count).  BTreeMap for
/// deterministic iteration.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Allocation {
    pub cores: BTreeMap<String, u32>,
}

impl Allocation {
    pub fn total_cores(&self) -> u32 {
        self.cores.values().sum()
    }

    pub fn node_count(&self) -> usize {
        self.cores.len()
    }

    pub fn nodes(&self) -> impl Iterator<Item = &String> {
        self.cores.keys()
    }
}

/// A node's free capacity as the allocator sees it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FreeNode {
    pub name: String,
    pub free_cores: u32,
}

/// First-fit decreasing match of a request against free nodes.  Torque
/// semantics: each requested "node" needs `ppn` cores on a single node;
/// multiple requested nodes may land on the same physical node if it has
/// capacity (like Torque with `np` overcommit disabled, chunks packed).
/// Returns None if unsatisfiable.
pub fn match_request(request: &ResourceRequest, free: &[FreeNode]) -> Option<Allocation> {
    let mut nodes: Vec<FreeNode> = free.iter().filter(|n| n.free_cores >= request.ppn).cloned().collect();
    // Big nodes first: minimizes fragmentation; name tiebreak = determinism.
    nodes.sort_by(|a, b| b.free_cores.cmp(&a.free_cores).then(a.name.cmp(&b.name)));
    let mut alloc = Allocation::default();
    let mut remaining = request.nodes;
    for node in &mut nodes {
        while remaining > 0 && node.free_cores >= request.ppn {
            *alloc.cores.entry(node.name.clone()).or_insert(0) += request.ppn;
            node.free_cores -= request.ppn;
            remaining -= 1;
        }
        if remaining == 0 {
            break;
        }
    }
    if remaining == 0 {
        Some(alloc)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, expect};

    fn free(spec: &[(&str, u32)]) -> Vec<FreeNode> {
        spec.iter()
            .map(|&(n, c)| FreeNode { name: n.to_string(), free_cores: c })
            .collect()
    }

    #[test]
    fn single_node_fit() {
        let a = match_request(
            &ResourceRequest { nodes: 1, ppn: 4 },
            &free(&[("n01", 12), ("n02", 6)]),
        )
        .unwrap();
        assert_eq!(a.total_cores(), 4);
        assert_eq!(a.node_count(), 1);
        assert_eq!(a.cores["n01"], 4); // biggest first
    }

    #[test]
    fn multi_chunk_spreads_when_needed() {
        let a = match_request(
            &ResourceRequest { nodes: 3, ppn: 4 },
            &free(&[("n01", 8), ("n02", 4), ("n03", 4)]),
        )
        .unwrap();
        assert_eq!(a.total_cores(), 12);
        assert_eq!(a.cores["n01"], 8); // two chunks packed on n01
        assert_eq!(a.node_count(), 2);
    }

    #[test]
    fn unsatisfiable_returns_none() {
        assert!(match_request(
            &ResourceRequest { nodes: 2, ppn: 8 },
            &free(&[("n01", 8), ("n02", 6)]),
        )
        .is_none());
        // Total capacity enough but ppn chunk doesn't fit any single node.
        assert!(match_request(
            &ResourceRequest { nodes: 1, ppn: 10 },
            &free(&[("a", 6), ("b", 6)]),
        )
        .is_none());
    }

    #[test]
    fn deterministic_tiebreak() {
        let a1 = match_request(&ResourceRequest { nodes: 1, ppn: 2 }, &free(&[("b", 4), ("a", 4)]));
        let a2 = match_request(&ResourceRequest { nodes: 1, ppn: 2 }, &free(&[("a", 4), ("b", 4)]));
        assert_eq!(a1, a2);
        assert_eq!(a1.unwrap().cores.keys().next().unwrap(), "a");
    }

    #[test]
    fn prop_allocation_never_exceeds_free() {
        prop::check(300, |g| {
            let n_nodes = g.usize_in(1..6);
            let free_nodes: Vec<FreeNode> = (0..n_nodes)
                .map(|i| FreeNode { name: format!("n{i:02}"), free_cores: g.u64_in(0..16) as u32 })
                .collect();
            let req = ResourceRequest {
                nodes: g.u64_in(1..5) as u32,
                ppn: g.u64_in(1..8) as u32,
            };
            match match_request(&req, &free_nodes) {
                None => prop::Outcome::Pass,
                Some(a) => {
                    // granted == requested, and per-node grants fit.
                    let exact = a.total_cores() == req.total_cores();
                    let fits = a.cores.iter().all(|(name, &c)| {
                        free_nodes.iter().find(|f| &f.name == name).map(|f| c <= f.free_cores).unwrap_or(false)
                    });
                    let chunks = a.cores.values().all(|&c| c % req.ppn == 0);
                    expect(
                        exact && fits && chunks,
                        &format!("req={req:?} free={free_nodes:?} alloc={a:?}"),
                    )
                }
            }
        });
    }
}
