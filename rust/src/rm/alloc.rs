//! Resource requests and allocations: matching `nodes=X:ppn=Y` against the
//! node registry.
//!
//! Two allocator paths produce bit-identical decisions:
//!
//! * [`match_request`] — the slice path, for callers holding an ad-hoc
//!   `&[FreeNode]` (shadow-time projections, tests).  It no longer clones
//!   and fully sorts the node list per call: a single scan finds the
//!   biggest eligible node and returns early when every chunk fits there,
//!   and the general case sorts *indices* in thread-local scratch storage.
//! * [`FreePool::match_request`] — the indexed path used by the server's
//!   hot scheduling loop: an incrementally maintained ordered index
//!   (`free cores → sorted node names`) updated on alloc/free/fault, so a
//!   match walks only the eligible buckets in O(log n + nodes granted)
//!   instead of sorting the whole grid.
//!
//! Both walk nodes in (free cores descending, name ascending) order and
//! pack `floor(free/ppn)` chunks per node, so for any pool state the two
//! paths return the same `Allocation`.

use std::cell::RefCell;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicU64, Ordering};

/// What a job asks for (`#PBS -l nodes=X:ppn=Y`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceRequest {
    pub nodes: u32,
    pub ppn: u32,
}

impl Default for ResourceRequest {
    fn default() -> Self {
        Self { nodes: 1, ppn: 1 }
    }
}

impl ResourceRequest {
    pub fn total_cores(&self) -> u32 {
        self.nodes * self.ppn
    }
}

/// Cores granted per node (node name → core count).  BTreeMap for
/// deterministic iteration.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Allocation {
    pub cores: BTreeMap<String, u32>,
}

impl Allocation {
    pub fn total_cores(&self) -> u32 {
        self.cores.values().sum()
    }

    pub fn node_count(&self) -> usize {
        self.cores.len()
    }

    pub fn nodes(&self) -> impl Iterator<Item = &String> {
        self.cores.keys()
    }
}

/// A node's free capacity as the allocator sees it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FreeNode {
    pub name: String,
    pub free_cores: u32,
}

thread_local! {
    /// Scratch index buffer for the slice allocator's general path, reused
    /// across calls so a scheduling cycle doesn't allocate per decision.
    static SCRATCH: RefCell<Vec<usize>> = const { RefCell::new(Vec::new()) };
}

/// First-fit decreasing match of a request against free nodes.  Torque
/// semantics: each requested "node" needs `ppn` cores on a single node;
/// multiple requested nodes may land on the same physical node if it has
/// capacity (like Torque with `np` overcommit disabled, chunks packed).
/// Returns None if unsatisfiable.
pub fn match_request(request: &ResourceRequest, free: &[FreeNode]) -> Option<Allocation> {
    let mut alloc = Allocation::default();
    let mut remaining = request.nodes;
    if remaining == 0 {
        return Some(alloc);
    }
    // One scan for the biggest eligible node (free desc, name asc — the
    // head of the historic full sort).
    let mut best: Option<&FreeNode> = None;
    for n in free {
        if n.free_cores < request.ppn {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => {
                n.free_cores > b.free_cores
                    || (n.free_cores == b.free_cores && n.name < b.name)
            }
        };
        if better {
            best = Some(n);
        }
    }
    let best = best?;
    if request.ppn == 0 {
        // Degenerate zero-width chunks: historically every chunk packed
        // onto the first sorted node, granting zero cores.
        alloc.cores.insert(best.name.clone(), 0);
        return Some(alloc);
    }
    // Early return: the whole request fits the best node, no sort needed.
    if best.free_cores / request.ppn >= remaining {
        alloc.cores.insert(best.name.clone(), remaining * request.ppn);
        return Some(alloc);
    }
    // General path: order eligible node *indices* in reusable scratch.
    SCRATCH.with(|cell| {
        let order = &mut *cell.borrow_mut();
        order.clear();
        order.extend(
            free.iter()
                .enumerate()
                .filter(|(_, n)| n.free_cores >= request.ppn)
                .map(|(i, _)| i),
        );
        // Big nodes first: minimizes fragmentation; name tiebreak =
        // determinism.
        order.sort_by(|&a, &b| {
            free[b]
                .free_cores
                .cmp(&free[a].free_cores)
                .then(free[a].name.cmp(&free[b].name))
        });
        for &i in order.iter() {
            let chunks = (free[i].free_cores / request.ppn).min(remaining);
            *alloc.cores.entry(free[i].name.clone()).or_insert(0) += chunks * request.ppn;
            remaining -= chunks;
            if remaining == 0 {
                break;
            }
        }
    });
    if remaining == 0 {
        Some(alloc)
    } else {
        None
    }
}

static NEXT_POOL_TAG: AtomicU64 = AtomicU64::new(1);

/// Incrementally maintained free-core index over one node pool.
///
/// Invariants:
/// * `by_node` (name → free cores) is the source of truth; `by_free`
///   contains exactly the inverse mapping, with no empty buckets.
/// * `version` bumps on **every** mutating call, even logical no-ops
///   (`touch`, a zero-core alloc), so any memo keyed on `(tag, version)` —
///   the backfill shadow cache — can never see a stale hit.
/// * `tag` is unique per pool instance (process-lifetime counter), so
///   memos can't confuse two pools that happen to share version numbers.
#[derive(Debug)]
pub struct FreePool {
    tag: u64,
    version: u64,
    by_free: BTreeMap<u32, BTreeSet<String>>,
    by_node: BTreeMap<String, u32>,
}

impl Default for FreePool {
    fn default() -> Self {
        Self::new()
    }
}

impl FreePool {
    pub fn new() -> Self {
        Self {
            tag: NEXT_POOL_TAG.fetch_add(1, Ordering::Relaxed),
            version: 0,
            by_free: BTreeMap::new(),
            by_node: BTreeMap::new(),
        }
    }

    /// Instance identity for memo keys.
    pub fn tag(&self) -> u64 {
        self.tag
    }

    /// Mutation counter for memo keys.
    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn len(&self) -> usize {
        self.by_node.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_node.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<u32> {
        self.by_node.get(name).copied()
    }

    /// Insert a node or update its free-core count.
    pub fn set(&mut self, name: &str, free_cores: u32) {
        self.version += 1;
        match self.by_node.get(name).copied() {
            Some(from) => self.rebucket(name, from, free_cores),
            None => {
                self.by_node.insert(name.to_string(), free_cores);
                self.by_free.entry(free_cores).or_default().insert(name.to_string());
            }
        }
    }

    /// Drop a node from the index (offline / faulted).
    pub fn remove(&mut self, name: &str) {
        self.version += 1;
        if let Some(from) = self.by_node.remove(name) {
            self.unbucket(name, from);
        }
    }

    /// Subtract an allocation's cores from the indexed nodes.
    pub fn apply_alloc(&mut self, alloc: &Allocation) {
        self.version += 1;
        for (name, &cores) in &alloc.cores {
            if let Some(from) = self.by_node.get(name).copied() {
                self.rebucket(name, from, from.saturating_sub(cores));
            }
        }
    }

    /// Return an allocation's cores to the indexed nodes.  Nodes no longer
    /// indexed (gone offline since the grant) are skipped — the server
    /// re-`set`s them on power-up from its own busy-core accounting.
    pub fn release_alloc(&mut self, alloc: &Allocation) {
        self.version += 1;
        for (name, &cores) in &alloc.cores {
            if let Some(from) = self.by_node.get(name).copied() {
                self.rebucket(name, from, from.saturating_add(cores));
            }
        }
    }

    /// Bump the version without changing contents: used when the *running
    /// set* changes with no free-core movement (e.g. a zero-core EP stub
    /// completes), which still invalidates backfill shadow projections.
    pub fn touch(&mut self) {
        self.version += 1;
    }

    /// Snapshot as a name-sorted `FreeNode` slice (for shadow projections
    /// and the slice-path allocator).
    pub fn to_free_nodes(&self) -> Vec<FreeNode> {
        self.by_node
            .iter()
            .map(|(name, &free_cores)| FreeNode { name: name.clone(), free_cores })
            .collect()
    }

    /// Indexed first-fit decreasing match: walks `by_free` buckets from
    /// the largest eligible down, names ascending within a bucket — the
    /// exact visit order of the slice path's full sort, without the sort.
    pub fn match_request(&self, request: &ResourceRequest) -> Option<Allocation> {
        let mut alloc = Allocation::default();
        let mut remaining = request.nodes;
        if remaining == 0 {
            return Some(alloc);
        }
        if request.ppn == 0 {
            let (_, names) = self.by_free.iter().next_back()?;
            let name = names.iter().next().expect("by_free buckets are never empty");
            alloc.cores.insert(name.clone(), 0);
            return Some(alloc);
        }
        for (&cap, names) in self.by_free.range(request.ppn..).rev() {
            for name in names {
                let chunks = (cap / request.ppn).min(remaining);
                *alloc.cores.entry(name.clone()).or_insert(0) += chunks * request.ppn;
                remaining -= chunks;
                if remaining == 0 {
                    return Some(alloc);
                }
            }
        }
        None
    }

    fn rebucket(&mut self, name: &str, from: u32, to: u32) {
        if from == to {
            return;
        }
        self.unbucket(name, from);
        self.by_free.entry(to).or_default().insert(name.to_string());
        self.by_node.insert(name.to_string(), to);
    }

    fn unbucket(&mut self, name: &str, from: u32) {
        if let Some(set) = self.by_free.get_mut(&from) {
            set.remove(name);
            if set.is_empty() {
                self.by_free.remove(&from);
            }
        }
    }

    /// Structural invariant check, used by tests.
    #[cfg(test)]
    pub fn audit(&self) {
        let mut rebuilt: BTreeMap<u32, BTreeSet<String>> = BTreeMap::new();
        for (name, &free) in &self.by_node {
            rebuilt.entry(free).or_default().insert(name.clone());
        }
        assert_eq!(self.by_free, rebuilt, "by_free diverged from by_node");
        assert!(self.by_free.values().all(|s| !s.is_empty()), "empty bucket left behind");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, expect};

    fn free(spec: &[(&str, u32)]) -> Vec<FreeNode> {
        spec.iter()
            .map(|&(n, c)| FreeNode { name: n.to_string(), free_cores: c })
            .collect()
    }

    /// The original clone-and-sort allocator, kept as the equivalence
    /// oracle for both the fast-path slice allocator and the index.
    fn reference_match(request: &ResourceRequest, free: &[FreeNode]) -> Option<Allocation> {
        let mut nodes: Vec<FreeNode> =
            free.iter().filter(|n| n.free_cores >= request.ppn).cloned().collect();
        nodes.sort_by(|a, b| b.free_cores.cmp(&a.free_cores).then(a.name.cmp(&b.name)));
        let mut alloc = Allocation::default();
        let mut remaining = request.nodes;
        for node in &mut nodes {
            while remaining > 0 && node.free_cores >= request.ppn {
                *alloc.cores.entry(node.name.clone()).or_insert(0) += request.ppn;
                node.free_cores -= request.ppn;
                remaining -= 1;
            }
            if remaining == 0 {
                break;
            }
        }
        if remaining == 0 {
            Some(alloc)
        } else {
            None
        }
    }

    fn pool_of(free: &[FreeNode]) -> FreePool {
        let mut p = FreePool::new();
        for n in free {
            p.set(&n.name, n.free_cores);
        }
        p
    }

    #[test]
    fn single_node_fit() {
        let a = match_request(
            &ResourceRequest { nodes: 1, ppn: 4 },
            &free(&[("n01", 12), ("n02", 6)]),
        )
        .unwrap();
        assert_eq!(a.total_cores(), 4);
        assert_eq!(a.node_count(), 1);
        assert_eq!(a.cores["n01"], 4); // biggest first
    }

    #[test]
    fn multi_chunk_spreads_when_needed() {
        let a = match_request(
            &ResourceRequest { nodes: 3, ppn: 4 },
            &free(&[("n01", 8), ("n02", 4), ("n03", 4)]),
        )
        .unwrap();
        assert_eq!(a.total_cores(), 12);
        assert_eq!(a.cores["n01"], 8); // two chunks packed on n01
        assert_eq!(a.node_count(), 2);
    }

    #[test]
    fn unsatisfiable_returns_none() {
        assert!(match_request(
            &ResourceRequest { nodes: 2, ppn: 8 },
            &free(&[("n01", 8), ("n02", 6)]),
        )
        .is_none());
        // Total capacity enough but ppn chunk doesn't fit any single node.
        assert!(match_request(
            &ResourceRequest { nodes: 1, ppn: 10 },
            &free(&[("a", 6), ("b", 6)]),
        )
        .is_none());
    }

    #[test]
    fn deterministic_tiebreak() {
        let a1 = match_request(&ResourceRequest { nodes: 1, ppn: 2 }, &free(&[("b", 4), ("a", 4)]));
        let a2 = match_request(&ResourceRequest { nodes: 1, ppn: 2 }, &free(&[("a", 4), ("b", 4)]));
        assert_eq!(a1, a2);
        assert_eq!(a1.unwrap().cores.keys().next().unwrap(), "a");
    }

    #[test]
    fn prop_allocation_never_exceeds_free() {
        prop::check(300, |g| {
            let n_nodes = g.usize_in(1..6);
            let free_nodes: Vec<FreeNode> = (0..n_nodes)
                .map(|i| FreeNode { name: format!("n{i:02}"), free_cores: g.u64_in(0..16) as u32 })
                .collect();
            let req = ResourceRequest {
                nodes: g.u64_in(1..5) as u32,
                ppn: g.u64_in(1..8) as u32,
            };
            match match_request(&req, &free_nodes) {
                None => prop::Outcome::Pass,
                Some(a) => {
                    // granted == requested, and per-node grants fit.
                    let exact = a.total_cores() == req.total_cores();
                    let fits = a.cores.iter().all(|(name, &c)| {
                        free_nodes.iter().find(|f| &f.name == name).map(|f| c <= f.free_cores).unwrap_or(false)
                    });
                    let chunks = a.cores.values().all(|&c| c % req.ppn == 0);
                    expect(
                        exact && fits && chunks,
                        &format!("req={req:?} free={free_nodes:?} alloc={a:?}"),
                    )
                }
            }
        });
    }

    // ------------------------------------------- fast paths + index

    #[test]
    fn zero_nodes_and_zero_ppn_edges_match_the_reference() {
        let nodes = free(&[("n01", 8), ("n02", 12), ("n03", 12)]);
        let pool = pool_of(&nodes);
        for req in [
            ResourceRequest { nodes: 0, ppn: 4 },
            ResourceRequest { nodes: 0, ppn: 0 },
            ResourceRequest { nodes: 3, ppn: 0 },
        ] {
            let want = reference_match(&req, &nodes);
            assert_eq!(match_request(&req, &nodes), want, "slice path, {req:?}");
            assert_eq!(pool.match_request(&req), want, "indexed path, {req:?}");
        }
        // Zero-width chunks on an empty pool: still unsatisfiable.
        let req = ResourceRequest { nodes: 2, ppn: 0 };
        assert_eq!(match_request(&req, &[]), None);
        assert_eq!(FreePool::new().match_request(&req), None);
    }

    #[test]
    fn prop_fast_paths_match_the_reference() {
        prop::check(500, |g| {
            let n_nodes = g.usize_in(0..8);
            let free_nodes: Vec<FreeNode> = (0..n_nodes)
                .map(|i| FreeNode { name: format!("n{i:02}"), free_cores: g.u64_in(0..20) as u32 })
                .collect();
            let req = ResourceRequest {
                nodes: g.u64_in(0..6) as u32,
                ppn: g.u64_in(0..9) as u32,
            };
            let want = reference_match(&req, &free_nodes);
            let got = match_request(&req, &free_nodes);
            expect(
                got == want,
                &format!("req={req:?} free={free_nodes:?} got={got:?} want={want:?}"),
            )
        });
    }

    #[test]
    fn prop_indexed_pool_matches_the_slice_path() {
        prop::check(500, |g| {
            let n_nodes = g.usize_in(0..8);
            let free_nodes: Vec<FreeNode> = (0..n_nodes)
                .map(|i| FreeNode { name: format!("n{i:02}"), free_cores: g.u64_in(0..20) as u32 })
                .collect();
            let pool = pool_of(&free_nodes);
            let req = ResourceRequest {
                nodes: g.u64_in(0..6) as u32,
                ppn: g.u64_in(0..9) as u32,
            };
            let want = reference_match(&req, &free_nodes);
            let got = pool.match_request(&req);
            expect(
                got == want,
                &format!("req={req:?} free={free_nodes:?} got={got:?} want={want:?}"),
            )
        });
    }

    #[test]
    fn pool_tracks_alloc_and_release() {
        let mut pool = pool_of(&free(&[("n01", 12), ("n02", 6), ("n03", 4)]));
        let req = ResourceRequest { nodes: 3, ppn: 4 };
        let a = pool.match_request(&req).unwrap();
        assert_eq!(a.cores["n01"], 8);
        assert_eq!(a.cores["n02"], 4);
        pool.apply_alloc(&a);
        pool.audit();
        assert_eq!(pool.get("n01"), Some(4));
        assert_eq!(pool.get("n02"), Some(2));
        // Post-alloc matches see the reduced capacity.
        let b = pool.match_request(&ResourceRequest { nodes: 1, ppn: 4 }).unwrap();
        assert_eq!(b.cores.keys().next().unwrap(), "n01");
        pool.release_alloc(&a);
        pool.audit();
        assert_eq!(pool.to_free_nodes(), free(&[("n01", 12), ("n02", 6), ("n03", 4)]));
    }

    #[test]
    fn prop_pool_mutations_keep_the_index_consistent() {
        prop::check(200, |g| {
            let mut pool = FreePool::new();
            let mut shadow: BTreeMap<String, u32> = BTreeMap::new();
            for _ in 0..g.usize_in(1..40) {
                let name = format!("n{:02}", g.u64_in(0..6));
                match g.u64_in(0..4) {
                    0 | 1 => {
                        let c = g.u64_in(0..16) as u32;
                        pool.set(&name, c);
                        shadow.insert(name, c);
                    }
                    2 => {
                        pool.remove(&name);
                        shadow.remove(&name);
                    }
                    _ => {
                        let mut a = Allocation::default();
                        a.cores.insert(name.clone(), g.u64_in(0..8) as u32);
                        if g.bool() {
                            pool.apply_alloc(&a);
                            if let Some(f) = shadow.get_mut(&name) {
                                *f = f.saturating_sub(a.cores[&name]);
                            }
                        } else {
                            pool.release_alloc(&a);
                            if let Some(f) = shadow.get_mut(&name) {
                                *f = f.saturating_add(a.cores[&name]);
                            }
                        }
                    }
                }
            }
            pool.audit();
            let got: BTreeMap<String, u32> =
                pool.to_free_nodes().into_iter().map(|n| (n.name, n.free_cores)).collect();
            expect(got == shadow, &format!("index {got:?} != shadow {shadow:?}"))
        });
    }

    #[test]
    fn versions_bump_on_every_mutation_and_tags_differ() {
        let mut a = FreePool::new();
        let b = FreePool::new();
        assert_ne!(a.tag(), b.tag());
        let v0 = a.version();
        a.set("n01", 4);
        a.touch();
        a.apply_alloc(&Allocation::default());
        a.release_alloc(&Allocation::default());
        a.remove("n01");
        a.remove("n01"); // even a no-op removal invalidates memos
        assert_eq!(a.version(), v0 + 6);
    }
}
