//! MOM (machine-oriented miniserver): the per-node execution agent.
//!
//! In Torque, pbs_mom runs on every node, launches the job's processes,
//! and reports exit status.  Here it adds the job prologue/epilogue costs
//! to run times and tracks per-node task occupancy — the piece of state
//! the fig3 harness uses to know how many cores are active on a client
//! (which feeds the Turbo model).

use super::job::JobId;
use crate::sim::clock::{SimTime, DUR_MS};
use std::collections::BTreeMap;

/// Prologue: stage-in, cgroup setup. Epilogue: cleanup, stage-out.
pub const PROLOGUE: SimTime = 350 * DUR_MS;
pub const EPILOGUE: SimTime = 200 * DUR_MS;

/// One task (one job's slice on this node).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Task {
    pub job: JobId,
    pub cores: u32,
    pub started_at: SimTime,
}

/// The per-node agent.
#[derive(Debug, Clone)]
pub struct Mom {
    pub node: String,
    pub cores: u32,
    tasks: BTreeMap<JobId, Task>,
}

impl Mom {
    pub fn new(node: &str, cores: u32) -> Self {
        Self { node: node.to_string(), cores, tasks: BTreeMap::new() }
    }

    /// Launch a task. Panics on oversubscription (scheduler invariant).
    pub fn launch(&mut self, job: JobId, cores: u32, now: SimTime) {
        assert!(
            self.busy_cores() + cores <= self.cores,
            "{}: oversubscribed ({} + {cores} > {})",
            self.node,
            self.busy_cores(),
            self.cores
        );
        assert!(!self.tasks.contains_key(&job), "{}: job {job} already here", self.node);
        self.tasks.insert(job, Task { job, cores, started_at: now });
    }

    /// Task finished or was killed.
    pub fn reap(&mut self, job: JobId) -> Option<Task> {
        self.tasks.remove(&job)
    }

    /// Kill everything (node crash/power-off).
    pub fn kill_all(&mut self) -> Vec<Task> {
        let tasks: Vec<Task> = self.tasks.values().cloned().collect();
        self.tasks.clear();
        tasks
    }

    pub fn busy_cores(&self) -> u32 {
        self.tasks.values().map(|t| t.cores).sum()
    }

    /// Active (busy) core count — the Turbo model input.
    pub fn active(&self) -> u32 {
        self.busy_cores()
    }

    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Wall time a payload of `compute` seconds occupies the node,
    /// including prologue/epilogue.
    pub fn wrap_runtime(compute: SimTime) -> SimTime {
        PROLOGUE + compute + EPILOGUE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_and_reap() {
        let mut m = Mom::new("n01", 12);
        m.launch(JobId(1), 4, 0);
        m.launch(JobId(2), 8, 5);
        assert_eq!(m.busy_cores(), 12);
        let t = m.reap(JobId(1)).unwrap();
        assert_eq!(t.cores, 4);
        assert_eq!(m.busy_cores(), 8);
        assert!(m.reap(JobId(1)).is_none());
    }

    #[test]
    #[should_panic(expected = "oversubscribed")]
    fn oversubscription_is_a_bug() {
        let mut m = Mom::new("n03", 4);
        m.launch(JobId(1), 3, 0);
        m.launch(JobId(2), 2, 0);
    }

    #[test]
    fn kill_all_on_crash() {
        let mut m = Mom::new("n02", 6);
        m.launch(JobId(1), 2, 0);
        m.launch(JobId(2), 2, 0);
        let killed = m.kill_all();
        assert_eq!(killed.len(), 2);
        assert_eq!(m.busy_cores(), 0);
    }

    #[test]
    fn runtime_wrapping() {
        assert_eq!(Mom::wrap_runtime(1_000 * DUR_MS), PROLOGUE + 1_000 * DUR_MS + EPILOGUE);
    }
}
