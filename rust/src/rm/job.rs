//! Job records and lifecycle.

use super::alloc::{Allocation, ResourceRequest};
use crate::sim::clock::SimTime;

/// Monotonic job identifier, rendered Torque-style ("17.gridlan").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}.gridlan", self.0)
    }
}

/// Torque single-letter job states.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobState {
    /// Q — waiting for resources.
    Queued,
    /// R — running on an allocation.
    Running,
    /// E — exiting (epilogue).
    Exiting,
    /// C — completed.
    Completed,
    /// H — held (operator or dependency).
    Held,
}

impl JobState {
    pub fn letter(self) -> char {
        match self {
            JobState::Queued => 'Q',
            JobState::Running => 'R',
            JobState::Exiting => 'E',
            JobState::Completed => 'C',
            JobState::Held => 'H',
        }
    }
}

/// One job as pbs_server tracks it.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: JobId,
    pub name: String,
    pub owner: String,
    pub queue: String,
    pub request: ResourceRequest,
    pub walltime: Option<SimTime>,
    pub state: JobState,
    pub submitted_at: SimTime,
    pub started_at: Option<SimTime>,
    pub completed_at: Option<SimTime>,
    /// Where it runs (set on start).
    pub allocation: Option<Allocation>,
    /// Exit code (set on completion; None = killed/requeued).
    pub exit_code: Option<i32>,
    /// How many times this job was requeued after node failures.
    pub requeues: u32,
    /// Opaque payload understood by the workload layer (e.g. EP class +
    /// pair range).
    pub payload: String,
}

impl Job {
    pub fn turnaround(&self) -> Option<SimTime> {
        Some(self.completed_at? - self.submitted_at)
    }

    pub fn wait_time(&self) -> Option<SimTime> {
        Some(self.started_at? - self.submitted_at)
    }

    pub fn run_time(&self) -> Option<SimTime> {
        Some(self.completed_at? - self.started_at?)
    }

    /// Did the job finish successfully?
    pub fn succeeded(&self) -> bool {
        self.state == JobState::Completed && self.exit_code == Some(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job() -> Job {
        Job {
            id: JobId(17),
            name: "ep".into(),
            owner: "attila".into(),
            queue: "gridlan".into(),
            request: ResourceRequest { nodes: 1, ppn: 4 },
            walltime: None,
            state: JobState::Queued,
            submitted_at: 100,
            started_at: None,
            completed_at: None,
            allocation: None,
            exit_code: None,
            requeues: 0,
            payload: String::new(),
        }
    }

    #[test]
    fn display_is_torque_style() {
        assert_eq!(JobId(17).to_string(), "17.gridlan");
    }

    #[test]
    fn state_letters() {
        assert_eq!(JobState::Queued.letter(), 'Q');
        assert_eq!(JobState::Running.letter(), 'R');
        assert_eq!(JobState::Completed.letter(), 'C');
    }

    #[test]
    fn timing_accessors() {
        let mut j = job();
        assert!(j.turnaround().is_none());
        j.started_at = Some(600);
        j.completed_at = Some(1600);
        j.state = JobState::Completed;
        j.exit_code = Some(0);
        assert_eq!(j.wait_time(), Some(500));
        assert_eq!(j.run_time(), Some(1000));
        assert_eq!(j.turnaround(), Some(1500));
        assert!(j.succeeded());
    }

    #[test]
    fn killed_job_did_not_succeed() {
        let mut j = job();
        j.state = JobState::Completed;
        j.exit_code = None;
        assert!(!j.succeeded());
    }
}
