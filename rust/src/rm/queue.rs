//! Queue definitions (paper §2.4): "A special queue for the Gridlan nodes
//! helps users choose the appropriate resources for their calculations" —
//! a `gridlan` queue next to pre-existing `cluster` queues on one server.

/// Which node pool a queue schedules onto.  `Ord` so pools can key the
/// server's per-pool free-core indexes deterministically.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum NodePool {
    /// Gridlan VMs (heterogeneous, fault-prone, behind the VPN).
    Gridlan,
    /// A conventional cluster partition attached to the same server.
    Cluster,
}

/// A queue.
#[derive(Debug, Clone, PartialEq)]
pub struct Queue {
    pub name: String,
    pub pool: NodePool,
    /// Max jobs running simultaneously from this queue (0 = unlimited).
    pub max_running: u32,
    /// Larger = drained first when multiple queues have work.
    pub priority: i32,
    pub enabled: bool,
}

impl Queue {
    pub fn gridlan_default() -> Self {
        Self { name: "gridlan".into(), pool: NodePool::Gridlan, max_running: 0, priority: 10, enabled: true }
    }

    pub fn cluster_default() -> Self {
        Self { name: "batch".into(), pool: NodePool::Cluster, max_running: 0, priority: 20, enabled: true }
    }

    pub fn can_start_more(&self, running_from_queue: u32) -> bool {
        self.enabled && (self.max_running == 0 || running_from_queue < self.max_running)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_target_their_pools() {
        assert_eq!(Queue::gridlan_default().pool, NodePool::Gridlan);
        assert_eq!(Queue::cluster_default().pool, NodePool::Cluster);
    }

    #[test]
    fn max_running_limit() {
        let mut q = Queue::gridlan_default();
        q.max_running = 2;
        assert!(q.can_start_more(0));
        assert!(q.can_start_more(1));
        assert!(!q.can_start_more(2));
    }

    #[test]
    fn disabled_queue_starts_nothing() {
        let mut q = Queue::gridlan_default();
        q.enabled = false;
        assert!(!q.can_start_more(0));
    }

    #[test]
    fn unlimited_when_zero() {
        let q = Queue::gridlan_default();
        assert!(q.can_start_more(10_000));
    }
}
