//! pbs_server: the resource manager's brain.
//!
//! Owns the node registry (gridlan VMs + any cluster partition), the
//! queues, and the job table; exposes the Torque verbs (`qsub`, `qstat`,
//! `qdel`, `pbsnodes`) and the scheduling cycle.  Time-driven behaviour
//! (run durations, completions) is injected by the coordinator via
//! [`PbsServer::start`] / [`PbsServer::complete`] so the server stays a
//! pure state machine — easy to test exhaustively.

use super::alloc::{Allocation, FreePool};
use super::job::{Job, JobId, JobState};
use super::queue::{NodePool, Queue};
use super::sched::{Decision, PendingJob, RunningJob, Scheduler};
use super::script::PbsScript;
use crate::sim::clock::{SimTime, DUR_SEC};
use std::collections::{BTreeMap, BTreeSet};

/// Node power/reachability as pbs_server sees it (fed by the monitor).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodePower {
    Online,
    Offline,
}

/// A registered execution node.
#[derive(Debug, Clone)]
pub struct NodeInfo {
    pub name: String,
    pub cores: u32,
    pub pool: NodePool,
    pub power: NodePower,
    pub busy_cores: u32,
}

impl NodeInfo {
    pub fn free_cores(&self) -> u32 {
        if self.power == NodePower::Offline {
            0
        } else {
            self.cores - self.busy_cores
        }
    }
}

/// Default walltime estimate when a script omits `-l walltime`.
pub const DEFAULT_WALLTIME: SimTime = 3600 * DUR_SEC;

/// What [`PbsServer::complete`] hands back: the completion hook's view of
/// the finished job, so time-driven callers (the scenario runner executes
/// real compute payloads at completion time) can account payload,
/// placement and wait without a second job-table lookup.
#[derive(Debug, Clone)]
pub struct CompletionRecord {
    pub id: JobId,
    pub exit_code: i32,
    /// Opaque workload payload (e.g. `ep:<offset>:<count>`).
    pub payload: String,
    /// Where the completing attempt ran.
    pub allocation: Allocation,
    /// Start time of the completing attempt.
    pub started_at: SimTime,
    /// Queue wait of the completing attempt.
    pub wait: SimTime,
}

/// Static per-pool capacity bounds, maintained at registration so `qsub`'s
/// admission check is O(log pools) instead of a full registry scan.
#[derive(Debug, Clone, Copy, Default)]
struct PoolCaps {
    max_node_cores: u32,
    total_cores: u32,
}

/// The server.
pub struct PbsServer {
    nodes: BTreeMap<String, NodeInfo>,
    queues: BTreeMap<String, Queue>,
    jobs: BTreeMap<JobId, Job>,
    /// Queued job ids in submission order.
    pending: Vec<JobId>,
    /// Ids of jobs in `Running` state (mirrors the job table), so a
    /// scheduling cycle walks the runners, not the whole job history.
    running: BTreeSet<JobId>,
    /// Per-pool free-core index over *online* nodes, kept in sync on every
    /// alloc/release/power transition.  Schedulers match and apply grants
    /// against this directly.
    free_idx: BTreeMap<NodePool, FreePool>,
    pool_caps: BTreeMap<NodePool, PoolCaps>,
    next_id: u64,
    pub default_queue: String,
}

impl PbsServer {
    pub fn new() -> Self {
        let mut queues = BTreeMap::new();
        let g = Queue::gridlan_default();
        let c = Queue::cluster_default();
        let default_queue = c.name.clone();
        queues.insert(g.name.clone(), g);
        queues.insert(c.name.clone(), c);
        Self {
            nodes: BTreeMap::new(),
            queues,
            jobs: BTreeMap::new(),
            pending: Vec::new(),
            running: BTreeSet::new(),
            free_idx: BTreeMap::new(),
            pool_caps: BTreeMap::new(),
            next_id: 1,
            default_queue,
        }
    }

    // ---------------------------------------------------------- registry

    pub fn register_node(&mut self, name: &str, cores: u32, pool: NodePool) {
        let prev = self.nodes.insert(
            name.to_string(),
            NodeInfo { name: name.to_string(), cores, pool, power: NodePower::Offline, busy_cores: 0 },
        );
        match prev {
            None => {
                let caps = self.pool_caps.entry(pool).or_default();
                caps.max_node_cores = caps.max_node_cores.max(cores);
                caps.total_cores += cores;
            }
            Some(old) => {
                // Re-registration replaces the node: drop the previous
                // incarnation from its index and rebuild the affected caps.
                if let Some(idx) = self.free_idx.get_mut(&old.pool) {
                    idx.remove(name);
                }
                self.recompute_caps(old.pool);
                if old.pool != pool {
                    self.recompute_caps(pool);
                }
            }
        }
    }

    fn recompute_caps(&mut self, pool: NodePool) {
        let mut caps = PoolCaps::default();
        for n in self.nodes.values().filter(|n| n.pool == pool) {
            caps.max_node_cores = caps.max_node_cores.max(n.cores);
            caps.total_cores += n.cores;
        }
        self.pool_caps.insert(pool, caps);
    }

    pub fn set_node_power(&mut self, name: &str, power: NodePower) {
        let Some(n) = self.nodes.get_mut(name) else { return };
        n.power = power;
        let (pool, free) = (n.pool, n.cores - n.busy_cores);
        match power {
            NodePower::Online => self.free_idx.entry(pool).or_default().set(name, free),
            NodePower::Offline => {
                if let Some(idx) = self.free_idx.get_mut(&pool) {
                    idx.remove(name);
                }
            }
        }
    }

    pub fn node(&self, name: &str) -> Option<&NodeInfo> {
        self.nodes.get(name)
    }

    /// `pbsnodes`-style listing.
    pub fn nodes(&self) -> impl Iterator<Item = &NodeInfo> {
        self.nodes.values()
    }

    pub fn queue(&self, name: &str) -> Option<&Queue> {
        self.queues.get(name)
    }

    pub fn add_queue(&mut self, q: Queue) {
        self.queues.insert(q.name.clone(), q);
    }

    // -------------------------------------------------------------- verbs

    /// Submit a job script. Returns the job id, or an error string in
    /// Torque's terse style.
    pub fn qsub(
        &mut self,
        script: &PbsScript,
        owner: &str,
        payload: &str,
        now: SimTime,
    ) -> Result<JobId, String> {
        let queue_name = script.queue.clone().unwrap_or_else(|| self.default_queue.clone());
        let queue = self
            .queues
            .get(&queue_name)
            .ok_or_else(|| format!("qsub: unknown queue '{queue_name}'"))?;
        if !queue.enabled {
            return Err(format!("qsub: queue '{queue_name}' disabled"));
        }
        // Reject requests that can never fit the pool (Torque does this at
        // submission when resources exceed any node).  The bounds come
        // from the registration-time caps, not a registry scan.
        let pool = queue.pool;
        let caps = self.pool_caps.get(&pool).copied().unwrap_or_default();
        let max_node_cores = caps.max_node_cores;
        if script.request.ppn > max_node_cores {
            return Err(format!(
                "qsub: ppn={} exceeds any {queue_name} node ({max_node_cores} cores max)",
                script.request.ppn
            ));
        }
        let total_pool = caps.total_cores;
        if script.request.total_cores() > total_pool {
            return Err(format!(
                "qsub: request {}x{} exceeds pool capacity {total_pool}",
                script.request.nodes, script.request.ppn
            ));
        }
        let id = JobId(self.next_id);
        self.next_id += 1;
        let job = Job {
            id,
            name: script.name.clone().unwrap_or_else(|| format!("STDIN-{}", id.0)),
            owner: owner.to_string(),
            queue: queue_name,
            request: script.request,
            walltime: script.walltime,
            state: JobState::Queued,
            submitted_at: now,
            started_at: None,
            completed_at: None,
            allocation: None,
            exit_code: None,
            requeues: 0,
            payload: payload.to_string(),
        };
        self.jobs.insert(id, job);
        self.pending.push(id);
        Ok(id)
    }

    /// Delete/kill a job.
    pub fn qdel(&mut self, id: JobId, now: SimTime) -> Result<(), String> {
        let job = self.jobs.get_mut(&id).ok_or_else(|| format!("qdel: unknown job {id}"))?;
        match job.state {
            JobState::Queued | JobState::Held => {
                job.state = JobState::Completed;
                job.completed_at = Some(now);
                job.exit_code = None;
                self.pending.retain(|&p| p != id);
                Ok(())
            }
            JobState::Running | JobState::Exiting => {
                let alloc = job.allocation.clone().unwrap_or_default();
                let queue = job.queue.clone();
                job.state = JobState::Completed;
                job.completed_at = Some(now);
                job.exit_code = None;
                self.running.remove(&id);
                self.release(&alloc);
                // The running set changed even if no cores moved (e.g. a
                // zero-core grant): invalidate shadow memos.
                if let Some(pool) = self.queues.get(&queue).map(|q| q.pool) {
                    self.free_idx.entry(pool).or_default().touch();
                }
                Ok(())
            }
            JobState::Completed => Err(format!("qdel: job {id} already completed")),
        }
    }

    /// `qstat` rows: (id, name, owner, state, queue).
    pub fn qstat(&self) -> Vec<(JobId, String, String, char, String)> {
        self.jobs
            .values()
            .map(|j| (j.id, j.name.clone(), j.owner.clone(), j.state.letter(), j.queue.clone()))
            .collect()
    }

    pub fn job(&self, id: JobId) -> Option<&Job> {
        self.jobs.get(&id)
    }

    pub fn jobs(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values()
    }

    // ---------------------------------------------------------- scheduling

    fn running_jobs(&self, pool: NodePool) -> Vec<RunningJob> {
        // Walk the running set (id order == the old full-table scan order),
        // not the whole job history.
        self.running
            .iter()
            .map(|id| &self.jobs[id])
            .filter(|j| self.queues.get(&j.queue).map(|q| q.pool == pool).unwrap_or(false))
            .map(|j| RunningJob {
                id: j.id,
                allocation: j.allocation.clone().unwrap_or_default(),
                expected_end: j.started_at.unwrap_or(0) + j.walltime.unwrap_or(DEFAULT_WALLTIME),
            })
            .collect()
    }

    /// One scheduling cycle for one pool. Returns what got started; the
    /// caller decides each job's actual run duration and later calls
    /// [`complete`].
    pub fn schedule_cycle(
        &mut self,
        pool: NodePool,
        scheduler: &dyn Scheduler,
        now: SimTime,
    ) -> Decision {
        // Pending jobs of queues on this pool, priority then FIFO order.
        let mut pending: Vec<PendingJob> = Vec::new();
        let mut running_per_queue: BTreeMap<String, u32> = BTreeMap::new();
        for id in &self.running {
            *running_per_queue.entry(self.jobs[id].queue.clone()).or_insert(0) += 1;
        }
        for &id in &self.pending {
            let j = &self.jobs[&id];
            let q = &self.queues[&j.queue];
            if q.pool != pool {
                continue;
            }
            if !q.can_start_more(running_per_queue.get(&j.queue).copied().unwrap_or(0)) {
                continue;
            }
            pending.push(PendingJob {
                id,
                request: j.request,
                walltime: j.walltime.unwrap_or(DEFAULT_WALLTIME),
                queue_priority: q.priority,
            });
        }
        pending.sort_by(|a, b| b.queue_priority.cmp(&a.queue_priority).then(a.id.cmp(&b.id)));
        let running = self.running_jobs(pool);
        // The scheduler works against the incrementally-maintained free-core
        // index and applies its grants to it; `start` only mirrors them onto
        // the node records (and asserts they fit).
        let decision = {
            let idx = self.free_idx.entry(pool).or_default();
            scheduler.select(&pending, idx, &running, now)
        };
        for (id, alloc) in &decision {
            self.start(*id, alloc.clone(), now);
        }
        // One pass over the pending list for the whole batch: a 100k-job
        // cycle must not pay a per-start O(pending) retain.
        if !decision.is_empty() {
            let started: BTreeSet<JobId> = decision.iter().map(|(id, _)| *id).collect();
            self.pending.retain(|id| !started.contains(id));
        }
        decision
    }

    /// Mark a job running on an allocation (called by schedule_cycle).
    fn start(&mut self, id: JobId, alloc: Allocation, now: SimTime) {
        for (node, cores) in &alloc.cores {
            let n = self.nodes.get_mut(node).expect("allocation on unknown node");
            assert!(
                n.busy_cores + cores <= n.cores,
                "over-allocation on {node}: busy {} + {} > {}",
                n.busy_cores,
                cores,
                n.cores
            );
            n.busy_cores += cores;
        }
        let job = self.jobs.get_mut(&id).expect("start unknown job");
        assert_eq!(job.state, JobState::Queued, "start non-queued job {id}");
        job.state = JobState::Running;
        job.started_at = Some(now);
        job.allocation = Some(alloc);
        self.running.insert(id);
        // The caller (schedule_cycle) prunes `pending` for the whole batch.
    }

    /// Job finished (successfully or not).  Returns the completion record
    /// (payload, placement, wait) for time-driven callers.
    pub fn complete(&mut self, id: JobId, exit_code: i32, now: SimTime) -> CompletionRecord {
        let job = self.jobs.get_mut(&id).expect("complete unknown job");
        assert_eq!(job.state, JobState::Running, "complete non-running job {id}");
        job.state = JobState::Completed;
        job.completed_at = Some(now);
        job.exit_code = Some(exit_code);
        let queue = job.queue.clone();
        let record = CompletionRecord {
            id,
            exit_code,
            payload: job.payload.clone(),
            allocation: job.allocation.clone().unwrap_or_default(),
            started_at: job.started_at.unwrap_or(now),
            wait: job.wait_time().unwrap_or(0),
        };
        self.running.remove(&id);
        self.release(&record.allocation);
        // A completion can move zero cores (zero-core grants), but it still
        // changes the running set a shadow memo may depend on.
        if let Some(pool) = self.queues.get(&queue).map(|q| q.pool) {
            self.free_idx.entry(pool).or_default().touch();
        }
        record
    }

    fn release(&mut self, alloc: &Allocation) {
        for (node, cores) in &alloc.cores {
            let Some(n) = self.nodes.get_mut(node) else { continue };
            n.busy_cores = n.busy_cores.saturating_sub(*cores);
            let (pool, free, online) =
                (n.pool, n.cores - n.busy_cores, n.power == NodePower::Online);
            if online {
                self.free_idx.entry(pool).or_default().set(node, free);
            }
        }
    }

    /// A node went down: mark offline, kill+requeue its running jobs.
    /// Returns the requeued job ids (the resilience layer re-submits them
    /// from the script folder).
    pub fn node_down(&mut self, name: &str, now: SimTime) -> Vec<JobId> {
        self.set_node_power(name, NodePower::Offline);
        if let Some(n) = self.nodes.get_mut(name) {
            n.busy_cores = 0;
        }
        let victims: Vec<JobId> = self
            .running
            .iter()
            .map(|id| &self.jobs[id])
            .filter(|j| j.allocation.as_ref().map(|a| a.cores.contains_key(name)).unwrap_or(false))
            .map(|j| j.id)
            .collect();
        for id in &victims {
            self.running.remove(id);
            let job = self.jobs.get_mut(id).unwrap();
            let alloc = job.allocation.take().unwrap_or_default();
            job.state = JobState::Queued;
            job.started_at = None;
            job.requeues += 1;
            job.submitted_at = now; // requeued now; goes to the back
            // Release cores on the *other* (still-online) nodes.
            let other: Allocation = Allocation {
                cores: alloc.cores.iter().filter(|(n, _)| n.as_str() != name).map(|(n, c)| (n.clone(), *c)).collect(),
            };
            self.release(&other);
            self.pending.push(*id);
        }
        victims
    }

    /// Node came (back) up.
    pub fn node_up(&mut self, name: &str) {
        self.set_node_power(name, NodePower::Online);
    }

    /// Rewrite a job's opaque payload in place.  The recovery layer uses
    /// this to shrink an EP job's range to the unexecuted remainder — on a
    /// salvage requeue (checkpointed sub-spans are banked, the requeued
    /// attempt carries only `ep:<cursor>:<rest>`) and on a straggler steal
    /// (the running parent is truncated at the split point).  Touches
    /// nothing but the payload string: state, allocation, the free index
    /// and the running-set mirror are all left alone.
    pub fn set_payload(&mut self, id: JobId, payload: &str) -> Result<(), String> {
        let job =
            self.jobs.get_mut(&id).ok_or_else(|| format!("set_payload: unknown job {id}"))?;
        job.payload = payload.to_string();
        Ok(())
    }

    /// Busy/total cores in a pool (for the metrics endpoint).
    pub fn pool_utilization(&self, pool: NodePool) -> (u32, u32) {
        let mut busy = 0;
        let mut total = 0;
        for n in self.nodes.values().filter(|n| n.pool == pool && n.power == NodePower::Online) {
            busy += n.busy_cores;
            total += n.cores;
        }
        (busy, total)
    }

    /// Cross-check every incrementally maintained structure against a
    /// from-scratch recomputation off the node/job tables.  Test-only: this
    /// is the O(everything) scan the indexes exist to avoid.
    #[cfg(test)]
    pub fn audit_free_index(&self) {
        use std::collections::BTreeMap as Map;
        // Per-pool free map over online nodes, rebuilt from the registry.
        let mut want_free: Map<NodePool, Map<String, u32>> = Map::new();
        let mut want_caps: Map<NodePool, (u32, u32)> = Map::new();
        for n in self.nodes.values() {
            let caps = want_caps.entry(n.pool).or_default();
            caps.0 = caps.0.max(n.cores);
            caps.1 += n.cores;
            if n.power == NodePower::Online {
                want_free.entry(n.pool).or_default().insert(n.name.clone(), n.cores - n.busy_cores);
            }
        }
        for (pool, idx) in &self.free_idx {
            idx.audit();
            let got: Map<String, u32> =
                idx.to_free_nodes().into_iter().map(|f| (f.name, f.free_cores)).collect();
            let want = want_free.remove(pool).unwrap_or_default();
            assert_eq!(got, want, "free index diverged for {pool:?}");
        }
        assert!(
            want_free.values().all(|m| m.is_empty()),
            "online nodes missing from the free index: {want_free:?}"
        );
        for (pool, caps) in &self.pool_caps {
            let (max_node, total) = want_caps.get(pool).copied().unwrap_or_default();
            assert_eq!(caps.max_node_cores, max_node, "max_node_cores stale for {pool:?}");
            assert_eq!(caps.total_cores, total, "total_cores stale for {pool:?}");
        }
        let want_running: BTreeSet<JobId> =
            self.jobs.values().filter(|j| j.state == JobState::Running).map(|j| j.id).collect();
        assert_eq!(self.running, want_running, "running-set mirror diverged");
    }
}

impl Default for PbsServer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rm::sched::FifoScheduler;

    fn server_with_grid() -> PbsServer {
        let mut s = PbsServer::new();
        for (name, cores) in [("n01", 12), ("n02", 6), ("n03", 4), ("n04", 4)] {
            s.register_node(name, cores, NodePool::Gridlan);
            s.node_up(name);
        }
        s
    }

    fn ep_script(nodes: u32, ppn: u32) -> PbsScript {
        PbsScript::parse(&format!(
            "#PBS -N ep\n#PBS -q gridlan\n#PBS -l nodes={nodes}:ppn={ppn}\n./ep.x\n"
        ))
        .unwrap()
    }

    #[test]
    fn qsub_schedule_complete_lifecycle() {
        let mut s = server_with_grid();
        let id = s.qsub(&ep_script(1, 4), "user", "", 0).unwrap();
        assert_eq!(s.job(id).unwrap().state, JobState::Queued);
        let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 10);
        assert_eq!(d.len(), 1);
        assert_eq!(s.job(id).unwrap().state, JobState::Running);
        let (busy, total) = s.pool_utilization(NodePool::Gridlan);
        assert_eq!((busy, total), (4, 26));
        s.complete(id, 0, 500);
        assert!(s.job(id).unwrap().succeeded());
        assert_eq!(s.pool_utilization(NodePool::Gridlan).0, 0);
    }

    #[test]
    fn completion_record_reports_payload_and_wait() {
        let mut s = server_with_grid();
        let id = s.qsub(&ep_script(1, 2), "u", "ep:0:4096", 5).unwrap();
        s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 25);
        let rec = s.complete(id, 0, 125);
        assert_eq!(rec.id, id);
        assert_eq!(rec.exit_code, 0);
        assert_eq!(rec.payload, "ep:0:4096");
        assert_eq!(rec.allocation.total_cores(), 2);
        assert_eq!(rec.started_at, 25);
        assert_eq!(rec.wait, 20);
    }

    #[test]
    fn qsub_rejects_unknown_queue_and_oversize() {
        let mut s = server_with_grid();
        let mut script = ep_script(1, 4);
        script.queue = Some("nope".into());
        assert!(s.qsub(&script, "u", "", 0).is_err());
        assert!(s.qsub(&ep_script(1, 13), "u", "", 0).is_err()); // ppn > any node
        assert!(s.qsub(&ep_script(7, 4), "u", "", 0).is_err()); // 28 > 26 pool
    }

    #[test]
    fn queue_selects_pool() {
        let mut s = server_with_grid();
        s.register_node("cl01", 64, NodePool::Cluster);
        s.node_up("cl01");
        // batch queue (cluster pool) job doesn't consume gridlan cores.
        let mut script = ep_script(1, 4);
        script.queue = Some("batch".into());
        let id = s.qsub(&script, "u", "", 0).unwrap();
        s.schedule_cycle(NodePool::Cluster, &FifoScheduler, 1);
        assert_eq!(s.job(id).unwrap().state, JobState::Running);
        assert_eq!(s.pool_utilization(NodePool::Gridlan).0, 0);
        assert_eq!(s.pool_utilization(NodePool::Cluster).0, 4);
    }

    #[test]
    fn qdel_queued_and_running() {
        let mut s = server_with_grid();
        let q = s.qsub(&ep_script(1, 2), "u", "", 0).unwrap();
        let r = s.qsub(&ep_script(1, 2), "u", "", 0).unwrap();
        s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
        // Both started actually; qdel the running one.
        assert_eq!(s.job(r).unwrap().state, JobState::Running);
        s.qdel(r, 50).unwrap();
        assert_eq!(s.job(r).unwrap().state, JobState::Completed);
        assert!(!s.job(r).unwrap().succeeded());
        s.qdel(q, 60).unwrap();
        assert!(s.qdel(q, 61).is_err()); // already completed
    }

    #[test]
    fn offline_nodes_are_not_allocated() {
        let mut s = server_with_grid();
        s.set_node_power("n01", NodePower::Offline);
        let id = s.qsub(&ep_script(1, 8), "u", "", 0).unwrap();
        let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
        assert!(d.is_empty(), "8-ppn job needs n01 which is offline");
        assert_eq!(s.job(id).unwrap().state, JobState::Queued);
        s.node_up("n01");
        let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 2);
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn node_down_requeues_running_jobs() {
        let mut s = server_with_grid();
        let id = s.qsub(&ep_script(2, 4), "u", "", 0).unwrap();
        s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
        let alloc = s.job(id).unwrap().allocation.clone().unwrap();
        let victim_node = alloc.cores.keys().next().unwrap().clone();
        let victims = s.node_down(&victim_node, 100);
        assert_eq!(victims, vec![id]);
        let j = s.job(id).unwrap();
        assert_eq!(j.state, JobState::Queued);
        assert_eq!(j.requeues, 1);
        // All cores released everywhere.
        assert_eq!(s.pool_utilization(NodePool::Gridlan).0, 0);
        // And it can start again once the node returns.
        s.node_up(&victim_node);
        let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 200);
        assert_eq!(d.len(), 1);
    }

    #[test]
    fn node_down_without_jobs_is_quiet() {
        let mut s = server_with_grid();
        assert!(s.node_down("n03", 5).is_empty());
        assert_eq!(s.node("n03").unwrap().power, NodePower::Offline);
    }

    #[test]
    fn qstat_reports_states() {
        let mut s = server_with_grid();
        let a = s.qsub(&ep_script(1, 2), "u", "", 0).unwrap();
        let rows = s.qstat();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].0, a);
        assert_eq!(rows[0].3, 'Q');
    }

    #[test]
    fn free_index_tracks_the_full_lifecycle() {
        let mut s = server_with_grid();
        s.audit_free_index();
        let a = s.qsub(&ep_script(2, 4), "u", "", 0).unwrap();
        let b = s.qsub(&ep_script(1, 6), "u", "", 0).unwrap();
        s.audit_free_index();
        s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
        s.audit_free_index();
        assert_eq!(s.job(a).unwrap().state, JobState::Running);
        assert_eq!(s.job(b).unwrap().state, JobState::Running);
        s.complete(a, 0, 50);
        s.audit_free_index();
        s.qdel(b, 60).unwrap();
        s.audit_free_index();
    }

    #[test]
    fn free_index_survives_power_flaps_and_faults() {
        let mut s = server_with_grid();
        let id = s.qsub(&ep_script(2, 4), "u", "", 0).unwrap();
        s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
        let victim =
            s.job(id).unwrap().allocation.clone().unwrap().cores.keys().next().unwrap().clone();
        s.node_down(&victim, 100);
        s.audit_free_index();
        s.set_node_power("n03", NodePower::Offline);
        s.audit_free_index();
        s.node_up(&victim);
        s.node_up("n03");
        s.audit_free_index();
        s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 200);
        s.audit_free_index();
        assert_eq!(s.job(id).unwrap().state, JobState::Running);
    }

    #[test]
    fn set_payload_rewrites_only_the_payload() {
        let mut s = server_with_grid();
        let id = s.qsub(&ep_script(1, 2), "u", "ep:0:4096", 0).unwrap();
        s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1);
        let before_alloc = s.job(id).unwrap().allocation.clone();
        s.set_payload(id, "ep:1024:3072").unwrap();
        let j = s.job(id).unwrap();
        assert_eq!(j.payload, "ep:1024:3072");
        assert_eq!(j.state, JobState::Running, "state untouched");
        assert_eq!(j.allocation, before_alloc, "allocation untouched");
        s.audit_free_index();
        // The completion record carries the rewritten range.
        let rec = s.complete(id, 0, 100);
        assert_eq!(rec.payload, "ep:1024:3072");
        assert!(s.set_payload(JobId(999), "x").is_err());
    }

    #[test]
    fn free_index_tracks_reregistration_and_caps() {
        let mut s = server_with_grid();
        // Re-register n02 with more cores while online: the index drops the
        // old incarnation (new one starts Offline) and caps are rebuilt.
        s.register_node("n02", 16, NodePool::Gridlan);
        s.audit_free_index();
        assert!(s.qsub(&ep_script(1, 13), "u", "", 0).is_ok(), "caps follow the bigger node");
        s.node_up("n02");
        s.audit_free_index();
        // Moving a node across pools rebuilds both pools' caps.
        s.register_node("n04", 8, NodePool::Cluster);
        s.node_up("n04");
        s.audit_free_index();
    }
}
