//! The Torque-like resource manager ("torq") — paper §2.4.
//!
//! The Gridlan's user-facing contract is Torque's: `qsub` a `#PBS` script
//! to a chosen queue, `qstat` it, `qdel` it.  The Gridlan pool appears as
//! one more queue next to any pre-existing cluster queues, so "a user who
//! wants to submit calculations may choose in the same server the
//! resource manager's queue corresponding to the grid infrastructure or
//! the cluster nodes".
//!
//! * [`script`] — `#PBS` directive parser (API-compatible subset);
//! * [`job`] — job records and lifecycle states (Q/R/E/C/H);
//! * [`queue`] — queue definitions and per-queue limits;
//! * [`alloc`] — `nodes=X:ppn=Y` matching against the node registry;
//! * [`sched`] — FIFO (Torque default) and conservative backfill (the A1
//!   ablation);
//! * [`server`] — the pbs_server: node registry + qsub/qstat/qdel + the
//!   scheduling cycle;
//! * [`mom`] — per-node machine-oriented-miniserver: task launch/track.

pub mod alloc;
pub mod job;
pub mod mom;
pub mod queue;
pub mod sched;
pub mod script;
pub mod server;

pub use alloc::{Allocation, ResourceRequest};
pub use job::{Job, JobId, JobState};
pub use queue::Queue;
pub use sched::{BackfillScheduler, FifoScheduler, Scheduler};
pub use script::PbsScript;
pub use server::{CompletionRecord, NodeInfo, NodePower, PbsServer};
