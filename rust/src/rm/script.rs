//! `#PBS` job script parsing — the subset of Torque directives the paper's
//! workflow uses.
//!
//! ```text
//! #!/bin/bash
//! #PBS -N ep-class-d
//! #PBS -q gridlan
//! #PBS -l nodes=2:ppn=4
//! #PBS -l walltime=02:00:00
//! cd $PBS_O_WORKDIR
//! mpirun ./ep.D.x
//! ```

use super::alloc::ResourceRequest;
use crate::sim::clock::{SimTime, DUR_SEC};

/// A parsed job script.
#[derive(Debug, Clone, PartialEq)]
pub struct PbsScript {
    pub name: Option<String>,
    pub queue: Option<String>,
    pub request: ResourceRequest,
    pub walltime: Option<SimTime>,
    /// Non-directive command lines (the payload).
    pub commands: Vec<String>,
}

/// Parse errors carry the offending line.
#[derive(Debug, Clone, PartialEq)]
pub struct ScriptError {
    pub line_no: usize,
    pub msg: String,
}

impl std::fmt::Display for ScriptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "script line {}: {}", self.line_no, self.msg)
    }
}

impl std::error::Error for ScriptError {}

impl PbsScript {
    pub fn parse(text: &str) -> Result<Self, ScriptError> {
        let mut out = PbsScript {
            name: None,
            queue: None,
            request: ResourceRequest::default(),
            walltime: None,
            commands: Vec::new(),
        };
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            let line = raw.trim();
            if line.starts_with("#PBS") {
                let rest = line["#PBS".len()..].trim();
                Self::parse_directive(rest, line_no, &mut out)?;
            } else if line.starts_with("#!") || line.starts_with('#') || line.is_empty() {
                continue;
            } else {
                out.commands.push(line.to_string());
            }
        }
        Ok(out)
    }

    fn parse_directive(rest: &str, line_no: usize, out: &mut PbsScript) -> Result<(), ScriptError> {
        let err = |msg: &str| ScriptError { line_no, msg: msg.to_string() };
        let mut parts = rest.splitn(2, char::is_whitespace);
        let flag = parts.next().ok_or_else(|| err("empty directive"))?;
        let val = parts.next().map(str::trim).unwrap_or("");
        match flag {
            "-N" => {
                if val.is_empty() {
                    return Err(err("-N needs a name"));
                }
                out.name = Some(val.to_string());
            }
            "-q" => {
                if val.is_empty() {
                    return Err(err("-q needs a queue"));
                }
                out.queue = Some(val.to_string());
            }
            "-l" => Self::parse_resource(val, line_no, out)?,
            _ => return Err(err(&format!("unsupported directive '{flag}'"))),
        }
        Ok(())
    }

    fn parse_resource(val: &str, line_no: usize, out: &mut PbsScript) -> Result<(), ScriptError> {
        let err = |msg: String| ScriptError { line_no, msg };
        for item in val.split(',') {
            let item = item.trim();
            if let Some(spec) = item.strip_prefix("nodes=") {
                let mut nodes = 0u32;
                let mut ppn = 1u32;
                for (k, part) in spec.split(':').enumerate() {
                    if k == 0 {
                        nodes = part
                            .parse()
                            .map_err(|_| err(format!("bad node count '{part}'")))?;
                    } else if let Some(p) = part.strip_prefix("ppn=") {
                        ppn = p.parse().map_err(|_| err(format!("bad ppn '{p}'")))?;
                    } else {
                        return Err(err(format!("unsupported node property '{part}'")));
                    }
                }
                if nodes == 0 {
                    return Err(err("nodes must be >= 1".into()));
                }
                out.request = ResourceRequest { nodes, ppn };
            } else if let Some(w) = item.strip_prefix("walltime=") {
                out.walltime = Some(Self::parse_walltime(w).map_err(|m| err(m))?);
            } else {
                return Err(err(format!("unsupported resource '{item}'")));
            }
        }
        Ok(())
    }

    fn parse_walltime(s: &str) -> Result<SimTime, String> {
        let fields: Vec<&str> = s.split(':').collect();
        let nums: Result<Vec<u64>, _> = fields.iter().map(|f| f.parse::<u64>()).collect();
        let nums = nums.map_err(|_| format!("bad walltime '{s}'"))?;
        let secs = match nums.as_slice() {
            [h, m, sec] => h * 3600 + m * 60 + sec,
            [m, sec] => m * 60 + sec,
            [sec] => *sec,
            _ => return Err(format!("bad walltime '{s}'")),
        };
        Ok(secs * DUR_SEC)
    }

    /// Render back to script text (used by the resilience script folder).
    pub fn render(&self) -> String {
        let mut s = String::from("#!/bin/bash\n");
        if let Some(n) = &self.name {
            s.push_str(&format!("#PBS -N {n}\n"));
        }
        if let Some(q) = &self.queue {
            s.push_str(&format!("#PBS -q {q}\n"));
        }
        s.push_str(&format!(
            "#PBS -l nodes={}:ppn={}\n",
            self.request.nodes, self.request.ppn
        ));
        if let Some(w) = self.walltime {
            let secs = w / DUR_SEC;
            s.push_str(&format!(
                "#PBS -l walltime={:02}:{:02}:{:02}\n",
                secs / 3600,
                (secs % 3600) / 60,
                secs % 60
            ));
        }
        for c in &self.commands {
            s.push_str(c);
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SCRIPT: &str = "#!/bin/bash\n#PBS -N ep-test\n#PBS -q gridlan\n#PBS -l nodes=2:ppn=4\n#PBS -l walltime=02:30:00\ncd $PBS_O_WORKDIR\nmpirun ./ep.D.x\n";

    #[test]
    fn parses_paper_style_script() {
        let s = PbsScript::parse(SCRIPT).unwrap();
        assert_eq!(s.name.as_deref(), Some("ep-test"));
        assert_eq!(s.queue.as_deref(), Some("gridlan"));
        assert_eq!(s.request, ResourceRequest { nodes: 2, ppn: 4 });
        assert_eq!(s.walltime, Some((2 * 3600 + 30 * 60) * DUR_SEC));
        assert_eq!(s.commands, vec!["cd $PBS_O_WORKDIR", "mpirun ./ep.D.x"]);
    }

    #[test]
    fn defaults_when_no_directives() {
        let s = PbsScript::parse("echo hi\n").unwrap();
        assert_eq!(s.request, ResourceRequest { nodes: 1, ppn: 1 });
        assert!(s.queue.is_none());
        assert_eq!(s.commands, vec!["echo hi"]);
    }

    #[test]
    fn combined_l_line() {
        let s = PbsScript::parse("#PBS -l nodes=3:ppn=2,walltime=00:10:00\n").unwrap();
        assert_eq!(s.request, ResourceRequest { nodes: 3, ppn: 2 });
        assert_eq!(s.walltime, Some(600 * DUR_SEC));
    }

    #[test]
    fn walltime_forms() {
        assert_eq!(PbsScript::parse_walltime("90").unwrap(), 90 * DUR_SEC);
        assert_eq!(PbsScript::parse_walltime("5:00").unwrap(), 300 * DUR_SEC);
        assert!(PbsScript::parse_walltime("x").is_err());
        assert!(PbsScript::parse_walltime("1:2:3:4").is_err());
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = PbsScript::parse("#PBS -Z foo\n").unwrap_err();
        assert_eq!(e.line_no, 1);
        let e = PbsScript::parse("echo a\n#PBS -l nodes=0\n").unwrap_err();
        assert_eq!(e.line_no, 2);
    }

    #[test]
    fn render_roundtrips() {
        let s = PbsScript::parse(SCRIPT).unwrap();
        let again = PbsScript::parse(&s.render()).unwrap();
        assert_eq!(s, again);
    }

    #[test]
    fn comments_ignored() {
        let s = PbsScript::parse("# just a comment\n#PBS -N x\n").unwrap();
        assert_eq!(s.name.as_deref(), Some("x"));
        assert!(s.commands.is_empty());
    }
}
