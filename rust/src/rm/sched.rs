//! Scheduling policies.
//!
//! Torque 2.4's default scheduler is FIFO; we implement it plus
//! conservative EASY backfill as the A1 ablation (DESIGN.md): backfill
//! lets short jobs jump ahead *only* if they cannot delay the head job's
//! earliest possible start.

use super::alloc::{match_request, Allocation, FreeNode, ResourceRequest};
use super::job::JobId;
use crate::sim::clock::SimTime;

/// A queued job as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct PendingJob {
    pub id: JobId,
    pub request: ResourceRequest,
    /// Walltime estimate (requested walltime, or a default).
    pub walltime: SimTime,
    pub queue_priority: i32,
}

/// A running job as the scheduler sees it (for backfill reservations).
#[derive(Debug, Clone)]
pub struct RunningJob {
    pub id: JobId,
    pub allocation: Allocation,
    pub expected_end: SimTime,
}

/// A scheduling decision.
pub type Decision = Vec<(JobId, Allocation)>;

/// Policy interface.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Choose jobs to start now.  `pending` is in queue order (priority
    /// then FIFO), `free` is current per-node free capacity.
    fn select(
        &self,
        pending: &[PendingJob],
        free: &[FreeNode],
        running: &[RunningJob],
        now: SimTime,
    ) -> Decision;
}

/// Strict FIFO: start jobs in order; stop at the first that doesn't fit
/// (no overtaking — the head job's resources are implicitly reserved).
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &self,
        pending: &[PendingJob],
        free: &[FreeNode],
        _running: &[RunningJob],
        _now: SimTime,
    ) -> Decision {
        let mut free = free.to_vec();
        let mut out = Decision::new();
        for job in pending {
            match match_request(&job.request, &free) {
                Some(alloc) => {
                    apply(&mut free, &alloc);
                    out.push((job.id, alloc));
                }
                None => break, // strict: nobody overtakes the head
            }
        }
        out
    }
}

/// EASY backfill: like FIFO, but when the head job blocks, compute its
/// shadow start time from running-job completions and let later jobs run
/// now if (a) they fit in current free capacity and (b) they will finish
/// before the shadow time OR don't touch the cores the head job needs.
/// Conservative approximation: condition (b) is `now + walltime <= shadow`.
pub struct BackfillScheduler;

impl Scheduler for BackfillScheduler {
    fn name(&self) -> &'static str {
        "backfill"
    }

    fn select(
        &self,
        pending: &[PendingJob],
        free: &[FreeNode],
        running: &[RunningJob],
        now: SimTime,
    ) -> Decision {
        let mut free = free.to_vec();
        let mut out = Decision::new();
        let mut idx = 0;
        // Greedy FIFO prefix.
        while idx < pending.len() {
            let job = &pending[idx];
            match match_request(&job.request, &free) {
                Some(alloc) => {
                    apply(&mut free, &alloc);
                    out.push((job.id, alloc));
                    idx += 1;
                }
                None => break,
            }
        }
        if idx >= pending.len() {
            return out;
        }
        // Head job blocked: find its shadow time by replaying completions.
        let head = &pending[idx];
        let shadow = shadow_time(&head.request, &free, running);
        // Backfill the rest.
        for job in &pending[idx + 1..] {
            if shadow.map(|s| now.saturating_add(job.walltime) <= s).unwrap_or(false) {
                if let Some(alloc) = match_request(&job.request, &free) {
                    apply(&mut free, &alloc);
                    out.push((job.id, alloc));
                }
            }
        }
        out
    }
}

/// Earliest time the blocked head job could start, assuming running jobs
/// end at their expected_end and release their cores.
fn shadow_time(
    request: &ResourceRequest,
    free: &[FreeNode],
    running: &[RunningJob],
) -> Option<SimTime> {
    let mut free = free.to_vec();
    let mut ends: Vec<&RunningJob> = running.iter().collect();
    ends.sort_by_key(|r| r.expected_end);
    for r in ends {
        // Release r's cores.
        for (node, cores) in &r.allocation.cores {
            if let Some(f) = free.iter_mut().find(|f| &f.name == node) {
                f.free_cores += cores;
            } else {
                free.push(FreeNode { name: node.clone(), free_cores: *cores });
            }
        }
        if match_request(request, &free).is_some() {
            return Some(r.expected_end);
        }
    }
    None
}

fn apply(free: &mut [FreeNode], alloc: &Allocation) {
    for (node, cores) in &alloc.cores {
        let f = free.iter_mut().find(|f| &f.name == node).expect("alloc on unknown node");
        assert!(f.free_cores >= *cores, "over-allocation on {node}");
        f.free_cores -= cores;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::DUR_SEC;
    use crate::util::prop::{self, expect};

    fn pj(id: u64, nodes: u32, ppn: u32, wall_secs: u64) -> PendingJob {
        PendingJob {
            id: JobId(id),
            request: ResourceRequest { nodes, ppn },
            walltime: wall_secs * DUR_SEC,
            queue_priority: 0,
        }
    }

    fn free(spec: &[(&str, u32)]) -> Vec<FreeNode> {
        spec.iter().map(|&(n, c)| FreeNode { name: n.into(), free_cores: c }).collect()
    }

    #[test]
    fn fifo_starts_in_order_until_blocked() {
        let pending = vec![pj(1, 1, 4, 100), pj(2, 1, 8, 100), pj(3, 1, 1, 100)];
        let d = FifoScheduler.select(&pending, &free(&[("n01", 8)]), &[], 0);
        // Job 1 takes 4 cores; job 2 needs 8 and blocks; job 3 must NOT
        // overtake under strict FIFO.
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, JobId(1));
    }

    #[test]
    fn backfill_lets_short_job_through() {
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n01".to_string(), 4u32)].into_iter().collect() },
            expected_end: 1000 * DUR_SEC,
        }];
        let pending = vec![pj(2, 1, 8, 100), pj(3, 1, 2, 100)];
        // 4 cores free now; head needs 8 (must wait for job 99).  Job 3
        // (2 cores, 100s) finishes long before t=1000s: backfill it.
        let d = BackfillScheduler.select(&pending, &free(&[("n01", 4)]), &running, 0);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, JobId(3));
    }

    #[test]
    fn backfill_never_delays_head() {
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n01".to_string(), 4u32)].into_iter().collect() },
            expected_end: 50 * DUR_SEC,
        }];
        // Job 3 would run 100s but head could start at t=50s: no backfill.
        let pending = vec![pj(2, 1, 8, 100), pj(3, 1, 2, 100)];
        let d = BackfillScheduler.select(&pending, &free(&[("n01", 4)]), &running, 0);
        assert!(d.is_empty());
    }

    #[test]
    fn backfill_equals_fifo_when_unblocked() {
        let pending = vec![pj(1, 1, 2, 10), pj(2, 1, 2, 10)];
        let f = free(&[("n01", 8)]);
        let d1 = FifoScheduler.select(&pending, &f, &[], 0);
        let d2 = BackfillScheduler.select(&pending, &f, &[], 0);
        assert_eq!(d1.len(), 2);
        assert_eq!(d1.iter().map(|x| x.0).collect::<Vec<_>>(), d2.iter().map(|x| x.0).collect::<Vec<_>>());
    }

    #[test]
    fn shadow_time_accumulates_releases() {
        // Head needs 8; 2 free; two running jobs release 3 each at t=10,20.
        let running = vec![
            RunningJob {
                id: JobId(1),
                allocation: Allocation { cores: [("n01".to_string(), 3u32)].into_iter().collect() },
                expected_end: 10,
            },
            RunningJob {
                id: JobId(2),
                allocation: Allocation { cores: [("n01".to_string(), 3u32)].into_iter().collect() },
                expected_end: 20,
            },
        ];
        let s = shadow_time(
            &ResourceRequest { nodes: 1, ppn: 8 },
            &free(&[("n01", 2)]),
            &running,
        );
        assert_eq!(s, Some(20));
    }

    #[test]
    fn prop_no_policy_overallocates() {
        prop::check(200, |g| {
            let n_nodes = g.usize_in(1..5);
            let capacities: Vec<u32> = (0..n_nodes).map(|_| g.u64_in(1..17) as u32).collect();
            let f: Vec<FreeNode> = capacities
                .iter()
                .enumerate()
                .map(|(i, &c)| FreeNode { name: format!("n{i:02}"), free_cores: c })
                .collect();
            let pending: Vec<PendingJob> = (0..g.usize_in(1..8))
                .map(|i| pj(i as u64, g.u64_in(1..4) as u32, g.u64_in(1..9) as u32, g.u64_in(1..1000)))
                .collect();
            for sched in [&FifoScheduler as &dyn Scheduler, &BackfillScheduler] {
                let d = sched.select(&pending, &f, &[], 0);
                // Sum of grants per node <= capacity.
                let mut used: std::collections::HashMap<&str, u32> = Default::default();
                for (_, a) in &d {
                    for (n, c) in &a.cores {
                        *used.entry(n.as_str()).or_insert(0) += c;
                    }
                }
                for (i, &cap) in capacities.iter().enumerate() {
                    let name = format!("n{i:02}");
                    if used.get(name.as_str()).copied().unwrap_or(0) > cap {
                        return expect(false, &format!("{} overallocated", sched.name()));
                    }
                }
                // No duplicate job starts.
                let mut ids: Vec<u64> = d.iter().map(|(j, _)| j.0).collect();
                ids.sort_unstable();
                ids.dedup();
                if ids.len() != d.len() {
                    return expect(false, "duplicate starts");
                }
            }
            prop::Outcome::Pass
        });
    }
}
