//! Scheduling policies.
//!
//! Torque 2.4's default scheduler is FIFO; we implement it plus
//! conservative EASY backfill as the A1 ablation (DESIGN.md): backfill
//! lets short jobs jump ahead *only* if they cannot delay the head job's
//! earliest possible start.
//!
//! Policies select against a [`FreePool`] — the server's incrementally
//! maintained free-core index — and apply their own grants to it, so one
//! scheduling cycle costs O(decisions · log n) instead of cloning and
//! sorting every free node per decision.  [`BackfillScheduler`] memoizes
//! the head job's shadow projection across cycles keyed on the pool's
//! `(tag, version)`: any alloc/free/fault/completion bumps the version,
//! so a hit is only possible when provably *nothing* changed.

use super::alloc::{match_request, Allocation, FreeNode, FreePool, ResourceRequest};
use super::job::JobId;
use crate::sim::clock::SimTime;
use std::cell::{Cell, RefCell};

/// A queued job as the scheduler sees it.
#[derive(Debug, Clone)]
pub struct PendingJob {
    pub id: JobId,
    pub request: ResourceRequest,
    /// Walltime estimate (requested walltime, or a default).
    pub walltime: SimTime,
    pub queue_priority: i32,
}

/// A running job as the scheduler sees it (for backfill reservations).
#[derive(Debug, Clone)]
pub struct RunningJob {
    pub id: JobId,
    pub allocation: Allocation,
    pub expected_end: SimTime,
}

/// A scheduling decision.
pub type Decision = Vec<(JobId, Allocation)>;

/// Policy interface.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Choose jobs to start now.  `pending` is in queue order (priority
    /// then FIFO); `pool` is the live free-core index — the policy applies
    /// its own grants to it, so on return the pool reflects the decision.
    fn select(
        &self,
        pending: &[PendingJob],
        pool: &mut FreePool,
        running: &[RunningJob],
        now: SimTime,
    ) -> Decision;
}

/// Strict FIFO: start jobs in order; stop at the first that doesn't fit
/// (no overtaking — the head job's resources are implicitly reserved).
pub struct FifoScheduler;

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &self,
        pending: &[PendingJob],
        pool: &mut FreePool,
        _running: &[RunningJob],
        _now: SimTime,
    ) -> Decision {
        let mut out = Decision::new();
        for job in pending {
            match pool.match_request(&job.request) {
                Some(alloc) => {
                    pool.apply_alloc(&alloc);
                    out.push((job.id, alloc));
                }
                None => break, // strict: nobody overtakes the head
            }
        }
        out
    }
}

/// One memoized shadow projection: valid for exactly one head job against
/// one pool state (and, via the version discipline, one running set — the
/// server touches the pool whenever the running set changes).
struct ShadowCache {
    head: JobId,
    pool_tag: u64,
    pool_version: u64,
    shadow: Option<(SimTime, Allocation)>,
}

/// EASY backfill: like FIFO, but when the head job blocks, compute its
/// shadow start time from running-job completions and let later jobs run
/// now if (a) they fit in current free capacity and (b) they will finish
/// before the shadow time OR their allocation doesn't touch the nodes the
/// head job's shadow allocation needs.  When no shadow exists (the head
/// can never start with the currently-online nodes, even after every
/// running job releases), nothing started now can delay it further, so
/// any fitting job may backfill.
///
/// The shadow is maintained incrementally across scheduling rounds: on the
/// common idle-head cycle (same blocked head, untouched pool) the replay
/// of running-job completions is skipped entirely.
pub struct BackfillScheduler {
    cache: RefCell<Option<ShadowCache>>,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

impl Default for BackfillScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl BackfillScheduler {
    pub fn new() -> Self {
        Self { cache: RefCell::new(None), hits: Cell::new(0), misses: Cell::new(0) }
    }

    /// (cache hits, cache misses) of the shadow memo — observability for
    /// the sched_ablation bench and tests.
    pub fn shadow_stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    fn shadow_for(
        &self,
        head: &PendingJob,
        pool: &FreePool,
        running: &[RunningJob],
        cacheable: bool,
    ) -> Option<(SimTime, Allocation)> {
        if cacheable {
            let cached = self.cache.borrow().as_ref().and_then(|c| {
                (c.head == head.id && c.pool_tag == pool.tag() && c.pool_version == pool.version())
                    .then(|| c.shadow.clone())
            });
            if let Some(shadow) = cached {
                self.hits.set(self.hits.get() + 1);
                return shadow;
            }
        }
        self.misses.set(self.misses.get() + 1);
        let shadow = shadow_time(&head.request, &pool.to_free_nodes(), running);
        if cacheable {
            *self.cache.borrow_mut() = Some(ShadowCache {
                head: head.id,
                pool_tag: pool.tag(),
                pool_version: pool.version(),
                shadow: shadow.clone(),
            });
        }
        shadow
    }
}

impl Scheduler for BackfillScheduler {
    fn name(&self) -> &'static str {
        "backfill"
    }

    fn select(
        &self,
        pending: &[PendingJob],
        pool: &mut FreePool,
        running: &[RunningJob],
        now: SimTime,
    ) -> Decision {
        let mut out = Decision::new();
        let mut idx = 0;
        // Greedy FIFO prefix.
        while idx < pending.len() {
            let job = &pending[idx];
            match pool.match_request(&job.request) {
                Some(alloc) => {
                    pool.apply_alloc(&alloc);
                    out.push((job.id, alloc));
                    idx += 1;
                }
                None => break,
            }
        }
        if idx >= pending.len() {
            return out;
        }
        // Head job blocked: find its shadow (time + allocation witness) by
        // replaying completions — or reuse the memo from the last round.
        // Memoizable only when no prefix start just moved the pool (a
        // prefix apply bumps the version, so the memo could never be
        // reused anyway — skip storing it).
        let head = &pending[idx];
        let shadow = self.shadow_for(head, pool, running, idx == 0);
        // Backfill the rest.
        for job in &pending[idx + 1..] {
            let Some(alloc) = pool.match_request(&job.request) else { continue };
            let ok = match &shadow {
                // (b1) ends before the head could start, or (b2) runs on
                // nodes the head's shadow allocation never touches — the
                // witness allocation stays intact either way.
                Some((t, head_alloc)) => {
                    now.saturating_add(job.walltime) <= *t
                        || alloc.cores.keys().all(|n| !head_alloc.cores.contains_key(n))
                }
                // No shadow: the online pool can never fit the head, and
                // backfilled cores drain back into the same pool.
                None => true,
            };
            if ok {
                pool.apply_alloc(&alloc);
                out.push((job.id, alloc));
            }
        }
        out
    }
}

/// Earliest time the blocked head job could start — and the allocation it
/// would get then — assuming running jobs end at their expected_end and
/// release their cores.
pub(crate) fn shadow_time(
    request: &ResourceRequest,
    free: &[FreeNode],
    running: &[RunningJob],
) -> Option<(SimTime, Allocation)> {
    let mut free = free.to_vec();
    let mut ends: Vec<&RunningJob> = running.iter().collect();
    ends.sort_by_key(|r| r.expected_end);
    for r in ends {
        // Release r's cores.
        for (node, cores) in &r.allocation.cores {
            if let Some(f) = free.iter_mut().find(|f| &f.name == node) {
                f.free_cores += cores;
            } else {
                free.push(FreeNode { name: node.clone(), free_cores: *cores });
            }
        }
        if let Some(alloc) = match_request(request, &free) {
            return Some((r.expected_end, alloc));
        }
    }
    None
}

#[cfg(test)]
fn apply(free: &mut [FreeNode], alloc: &Allocation) {
    for (node, cores) in &alloc.cores {
        let f = free.iter_mut().find(|f| &f.name == node).expect("alloc on unknown node");
        assert!(f.free_cores >= *cores, "over-allocation on {node}");
        f.free_cores -= cores;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::DUR_SEC;
    use crate::util::prop::{self, expect};

    fn pj(id: u64, nodes: u32, ppn: u32, wall_secs: u64) -> PendingJob {
        PendingJob {
            id: JobId(id),
            request: ResourceRequest { nodes, ppn },
            walltime: wall_secs * DUR_SEC,
            queue_priority: 0,
        }
    }

    fn free(spec: &[(&str, u32)]) -> Vec<FreeNode> {
        spec.iter().map(|&(n, c)| FreeNode { name: n.into(), free_cores: c }).collect()
    }

    fn pool_of(free: &[FreeNode]) -> FreePool {
        let mut p = FreePool::new();
        for n in free {
            p.set(&n.name, n.free_cores);
        }
        p
    }

    #[test]
    fn fifo_starts_in_order_until_blocked() {
        let pending = vec![pj(1, 1, 4, 100), pj(2, 1, 8, 100), pj(3, 1, 1, 100)];
        let d = FifoScheduler.select(&pending, &mut pool_of(&free(&[("n01", 8)])), &[], 0);
        // Job 1 takes 4 cores; job 2 needs 8 and blocks; job 3 must NOT
        // overtake under strict FIFO.
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, JobId(1));
    }

    #[test]
    fn backfill_lets_short_job_through() {
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n01".to_string(), 4u32)].into_iter().collect() },
            expected_end: 1000 * DUR_SEC,
        }];
        let pending = vec![pj(2, 1, 8, 100), pj(3, 1, 2, 100)];
        // 4 cores free now; head needs 8 (must wait for job 99).  Job 3
        // (2 cores, 100s) finishes long before t=1000s: backfill it.
        let d = BackfillScheduler::new().select(
            &pending,
            &mut pool_of(&free(&[("n01", 4)])),
            &running,
            0,
        );
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, JobId(3));
    }

    #[test]
    fn backfill_never_delays_head() {
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n01".to_string(), 4u32)].into_iter().collect() },
            expected_end: 50 * DUR_SEC,
        }];
        // Job 3 would run 100s but head could start at t=50s: no backfill.
        let pending = vec![pj(2, 1, 8, 100), pj(3, 1, 2, 100)];
        let d = BackfillScheduler::new().select(
            &pending,
            &mut pool_of(&free(&[("n01", 4)])),
            &running,
            0,
        );
        assert!(d.is_empty());
    }

    #[test]
    fn backfill_on_disjoint_nodes_despite_long_walltime() {
        // Regression: the doc promises backfill for jobs that either end
        // before the shadow time OR never touch the head job's cores; the
        // old code only checked walltime.  Job 3 runs far past the shadow
        // but fits entirely on n02, which the head's shadow allocation
        // (all of n01) never uses — it must backfill.
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n01".to_string(), 6u32)].into_iter().collect() },
            expected_end: 1000 * DUR_SEC,
        }];
        // n01: 2 free now, 8 after job 99 ends; n02: 4 free.
        let pending = vec![pj(2, 1, 8, 5000), pj(3, 1, 4, 5000)];
        let d = BackfillScheduler::new().select(
            &pending,
            &mut pool_of(&free(&[("n01", 2), ("n02", 4)])),
            &running,
            0,
        );
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, JobId(3));
        assert!(d[0].1.cores.contains_key("n02"));
    }

    #[test]
    fn no_shadow_still_backfills_fitting_jobs() {
        // Regression: when the head can never start on the online pool
        // (shadow None), backfill used to shut off entirely and strand
        // every fitting job behind it.
        let pending = vec![pj(2, 1, 16, 100), pj(3, 1, 2, 100)];
        let d =
            BackfillScheduler::new().select(&pending, &mut pool_of(&free(&[("n01", 8)])), &[], 0);
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0, JobId(3));
    }

    #[test]
    fn backfill_equals_fifo_when_unblocked() {
        let pending = vec![pj(1, 1, 2, 10), pj(2, 1, 2, 10)];
        let f = free(&[("n01", 8)]);
        let d1 = FifoScheduler.select(&pending, &mut pool_of(&f), &[], 0);
        let d2 = BackfillScheduler::new().select(&pending, &mut pool_of(&f), &[], 0);
        assert_eq!(d1.len(), 2);
        assert_eq!(d1.iter().map(|x| x.0).collect::<Vec<_>>(), d2.iter().map(|x| x.0).collect::<Vec<_>>());
    }

    #[test]
    fn shadow_time_accumulates_releases() {
        // Head needs 8; 2 free; two running jobs release 3 each at t=10,20.
        let running = vec![
            RunningJob {
                id: JobId(1),
                allocation: Allocation { cores: [("n01".to_string(), 3u32)].into_iter().collect() },
                expected_end: 10,
            },
            RunningJob {
                id: JobId(2),
                allocation: Allocation { cores: [("n01".to_string(), 3u32)].into_iter().collect() },
                expected_end: 20,
            },
        ];
        let s = shadow_time(
            &ResourceRequest { nodes: 1, ppn: 8 },
            &free(&[("n01", 2)]),
            &running,
        );
        let (t, alloc) = s.unwrap();
        assert_eq!(t, 20);
        assert_eq!(alloc.cores["n01"], 8);
    }

    #[test]
    fn decisions_are_identical_across_repeated_runs() {
        // Regression for the deterministic-tie-break hazard: scheduler
        // decisions (and the accounting around them) must be a pure
        // function of the inputs — re-running the same select many times,
        // with node capacities that force multi-node ties, must yield the
        // exact same decision vector every time.  Unordered maps in the
        // path would let hasher state leak into allocation order.
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n02".to_string(), 4u32)].into_iter().collect() },
            expected_end: 500 * DUR_SEC,
        }];
        // Ties everywhere: three 8-core nodes, jobs that fit several ways.
        let f = free(&[("n03", 8), ("n01", 8), ("n02", 4)]);
        let pending = vec![pj(1, 2, 4, 300), pj(2, 1, 8, 800), pj(3, 1, 4, 100), pj(4, 1, 2, 50)];
        let bf = BackfillScheduler::new();
        for sched in [&FifoScheduler as &dyn Scheduler, &bf] {
            let first = sched.select(&pending, &mut pool_of(&f), &running, 0);
            for _ in 0..50 {
                let again = sched.select(&pending, &mut pool_of(&f), &running, 0);
                assert_eq!(first, again, "{} decisions drifted across runs", sched.name());
            }
            // And the placement itself is name-deterministic: every
            // allocation's node list is sorted (BTreeMap order).
            for (_, alloc) in &first {
                let nodes: Vec<&String> = alloc.cores.keys().collect();
                let mut sorted = nodes.clone();
                sorted.sort();
                assert_eq!(nodes, sorted);
            }
        }
    }

    #[test]
    fn shadow_memo_hits_only_while_nothing_changed() {
        // Head blocked, nothing can backfill: the select is a read-only
        // cycle, so the shadow memo must hit on repeats and invalidate on
        // any pool mutation.
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n01".to_string(), 6u32)].into_iter().collect() },
            expected_end: 300 * DUR_SEC,
        }];
        let pending = vec![pj(1, 1, 8, 600)];
        let mut pool = pool_of(&free(&[("n01", 2)]));
        let bf = BackfillScheduler::new();
        let d1 = bf.select(&pending, &mut pool, &running, 0);
        assert!(d1.is_empty());
        assert_eq!(bf.shadow_stats(), (0, 1), "first cycle computes");
        let d2 = bf.select(&pending, &mut pool, &running, 10 * DUR_SEC);
        assert_eq!(d1, d2);
        assert_eq!(bf.shadow_stats(), (1, 1), "idle repeat reuses the memo");
        // Any mutation — here a running-set change surfaced via touch —
        // forces a recompute.
        pool.touch();
        bf.select(&pending, &mut pool, &running, 20 * DUR_SEC);
        assert_eq!(bf.shadow_stats(), (1, 2));
        // A different head never reuses another head's memo.
        let other = vec![pj(2, 1, 8, 600)];
        bf.select(&other, &mut pool, &running, 20 * DUR_SEC);
        assert_eq!(bf.shadow_stats(), (1, 3));
    }

    #[test]
    fn cached_scheduler_matches_a_fresh_one_across_a_round_sequence() {
        // Same cycle sequence through one long-lived (memoizing) scheduler
        // and through fresh instances: decisions must be identical.
        let running = vec![RunningJob {
            id: JobId(99),
            allocation: Allocation { cores: [("n01".to_string(), 6u32)].into_iter().collect() },
            expected_end: 400 * DUR_SEC,
        }];
        let f = free(&[("n01", 2), ("n02", 4)]);
        let rounds: Vec<Vec<PendingJob>> = vec![
            vec![pj(1, 1, 8, 900), pj(2, 1, 4, 600)],
            vec![pj(1, 1, 8, 900)],
            vec![pj(1, 1, 8, 900), pj(3, 1, 2, 10)],
        ];
        let cached = BackfillScheduler::new();
        let mut cached_pool = pool_of(&f);
        let mut fresh_pool = pool_of(&f);
        for (i, pending) in rounds.iter().enumerate() {
            let now = i as SimTime * 60 * DUR_SEC;
            let a = cached.select(pending, &mut cached_pool, &running, now);
            let b = BackfillScheduler::new().select(pending, &mut fresh_pool, &running, now);
            assert_eq!(a, b, "round {i} diverged");
        }
        let (hits, misses) = cached.shadow_stats();
        assert!(hits + misses >= 3);
    }

    #[test]
    fn prop_no_policy_overallocates() {
        prop::check(200, |g| {
            let n_nodes = g.usize_in(1..5);
            let capacities: Vec<u32> = (0..n_nodes).map(|_| g.u64_in(1..17) as u32).collect();
            // Random running allocations consume part of each node, so the
            // backfill branch (shadow replay + disjoint-cores clause) is
            // actually exercised.
            let mut running: Vec<RunningJob> = Vec::new();
            let mut busy: Vec<u32> = vec![0; n_nodes];
            for r in 0..g.usize_in(0..5) {
                let node = g.usize_in(0..n_nodes);
                let avail = capacities[node] - busy[node];
                if avail == 0 {
                    continue;
                }
                let cores = g.u64_in(1..u64::from(avail) + 1) as u32;
                busy[node] += cores;
                running.push(RunningJob {
                    id: JobId(1000 + r as u64),
                    allocation: Allocation {
                        cores: [(format!("n{node:02}"), cores)].into_iter().collect(),
                    },
                    expected_end: g.u64_in(1..5000) * DUR_SEC,
                });
            }
            let f: Vec<FreeNode> = capacities
                .iter()
                .enumerate()
                .map(|(i, &c)| FreeNode { name: format!("n{i:02}"), free_cores: c - busy[i] })
                .collect();
            let pending: Vec<PendingJob> = (0..g.usize_in(1..8))
                .map(|i| pj(i as u64, g.u64_in(1..4) as u32, g.u64_in(1..9) as u32, g.u64_in(1..1000)))
                .collect();
            let bf = BackfillScheduler::new();
            for sched in [&FifoScheduler as &dyn Scheduler, &bf] {
                let d = sched.select(&pending, &mut pool_of(&f), &running, 0);
                // Sum of grants per node <= free capacity.  BTreeMap: the
                // accounting (and any diagnostic it prints) must not vary
                // with hasher state.
                let mut used: std::collections::BTreeMap<&str, u32> = Default::default();
                for (_, a) in &d {
                    for (n, c) in &a.cores {
                        *used.entry(n.as_str()).or_insert(0) += c;
                    }
                }
                for fnode in &f {
                    if used.get(fnode.name.as_str()).copied().unwrap_or(0) > fnode.free_cores {
                        return expect(false, &format!("{} overallocated", sched.name()));
                    }
                }
                // No duplicate job starts.
                let mut ids: Vec<u64> = d.iter().map(|(j, _)| j.0).collect();
                ids.sort_unstable();
                ids.dedup();
                if ids.len() != d.len() {
                    return expect(false, "duplicate starts");
                }
                // The no-head-delay invariant: whatever backfilled must not
                // push the blocked head job's earliest possible start out.
                if sched.name() == "backfill" {
                    let started: std::collections::BTreeSet<u64> =
                        d.iter().map(|(j, _)| j.0).collect();
                    let Some(head_pos) = pending.iter().position(|p| !started.contains(&p.id.0))
                    else {
                        continue; // everything started: no head to delay
                    };
                    let head = &pending[head_pos];
                    let pos_of = |id: JobId| pending.iter().position(|p| p.id == id).unwrap();
                    // Free capacity after the FIFO prefix (starts before the head).
                    let mut free_prefix = f.clone();
                    for (id, a) in &d {
                        if pos_of(*id) < head_pos {
                            apply(&mut free_prefix, a);
                        }
                    }
                    let before = shadow_time(&head.request, &free_prefix, &running);
                    // World with the backfilled jobs treated as running.
                    let mut free_after = free_prefix.clone();
                    let mut running_after = running.clone();
                    for (id, a) in &d {
                        let pos = pos_of(*id);
                        if pos > head_pos {
                            apply(&mut free_after, a);
                            running_after.push(RunningJob {
                                id: *id,
                                allocation: a.clone(),
                                expected_end: pending[pos].walltime, // now == 0
                            });
                        }
                    }
                    let after = shadow_time(&head.request, &free_after, &running_after);
                    if let Some((t_before, _)) = before {
                        let ok = matches!(&after, Some((t_after, _)) if *t_after <= t_before);
                        if !ok {
                            return expect(
                                false,
                                &format!("backfill delayed head: {t_before} -> {after:?}"),
                            );
                        }
                    }
                }
            }
            prop::Outcome::Pass
        });
    }
}
