//! The original `BinaryHeap + tombstone-set` event core, kept verbatim as a
//! reference implementation.
//!
//! [`HeapSimulator`] is the oracle for the timing-wheel engine in
//! [`super::engine`]: property tests drive identical schedule / cancel /
//! advance sequences through both and assert identical firing order and
//! `now()` trajectories, and `benches/sim_engine.rs` uses it for the
//! heap-vs-wheel comparison series.  It is **not** wired into any scenario
//! path — production code runs on the wheel.
//!
//! Semantics intentionally preserved, quirks included:
//!
//! * deterministic (time, then insertion sequence) tie-break;
//! * `schedule_at` clamps past times to `now`;
//! * `run_until`'s gating peek sees cancelled tombstones, so a tombstone at
//!   `t <= until` admits a step that can fire the next live event past
//!   `until`;
//! * tombstones are only reclaimed when popped.
//!
//! The one deliberate divergence from the historical code: `cancel` returns
//! whether the event was live (tracked by a key set), matching the wheel's
//! fixed signature so tests can compare return values too.

use super::clock::SimTime;
use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

/// Handle for a scheduled event (usable for cancellation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct HeapEventId(u64);

type Handler<W> = Box<dyn FnOnce(&mut HeapSimulator<W>, &mut W)>;

struct Entry<W> {
    time: SimTime,
    seq: u64,
    id: HeapEventId,
    handler: Handler<W>,
}

// Order by (time, seq): deterministic FIFO within a timestamp.
impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<W> Eq for Entry<W> {}
impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// The reference heap-based discrete-event simulator.
pub struct HeapSimulator<W> {
    now: SimTime,
    queue: BinaryHeap<Reverse<Entry<W>>>,
    next_seq: u64,
    /// Ordered sets: core DES state must never introduce hasher-dependent
    /// behavior.
    cancelled: BTreeSet<HeapEventId>,
    live: BTreeSet<HeapEventId>,
    executed: u64,
}

impl<W> Default for HeapSimulator<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> HeapSimulator<W> {
    pub fn new() -> Self {
        Self {
            now: 0,
            queue: BinaryHeap::new(),
            next_seq: 0,
            cancelled: BTreeSet::new(),
            live: BTreeSet::new(),
            executed: 0,
        }
    }

    /// Current simulated time (ns).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of pending (non-cancelled) events.
    pub fn pending(&self) -> usize {
        self.live.len()
    }

    /// Timestamp of the earliest stored event, tombstones included.
    pub fn next_event_time(&self) -> Option<SimTime> {
        self.queue.peek().map(|Reverse(e)| e.time)
    }

    /// Schedule `handler` at absolute time `at` (>= now).
    pub fn schedule_at<F>(&mut self, at: SimTime, handler: F) -> HeapEventId
    where
        F: FnOnce(&mut HeapSimulator<W>, &mut W) + 'static,
    {
        let at = at.max(self.now);
        let id = HeapEventId(self.next_seq);
        self.queue.push(Reverse(Entry {
            time: at,
            seq: self.next_seq,
            id,
            handler: Box::new(handler),
        }));
        self.live.insert(id);
        self.next_seq += 1;
        id
    }

    /// Schedule `handler` after a relative delay.
    pub fn schedule_in<F>(&mut self, delay: SimTime, handler: F) -> HeapEventId
    where
        F: FnOnce(&mut HeapSimulator<W>, &mut W) + 'static,
    {
        self.schedule_at(self.now.saturating_add(delay), handler)
    }

    /// Cancel a pending event; returns whether it was live.
    pub fn cancel(&mut self, id: HeapEventId) -> bool {
        if self.live.remove(&id) {
            self.cancelled.insert(id);
            true
        } else {
            false
        }
    }

    /// Execute the next event. Returns false when the queue is empty.
    pub fn step(&mut self, world: &mut W) -> bool {
        while let Some(Reverse(e)) = self.queue.pop() {
            if self.cancelled.remove(&e.id) {
                continue;
            }
            debug_assert!(e.time >= self.now, "time went backwards");
            self.live.remove(&e.id);
            self.now = e.time;
            self.executed += 1;
            (e.handler)(self, world);
            return true;
        }
        false
    }

    /// Run until the queue drains or `until` is reached (events exactly at
    /// `until` still run). Returns the number of events executed.
    pub fn run_until(&mut self, world: &mut W, until: SimTime) -> u64 {
        let start = self.executed;
        loop {
            match self.queue.peek() {
                None => break,
                Some(Reverse(e)) if e.time > until => break,
                _ => {}
            }
            if !self.step(world) {
                break;
            }
        }
        // Even if no events remain beyond `until`, time advances to it.
        if self.now < until {
            self.now = until;
        }
        self.executed - start
    }

    /// Run until the queue is fully drained.
    pub fn run_to_completion(&mut self, world: &mut W) -> u64 {
        let start = self.executed;
        while self.step(world) {}
        self.executed - start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Default)]
    struct World {
        trace: Vec<(SimTime, u32)>,
    }

    #[test]
    fn oracle_preserves_heap_order_and_quirks() {
        let mut sim = HeapSimulator::<World>::new();
        let mut w = World::default();
        let a = sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(20, |s, w| w.trace.push((s.now(), 2)));
        assert!(sim.cancel(a));
        assert!(!sim.cancel(a));
        assert_eq!(sim.pending(), 1);
        // The tombstone at 10 gates run_until(15) open: the live event at
        // 20 fires past the boundary, as the historical core did.
        let n = sim.run_until(&mut w, 15);
        assert_eq!(n, 1);
        assert_eq!(sim.now(), 20);
        assert_eq!(w.trace, vec![(20, 2)]);
    }

    #[test]
    fn oracle_equal_times_fifo() {
        let mut sim = HeapSimulator::<World>::new();
        let mut w = World::default();
        for i in 0..10u32 {
            sim.schedule_at(5, move |s, w| w.trace.push((s.now(), i)));
        }
        sim.run_to_completion(&mut w);
        let order: Vec<u32> = w.trace.iter().map(|&(_, i)| i).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }
}
