//! Deterministic discrete-event simulation core.
//!
//! Everything in the Gridlan stack that has *time* — packet flight, VM
//! boot, scheduler cycles, the 5-minute monitor ping — runs on this engine.
//! Determinism contract: events at equal timestamps fire in insertion
//! order (a monotone sequence number breaks ties), and all randomness comes
//! from seeded [`crate::util::rng::SplitMix64`] streams, so a scenario
//! replays bit-identically.
//!
//! The production engine is the hierarchical timing wheel in [`engine`];
//! [`baseline`] keeps the original `BinaryHeap` core as a test oracle and
//! bench comparison point.

pub mod baseline;
pub mod clock;
pub mod engine;

pub use baseline::HeapSimulator;
pub use clock::{SimTime, DUR_MS, DUR_SEC, DUR_US};
pub use engine::{EventId, Handler, Simulator};
