//! Deterministic discrete-event simulation core.
//!
//! Everything in the Gridlan stack that has *time* — packet flight, VM
//! boot, scheduler cycles, the 5-minute monitor ping — runs on this engine.
//! Determinism contract: events at equal timestamps fire in insertion
//! order (a monotone sequence number breaks ties), and all randomness comes
//! from seeded [`crate::util::rng::SplitMix64`] streams, so a scenario
//! replays bit-identically.

pub mod clock;
pub mod engine;

pub use clock::{SimTime, DUR_MS, DUR_SEC, DUR_US};
pub use engine::{EventId, Simulator};
