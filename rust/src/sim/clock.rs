//! Simulated time: u64 nanoseconds since scenario start.

/// Simulated time in nanoseconds.
pub type SimTime = u64;

/// One microsecond in [`SimTime`] units.
pub const DUR_US: SimTime = 1_000;
/// One millisecond.
pub const DUR_MS: SimTime = 1_000_000;
/// One second.
pub const DUR_SEC: SimTime = 1_000_000_000;

/// Convert seconds (f64) to SimTime, saturating at u64::MAX.
pub fn from_secs_f64(s: f64) -> SimTime {
    if s <= 0.0 {
        0
    } else {
        let ns = (s * 1e9).round();
        if ns >= u64::MAX as f64 {
            u64::MAX
        } else {
            ns as u64
        }
    }
}

/// Convert SimTime to seconds.
pub fn to_secs_f64(t: SimTime) -> f64 {
    t as f64 / 1e9
}

/// Convert microseconds (f64) to SimTime.
pub fn from_us_f64(us: f64) -> SimTime {
    from_secs_f64(us * 1e-6)
}

/// Convert SimTime to microseconds.
pub fn to_us_f64(t: SimTime) -> f64 {
    t as f64 / 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(from_secs_f64(1.5), 1_500_000_000);
        assert!((to_secs_f64(2 * DUR_SEC) - 2.0).abs() < 1e-12);
        assert_eq!(from_us_f64(550.0), 550 * DUR_US);
        assert!((to_us_f64(1250 * DUR_US) - 1250.0).abs() < 1e-9);
    }

    #[test]
    fn negative_and_overflow_saturate() {
        assert_eq!(from_secs_f64(-5.0), 0);
        assert_eq!(from_secs_f64(1e30), u64::MAX);
    }
}
