//! The event engine: a priority queue of timestamped closures over a
//! user-supplied world state `W`.
//!
//! Handlers get `(&mut Simulator<W>, &mut W)` so they can schedule further
//! events — the standard process-interaction DES pattern without coroutines.

use super::clock::SimTime;
use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

/// Handle for a scheduled event (usable for cancellation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EventId(u64);

type Handler<W> = Box<dyn FnOnce(&mut Simulator<W>, &mut W)>;

struct Entry<W> {
    time: SimTime,
    seq: u64,
    id: EventId,
    handler: Handler<W>,
}

// Order by (time, seq): deterministic FIFO within a timestamp.
impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<W> Eq for Entry<W> {}
impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

/// The discrete-event simulator.
pub struct Simulator<W> {
    now: SimTime,
    queue: BinaryHeap<Reverse<Entry<W>>>,
    next_seq: u64,
    /// Ordered set: the cancellation table is core DES state and must
    /// never introduce hasher-dependent behavior.
    cancelled: BTreeSet<EventId>,
    executed: u64,
}

impl<W> Default for Simulator<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Simulator<W> {
    pub fn new() -> Self {
        Self {
            now: 0,
            queue: BinaryHeap::new(),
            next_seq: 0,
            cancelled: BTreeSet::new(),
            executed: 0,
        }
    }

    /// Current simulated time (ns).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of pending (non-cancelled) events.
    pub fn pending(&self) -> usize {
        self.queue.len() - self.cancelled.len().min(self.queue.len())
    }

    /// Schedule `handler` at absolute time `at` (>= now).
    pub fn schedule_at<F>(&mut self, at: SimTime, handler: F) -> EventId
    where
        F: FnOnce(&mut Simulator<W>, &mut W) + 'static,
    {
        let at = at.max(self.now);
        let id = EventId(self.next_seq);
        self.queue.push(Reverse(Entry {
            time: at,
            seq: self.next_seq,
            id,
            handler: Box::new(handler),
        }));
        self.next_seq += 1;
        id
    }

    /// Schedule `handler` after a relative delay.
    pub fn schedule_in<F>(&mut self, delay: SimTime, handler: F) -> EventId
    where
        F: FnOnce(&mut Simulator<W>, &mut W) + 'static,
    {
        self.schedule_at(self.now.saturating_add(delay), handler)
    }

    /// Cancel a pending event. Safe to call on already-fired ids (no-op).
    pub fn cancel(&mut self, id: EventId) {
        self.cancelled.insert(id);
    }

    /// Execute the next event. Returns false when the queue is empty.
    pub fn step(&mut self, world: &mut W) -> bool {
        while let Some(Reverse(e)) = self.queue.pop() {
            if self.cancelled.remove(&e.id) {
                continue;
            }
            debug_assert!(e.time >= self.now, "time went backwards");
            self.now = e.time;
            self.executed += 1;
            (e.handler)(self, world);
            return true;
        }
        false
    }

    /// Run until the queue drains or `until` is reached (events exactly at
    /// `until` still run). Returns the number of events executed.
    pub fn run_until(&mut self, world: &mut W, until: SimTime) -> u64 {
        let start = self.executed;
        loop {
            match self.queue.peek() {
                None => break,
                Some(Reverse(e)) if e.time > until => break,
                _ => {}
            }
            if !self.step(world) {
                break;
            }
        }
        // Even if no events remain beyond `until`, time advances to it.
        if self.now < until {
            self.now = until;
        }
        self.executed - start
    }

    /// Run until the queue is fully drained.
    pub fn run_to_completion(&mut self, world: &mut W) -> u64 {
        let start = self.executed;
        while self.step(world) {}
        self.executed - start
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::DUR_SEC;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[derive(Default)]
    struct World {
        trace: Vec<(SimTime, u32)>,
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(30, |s, w| w.trace.push((s.now(), 3)));
        sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(20, |s, w| w.trace.push((s.now(), 2)));
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn equal_times_fifo() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        for i in 0..10u32 {
            sim.schedule_at(5, move |s, w| w.trace.push((s.now(), i)));
        }
        sim.run_to_completion(&mut w);
        let order: Vec<u32> = w.trace.iter().map(|&(_, i)| i).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_schedule_more() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(10, |s, _w: &mut World| {
            s.schedule_in(5, |s2, w2| w2.trace.push((s2.now(), 99)));
        });
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(15, 99)]);
    }

    #[test]
    fn cancellation() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        let id = sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(20, |s, w| w.trace.push((s.now(), 2)));
        sim.cancel(id);
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(20, 2)]);
    }

    #[test]
    fn run_until_boundary_inclusive() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(11, |s, w| w.trace.push((s.now(), 2)));
        let n = sim.run_until(&mut w, 10);
        assert_eq!(n, 1);
        assert_eq!(sim.now(), 10);
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace.len(), 2);
    }

    #[test]
    fn run_until_advances_time_with_empty_queue() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.run_until(&mut w, 5 * DUR_SEC);
        assert_eq!(sim.now(), 5 * DUR_SEC);
    }

    #[test]
    fn periodic_pattern() {
        // A self-rescheduling event: the monitor's 5-minute ping loop shape.
        struct P {
            count: Rc<RefCell<u32>>,
        }
        fn tick(s: &mut Simulator<P>, w: &mut P) {
            *w.count.borrow_mut() += 1;
            if *w.count.borrow() < 5 {
                s.schedule_in(300 * DUR_SEC, tick);
            }
        }
        let count = Rc::new(RefCell::new(0));
        let mut w = P { count: count.clone() };
        let mut sim = Simulator::<P>::new();
        sim.schedule_at(0, tick);
        sim.run_to_completion(&mut w);
        assert_eq!(*count.borrow(), 5);
        assert_eq!(sim.now(), 4 * 300 * DUR_SEC);
    }

    #[test]
    fn past_schedule_clamps_to_now() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(100, |s, _w: &mut World| {
            s.schedule_at(50, |s2, w2| w2.trace.push((s2.now(), 7)));
        });
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(100, 7)]);
    }
}
