//! The event engine: a hierarchical timing wheel of timestamped closures
//! over a user-supplied world state `W`.
//!
//! Handlers get `(&mut Simulator<W>, &mut W)` so they can schedule further
//! events — the standard process-interaction DES pattern without coroutines.
//!
//! # Structure
//!
//! Events live in a slab (`Vec<Slot>` + free list): a stable `u32` index
//! plus a per-slot generation counter form the public [`EventId`], so
//! cancellation is O(1) — mark the slot, drop the closure — with no
//! tombstone set to search.  The *order* of events is kept separately as
//! bare slot indices in a hierarchical timing wheel:
//!
//! * 8 levels × 64 slots, 6 bits per level, 1 tick = 1 ns.  The wheel spans
//!   2^48 ns (~78 h) ahead of its `cursor`; anything beyond parks in a
//!   `BTreeMap` overflow keyed by `(time, seq)`.
//! * An event at time `t` is bucketed by the *highest bit in which `t`
//!   differs from the cursor*: level `floor(h/6)`, slot `(t >> 6·level) & 63`.
//!   `t == cursor` maps to level 0.  Per-level occupancy bitmaps make
//!   find-minimum a couple of `trailing_zeros` calls.
//! * Draining the earliest level-0 bucket yields every event at one exact
//!   timestamp; the batch is sorted by insertion sequence (`seq`) and fired
//!   FIFO, preserving the documented deterministic tie-break — (time, then
//!   insertion order) — bit-for-bit against the previous `BinaryHeap` core
//!   (see `sim::baseline::HeapSimulator`, the reference implementation kept
//!   as a test oracle).
//! * Draining a level ≥ 1 bucket first advances the cursor to the bucket's
//!   base time, then re-buckets ("cascades") its entries; each provably
//!   lands at a strictly lower level, so cascades terminate.
//!
//! # Invariants (see also DESIGN.md §7)
//!
//! * Every stored event has `t >= cursor`, and `cursor <= now` whenever
//!   user code can observe the engine.
//! * For levels ≥ 1, occupied slots are strictly greater than the cursor's
//!   slot at that level; at level 0, `>=`.  Hence the lowest set bit of the
//!   lowest non-empty level's bitmap names the bucket holding the global
//!   minimum, and no wrap-around handling is needed.
//! * Overflow entries are strictly later than every in-wheel entry, and
//!   cursor advances within the wheel never pull overflow into the horizon
//!   (the moved bits sit below bit 48), so promotion happens only when the
//!   wheel itself is empty.

use super::clock::SimTime;
use std::collections::BTreeMap;

const LEVEL_BITS: usize = 6;
const SLOTS: usize = 1 << LEVEL_BITS; // 64 slots per level
const LEVELS: usize = 8;
const WHEEL_BITS: usize = LEVEL_BITS * LEVELS; // 48-bit horizon
const SLOT_MASK: u64 = (SLOTS - 1) as u64;

/// Handle for a scheduled event (usable for cancellation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EventId(u64);

/// The closure type fired by the engine.  Public so call sites can build
/// batches for [`Simulator::schedule_batch`].
pub type Handler<W> = Box<dyn FnOnce(&mut Simulator<W>, &mut W)>;

/// One slab slot.  Occupied-live: `handler` is `Some`.  Occupied-cancelled
/// (tombstone awaiting its bucket drain): `cancelled` true, handler already
/// dropped.  Free: neither.
struct Slot<W> {
    gen: u32,
    time: SimTime,
    seq: u64,
    cancelled: bool,
    handler: Option<Handler<W>>,
}

/// The discrete-event simulator.
pub struct Simulator<W> {
    now: SimTime,
    /// Wheel reference time: all stored events are at `t >= cursor`, and
    /// `(t ^ cursor) >> 48 == 0` for in-wheel events.
    cursor: SimTime,
    next_seq: u64,
    executed: u64,
    /// Pending non-cancelled events.
    live: u64,
    /// Occupied slab slots: live + cancelled-but-not-yet-drained.
    stored: usize,
    slots: Vec<Slot<W>>,
    free_list: Vec<u32>,
    /// `LEVELS * SLOTS` buckets of slab indices.
    buckets: Vec<Vec<u32>>,
    /// Per-level occupancy bitmaps (bit = slot has a non-empty bucket).
    occupied: [u64; LEVELS],
    /// Events beyond the wheel horizon, ordered: deterministic promotion.
    overflow: BTreeMap<(SimTime, u64), u32>,
    /// The batch currently being fired: one exact timestamp, seq-sorted.
    due: Vec<u32>,
    due_head: usize,
    due_time: SimTime,
    due_active: bool,
}

impl<W> Default for Simulator<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Simulator<W> {
    pub fn new() -> Self {
        Self {
            now: 0,
            cursor: 0,
            next_seq: 0,
            executed: 0,
            live: 0,
            stored: 0,
            slots: Vec::new(),
            free_list: Vec::new(),
            buckets: (0..LEVELS * SLOTS).map(|_| Vec::new()).collect(),
            occupied: [0; LEVELS],
            overflow: BTreeMap::new(),
            due: Vec::new(),
            due_head: 0,
            due_time: 0,
            due_active: false,
        }
    }

    /// Current simulated time (ns).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn executed(&self) -> u64 {
        self.executed
    }

    /// Number of pending (non-cancelled) events.
    pub fn pending(&self) -> usize {
        self.live as usize
    }

    /// Timestamp of the earliest *stored* event, cancelled tombstones
    /// included — the same view the old heap's `peek` had, which
    /// `run_until` depends on (see the boundary note there).
    pub fn next_event_time(&self) -> Option<SimTime> {
        if self.due_head < self.due.len() {
            return Some(self.due_time);
        }
        for level in 0..LEVELS {
            if self.occupied[level] == 0 {
                continue;
            }
            let slot = self.occupied[level].trailing_zeros() as usize;
            if level == 0 {
                // Level-0 buckets hold one exact timestamp.
                return Some((self.cursor & !SLOT_MASK) | slot as u64);
            }
            // The lowest bucket of the lowest non-empty level contains the
            // global minimum; for levels >= 1 low-order bits vary, so scan.
            return self.buckets[level * SLOTS + slot]
                .iter()
                .map(|&i| self.slots[i as usize].time)
                .min();
        }
        self.overflow.keys().next().map(|&(t, _)| t)
    }

    /// Schedule `handler` at absolute time `at` (>= now).
    pub fn schedule_at<F>(&mut self, at: SimTime, handler: F) -> EventId
    where
        F: FnOnce(&mut Simulator<W>, &mut W) + 'static,
    {
        let at = at.max(self.now);
        self.insert(at, Box::new(handler))
    }

    /// Schedule `handler` after a relative delay.
    pub fn schedule_in<F>(&mut self, delay: SimTime, handler: F) -> EventId
    where
        F: FnOnce(&mut Simulator<W>, &mut W) + 'static,
    {
        self.schedule_at(self.now.saturating_add(delay), handler)
    }

    /// Batched insertion for storm workloads (boot storms, trace replays):
    /// one slab/ids reservation up front, then the exact per-event path, so
    /// ids and firing order are identical to sequential `schedule_at` calls.
    pub fn schedule_batch<I>(&mut self, events: I) -> Vec<EventId>
    where
        I: IntoIterator<Item = (SimTime, Handler<W>)>,
    {
        let events = events.into_iter();
        let hint = events.size_hint().0;
        let mut ids = Vec::with_capacity(hint);
        let shortfall = hint.saturating_sub(self.free_list.len());
        self.slots.reserve(shortfall);
        for (at, handler) in events {
            ids.push(self.insert(at.max(self.now), handler));
        }
        ids
    }

    /// Cancel a pending event: O(1), drops the handler immediately.
    /// Returns whether the event was live — `false` for already-fired,
    /// already-cancelled, or otherwise stale ids (which previously
    /// *silently succeeded* and skewed `pending()`).
    pub fn cancel(&mut self, id: EventId) -> bool {
        let idx = (id.0 & u32::MAX as u64) as usize;
        let gen = (id.0 >> 32) as u32;
        match self.slots.get_mut(idx) {
            Some(s) if s.gen == gen && s.handler.is_some() => {
                s.handler = None;
                s.cancelled = true;
                self.live -= 1;
                true
            }
            _ => false,
        }
    }

    /// Execute the next event. Returns false when the queue is empty.
    pub fn step(&mut self, world: &mut W) -> bool {
        loop {
            while self.due_head < self.due.len() {
                let idx = self.due[self.due_head];
                self.due_head += 1;
                if self.slots[idx as usize].cancelled {
                    self.free_slot(idx);
                    continue;
                }
                let handler = self.slots[idx as usize]
                    .handler
                    .take()
                    .expect("due entry neither cancelled nor live");
                let time = self.slots[idx as usize].time;
                self.free_slot(idx);
                debug_assert!(time >= self.now, "time went backwards");
                self.now = time;
                self.executed += 1;
                self.live -= 1;
                (handler)(self, world);
                return true;
            }
            if !self.take_due() {
                return false;
            }
        }
    }

    /// Run until the queue drains or `until` is reached (events exactly at
    /// `until` still run). Returns the number of events executed.
    ///
    /// Boundary semantics match the original heap core exactly: the peek
    /// that gates the loop sees cancelled tombstones, so a tombstone at
    /// `t <= until` admits one `step` that may fire the next *live* event
    /// past `until`.  `sim::baseline` keeps the reference behaviour.
    pub fn run_until(&mut self, world: &mut W, until: SimTime) -> u64 {
        let start = self.executed;
        loop {
            match self.next_event_time() {
                Some(t) if t <= until => {
                    if !self.step(world) {
                        break;
                    }
                }
                _ => break,
            }
        }
        // Even if no events remain beyond `until`, time advances to it.
        if self.now < until {
            self.now = until;
        }
        self.executed - start
    }

    /// Run until the queue is fully drained.
    pub fn run_to_completion(&mut self, world: &mut W) -> u64 {
        let start = self.executed;
        while self.step(world) {}
        self.executed - start
    }

    // ------------------------------------------------------------ internals

    fn insert(&mut self, t: SimTime, handler: Handler<W>) -> EventId {
        let seq = self.next_seq;
        self.next_seq += 1;
        let idx = match self.free_list.pop() {
            Some(i) => {
                let s = &mut self.slots[i as usize];
                s.time = t;
                s.seq = seq;
                s.cancelled = false;
                s.handler = Some(handler);
                i
            }
            None => {
                debug_assert!(self.slots.len() < u32::MAX as usize, "slab index overflow");
                self.slots.push(Slot { gen: 0, time: t, seq, cancelled: false, handler: Some(handler) });
                (self.slots.len() - 1) as u32
            }
        };
        self.live += 1;
        self.stored += 1;
        self.place(idx);
        EventId(((self.slots[idx as usize].gen as u64) << 32) | idx as u64)
    }

    fn free_slot(&mut self, idx: u32) {
        let s = &mut self.slots[idx as usize];
        s.gen = s.gen.wrapping_add(1);
        s.handler = None;
        s.cancelled = false;
        self.stored -= 1;
        self.free_list.push(idx);
    }

    /// Bucket slab index `idx` by its slot's time, relative to the cursor.
    fn place(&mut self, idx: u32) {
        let t = self.slots[idx as usize].time;
        if self.due_active && t == self.due_time {
            // Scheduled at the timestamp currently being fired (t == now ==
            // cursor == due_time): its seq is the largest allocated, so
            // appending keeps the batch seq-sorted and it fires this round —
            // exactly what the heap did with an equal-time push mid-fire.
            self.due.push(idx);
            return;
        }
        let x = t ^ self.cursor;
        if x >> WHEEL_BITS != 0 {
            let seq = self.slots[idx as usize].seq;
            self.overflow.insert((t, seq), idx);
            return;
        }
        let level = if x == 0 { 0 } else { (63 - x.leading_zeros() as usize) / LEVEL_BITS };
        let slot = ((t >> (LEVEL_BITS * level)) & SLOT_MASK) as usize;
        self.buckets[level * SLOTS + slot].push(idx);
        self.occupied[level] |= 1u64 << slot;
    }

    /// Refill `due` with the earliest pending batch.  Returns false when
    /// nothing is stored anywhere (and re-anchors the cursor to `now`, so a
    /// drain that consumed only tombstones cannot leave `cursor > now` and
    /// misplace a later, earlier-than-cursor schedule).
    fn take_due(&mut self) -> bool {
        self.due.clear();
        self.due_head = 0;
        loop {
            if self.stored == 0 {
                self.cursor = self.now;
                return false;
            }
            let Some(level) = (0..LEVELS).find(|&l| self.occupied[l] != 0) else {
                // Wheel empty, overflow is not: promote.
                self.promote_overflow();
                continue;
            };
            let slot = self.occupied[level].trailing_zeros() as usize;
            let bi = level * SLOTS + slot;
            if level == 0 {
                let t0 = (self.cursor & !SLOT_MASK) | slot as u64;
                self.cursor = t0;
                self.occupied[0] &= !(1u64 << slot);
                // Swap recycles the previous batch Vec's capacity.
                std::mem::swap(&mut self.due, &mut self.buckets[bi]);
                let mut due = std::mem::take(&mut self.due);
                due.sort_by_key(|&i| self.slots[i as usize].seq);
                self.due = due;
                self.due_time = t0;
                self.due_active = true;
                return true;
            }
            // Cascade: advance the cursor to the bucket's base time first —
            // every entry then re-buckets at a strictly lower level.
            let span_mask = (1u64 << (LEVEL_BITS * (level + 1))) - 1;
            let base = (self.cursor & !span_mask) | ((slot as u64) << (LEVEL_BITS * level));
            self.cursor = base;
            self.occupied[level] &= !(1u64 << slot);
            let mut entries = std::mem::take(&mut self.buckets[bi]);
            for &idx in &entries {
                self.place(idx);
            }
            entries.clear();
            self.buckets[bi] = entries;
        }
    }

    /// Wheel is empty but overflow is not: jump the cursor to the overflow
    /// minimum and pull everything inside the new horizon into the wheel.
    fn promote_overflow(&mut self) {
        let t_min = self
            .overflow
            .keys()
            .next()
            .map(|&(t, _)| t)
            .expect("promote_overflow called with an empty overflow");
        self.cursor = t_min;
        loop {
            let Some(&(t, seq)) = self.overflow.keys().next() else { break };
            if (t ^ self.cursor) >> WHEEL_BITS != 0 {
                break;
            }
            let idx = self.overflow.remove(&(t, seq)).expect("key just observed");
            self.place(idx);
        }
    }

    /// Structural invariant check, used by tests.
    #[cfg(test)]
    fn audit(&self) {
        let unfired_due = self.due.len() - self.due_head;
        let in_buckets: usize = self.buckets.iter().map(|b| b.len()).sum();
        assert_eq!(self.stored, in_buckets + self.overflow.len() + unfired_due, "stored count");
        let occupied_live =
            self.slots.iter().filter(|s| s.handler.is_some()).count() as u64;
        assert_eq!(self.live, occupied_live, "live count");
        for level in 0..LEVELS {
            for slot in 0..SLOTS {
                let bucket = &self.buckets[level * SLOTS + slot];
                assert_eq!(
                    self.occupied[level] & (1u64 << slot) != 0,
                    !bucket.is_empty(),
                    "occupancy bit vs bucket at L{level} S{slot}"
                );
                for &idx in bucket {
                    let t = self.slots[idx as usize].time;
                    assert!(t >= self.cursor, "bucketed event before cursor");
                    let x = t ^ self.cursor;
                    assert_eq!(x >> WHEEL_BITS, 0, "bucketed event beyond horizon");
                    let want_level =
                        if x == 0 { 0 } else { (63 - x.leading_zeros() as usize) / LEVEL_BITS };
                    let want_slot = ((t >> (LEVEL_BITS * want_level)) & SLOT_MASK) as usize;
                    assert_eq!((level, slot), (want_level, want_slot), "misfiled event");
                }
            }
        }
        for &(t, _) in self.overflow.keys() {
            assert_ne!((t ^ self.cursor) >> WHEEL_BITS, 0, "overflow event within horizon");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::DUR_SEC;
    use std::cell::RefCell;
    use std::rc::Rc;

    #[derive(Default)]
    struct World {
        trace: Vec<(SimTime, u32)>,
    }

    #[test]
    fn events_fire_in_time_order() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(30, |s, w| w.trace.push((s.now(), 3)));
        sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(20, |s, w| w.trace.push((s.now(), 2)));
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(10, 1), (20, 2), (30, 3)]);
    }

    #[test]
    fn equal_times_fifo() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        for i in 0..10u32 {
            sim.schedule_at(5, move |s, w| w.trace.push((s.now(), i)));
        }
        sim.run_to_completion(&mut w);
        let order: Vec<u32> = w.trace.iter().map(|&(_, i)| i).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn handlers_can_schedule_more() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(10, |s, _w: &mut World| {
            s.schedule_in(5, |s2, w2| w2.trace.push((s2.now(), 99)));
        });
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(15, 99)]);
    }

    #[test]
    fn cancellation() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        let id = sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(20, |s, w| w.trace.push((s.now(), 2)));
        sim.cancel(id);
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(20, 2)]);
    }

    #[test]
    fn run_until_boundary_inclusive() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(11, |s, w| w.trace.push((s.now(), 2)));
        let n = sim.run_until(&mut w, 10);
        assert_eq!(n, 1);
        assert_eq!(sim.now(), 10);
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace.len(), 2);
    }

    #[test]
    fn run_until_advances_time_with_empty_queue() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.run_until(&mut w, 5 * DUR_SEC);
        assert_eq!(sim.now(), 5 * DUR_SEC);
    }

    #[test]
    fn periodic_pattern() {
        // A self-rescheduling event: the monitor's 5-minute ping loop shape.
        struct P {
            count: Rc<RefCell<u32>>,
        }
        fn tick(s: &mut Simulator<P>, w: &mut P) {
            *w.count.borrow_mut() += 1;
            if *w.count.borrow() < 5 {
                s.schedule_in(300 * DUR_SEC, tick);
            }
        }
        let count = Rc::new(RefCell::new(0));
        let mut w = P { count: count.clone() };
        let mut sim = Simulator::<P>::new();
        sim.schedule_at(0, tick);
        sim.run_to_completion(&mut w);
        assert_eq!(*count.borrow(), 5);
        assert_eq!(sim.now(), 4 * 300 * DUR_SEC);
    }

    #[test]
    fn past_schedule_clamps_to_now() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(100, |s, _w: &mut World| {
            s.schedule_at(50, |s2, w2| w2.trace.push((s2.now(), 7)));
        });
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(100, 7)]);
    }

    // ------------------------------------------- wheel-specific coverage

    #[test]
    fn cancel_reports_liveness() {
        // Regression for the silent-success edge: cancelling a fired or
        // already-cancelled id must return false and not skew pending().
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        let a = sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        let b = sim.schedule_at(20, |s, w| w.trace.push((s.now(), 2)));
        assert!(sim.cancel(a), "first cancel of a pending event is live");
        assert!(!sim.cancel(a), "second cancel is a stale no-op");
        assert_eq!(sim.pending(), 1);
        sim.run_to_completion(&mut w);
        assert!(!sim.cancel(b), "cancelling a fired event reports dead");
        assert_eq!(sim.pending(), 0);
        assert_eq!(w.trace, vec![(20, 2)]);
    }

    #[test]
    fn stale_cancel_does_not_hit_a_reused_slot() {
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        let a = sim.schedule_at(5, |s, w| w.trace.push((s.now(), 1)));
        sim.run_to_completion(&mut w);
        // The freed slot is reused with a bumped generation: the stale id
        // must not cancel the new tenant.
        let _b = sim.schedule_at(9, |s, w| w.trace.push((s.now(), 2)));
        assert!(!sim.cancel(a));
        assert_eq!(sim.pending(), 1);
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(5, 1), (9, 2)]);
    }

    #[test]
    fn cancelled_only_drain_keeps_earlier_schedules_valid() {
        // Regression for the cursor leak: a step() that consumes only
        // tombstones must not strand the cursor past now, or a later
        // schedule at an earlier absolute time would be misplaced.
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        let a = sim.schedule_at(100, |s, w| w.trace.push((s.now(), 1)));
        assert!(sim.cancel(a));
        assert!(!sim.step(&mut w));
        assert_eq!(sim.now(), 0, "draining tombstones does not advance time");
        sim.audit();
        sim.schedule_at(50, |s, w| w.trace.push((s.now(), 2)));
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(50, 2)]);
    }

    #[test]
    fn run_until_boundary_counts_tombstones_like_the_heap() {
        // The old heap's peek saw cancelled entries, so a tombstone at
        // t <= until admitted a step that fired the next live event past
        // until.  The wheel preserves that observable behaviour.
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        let a = sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(20, |s, w| w.trace.push((s.now(), 2)));
        sim.cancel(a);
        let n = sim.run_until(&mut w, 15);
        assert_eq!(n, 1);
        assert_eq!(sim.now(), 20);
        assert_eq!(w.trace, vec![(20, 2)]);
    }

    #[test]
    fn level_boundaries_fire_in_order() {
        // Times straddling every wheel-level boundary, scheduled shuffled.
        let times: Vec<SimTime> = vec![
            0,
            1,
            63,
            64,
            65,
            4_095,
            4_096,
            4_097,
            262_143,
            262_144,
            (1u64 << 42) - 1,
            1u64 << 42,
            (1u64 << 47) + 123,
        ];
        let mut shuffled = times.clone();
        shuffled.reverse();
        shuffled.swap(0, 5);
        shuffled.swap(2, 9);
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        for (i, &t) in shuffled.iter().enumerate() {
            sim.schedule_at(t, move |s, w| w.trace.push((s.now(), i as u32)));
        }
        sim.audit();
        sim.run_to_completion(&mut w);
        let fired: Vec<SimTime> = w.trace.iter().map(|&(t, _)| t).collect();
        assert_eq!(fired, times);
        sim.audit();
    }

    #[test]
    fn overflow_beyond_horizon_fires_in_order() {
        // Events past the 2^48 ns wheel horizon park in overflow and
        // promote deterministically, interleaved with near events.
        let horizon = 1u64 << 48;
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(horizon + 10, |s, w| w.trace.push((s.now(), 3)));
        sim.schedule_at(5, |s, w| w.trace.push((s.now(), 1)));
        sim.schedule_at(horizon - 1, |s, w| w.trace.push((s.now(), 2)));
        sim.schedule_at(3 * horizon + 7, |s, w| w.trace.push((s.now(), 4)));
        sim.audit();
        sim.run_to_completion(&mut w);
        assert_eq!(
            w.trace,
            vec![(5, 1), (horizon - 1, 2), (horizon + 10, 3), (3 * horizon + 7, 4)]
        );
        assert_eq!(sim.pending(), 0);
    }

    #[test]
    fn far_future_now_still_schedules_immediates() {
        // run_until can push now far past the cursor with an empty wheel; a
        // schedule at that now lands in overflow and must still fire.
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(10, |s, w| w.trace.push((s.now(), 1)));
        sim.run_to_completion(&mut w);
        let far = 10 + (1u64 << 50);
        sim.run_until(&mut w, far);
        assert_eq!(sim.now(), far);
        sim.schedule_at(far, |s, w| w.trace.push((s.now(), 2)));
        sim.audit();
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(10, 1), (far, 2)]);
    }

    #[test]
    fn same_time_storm_keeps_insertion_order() {
        // One deep equal-timestamp batch: the seq sort on the drained
        // bucket must reproduce exact insertion order.
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        for i in 0..1_000u32 {
            sim.schedule_at(7 * DUR_SEC, move |s, w| w.trace.push((s.now(), i)));
        }
        sim.run_to_completion(&mut w);
        let order: Vec<u32> = w.trace.iter().map(|&(_, i)| i).collect();
        assert_eq!(order, (0..1_000).collect::<Vec<_>>());
    }

    #[test]
    fn mid_fire_schedule_at_current_time_joins_the_batch() {
        // A handler scheduling at the timestamp currently firing appends to
        // the live batch and fires this round, after all earlier seqs —
        // exactly the heap's equal-time push semantics.
        let mut sim = Simulator::<World>::new();
        let mut w = World::default();
        sim.schedule_at(10, |s, w: &mut World| {
            w.trace.push((s.now(), 1));
            s.schedule_at(10, |s2, w2| w2.trace.push((s2.now(), 3)));
        });
        sim.schedule_at(10, |s, w| w.trace.push((s.now(), 2)));
        sim.run_to_completion(&mut w);
        assert_eq!(w.trace, vec![(10, 1), (10, 2), (10, 3)]);
        assert_eq!(sim.now(), 10);
    }

    #[test]
    fn schedule_batch_matches_sequential_scheduling() {
        let mut seq_sim = Simulator::<World>::new();
        let mut batch_sim = Simulator::<World>::new();
        let times = [40u64, 10, 10, 30, 20];
        let mut seq_ids = Vec::new();
        for (i, &t) in times.iter().enumerate() {
            let i = i as u32;
            seq_ids.push(seq_sim.schedule_at(t, move |s, w: &mut World| {
                w.trace.push((s.now(), i))
            }));
        }
        let batch: Vec<(SimTime, Handler<World>)> = times
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                let i = i as u32;
                let h: Handler<World> =
                    Box::new(move |s: &mut Simulator<World>, w: &mut World| {
                        w.trace.push((s.now(), i))
                    });
                (t, h)
            })
            .collect();
        let batch_ids = batch_sim.schedule_batch(batch);
        assert_eq!(seq_ids, batch_ids, "ids are allocated identically");
        let mut w1 = World::default();
        let mut w2 = World::default();
        seq_sim.run_to_completion(&mut w1);
        batch_sim.run_to_completion(&mut w2);
        assert_eq!(w1.trace, w2.trace, "firing order is identical");
        assert_eq!(w1.trace, vec![(10, 1), (10, 2), (20, 4), (30, 3), (40, 0)]);
    }

    #[test]
    fn slab_reuses_slots_across_a_long_run() {
        // A periodic chain keeps at most a couple of events live; the slab
        // must recycle rather than grow per event.
        struct P {
            count: u32,
        }
        fn tick(s: &mut Simulator<P>, w: &mut P) {
            w.count += 1;
            if w.count < 10_000 {
                s.schedule_in(1_000, tick);
            }
        }
        let mut sim = Simulator::<P>::new();
        let mut w = P { count: 0 };
        sim.schedule_at(0, tick);
        sim.run_to_completion(&mut w);
        assert_eq!(w.count, 10_000);
        assert!(sim.slots.len() <= 4, "slab grew to {} slots", sim.slots.len());
        sim.audit();
    }
}
