//! Collective operations over the hub star topology.
//!
//! Because every node↔node path crosses the server (paper §2.1), the hub
//! IS the natural collective root: bcast/gather are one leg per node, and
//! node-rooted collectives pay an extra hop to reach the server first.

use super::comm::{Communicator, RankLoc};
use crate::netsim::topology::Network;
use crate::util::rng::SplitMix64;
use crate::vpn::hub::VpnHub;

/// Duration (µs) of a broadcast from rank `root` to all other ranks.
/// Server sends sequentially per tunnel (single uplink NIC) but the legs
/// overlap on distinct client links: cost = serialization of all sends at
/// the root + the slowest flight.
pub fn bcast_us(
    comm: &Communicator,
    net: &Network,
    hub: &VpnHub,
    root: usize,
    bytes: u32,
    rng: &mut SplitMix64,
) -> Option<f64> {
    let mut to_server = 0.0;
    // Non-server root first relays to the server (hub routing).
    if !matches!(comm.ranks[root], RankLoc::Server) {
        // Approximate with a send to rank "server" if present, else one leg.
        to_server = comm.send_us(net, hub, root, server_rank(comm)?, bytes, rng)?;
    }
    let mut slowest: f64 = 0.0;
    let mut fanout = 0.0;
    for (i, loc) in comm.ranks.iter().enumerate() {
        if i == root || matches!(loc, RankLoc::Server) {
            continue;
        }
        let leg = comm.send_us(net, hub, server_rank(comm)?, i, bytes, rng)?;
        slowest = slowest.max(leg);
        fanout += 8.0; // per-send server CPU cost, µs
    }
    Some(to_server + fanout + slowest)
}

/// Duration (µs) of a reduce to `root` (gather legs overlap; root pays a
/// per-message combine cost).
pub fn reduce_us(
    comm: &Communicator,
    net: &Network,
    hub: &VpnHub,
    root: usize,
    bytes: u32,
    rng: &mut SplitMix64,
) -> Option<f64> {
    let mut slowest: f64 = 0.0;
    let mut combine = 0.0;
    for i in 0..comm.ranks.len() {
        if i == root {
            continue;
        }
        let leg = comm.send_us(net, hub, i, root, bytes, rng)?;
        slowest = slowest.max(leg);
        combine += 3.0; // µs per partial combined at the root
    }
    Some(slowest + combine)
}

/// allreduce = reduce to server-side root + bcast back.
pub fn allreduce_us(
    comm: &Communicator,
    net: &Network,
    hub: &VpnHub,
    bytes: u32,
    rng: &mut SplitMix64,
) -> Option<f64> {
    let root = server_rank(comm)?;
    Some(reduce_us(comm, net, hub, root, bytes, rng)? + bcast_us(comm, net, hub, root, bytes, rng)?)
}

fn server_rank(comm: &Communicator) -> Option<usize> {
    comm.ranks.iter().position(|r| matches!(r, RankLoc::Server))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::comm::tests::rig;

    fn comm3() -> Communicator {
        Communicator::new(vec![
            RankLoc::Server,
            RankLoc::Node { client: "n01".into(), vnet_us: 165.0 },
            RankLoc::Node { client: "n02".into(), vnet_us: 165.0 },
        ])
    }

    #[test]
    fn bcast_from_server_is_one_leg_deep() {
        let (net, hub, _) = rig();
        let comm = comm3();
        let mut rng = SplitMix64::new(5);
        let b = bcast_us(&comm, &net, &hub, 0, 1024, &mut rng).unwrap();
        let mut rng2 = SplitMix64::new(5);
        let leg = comm.send_us(&net, &hub, 0, 1, 1024, &mut rng2).unwrap();
        assert!(b < 2.0 * leg, "b={b} leg={leg}");
    }

    #[test]
    fn node_rooted_bcast_pays_uplink() {
        let (net, hub, _) = rig();
        let comm = comm3();
        let mut r1 = SplitMix64::new(6);
        let mut r2 = SplitMix64::new(6);
        let from_server = bcast_us(&comm, &net, &hub, 0, 1024, &mut r1).unwrap();
        let from_node = bcast_us(&comm, &net, &hub, 1, 1024, &mut r2).unwrap();
        assert!(from_node > from_server);
    }

    #[test]
    fn allreduce_is_reduce_plus_bcast() {
        let (net, hub, _) = rig();
        let comm = comm3();
        let mut rng = SplitMix64::new(7);
        let ar = allreduce_us(&comm, &net, &hub, 4096, &mut rng).unwrap();
        let mut rng = SplitMix64::new(7);
        let r = reduce_us(&comm, &net, &hub, 0, 4096, &mut rng).unwrap();
        let b = bcast_us(&comm, &net, &hub, 0, 4096, &mut rng).unwrap();
        assert!((ar - (r + b)).abs() < 1.0);
    }
}
