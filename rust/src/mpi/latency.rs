//! The MPI latency (ping-pong) test — paper §3.3, experiment M1.
//!
//! "An additional latency test was also carried out ... with an MPI
//! latency test using the same 56 bytes for the message as the default
//! ICMP ping."  Result in the paper: 1200(80) µs for n01's node vs the
//! 1250(30) µs ICMP node ping — i.e. MPI sees what ping sees.

use super::comm::Communicator;
use crate::netsim::topology::Network;
use crate::util::rng::SplitMix64;
use crate::util::stats::Summary;
use crate::vpn::hub::VpnHub;

/// Round-trip (ping-pong) samples between two ranks.  Returns the RTT
/// summary in µs over `iters` iterations.
pub fn mpi_latency_test(
    comm: &Communicator,
    net: &Network,
    hub: &VpnHub,
    a: usize,
    b: usize,
    bytes: u32,
    iters: usize,
    rng: &mut SplitMix64,
) -> Option<Summary> {
    let mut s = Summary::new();
    for _ in 0..iters {
        let fwd = comm.send_us(net, hub, a, b, bytes, rng)?;
        let back = comm.send_us(net, hub, b, a, bytes, rng)?;
        s.push(fwd + back);
    }
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::comm::{tests::rig, RankLoc};

    #[test]
    fn pingpong_consistent_with_two_sends() {
        let (net, hub, _) = rig();
        let comm = Communicator::new(vec![
            RankLoc::Server,
            RankLoc::Node { client: "n01".into(), vnet_us: 165.0 },
        ]);
        let mut rng = SplitMix64::new(2);
        let s = mpi_latency_test(&comm, &net, &hub, 0, 1, 56, 100, &mut rng).unwrap();
        let mut rng2 = SplitMix64::new(99);
        let one = comm.send_us(&net, &hub, 0, 1, 56, &mut rng2).unwrap();
        assert!((s.mean() - 2.0 * one).abs() < one * 0.05, "mean={} one={one}", s.mean());
        assert_eq!(s.len(), 100);
    }

    #[test]
    fn unreachable_gives_none() {
        let (net, mut hub, _) = rig();
        hub.disconnect("n01");
        let comm = Communicator::new(vec![
            RankLoc::Server,
            RankLoc::Node { client: "n01".into(), vnet_us: 165.0 },
        ]);
        let mut rng = SplitMix64::new(2);
        assert!(mpi_latency_test(&comm, &net, &hub, 0, 1, 56, 5, &mut rng).is_none());
    }
}
