//! Communicators and point-to-point transfer over the Gridlan.

use crate::netsim::packet::{Layer, Packet};
use crate::netsim::topology::Network;
use crate::util::rng::SplitMix64;
use crate::vpn::hub::VpnHub;

/// Where a rank runs.
#[derive(Debug, Clone, PartialEq)]
pub enum RankLoc {
    /// On the Gridlan server itself.
    Server,
    /// On the node hosted by this client (traffic rides the tunnel +
    /// virtio; `vnet_us` is the hypervisor's one-way virtual-NIC cost).
    Node { client: String, vnet_us: f64 },
}

/// An MPI communicator: rank i lives at `ranks[i]`.
#[derive(Debug, Clone)]
pub struct Communicator {
    pub ranks: Vec<RankLoc>,
    /// MPI software stack cost per message per endpoint (marshalling,
    /// matching), µs.
    pub sw_stack_us: f64,
}

impl Communicator {
    pub fn new(ranks: Vec<RankLoc>) -> Self {
        Self { ranks, sw_stack_us: 12.0 }
    }

    pub fn size(&self) -> usize {
        self.ranks.len()
    }

    fn msg(bytes: u32) -> Packet {
        Packet::new(bytes, 1).push_layer(Layer::Ipv4).push_layer(Layer::Udp)
    }

    /// One-way delay (µs) of a `bytes`-byte message from rank `src` to
    /// rank `dst`.  None if either endpoint is unreachable.
    pub fn send_us(
        &self,
        net: &Network,
        hub: &VpnHub,
        src: usize,
        dst: usize,
        bytes: u32,
        rng: &mut SplitMix64,
    ) -> Option<f64> {
        let p = Self::msg(bytes);
        let base = match (&self.ranks[src], &self.ranks[dst]) {
            (RankLoc::Server, RankLoc::Server) => 2.0, // shared memory
            (RankLoc::Server, RankLoc::Node { client, vnet_us }) => {
                hub.server_to_client_us(net, client, &p, rng)? + vnet_us
            }
            (RankLoc::Node { client, vnet_us }, RankLoc::Server) => {
                hub.server_to_client_us(net, client, &p, rng)? + vnet_us
            }
            (
                RankLoc::Node { client: c1, vnet_us: v1 },
                RankLoc::Node { client: c2, vnet_us: v2 },
            ) => {
                if c1 == c2 {
                    // Same VM: loopback.
                    5.0
                } else {
                    hub.client_to_client_us(net, c1, c2, &p, rng)? + v1 + v2
                }
            }
        };
        Some(base + 2.0 * self.sw_stack_us)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::netsim::topology::{DeviceId, LinkProfile, Network};
    use crate::vpn::tunnel::TunnelCost;

    pub fn rig() -> (Network, VpnHub, DeviceId) {
        let mut n = Network::new();
        n.jitter_sigma_us = 0.0;
        let srv = n.add_host("server", 50.0);
        let sw = n.add_switch("sw", 20.0);
        let h1 = n.add_host("h1", 60.0);
        let h2 = n.add_host("h2", 60.0);
        let g = LinkProfile::gigabit();
        n.link(srv, sw, g);
        n.link(sw, h1, g);
        n.link(sw, h2, g);
        let mut hub = VpnHub::new(srv, 3);
        for (name, dev) in [("n01", h1), ("n02", h2)] {
            let k = hub.provision(name);
            hub.connect(name, &k, dev, TunnelCost::default()).unwrap();
        }
        (n, hub, srv)
    }

    fn node(client: &str) -> RankLoc {
        RankLoc::Node { client: client.into(), vnet_us: 165.0 }
    }

    #[test]
    fn node_to_node_slower_than_server_to_node() {
        let (net, hub, _) = rig();
        let comm = Communicator::new(vec![RankLoc::Server, node("n01"), node("n02")]);
        let mut rng = SplitMix64::new(1);
        let s2n = comm.send_us(&net, &hub, 0, 1, 56, &mut rng).unwrap();
        let n2n = comm.send_us(&net, &hub, 1, 2, 56, &mut rng).unwrap();
        assert!(n2n > 1.7 * s2n, "n2n={n2n} s2n={s2n}");
    }

    #[test]
    fn same_vm_is_loopback() {
        let (net, hub, _) = rig();
        let comm = Communicator::new(vec![node("n01"), node("n01")]);
        let mut rng = SplitMix64::new(1);
        let d = comm.send_us(&net, &hub, 0, 1, 56, &mut rng).unwrap();
        assert!(d < 50.0, "loopback d={d}");
    }

    #[test]
    fn disconnected_client_unreachable() {
        let (net, mut hub, _) = rig();
        hub.disconnect("n02");
        let comm = Communicator::new(vec![RankLoc::Server, node("n02")]);
        let mut rng = SplitMix64::new(1);
        assert!(comm.send_us(&net, &hub, 0, 1, 56, &mut rng).is_none());
    }

    #[test]
    fn bigger_messages_cost_more() {
        let (net, hub, _) = rig();
        let comm = Communicator::new(vec![RankLoc::Server, node("n01")]);
        let mut rng = SplitMix64::new(1);
        let small = comm.send_us(&net, &hub, 0, 1, 56, &mut rng).unwrap();
        let big = comm.send_us(&net, &hub, 0, 1, 1_000_000, &mut rng).unwrap();
        assert!(big > small * 2.0, "big={big} small={small}");
    }
}
