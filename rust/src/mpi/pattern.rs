//! Compute/communication pattern analysis (paper §4).
//!
//! "An example of an intermediate case would be a process that spent 70%
//! of the time performing calculations and 30% of the time communicating.
//! It would be up to the user to decide whether this parallelization
//! algorithm was acceptable".  This module quantifies that decision:
//! given a job's per-iteration compute time and message profile, estimate
//! parallel efficiency on the Gridlan vs on a homogeneous cluster.

/// A bulk-synchronous job's communication pattern.
#[derive(Debug, Clone, Copy)]
pub struct CommPattern {
    /// Compute time per iteration per process, µs.
    pub compute_us: f64,
    /// Messages exchanged per process per iteration.
    pub msgs_per_iter: f64,
    /// Bytes per message.
    pub msg_bytes: u32,
}

impl CommPattern {
    /// Embarrassingly parallel: no communication at all.
    pub fn embarrassingly_parallel(compute_us: f64) -> Self {
        Self { compute_us, msgs_per_iter: 0.0, msg_bytes: 0 }
    }

    /// Communication time per iteration given per-message latency (µs) and
    /// per-byte cost (µs/B) of the interconnect.
    pub fn comm_us(&self, latency_us: f64, us_per_byte: f64) -> f64 {
        self.msgs_per_iter * (latency_us + self.msg_bytes as f64 * us_per_byte)
    }

    /// Parallel efficiency: compute / (compute + comm).  The §4 rule of
    /// thumb — the allocated CPU idles while communicating.
    pub fn efficiency(&self, latency_us: f64, us_per_byte: f64) -> f64 {
        let c = self.comm_us(latency_us, us_per_byte);
        if self.compute_us <= 0.0 {
            return 0.0;
        }
        self.compute_us / (self.compute_us + c)
    }

    /// The paper's acceptability analysis: is this job worth parallelizing
    /// across Gridlan nodes (threshold = user's tolerance, e.g. 0.7)?
    pub fn acceptable_on(&self, latency_us: f64, us_per_byte: f64, threshold: f64) -> bool {
        self.efficiency(latency_us, us_per_byte) >= threshold
    }

    /// Latency bound: the largest interconnect latency at which the job
    /// still reaches `threshold` efficiency (µs); None if even zero-latency
    /// can't (bandwidth-bound).
    pub fn max_latency_us(&self, us_per_byte: f64, threshold: f64) -> Option<f64> {
        if self.msgs_per_iter == 0.0 {
            return Some(f64::INFINITY);
        }
        // eff = c/(c + m(l + b)) >= th  =>  l <= (c(1-th)/th)/m - b
        let budget = self.compute_us * (1.0 - threshold) / threshold;
        let per_msg_budget = budget / self.msgs_per_iter;
        let l = per_msg_budget - self.msg_bytes as f64 * us_per_byte;
        if l >= 0.0 {
            Some(l)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ep_jobs_are_perfectly_efficient() {
        let p = CommPattern::embarrassingly_parallel(1e6);
        assert_eq!(p.efficiency(1400.0, 0.08), 1.0);
        assert!(p.acceptable_on(1e9, 1.0, 0.999));
    }

    #[test]
    fn paper_70_30_example() {
        // Construct a pattern that spends 70% computing / 30% communicating
        // on the Gridlan interconnect (1400 µs node-node latency).
        let p = CommPattern { compute_us: 7000.0, msgs_per_iter: 2.0, msg_bytes: 1000 };
        let eff = p.efficiency(1400.0, 0.08);
        assert!((eff - 0.7).abs() < 0.02, "eff={eff}");
        // On a cluster (50 µs, 0.008 µs/B) the same job is fine.
        assert!(p.efficiency(50.0, 0.008) > 0.97);
    }

    #[test]
    fn latency_bound_consistent_with_efficiency() {
        let p = CommPattern { compute_us: 10_000.0, msgs_per_iter: 4.0, msg_bytes: 512 };
        let l = p.max_latency_us(0.08, 0.8).unwrap();
        let eff = p.efficiency(l, 0.08);
        assert!((eff - 0.8).abs() < 1e-6, "eff={eff}");
        assert!(p.efficiency(l * 1.3, 0.08) < 0.8);
    }

    #[test]
    fn bandwidth_bound_job_has_no_latency_budget() {
        let p = CommPattern { compute_us: 100.0, msgs_per_iter: 1.0, msg_bytes: 1_000_000 };
        assert!(p.max_latency_us(0.08, 0.9).is_none());
    }
}
