//! A small MPI-like message layer over the Gridlan transport.
//!
//! The paper uses an "MPI latency test" (§3.3) to confirm ICMP ping is a
//! fair proxy for what scientific tools experience, and §4 analyses when
//! communicating parallel jobs are worth running on the Gridlan at all.
//!
//! * [`comm`] — communicators: ranks pinned to the server or to nodes;
//!   point-to-point delays via the VPN hub (node↔node = two legs);
//! * [`latency`] — the 56-byte ping-pong test (experiment M1);
//! * [`collectives`] — bcast/reduce/allreduce over the hub star;
//! * [`pattern`] — the §4 compute/communication efficiency analysis.

pub mod collectives;
pub mod comm;
pub mod latency;
pub mod pattern;

pub use comm::{Communicator, RankLoc};
pub use latency::mpi_latency_test;
pub use pattern::CommPattern;
