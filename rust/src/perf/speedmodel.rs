//! EP elapsed-time prediction for Gridlan placements and the comparison
//! server — the model behind Fig. 3.
//!
//! Methodology (paper §3.4): "For each run, a random number of Gridlan
//! cores were chosen, from 1 to 26 ... The processes were then scattered
//! randomly through the Gridlan clients, taking account of the number of
//! available cores of each client."  Elapsed time is the slowest process
//! (EP has no communication), and per-process speed depends on how many
//! sibling processes share the client's CPU (Turbo) and on the hypervisor.

use crate::host::client::ClientAgent;
use crate::sim::clock::{from_secs_f64, SimTime};
use crate::util::rng::SplitMix64;
use crate::vm::cpu::CpuModel;
use std::collections::BTreeMap;

/// A process placement: client name → number of processes there.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct Placement {
    pub per_client: BTreeMap<String, u32>,
}

impl Placement {
    pub fn total_procs(&self) -> u32 {
        self.per_client.values().sum()
    }
}

/// The Gridlan client pool for the fig3 experiment.
#[derive(Debug, Clone)]
pub struct GridlanPool {
    pub clients: Vec<ClientAgent>,
}

impl GridlanPool {
    pub fn table1() -> Self {
        Self { clients: ClientAgent::table1() }
    }

    pub fn total_cores(&self) -> u32 {
        self.clients.iter().map(|c| c.cpu.cores).sum()
    }

    /// Random placement of `n` processes, never oversubscribing a client
    /// (the paper "tak[es] account of the number of available cores").
    pub fn random_placement(&self, n: u32, rng: &mut SplitMix64) -> Placement {
        assert!(n >= 1 && n <= self.total_cores(), "n={n} out of range");
        // Build the core slot list, shuffle, take n.
        let mut slots: Vec<&str> = Vec::new();
        for c in &self.clients {
            for _ in 0..c.cpu.cores {
                slots.push(&c.name);
            }
        }
        rng.shuffle(&mut slots);
        let mut p = Placement::default();
        for &slot in slots.iter().take(n as usize) {
            *p.per_client.entry(slot.to_string()).or_insert(0) += 1;
        }
        p
    }

    /// Predicted elapsed seconds for `pairs` total pairs over `placement`.
    /// Work is split evenly across processes; elapsed = slowest process.
    pub fn elapsed_secs(&self, pairs: u64, placement: &Placement) -> f64 {
        let n = placement.total_procs() as u64;
        assert!(n >= 1);
        let work_per_proc = pairs as f64 / n as f64;
        let mut worst: f64 = 0.0;
        for (client_name, &procs) in &placement.per_client {
            let client = self
                .clients
                .iter()
                .find(|c| &c.name == client_name)
                .unwrap_or_else(|| panic!("unknown client {client_name}"));
            assert!(procs <= client.cpu.cores, "oversubscribed {client_name}");
            // All `procs` processes on this client are active together.
            let rate_mpairs = client.guest_ep_rate(procs);
            let secs = work_per_proc / (rate_mpairs * 1e6);
            worst = worst.max(secs);
        }
        worst
    }

    /// Elapsed as SimTime (for the event-driven path).
    pub fn elapsed(&self, pairs: u64, placement: &Placement) -> SimTime {
        from_secs_f64(self.elapsed_secs(pairs, placement))
    }
}

/// The paper's comparison server: bare metal, one CPU model, n cores used.
#[derive(Debug, Clone)]
pub struct ComparisonServer {
    pub cpu: CpuModel,
}

impl ComparisonServer {
    pub fn opteron() -> Self {
        Self { cpu: CpuModel::opteron_6376_quad() }
    }

    /// Elapsed seconds using `n` cores (even split, all active together).
    pub fn elapsed_secs(&self, pairs: u64, n: u32) -> f64 {
        assert!(n >= 1 && n <= self.cpu.cores);
        let work_per_proc = pairs as f64 / n as f64;
        work_per_proc / (self.cpu.ep_rate_mpairs(n) * 1e6)
    }

    /// Smallest core count whose elapsed time beats `target_secs`
    /// (None if even all cores can't).  The paper: "to achieve the same
    /// performance, the comparison server requires 38 cores".
    pub fn cores_to_match(&self, pairs: u64, target_secs: f64) -> Option<u32> {
        (1..=self.cpu.cores).find(|&n| self.elapsed_secs(pairs, n) <= target_secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{self, expect};
    use crate::workload::ep::EpClass;

    fn full_placement(pool: &GridlanPool) -> Placement {
        let mut p = Placement::default();
        for c in &pool.clients {
            p.per_client.insert(c.name.clone(), c.cpu.cores);
        }
        p
    }

    #[test]
    fn fig3_headline_26_cores_near_212s() {
        let pool = GridlanPool::table1();
        let p = full_placement(&pool);
        assert_eq!(p.total_procs(), 26);
        let t = pool.elapsed_secs(EpClass::D.pairs(), &p);
        assert!((190.0..235.0).contains(&t), "26-core class D elapsed = {t}");
    }

    #[test]
    fn fig3_headline_server_needs_about_38_cores() {
        let pool = GridlanPool::table1();
        let t26 = pool.elapsed_secs(EpClass::D.pairs(), &full_placement(&pool));
        let server = ComparisonServer::opteron();
        let need = server.cores_to_match(EpClass::D.pairs(), t26).unwrap();
        assert!((34..=42).contains(&need), "server needs {need} cores");
        // And the Gridlan beats the server at equal core counts up to 26.
        for n in [4u32, 13, 26] {
            let mut rng = SplitMix64::new(n as u64);
            let gp = pool.random_placement(n, &mut rng);
            let tg = pool.elapsed_secs(EpClass::D.pairs(), &gp);
            let ts = server.elapsed_secs(EpClass::D.pairs(), n);
            assert!(tg < ts, "n={n}: gridlan {tg} vs server {ts}");
        }
    }

    #[test]
    fn random_placement_respects_core_counts() {
        let pool = GridlanPool::table1();
        let mut rng = SplitMix64::new(1);
        for n in [1u32, 5, 13, 26] {
            let p = pool.random_placement(n, &mut rng);
            assert_eq!(p.total_procs(), n);
            for (name, procs) in &p.per_client {
                let c = pool.clients.iter().find(|c| &c.name == name).unwrap();
                assert!(*procs <= c.cpu.cores);
            }
        }
    }

    #[test]
    fn turbo_makes_results_beat_naive_extrapolation() {
        // t(26) should exceed t1/26: single-core runs enjoy max turbo.
        let pool = GridlanPool::table1();
        let mut rng = SplitMix64::new(3);
        // Best-case t1 (the paper plots measured t1 which had turbo).
        let t1 = (0..20)
            .map(|_| pool.elapsed_secs(EpClass::D.pairs(), &pool.random_placement(1, &mut rng)))
            .fold(f64::INFINITY, f64::min);
        let t26 = pool.elapsed_secs(EpClass::D.pairs(), &full_placement(&pool));
        assert!(t26 > t1 / 26.0 * 1.05, "t26={t26} vs ideal {}", t1 / 26.0);
    }

    #[test]
    fn more_cores_never_slower() {
        let server = ComparisonServer::opteron();
        let mut prev = f64::INFINITY;
        for n in 1..=64 {
            let t = server.elapsed_secs(EpClass::D.pairs(), n);
            assert!(t <= prev);
            prev = t;
        }
    }

    #[test]
    fn prop_elapsed_positive_and_monotone_in_work() {
        let pool = GridlanPool::table1();
        prop::check(100, |g| {
            let n = g.u64_in(1..27) as u32;
            let mut rng = SplitMix64::new(g.u64_in(0..1000));
            let p = pool.random_placement(n, &mut rng);
            let small = pool.elapsed_secs(1 << 24, &p);
            let big = pool.elapsed_secs(1 << 26, &p);
            expect(small > 0.0 && big > small, &format!("n={n} small={small} big={big}"))
        });
    }
}
