//! Calibration: tie the simulation's time model to measured PJRT
//! throughput on this host.
//!
//! The paper's Fig. 3 ran class D on real hardware; this container has one
//! CPU, so the end-to-end example runs real EP at class S/W scale through
//! PJRT and the models extrapolate (DESIGN.md §6).  A [`Calibration`]
//! captures the measured host rate and converts (pairs → seconds) for
//! "real-compute" experiment modes.

/// Measured host EP throughput.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Calibration {
    /// Measured Mpairs/s of the PJRT EP path on this host (single core).
    pub host_mpairs: f64,
}

impl Calibration {
    pub fn new(host_mpairs: f64) -> Self {
        assert!(host_mpairs > 0.0);
        Self { host_mpairs }
    }

    /// A conservative default when no measurement is available (tests,
    /// docs builds).  Order of magnitude of interpret-lowered EP on CPU.
    pub fn fallback() -> Self {
        Self { host_mpairs: 2.0 }
    }

    /// Seconds of real compute for `pairs` pairs on this host.
    pub fn secs_for(&self, pairs: u64) -> f64 {
        pairs as f64 / (self.host_mpairs * 1e6)
    }

    /// Scale factor mapping this host's rate to a modeled node core rate:
    /// used when replaying real measurements inside the simulation so the
    /// sim's relative speeds stay faithful to the Table-1 hardware.
    pub fn scale_to(&self, node_rate_mpairs: f64) -> f64 {
        node_rate_mpairs / self.host_mpairs
    }

    /// Pick a class-S-scale pair count that runs in roughly `budget_secs`
    /// on this host (for the end-to-end example's real-compute leg).
    pub fn pairs_for_budget(&self, budget_secs: f64) -> u64 {
        ((self.host_mpairs * 1e6 * budget_secs) as u64).max(1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn secs_scale_linearly() {
        let c = Calibration::new(10.0);
        assert!((c.secs_for(10_000_000) - 1.0).abs() < 1e-9);
        assert!((c.secs_for(20_000_000) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn scale_factors() {
        let c = Calibration::new(5.0);
        assert!((c.scale_to(15.0) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn budget_sizing() {
        let c = Calibration::new(2.0);
        assert_eq!(c.pairs_for_budget(1.0), 2_000_000);
        assert_eq!(c.pairs_for_budget(0.0), 1024); // floor
    }

    #[test]
    #[should_panic]
    fn zero_rate_rejected() {
        Calibration::new(0.0);
    }
}
