//! Performance models: how long work takes where (Fig. 3's engine).
//!
//! * [`speedmodel`] — per-client EP throughput under the Turbo model +
//!   hypervisor efficiency; random process placement; elapsed-time
//!   prediction for a placement;
//! * [`amdahl`] — ideal speed-up curves (`t(n) = t1/n`, the paper's
//!   "continuous line") and deviation metrics;
//! * [`calibrate`] — ties the model to *measured* PJRT throughput on this
//!   host so the end-to-end example runs real compute.

pub mod amdahl;
pub mod calibrate;
pub mod speedmodel;

pub use amdahl::{ideal_curve, IdealFit};
pub use calibrate::Calibration;
pub use speedmodel::{ComparisonServer, GridlanPool, Placement};
