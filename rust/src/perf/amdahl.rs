//! Ideal speed-up reference curves (the paper's "continuous line
//! represents the ideal speed-up t(n) = t1/n", citing Amdahl).

/// Points of the ideal curve t(n) = t1/n for n = 1..=max_n.
pub fn ideal_curve(t1_secs: f64, max_n: u32) -> Vec<(u32, f64)> {
    (1..=max_n).map(|n| (n, t1_secs / n as f64)).collect()
}

/// Amdahl's law proper: speedup with serial fraction `s` on n cores.
pub fn amdahl_speedup(serial_fraction: f64, n: u32) -> f64 {
    assert!((0.0..=1.0).contains(&serial_fraction));
    1.0 / (serial_fraction + (1.0 - serial_fraction) / n as f64)
}

/// Fit t1 from measured (n, t) points assuming t = t1/n (least squares on
/// t*n), and report mean relative deviation from the fitted ideal — the
/// quantity Fig. 3's discussion is about (Turbo pushes it positive).
#[derive(Debug, Clone, Copy)]
pub struct IdealFit {
    pub t1: f64,
    /// Mean of (t_measured - t_ideal)/t_ideal over the points.
    pub mean_rel_deviation: f64,
}

pub fn fit_ideal(points: &[(u32, f64)]) -> IdealFit {
    assert!(!points.is_empty());
    let t1 = points.iter().map(|&(n, t)| t * n as f64).sum::<f64>() / points.len() as f64;
    let mean_rel_deviation = points
        .iter()
        .map(|&(n, t)| (t - t1 / n as f64) / (t1 / n as f64))
        .sum::<f64>()
        / points.len() as f64;
    IdealFit { t1, mean_rel_deviation }
}

/// Deviation of measured points against an *externally chosen* t1 (the
/// paper uses the measured single-core time).
pub fn deviation_from_t1(t1: f64, points: &[(u32, f64)]) -> Vec<(u32, f64)> {
    points
        .iter()
        .map(|&(n, t)| (n, (t - t1 / n as f64) / (t1 / n as f64)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ideal_curve_shape() {
        let c = ideal_curve(100.0, 4);
        assert_eq!(c, vec![(1, 100.0), (2, 50.0), (3, 100.0 / 3.0), (4, 25.0)]);
    }

    #[test]
    fn amdahl_limits() {
        assert!((amdahl_speedup(0.0, 16) - 16.0).abs() < 1e-12);
        assert!(amdahl_speedup(0.5, 1_000) < 2.0001);
    }

    #[test]
    fn fit_recovers_exact_ideal() {
        let pts: Vec<(u32, f64)> = (1..=10).map(|n| (n, 500.0 / n as f64)).collect();
        let fit = fit_ideal(&pts);
        assert!((fit.t1 - 500.0).abs() < 1e-9);
        assert!(fit.mean_rel_deviation.abs() < 1e-12);
    }

    #[test]
    fn turbo_like_points_deviate_positively() {
        // Single core fast (turbo), full load slower than ideal.
        let pts = vec![(1u32, 100.0), (8u32, 16.0)]; // ideal would be 12.5
        let dev = deviation_from_t1(100.0, &pts);
        assert!(dev[0].1.abs() < 1e-12);
        assert!(dev[1].1 > 0.2);
    }
}
