//! Tunnel cost model: what a packet pays for riding the VPN.
//!
//! OpenVPN in the paper's configuration is a user-space process: each
//! packet crosses the tun device, gets HMAC'd + encrypted, and is re-sent
//! over UDP.  Per direction that is
//!
//!   tun traversal + user/kernel context switches  (fixed µs)
//! + cipher + HMAC                                 (µs per KB)
//! + bigger on-wire frame                          (handled by netsim via
//!                                                  the VPN_HEADER bytes)
//!
//! Defaults are calibrated so Table 2 reproduces: the paper's node pings
//! sit ~700–900 µs RTT above the host pings, split between VPN and the
//! virtio layer (see `vm::hypervisor`).

use crate::netsim::packet::{Layer, Packet};
use crate::netsim::topology::{DeviceId, Network};
use crate::util::rng::SplitMix64;

/// Per-direction tunnel processing costs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunnelCost {
    /// Fixed per-packet cost of encapsulation (tun + context switch), µs.
    pub encap_us: f64,
    /// Fixed per-packet cost of decapsulation, µs.
    pub decap_us: f64,
    /// Cipher+HMAC throughput cost, µs per KB of payload.
    pub crypto_us_per_kb: f64,
}

impl Default for TunnelCost {
    fn default() -> Self {
        // Calibrated to the paper's measured overhead (see DESIGN.md §5):
        // ~175 µs fixed per direction -> ~350 µs RTT fixed.
        Self { encap_us: 90.0, decap_us: 85.0, crypto_us_per_kb: 6.0 }
    }
}

impl TunnelCost {
    /// Processing delay for one direction, µs.
    pub fn one_way_us(&self, payload_bytes: u32) -> f64 {
        self.encap_us + self.decap_us + self.crypto_us_per_kb * payload_bytes as f64 / 1024.0
    }
}

/// One end of an established tunnel (client side).
#[derive(Debug, Clone)]
pub struct TunnelEndpoint {
    /// The client host device carrying this tunnel.
    pub host: DeviceId,
    /// The virtual subnet address of this endpoint (for display only).
    pub vpn_addr: String,
    pub cost: TunnelCost,
    pub established: bool,
}

impl TunnelEndpoint {
    pub fn new(host: DeviceId, vpn_addr: &str, cost: TunnelCost) -> Self {
        Self { host, vpn_addr: vpn_addr.to_string(), cost, established: true }
    }

    /// One-way delay (µs) for `packet` from this client host to the server
    /// through the tunnel: physical path of the *encapsulated* frame plus
    /// tunnel processing.  `None` if disconnected.
    pub fn one_way_to_server_us(
        &self,
        net: &Network,
        server: DeviceId,
        packet: &Packet,
        rng: &mut SplitMix64,
    ) -> Option<f64> {
        if !self.established {
            return None;
        }
        let encapped = packet.clone().push_layer(Layer::Vpn);
        let wire = net.sample_one_way(self.host, server, encapped.wire_bytes(), rng)? as f64 / 1e3;
        Some(wire + self.cost.one_way_us(packet.wire_bytes()))
    }

    /// Same cost from server to this client (symmetric model).
    pub fn one_way_from_server_us(
        &self,
        net: &Network,
        server: DeviceId,
        packet: &Packet,
        rng: &mut SplitMix64,
    ) -> Option<f64> {
        self.one_way_to_server_us(net, server, packet, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::topology::LinkProfile;

    fn net() -> (Network, DeviceId, DeviceId) {
        let mut n = Network::new();
        n.jitter_sigma_us = 0.0;
        let srv = n.add_host("server", 50.0);
        let sw = n.add_switch("sw", 20.0);
        let host = n.add_host("host", 60.0);
        n.link(srv, sw, LinkProfile::gigabit());
        n.link(sw, host, LinkProfile::gigabit());
        (n, srv, host)
    }

    #[test]
    fn tunnel_adds_processing_and_header_cost() {
        let (n, srv, host) = net();
        let mut rng = SplitMix64::new(1);
        let p = Packet::icmp_echo();
        let raw = n.one_way_delay_us(host, srv, p.wire_bytes()).unwrap();
        let ep = TunnelEndpoint::new(host, "10.8.0.2", TunnelCost::default());
        let tun = ep.one_way_to_server_us(&n, srv, &p, &mut rng).unwrap();
        let floor = TunnelCost::default().one_way_us(p.wire_bytes());
        assert!(tun > raw + floor * 0.9, "tun={tun} raw={raw}");
    }

    #[test]
    fn disconnected_tunnel_drops() {
        let (n, srv, host) = net();
        let mut rng = SplitMix64::new(1);
        let mut ep = TunnelEndpoint::new(host, "10.8.0.2", TunnelCost::default());
        ep.established = false;
        assert!(ep.one_way_to_server_us(&n, srv, &Packet::icmp_echo(), &mut rng).is_none());
    }

    #[test]
    fn crypto_cost_scales_with_size() {
        let c = TunnelCost::default();
        assert!(c.one_way_us(10_240) > c.one_way_us(102) + 50.0);
    }
}
