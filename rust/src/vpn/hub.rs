//! Hub routing (paper §2.1): "the network traffic is all routed via the
//! Gridlan server.  When two nodes exchange data, the latter always passes
//! through the Gridlan server."
//!
//! The hub owns the PKI and the set of established tunnels, keyed by client
//! name; it answers delay queries for client→server and client→client
//! (two-leg) traffic.  The MPI layer and the nfs/dhcp protocols ride on it.

use super::pki::{ClientKey, Pki};
use super::tunnel::{TunnelCost, TunnelEndpoint};
use crate::netsim::packet::Packet;
use crate::netsim::topology::{DeviceId, Network};
use crate::util::rng::SplitMix64;
use std::collections::BTreeMap;

/// Server-side forwarding cost between two tunnels (routing table lookup +
/// re-encrypt), µs.
pub const HUB_FORWARD_US: f64 = 25.0;

/// The VPN server with its connected clients.
pub struct VpnHub {
    pub server: DeviceId,
    pki: Pki,
    tunnels: BTreeMap<String, TunnelEndpoint>,
    /// Stable per-client address assignment (clients that reconnect get
    /// their old address back, like DHCP lease affinity).
    addrs: BTreeMap<String, String>,
    next_addr: u32,
}

impl VpnHub {
    pub fn new(server: DeviceId, pki_seed: u64) -> Self {
        Self {
            server,
            pki: Pki::new(pki_seed),
            tunnels: BTreeMap::new(),
            addrs: BTreeMap::new(),
            next_addr: 2,
        }
    }

    /// Administrator: provision a key for a client.
    pub fn provision(&mut self, client: &str) -> ClientKey {
        self.pki.issue(client)
    }

    /// Client connects at OS start-up. Fails if the key doesn't verify.
    pub fn connect(
        &mut self,
        client: &str,
        key: &ClientKey,
        host: DeviceId,
        cost: TunnelCost,
    ) -> Result<String, String> {
        if key.client != client {
            return Err(format!("key issued to '{}', not '{client}'", key.client));
        }
        if !self.pki.verify(key) {
            return Err(format!("key for '{client}' rejected by PKI"));
        }
        let addr = match self.addrs.get(client) {
            Some(a) => a.clone(),
            None => {
                let a = format!("10.8.{}.{}", self.next_addr / 256, self.next_addr % 256);
                self.next_addr += 1;
                self.addrs.insert(client.to_string(), a.clone());
                a
            }
        };
        self.tunnels.insert(client.to_string(), TunnelEndpoint::new(host, &addr, cost));
        Ok(addr)
    }

    /// Client disconnects (shutdown, crash, cable pull).
    pub fn disconnect(&mut self, client: &str) {
        self.tunnels.remove(client);
    }

    pub fn is_connected(&self, client: &str) -> bool {
        self.tunnels.get(client).map(|t| t.established).unwrap_or(false)
    }

    pub fn connected_clients(&self) -> Vec<String> {
        let mut v: Vec<String> = self.tunnels.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn endpoint(&self, client: &str) -> Option<&TunnelEndpoint> {
        self.tunnels.get(client)
    }

    /// One-way delay µs, server → client's tunnel endpoint.
    pub fn server_to_client_us(
        &self,
        net: &Network,
        client: &str,
        packet: &Packet,
        rng: &mut SplitMix64,
    ) -> Option<f64> {
        self.tunnels.get(client)?.one_way_from_server_us(net, self.server, packet, rng)
    }

    /// One-way delay µs, client → client: ALWAYS two tunnel legs via the
    /// hub (the paper's defining routing property).
    pub fn client_to_client_us(
        &self,
        net: &Network,
        from: &str,
        to: &str,
        packet: &Packet,
        rng: &mut SplitMix64,
    ) -> Option<f64> {
        let leg1 = self.tunnels.get(from)?.one_way_to_server_us(net, self.server, packet, rng)?;
        let leg2 = self.tunnels.get(to)?.one_way_from_server_us(net, self.server, packet, rng)?;
        Some(leg1 + HUB_FORWARD_US + leg2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::topology::LinkProfile;

    fn lan3() -> (Network, DeviceId, DeviceId, DeviceId) {
        let mut n = Network::new();
        n.jitter_sigma_us = 0.0;
        let srv = n.add_host("server", 50.0);
        let sw = n.add_switch("sw", 20.0);
        let h1 = n.add_host("h1", 60.0);
        let h2 = n.add_host("h2", 60.0);
        let g = LinkProfile::gigabit();
        n.link(srv, sw, g);
        n.link(sw, h1, g);
        n.link(sw, h2, g);
        (n, srv, h1, h2)
    }

    #[test]
    fn connect_requires_valid_key() {
        let (_, srv, h1, _) = lan3();
        let mut hub = VpnHub::new(srv, 9);
        let key = hub.provision("n01");
        assert!(hub.connect("n01", &key, h1, TunnelCost::default()).is_ok());
        assert!(hub.is_connected("n01"));
    }

    #[test]
    fn wrong_name_or_forged_key_rejected() {
        let (_, srv, h1, _) = lan3();
        let mut hub = VpnHub::new(srv, 9);
        let key = hub.provision("n01");
        assert!(hub.connect("n02", &key, h1, TunnelCost::default()).is_err());
        let mut forged = key.clone();
        forged.tag[3] ^= 1;
        assert!(hub.connect("n01", &forged, h1, TunnelCost::default()).is_err());
    }

    #[test]
    fn addresses_are_unique() {
        let (_, srv, h1, h2) = lan3();
        let mut hub = VpnHub::new(srv, 9);
        let k1 = hub.provision("n01");
        let k2 = hub.provision("n02");
        let a1 = hub.connect("n01", &k1, h1, TunnelCost::default()).unwrap();
        let a2 = hub.connect("n02", &k2, h2, TunnelCost::default()).unwrap();
        assert_ne!(a1, a2);
    }

    #[test]
    fn node_to_node_passes_through_hub() {
        let (n, srv, h1, h2) = lan3();
        let mut hub = VpnHub::new(srv, 9);
        let k1 = hub.provision("n01");
        let k2 = hub.provision("n02");
        hub.connect("n01", &k1, h1, TunnelCost::default()).unwrap();
        hub.connect("n02", &k2, h2, TunnelCost::default()).unwrap();
        let mut rng = SplitMix64::new(4);
        let p = Packet::icmp_echo();
        let c2c = hub.client_to_client_us(&n, "n01", "n02", &p, &mut rng).unwrap();
        let mut rng2 = SplitMix64::new(4);
        let s2c1 = hub.server_to_client_us(&n, "n01", &p, &mut rng2).unwrap();
        let s2c2 = hub.server_to_client_us(&n, "n02", &p, &mut rng2).unwrap();
        // Two legs + forward cost: strictly more than either single leg.
        assert!(c2c > s2c1.max(s2c2));
        assert!((c2c - (s2c1 + s2c2 + HUB_FORWARD_US)).abs() < 1.0);
    }

    #[test]
    fn reconnect_reuses_address_forever() {
        // Regression: a fault-storm's reconnect churn must not exhaust the
        // address space (next_addr used to be a u8 that overflowed).
        let (_, srv, h1, _) = lan3();
        let mut hub = VpnHub::new(srv, 9);
        let key = hub.provision("n01");
        let first = hub.connect("n01", &key, h1, TunnelCost::default()).unwrap();
        for _ in 0..1000 {
            hub.disconnect("n01");
            let again = hub.connect("n01", &key, h1, TunnelCost::default()).unwrap();
            assert_eq!(again, first);
        }
    }

    #[test]
    fn disconnect_stops_traffic() {
        let (n, srv, h1, _) = lan3();
        let mut hub = VpnHub::new(srv, 9);
        let key = hub.provision("n01");
        hub.connect("n01", &key, h1, TunnelCost::default()).unwrap();
        hub.disconnect("n01");
        let mut rng = SplitMix64::new(4);
        assert!(hub
            .server_to_client_us(&n, "n01", &Packet::icmp_echo(), &mut rng)
            .is_none());
    }
}
