//! Key management for the Gridlan VPN.
//!
//! Paper §2.1: "To add a new client to the Gridlan VPN, a private key must
//! be created by the server administrator and copied to the new client."
//!
//! We model the trust relation with HMAC-SHA256: the server holds a CA
//! secret; a client key is `HMAC(ca_secret, client_name || serial)`.
//! Verification recomputes the tag — no client can mint a key without the
//! CA secret, and revocation is by serial.

use crate::util::sha256::hmac_sha256;
use std::collections::{BTreeMap, BTreeSet};

/// A key issued to one client.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClientKey {
    pub client: String,
    pub serial: u64,
    pub tag: [u8; 32],
}

/// The server-side certificate authority.
#[derive(Debug)]
pub struct Pki {
    ca_secret: [u8; 32],
    next_serial: u64,
    // Ordered maps: PKI state is sim-reachable (fault storms reconnect
    // through it), so iteration order must not depend on hasher state.
    issued: BTreeMap<String, u64>,
    revoked: BTreeSet<u64>,
}

impl Pki {
    /// Create a CA from a seed (deterministic for tests; any entropy works).
    pub fn new(seed: u64) -> Self {
        let mut secret = [0u8; 32];
        let mut s = seed;
        for chunk in secret.chunks_mut(8) {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            chunk.copy_from_slice(&s.to_le_bytes());
        }
        Self {
            ca_secret: secret,
            next_serial: 1,
            issued: BTreeMap::new(),
            revoked: BTreeSet::new(),
        }
    }

    fn tag_for(&self, client: &str, serial: u64) -> [u8; 32] {
        hmac_sha256(&self.ca_secret, &[client.as_bytes(), &serial.to_le_bytes()])
    }

    /// Administrator operation: issue (or re-issue) a key for a client.
    pub fn issue(&mut self, client: &str) -> ClientKey {
        let serial = self.next_serial;
        self.next_serial += 1;
        self.issued.insert(client.to_string(), serial);
        ClientKey { client: client.to_string(), serial, tag: self.tag_for(client, serial) }
    }

    /// Server-side check at tunnel setup.
    pub fn verify(&self, key: &ClientKey) -> bool {
        if self.revoked.contains(&key.serial) {
            return false;
        }
        // Latest-issued key per client wins (re-issue invalidates old).
        if self.issued.get(&key.client) != Some(&key.serial) {
            return false;
        }
        // Constant-time-ish comparison (simulation: plain eq is fine, but
        // keep the semantic).
        self.tag_for(&key.client, key.serial) == key.tag
    }

    /// Revoke by serial (e.g. a stolen laptop).
    pub fn revoke(&mut self, serial: u64) {
        self.revoked.insert(serial);
    }

    pub fn issued_count(&self) -> usize {
        self.issued.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn issued_key_verifies() {
        let mut pki = Pki::new(42);
        let key = pki.issue("n01");
        assert!(pki.verify(&key));
    }

    #[test]
    fn forged_key_fails() {
        let mut pki = Pki::new(42);
        let mut key = pki.issue("n01");
        key.tag[0] ^= 0xFF;
        assert!(!pki.verify(&key));
    }

    #[test]
    fn key_for_other_client_fails() {
        let mut pki = Pki::new(42);
        let key = pki.issue("n01");
        let stolen = ClientKey { client: "n02".into(), ..key };
        assert!(!pki.verify(&stolen));
    }

    #[test]
    fn revocation() {
        let mut pki = Pki::new(42);
        let key = pki.issue("n01");
        pki.revoke(key.serial);
        assert!(!pki.verify(&key));
    }

    #[test]
    fn reissue_invalidates_old_key() {
        let mut pki = Pki::new(42);
        let old = pki.issue("n01");
        let new = pki.issue("n01");
        assert!(!pki.verify(&old));
        assert!(pki.verify(&new));
    }

    #[test]
    fn different_cas_dont_cross_verify() {
        let mut a = Pki::new(1);
        let mut b = Pki::new(2);
        let key_a = a.issue("n01");
        b.issue("n01"); // same name, same serial counter
        assert!(!b.verify(&key_a));
    }
}
