//! VPN substrate (paper §2.1).
//!
//! Every Gridlan client opens a tunnel to the server at OS start-up.  Key
//! facts the paper relies on, all modeled here:
//!
//! * **authorization**: a client participates only if the administrator
//!   issued it a private key ([`pki`]);
//! * **hub routing**: *all* node↔node traffic passes through the server —
//!   two tunnel traversals ([`hub`]);
//! * **latency cost**: encapsulation + cipher work adds delay on every
//!   packet — a large share of Table 2's ~900 µs overhead ([`tunnel`]).

pub mod hub;
pub mod pki;
pub mod tunnel;

pub use hub::VpnHub;
pub use pki::{ClientKey, Pki};
pub use tunnel::{TunnelCost, TunnelEndpoint};
