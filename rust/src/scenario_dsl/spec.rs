//! Typed scenario spec: parse + validate a scenario JSON document.
//!
//! Parsing is strict: unknown keys, bad enum values, missing required
//! fields, and out-of-range node references are all errors, and every
//! error carries either a `line:col` (syntax) or a JSON path like
//! `faults[2].kind` (semantics) plus what was expected — a scenario file
//! is an experiment definition, and a silently-ignored typo would change
//! the experiment.
//!
//! All durations are spelled as `*_secs` JSON numbers (fractions allowed)
//! and converted to [`SimTime`] nanoseconds via
//! [`crate::sim::clock::from_secs_f64`].

use std::fmt;

use crate::config::SchedPolicy;
use crate::coordinator::scenario::RecoveryPolicy;
use crate::host::faults::{FaultKind, FaultPlan};
use crate::scenario_dsl::expect::Expect;
use crate::sim::clock::{from_secs_f64, SimTime, DUR_SEC};
use crate::util::json::{Json, JsonObj};

/// Hard cap on repetition counts (faults, workload batches): a typo like
/// `"count": 3e9` should fail parse, not melt the DES.
const MAX_COUNT: u64 = 1_000_000;

/// A scenario-file error: where (`line:col` or JSON path) and what.
#[derive(Debug, Clone, PartialEq)]
pub struct DslError {
    /// `line L:C` for syntax errors, a JSON path (`faults[2].kind`) for
    /// semantic ones, empty for whole-document errors.
    pub path: String,
    pub msg: String,
}

impl DslError {
    pub fn at(path: impl Into<String>, msg: impl Into<String>) -> Self {
        Self { path: path.into(), msg: msg.into() }
    }
}

impl fmt::Display for DslError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.path.is_empty() {
            write!(f, "{}", self.msg)
        } else {
            write!(f, "{}: {}", self.path, self.msg)
        }
    }
}

impl std::error::Error for DslError {}

/// Convert a byte offset into 1-based (line, column) for syntax errors.
fn line_col(src: &str, offset: usize) -> (usize, usize) {
    let (mut line, mut col) = (1usize, 1usize);
    for (i, b) in src.bytes().enumerate() {
        if i >= offset {
            break;
        }
        if b == b'\n' {
            line += 1;
            col = 1;
        } else {
            col += 1;
        }
    }
    (line, col)
}

// ------------------------------------------------------------ helpers

pub(crate) fn join(path: &str, key: &str) -> String {
    if path.is_empty() {
        key.to_string()
    } else {
        format!("{path}.{key}")
    }
}

/// Strict-schema guard: every present key must be in `allowed`.
pub(crate) fn check_keys(o: &JsonObj, path: &str, allowed: &[&str]) -> Result<(), DslError> {
    for (k, _) in o.iter() {
        if !allowed.contains(&k.as_str()) {
            return Err(DslError::at(
                join(path, k),
                format!("unknown key (expected one of: {})", allowed.join(", ")),
            ));
        }
    }
    Ok(())
}

pub(crate) fn get_num(o: &JsonObj, path: &str, key: &str) -> Result<Option<f64>, DslError> {
    match o.get(key) {
        None => Ok(None),
        Some(j) => {
            let n = j
                .as_f64()
                .ok_or_else(|| DslError::at(join(path, key), "must be a number"))?;
            if !n.is_finite() {
                return Err(DslError::at(join(path, key), "must be a finite number"));
            }
            Ok(Some(n))
        }
    }
}

pub(crate) fn get_secs(o: &JsonObj, path: &str, key: &str) -> Result<Option<SimTime>, DslError> {
    match get_num(o, path, key)? {
        None => Ok(None),
        Some(n) if n < 0.0 => Err(DslError::at(join(path, key), "must be >= 0 (seconds)")),
        Some(n) => Ok(Some(from_secs_f64(n))),
    }
}

pub(crate) fn get_count(o: &JsonObj, path: &str, key: &str) -> Result<Option<u64>, DslError> {
    match o.get(key) {
        None => Ok(None),
        Some(j) => Ok(Some(j.as_u64().ok_or_else(|| {
            DslError::at(join(path, key), "must be a non-negative integer")
        })?)),
    }
}

pub(crate) fn get_str(o: &JsonObj, path: &str, key: &str) -> Result<Option<String>, DslError> {
    match o.get(key) {
        None => Ok(None),
        Some(j) => Ok(Some(
            j.as_str()
                .ok_or_else(|| DslError::at(join(path, key), "must be a string"))?
                .to_string(),
        )),
    }
}

pub(crate) fn get_bool(o: &JsonObj, path: &str, key: &str) -> Result<Option<bool>, DslError> {
    match o.get(key) {
        None => Ok(None),
        Some(j) => Ok(Some(j.as_bool().ok_or_else(|| {
            DslError::at(join(path, key), "must be true or false")
        })?)),
    }
}

fn secs_value(j: &Json, path: &str) -> Result<SimTime, DslError> {
    let n = j.as_f64().ok_or_else(|| DslError::at(path, "must be a number (seconds)"))?;
    if !n.is_finite() || n < 0.0 {
        return Err(DslError::at(path, "must be a finite number >= 0 (seconds)"));
    }
    Ok(from_secs_f64(n))
}

// -------------------------------------------------------------- nodes

/// The grid under test.
#[derive(Debug, Clone, PartialEq)]
pub enum NodesSpec {
    /// The paper's Table-1 testbed (4 clients, 26 cores).
    Table1 { prebooted: bool },
    /// A synthetic homogeneous deployment: `count` clients of `cores`
    /// cores each, Linux, default hypervisor.  The last `slow_nodes`
    /// clients run at `1/slow_factor` EP throughput (heterogeneous
    /// straggler experiments — the analogue of Table 1's n04).
    Custom {
        count: u32,
        cores: u32,
        prebooted: bool,
        switch_hops: u32,
        stack_us: f64,
        link_mbps: f64,
        slow_nodes: u32,
        slow_factor: f64,
    },
}

impl NodesSpec {
    /// Node names in deterministic order (the fault-target namespace).
    pub fn names(&self) -> Vec<String> {
        match self {
            NodesSpec::Table1 { .. } => {
                vec!["n01".into(), "n02".into(), "n03".into(), "n04".into()]
            }
            NodesSpec::Custom { count, .. } => {
                (0..*count).map(|i| format!("n{:02}", i + 1)).collect()
            }
        }
    }

    pub fn prebooted(&self) -> bool {
        match self {
            NodesSpec::Table1 { prebooted } | NodesSpec::Custom { prebooted, .. } => *prebooted,
        }
    }

    /// Widest single node (for `ppn` range checks at parse time).
    pub fn max_cores(&self) -> u32 {
        match self {
            NodesSpec::Table1 { .. } => 12,
            NodesSpec::Custom { cores, .. } => *cores,
        }
    }

    pub fn node_count(&self) -> u32 {
        match self {
            NodesSpec::Table1 { .. } => 4,
            NodesSpec::Custom { count, .. } => *count,
        }
    }
}

fn parse_nodes(j: Option<&Json>) -> Result<NodesSpec, DslError> {
    let Some(j) = j else {
        return Ok(NodesSpec::Table1 { prebooted: false });
    };
    let o = j.as_obj().ok_or_else(|| DslError::at("nodes", "must be an object"))?;
    check_keys(
        o,
        "nodes",
        &[
            "preset",
            "count",
            "cores",
            "prebooted",
            "switch_hops",
            "stack_us",
            "link_mbps",
            "slow_nodes",
            "slow_factor",
        ],
    )?;
    let prebooted = get_bool(o, "nodes", "prebooted")?.unwrap_or(false);
    match get_str(o, "nodes", "preset")?.as_deref() {
        Some("table1") => {
            for k in
                ["count", "cores", "switch_hops", "stack_us", "link_mbps", "slow_nodes", "slow_factor"]
            {
                if o.contains(k) {
                    return Err(DslError::at(
                        join("nodes", k),
                        "not valid together with preset \"table1\"",
                    ));
                }
            }
            Ok(NodesSpec::Table1 { prebooted })
        }
        Some(other) => Err(DslError::at(
            "nodes.preset",
            format!("unknown preset '{other}' (expected table1)"),
        )),
        None => {
            let count = get_count(o, "nodes", "count")?
                .ok_or_else(|| DslError::at("nodes.count", "required without a preset"))?;
            if count == 0 || count > 100_000 {
                return Err(DslError::at("nodes.count", "must be in 1..=100000"));
            }
            let cores = get_count(o, "nodes", "cores")?
                .ok_or_else(|| DslError::at("nodes.cores", "required without a preset"))?;
            if cores == 0 || cores > 1024 {
                return Err(DslError::at("nodes.cores", "must be in 1..=1024"));
            }
            let switch_hops = get_count(o, "nodes", "switch_hops")?.unwrap_or(2);
            let stack_us = get_num(o, "nodes", "stack_us")?.unwrap_or(120.0);
            let link_mbps = get_num(o, "nodes", "link_mbps")?.unwrap_or(1000.0);
            let slow_nodes = get_count(o, "nodes", "slow_nodes")?.unwrap_or(0);
            if slow_nodes >= count {
                return Err(DslError::at(
                    "nodes.slow_nodes",
                    "must leave at least one full-speed node (slow_nodes < count)",
                ));
            }
            let slow_factor = get_num(o, "nodes", "slow_factor")?.unwrap_or(8.0);
            if slow_factor < 1.0 {
                return Err(DslError::at("nodes.slow_factor", "must be >= 1"));
            }
            Ok(NodesSpec::Custom {
                count: count as u32,
                cores: cores as u32,
                prebooted,
                switch_hops: switch_hops as u32,
                stack_us,
                link_mbps,
                slow_nodes: slow_nodes as u32,
                slow_factor,
            })
        }
    }
}

// ------------------------------------------------------------- faults

/// When a fault block fires.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultTiming {
    /// One shot at an absolute time.
    At(SimTime),
    /// `count` repetitions at `start`, `start + every`, ...
    Every { start: SimTime, every: SimTime, count: u32 },
    /// `count` events placed by the scenario seed inside a time window
    /// (QSL-style `k = seed + idx` placement: each event draws its time
    /// and target from its own derived generator).
    Seeded { count: u32, window: (SimTime, SimTime) },
}

/// One declarative fault block.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    pub kind: FaultKind,
    /// Resolved target node names (never empty; defaults to all nodes).
    pub targets: Vec<String>,
    pub timing: FaultTiming,
    pub outage: SimTime,
}

fn parse_fault(j: &Json, path: &str, names: &[String]) -> Result<FaultSpec, DslError> {
    let o = j.as_obj().ok_or_else(|| DslError::at(path, "must be an object"))?;
    check_keys(
        o,
        path,
        &[
            "kind",
            "target",
            "targets",
            "at_secs",
            "every_secs",
            "start_secs",
            "count",
            "seeded",
            "window_secs",
            "outage_secs",
        ],
    )?;
    let kind = match get_str(o, path, "kind")?.as_deref() {
        Some("vm_crash") => FaultKind::VmCrash,
        Some("power_off") => FaultKind::ClientPowerOff,
        Some("net_drop") => FaultKind::NetworkDrop,
        Some(other) => {
            return Err(DslError::at(
                join(path, "kind"),
                format!("unknown fault kind '{other}' (expected vm_crash, power_off, or net_drop)"),
            ))
        }
        None => {
            return Err(DslError::at(
                join(path, "kind"),
                "required (vm_crash, power_off, or net_drop)",
            ))
        }
    };
    let outage = get_secs(o, path, "outage_secs")?.unwrap_or(60 * DUR_SEC);

    // Targets: a single name, an explicit list, "all", or (default) all.
    let targets: Vec<String> = match (o.get("target"), o.get("targets")) {
        (Some(_), Some(_)) => {
            return Err(DslError::at(path, "give either target or targets, not both"))
        }
        (Some(t), None) => {
            let name = t
                .as_str()
                .ok_or_else(|| DslError::at(join(path, "target"), "must be a node name string"))?;
            vec![name.to_string()]
        }
        (None, Some(t)) => match t {
            Json::Str(s) if s == "all" => names.to_vec(),
            Json::Arr(a) => {
                let mut v = Vec::new();
                for (i, e) in a.iter().enumerate() {
                    let name = e.as_str().ok_or_else(|| {
                        DslError::at(
                            format!("{}[{i}]", join(path, "targets")),
                            "must be a node name string",
                        )
                    })?;
                    v.push(name.to_string());
                }
                if v.is_empty() {
                    return Err(DslError::at(join(path, "targets"), "must not be empty"));
                }
                v
            }
            _ => {
                return Err(DslError::at(
                    join(path, "targets"),
                    "must be \"all\" or an array of node names",
                ))
            }
        },
        (None, None) => names.to_vec(),
    };
    for t in &targets {
        if !names.iter().any(|n| n == t) {
            return Err(DslError::at(
                path,
                format!("unknown node '{t}' (this grid has: {})", names.join(", ")),
            ));
        }
    }

    // Timing: exactly one of at_secs | every_secs | seeded.
    let at = get_secs(o, path, "at_secs")?;
    let every = get_secs(o, path, "every_secs")?;
    let seeded = get_count(o, path, "seeded")?;
    let modes = [at.is_some(), every.is_some(), seeded.is_some()]
        .iter()
        .filter(|b| **b)
        .count();
    if modes != 1 {
        return Err(DslError::at(
            path,
            "exactly one of at_secs, every_secs, or seeded must be set",
        ));
    }
    let timing = if let Some(at) = at {
        for k in ["start_secs", "count", "window_secs"] {
            if o.contains(k) {
                return Err(DslError::at(
                    join(path, k),
                    "only valid with every_secs or seeded timing",
                ));
            }
        }
        FaultTiming::At(at)
    } else if let Some(every) = every {
        if o.contains("window_secs") {
            return Err(DslError::at(
                join(path, "window_secs"),
                "only valid with seeded timing",
            ));
        }
        if every == 0 {
            return Err(DslError::at(join(path, "every_secs"), "must be > 0"));
        }
        let count = get_count(o, path, "count")?.ok_or_else(|| {
            DslError::at(join(path, "count"), "required with every_secs (how many repetitions)")
        })?;
        if count == 0 || count > MAX_COUNT {
            return Err(DslError::at(join(path, "count"), "must be in 1..=1000000"));
        }
        let start = get_secs(o, path, "start_secs")?.unwrap_or(every);
        FaultTiming::Every { start, every, count: count as u32 }
    } else {
        let count = seeded.unwrap_or(0);
        if count == 0 || count > MAX_COUNT {
            return Err(DslError::at(join(path, "seeded"), "must be in 1..=1000000"));
        }
        for k in ["start_secs", "count"] {
            if o.contains(k) {
                return Err(DslError::at(join(path, k), "not valid with seeded timing"));
            }
        }
        let w = o.get("window_secs").ok_or_else(|| {
            DslError::at(
                join(path, "window_secs"),
                "required with seeded timing: [lo_secs, hi_secs]",
            )
        })?;
        let arr = w
            .as_arr()
            .filter(|a| a.len() == 2)
            .ok_or_else(|| DslError::at(join(path, "window_secs"), "must be [lo_secs, hi_secs]"))?;
        let lo = secs_value(&arr[0], &format!("{}[0]", join(path, "window_secs")))?;
        let hi = secs_value(&arr[1], &format!("{}[1]", join(path, "window_secs")))?;
        if lo > hi {
            return Err(DslError::at(join(path, "window_secs"), "window lo must be <= hi"));
        }
        FaultTiming::Seeded { count: count as u32, window: (lo, hi) }
    };
    Ok(FaultSpec { kind, targets, timing, outage })
}

// -------------------------------------------------------------- storm

/// A random MTBF-driven fault storm (lowered to [`FaultPlan`]).
#[derive(Debug, Clone, PartialEq)]
pub struct StormSpec {
    pub power_off_mtbf: SimTime,
    pub net_drop_mtbf: SimTime,
    pub vm_crash_mtbf: SimTime,
    pub mean_outage: SimTime,
    pub scale: f64,
}

impl StormSpec {
    pub fn to_plan(&self) -> FaultPlan {
        FaultPlan {
            mtbf_power_off: self.power_off_mtbf,
            mtbf_net_drop: self.net_drop_mtbf,
            mtbf_vm_crash: self.vm_crash_mtbf,
            mean_outage: self.mean_outage,
        }
        .scaled(self.scale)
    }
}

fn parse_storm(j: Option<&Json>) -> Result<Option<StormSpec>, DslError> {
    let Some(j) = j else { return Ok(None) };
    let o = j.as_obj().ok_or_else(|| DslError::at("storm", "must be an object"))?;
    check_keys(
        o,
        "storm",
        &[
            "preset",
            "scale",
            "power_off_mtbf_secs",
            "net_drop_mtbf_secs",
            "vm_crash_mtbf_secs",
            "mean_outage_secs",
        ],
    )?;
    let (mut po, mut nd, mut vc, mut out) = match get_str(o, "storm", "preset")?.as_deref() {
        Some("lab") => {
            let p = FaultPlan::lab_default();
            (p.mtbf_power_off, p.mtbf_net_drop, p.mtbf_vm_crash, p.mean_outage)
        }
        Some(other) => {
            return Err(DslError::at(
                "storm.preset",
                format!("unknown preset '{other}' (expected lab)"),
            ))
        }
        None => (0, 0, 0, 600 * DUR_SEC),
    };
    if let Some(v) = get_secs(o, "storm", "power_off_mtbf_secs")? {
        po = v;
    }
    if let Some(v) = get_secs(o, "storm", "net_drop_mtbf_secs")? {
        nd = v;
    }
    if let Some(v) = get_secs(o, "storm", "vm_crash_mtbf_secs")? {
        vc = v;
    }
    if let Some(v) = get_secs(o, "storm", "mean_outage_secs")? {
        out = v;
    }
    let scale = get_num(o, "storm", "scale")?.unwrap_or(1.0);
    if scale <= 0.0 {
        return Err(DslError::at("storm.scale", "must be > 0"));
    }
    if po == 0 && nd == 0 && vc == 0 {
        return Err(DslError::at(
            "storm",
            "set preset \"lab\" or at least one *_mtbf_secs rate",
        ));
    }
    Ok(Some(StormSpec {
        power_off_mtbf: po,
        net_drop_mtbf: nd,
        vm_crash_mtbf: vc,
        mean_outage: out,
        scale,
    }))
}

// ----------------------------------------------------------- workloads

/// One workload block.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// A batch of synthetic jobs: `count` submissions at `start`,
    /// `start + every`, ...
    Trace {
        count: u32,
        start: SimTime,
        every: SimTime,
        nodes: u32,
        ppn: u32,
        compute: SimTime,
        walltime: SimTime,
        owner: String,
    },
    /// An `ep:<offset>:<count>` flood: `slices` single-core real-compute
    /// jobs over consecutive pair ranges.
    Ep {
        slices: u32,
        pair_offset: u64,
        pairs_per_slice: u64,
        start: SimTime,
        every: SimTime,
        walltime: SimTime,
    },
    /// Open-loop multi-user arrivals via [`crate::workload::trace::TraceGenerator`],
    /// seeded from the scenario seed.
    Arrivals {
        users: u32,
        /// Submission horizon (defaults to the scenario horizon).
        horizon: Option<SimTime>,
        mean_gap: SimTime,
        wide_fraction: f64,
    },
}

fn parse_workload(j: &Json, path: &str, nodes: &NodesSpec) -> Result<WorkloadSpec, DslError> {
    let o = j.as_obj().ok_or_else(|| DslError::at(path, "must be an object"))?;
    let kind = get_str(o, path, "kind")?
        .ok_or_else(|| DslError::at(join(path, "kind"), "required (trace, ep, or arrivals)"))?;
    match kind.as_str() {
        "trace" => {
            check_keys(
                o,
                path,
                &[
                    "kind",
                    "count",
                    "start_secs",
                    "every_secs",
                    "nodes",
                    "ppn",
                    "compute_secs",
                    "walltime_secs",
                    "owner",
                ],
            )?;
            let count = get_count(o, path, "count")?.unwrap_or(1);
            if count == 0 || count > MAX_COUNT {
                return Err(DslError::at(join(path, "count"), "must be in 1..=1000000"));
            }
            let start = get_secs(o, path, "start_secs")?.unwrap_or(0);
            let every = get_secs(o, path, "every_secs")?.unwrap_or(0);
            let req_nodes = get_count(o, path, "nodes")?.unwrap_or(1) as u32;
            let ppn = get_count(o, path, "ppn")?.unwrap_or(1) as u32;
            if req_nodes == 0 || req_nodes > nodes.node_count() {
                return Err(DslError::at(
                    join(path, "nodes"),
                    format!("must be in 1..={} (this grid's node count)", nodes.node_count()),
                ));
            }
            if ppn == 0 || ppn > nodes.max_cores() {
                return Err(DslError::at(
                    join(path, "ppn"),
                    format!("must be in 1..={} (this grid's widest node)", nodes.max_cores()),
                ));
            }
            let compute = get_secs(o, path, "compute_secs")?
                .ok_or_else(|| DslError::at(join(path, "compute_secs"), "required (seconds)"))?;
            let walltime =
                get_secs(o, path, "walltime_secs")?.unwrap_or(compute.saturating_mul(4));
            if walltime == 0 {
                return Err(DslError::at(join(path, "walltime_secs"), "must be > 0"));
            }
            let owner = get_str(o, path, "owner")?.unwrap_or_else(|| "user".to_string());
            Ok(WorkloadSpec::Trace {
                count: count as u32,
                start,
                every,
                nodes: req_nodes,
                ppn,
                compute,
                walltime,
                owner,
            })
        }
        "ep" => {
            check_keys(
                o,
                path,
                &[
                    "kind",
                    "slices",
                    "pair_offset",
                    "pairs_per_slice",
                    "start_secs",
                    "every_secs",
                    "walltime_secs",
                ],
            )?;
            let slices = get_count(o, path, "slices")?
                .ok_or_else(|| DslError::at(join(path, "slices"), "required (how many jobs)"))?;
            if slices == 0 || slices > MAX_COUNT {
                return Err(DslError::at(join(path, "slices"), "must be in 1..=1000000"));
            }
            let pairs_per_slice = get_count(o, path, "pairs_per_slice")?.ok_or_else(|| {
                DslError::at(join(path, "pairs_per_slice"), "required (pairs per job)")
            })?;
            if pairs_per_slice == 0 {
                return Err(DslError::at(join(path, "pairs_per_slice"), "must be > 0"));
            }
            let pair_offset = get_count(o, path, "pair_offset")?.unwrap_or(0);
            let start = get_secs(o, path, "start_secs")?.unwrap_or(0);
            let every = get_secs(o, path, "every_secs")?.unwrap_or(0);
            let walltime = get_secs(o, path, "walltime_secs")?.unwrap_or(3600 * DUR_SEC);
            if walltime == 0 {
                return Err(DslError::at(join(path, "walltime_secs"), "must be > 0"));
            }
            Ok(WorkloadSpec::Ep {
                slices: slices as u32,
                pair_offset,
                pairs_per_slice,
                start,
                every,
                walltime,
            })
        }
        "arrivals" => {
            check_keys(
                o,
                path,
                &["kind", "users", "horizon_secs", "mean_gap_secs", "wide_fraction"],
            )?;
            let users = get_count(o, path, "users")?
                .ok_or_else(|| DslError::at(join(path, "users"), "required (how many users)"))?;
            if users == 0 || users > MAX_COUNT {
                return Err(DslError::at(join(path, "users"), "must be in 1..=1000000"));
            }
            let horizon = get_secs(o, path, "horizon_secs")?;
            let mean_gap = get_secs(o, path, "mean_gap_secs")?.unwrap_or(1800 * DUR_SEC);
            if mean_gap == 0 {
                return Err(DslError::at(join(path, "mean_gap_secs"), "must be > 0"));
            }
            let wide_fraction = get_num(o, path, "wide_fraction")?.unwrap_or(0.15);
            if !(0.0..=1.0).contains(&wide_fraction) {
                return Err(DslError::at(join(path, "wide_fraction"), "must be in 0..=1"));
            }
            Ok(WorkloadSpec::Arrivals { users: users as u32, horizon, mean_gap, wide_fraction })
        }
        other => Err(DslError::at(
            join(path, "kind"),
            format!("unknown workload kind '{other}' (expected trace, ep, or arrivals)"),
        )),
    }
}

// ------------------------------------------------------------ recovery

/// Parse the `recovery` block into the runner's [`RecoveryPolicy`]:
/// `salvage` (default true) banks checkpointed sub-spans across faults,
/// `checkpoint_interval_pairs` (default 0 = auto ~count/16) sets the
/// sub-span size, and `steal` (default false) splits stragglers'
/// remainders onto idle cores.
fn parse_recovery(j: Option<&Json>) -> Result<RecoveryPolicy, DslError> {
    let Some(j) = j else { return Ok(RecoveryPolicy::default()) };
    let o = j.as_obj().ok_or_else(|| DslError::at("recovery", "must be an object"))?;
    check_keys(o, "recovery", &["salvage", "checkpoint_interval_pairs", "steal"])?;
    let d = RecoveryPolicy::default();
    Ok(RecoveryPolicy {
        salvage: get_bool(o, "recovery", "salvage")?.unwrap_or(d.salvage),
        checkpoint_interval: get_count(o, "recovery", "checkpoint_interval_pairs")?
            .unwrap_or(d.checkpoint_interval),
        steal: get_bool(o, "recovery", "steal")?.unwrap_or(d.steal),
    })
}

// -------------------------------------------------------------- engine

/// Which compute backend runs EP payloads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineSpec {
    Scalar,
    Threaded(usize),
}

fn parse_engine(root: &JsonObj) -> Result<EngineSpec, DslError> {
    match get_str(root, "", "engine")?.as_deref() {
        None | Some("scalar") => Ok(EngineSpec::Scalar),
        Some("threaded") => Ok(EngineSpec::Threaded(2)),
        Some(s) if s.starts_with("threaded:") => {
            let n: usize = s["threaded:".len()..]
                .parse()
                .map_err(|_| DslError::at("engine", format!("bad thread count in '{s}'")))?;
            if n == 0 || n > 256 {
                return Err(DslError::at("engine", "thread count must be in 1..=256"));
            }
            Ok(EngineSpec::Threaded(n))
        }
        Some(other) => Err(DslError::at(
            "engine",
            format!("unknown engine '{other}' (expected scalar, threaded, or threaded:N)"),
        )),
    }
}

// ---------------------------------------------------------------- spec

/// A fully parsed + validated scenario document.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    /// Root of all randomness in the run (required in every file).
    pub seed: u64,
    pub horizon: SimTime,
    pub sched: SchedPolicy,
    pub sched_period: SimTime,
    pub engine: EngineSpec,
    pub nodes: NodesSpec,
    /// EP checkpoint/salvage/steal policy (the `recovery` block).
    pub recovery: RecoveryPolicy,
    pub faults: Vec<FaultSpec>,
    pub storm: Option<StormSpec>,
    pub workloads: Vec<WorkloadSpec>,
    pub expect: Expect,
}

impl ScenarioSpec {
    /// Parse a scenario document, reporting `line:col` on syntax errors
    /// and a JSON path on semantic ones.
    pub fn parse(src: &str) -> Result<ScenarioSpec, DslError> {
        let doc = Json::parse(src).map_err(|e| {
            let (line, col) = line_col(src, e.offset);
            DslError::at(format!("line {line}:{col}"), format!("syntax error: {}", e.msg))
        })?;
        Self::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<ScenarioSpec, DslError> {
        let root = doc
            .as_obj()
            .ok_or_else(|| DslError::at("", "scenario file must be a JSON object"))?;
        check_keys(
            root,
            "",
            &[
                "name",
                "seed",
                "horizon_secs",
                "sched",
                "sched_period_secs",
                "engine",
                "nodes",
                "recovery",
                "faults",
                "storm",
                "workloads",
                "expect",
            ],
        )?;
        let name = get_str(root, "", "name")?.unwrap_or_else(|| "scenario".to_string());
        let seed = get_count(root, "", "seed")?.ok_or_else(|| {
            DslError::at("seed", "required (integer): every scenario must pin its replay seed")
        })?;
        let horizon = get_secs(root, "", "horizon_secs")?.unwrap_or(4 * 3600 * DUR_SEC);
        if horizon == 0 {
            return Err(DslError::at("horizon_secs", "must be > 0"));
        }
        let sched = match get_str(root, "", "sched")?.as_deref() {
            None | Some("fifo") => SchedPolicy::Fifo,
            Some("backfill") => SchedPolicy::Backfill,
            Some(other) => {
                return Err(DslError::at(
                    "sched",
                    format!("unknown policy '{other}' (expected fifo or backfill)"),
                ))
            }
        };
        let sched_period = get_secs(root, "", "sched_period_secs")?.unwrap_or(10 * DUR_SEC);
        if sched_period == 0 {
            return Err(DslError::at("sched_period_secs", "must be > 0"));
        }
        let engine = parse_engine(root)?;
        let nodes = parse_nodes(root.get("nodes"))?;
        let recovery = parse_recovery(root.get("recovery"))?;
        let names = nodes.names();

        let mut faults = Vec::new();
        if let Some(j) = root.get("faults") {
            let arr = j
                .as_arr()
                .ok_or_else(|| DslError::at("faults", "must be an array of fault blocks"))?;
            for (i, f) in arr.iter().enumerate() {
                faults.push(parse_fault(f, &format!("faults[{i}]"), &names)?);
            }
        }
        let storm = parse_storm(root.get("storm"))?;

        let mut workloads = Vec::new();
        if let Some(j) = root.get("workloads") {
            let arr = j
                .as_arr()
                .ok_or_else(|| DslError::at("workloads", "must be an array of workload blocks"))?;
            for (i, w) in arr.iter().enumerate() {
                workloads.push(parse_workload(w, &format!("workloads[{i}]"), &nodes)?);
            }
        }

        let expect = match root.get("expect") {
            Some(j) => Expect::from_json(j, "expect")?,
            None => Expect::default(),
        };

        Ok(ScenarioSpec {
            name,
            seed,
            horizon,
            sched,
            sched_period,
            engine,
            nodes,
            recovery,
            faults,
            storm,
            workloads,
            expect,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal(extra: &str) -> String {
        format!(r#"{{"seed": 7{}{extra}}}"#, if extra.is_empty() { "" } else { "," })
    }

    fn parse_err(src: &str) -> DslError {
        ScenarioSpec::parse(src).expect_err("must fail to parse")
    }

    #[test]
    fn minimal_spec_defaults() {
        let s = ScenarioSpec::parse(&minimal("")).unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(s.name, "scenario");
        assert_eq!(s.horizon, 4 * 3600 * DUR_SEC);
        assert_eq!(s.sched, SchedPolicy::Fifo);
        assert_eq!(s.sched_period, 10 * DUR_SEC);
        assert_eq!(s.engine, EngineSpec::Scalar);
        assert_eq!(s.nodes, NodesSpec::Table1 { prebooted: false });
        assert_eq!(s.recovery, RecoveryPolicy::default());
        assert!(s.recovery.salvage && !s.recovery.steal);
        assert!(s.faults.is_empty() && s.workloads.is_empty() && s.storm.is_none());
        assert!(s.expect.is_empty());
    }

    #[test]
    fn recovery_block_parses_strictly() {
        let s = ScenarioSpec::parse(&minimal(
            r#""recovery": {"salvage": false, "checkpoint_interval_pairs": 8192, "steal": true}"#,
        ))
        .unwrap();
        assert!(!s.recovery.salvage);
        assert_eq!(s.recovery.checkpoint_interval, 8192);
        assert!(s.recovery.steal);
        let e = parse_err(&minimal(r#""recovery": {"salvge": false}"#));
        assert_eq!(e.path, "recovery.salvge");
        let e = parse_err(&minimal(r#""recovery": {"steal": "yes"}"#));
        assert_eq!(e.path, "recovery.steal");
    }

    #[test]
    fn slow_nodes_parse_and_validate() {
        let s = ScenarioSpec::parse(&minimal(
            r#""nodes": {"count": 3, "cores": 2, "slow_nodes": 1, "slow_factor": 16}"#,
        ))
        .unwrap();
        match s.nodes {
            NodesSpec::Custom { slow_nodes, slow_factor, .. } => {
                assert_eq!(slow_nodes, 1);
                assert_eq!(slow_factor, 16.0);
            }
            other => panic!("wrong nodes: {other:?}"),
        }
        let e = parse_err(&minimal(r#""nodes": {"count": 2, "cores": 1, "slow_nodes": 2}"#));
        assert_eq!(e.path, "nodes.slow_nodes");
        let e = parse_err(&minimal(
            r#""nodes": {"count": 2, "cores": 1, "slow_nodes": 1, "slow_factor": 0.5}"#,
        ));
        assert_eq!(e.path, "nodes.slow_factor");
        let e = parse_err(&minimal(r#""nodes": {"preset": "table1", "slow_nodes": 1}"#));
        assert_eq!(e.path, "nodes.slow_nodes");
    }

    #[test]
    fn missing_seed_is_an_error() {
        let e = parse_err(r#"{"name": "x"}"#);
        assert_eq!(e.path, "seed");
        assert!(e.msg.contains("required"), "{e}");
    }

    #[test]
    fn unknown_top_level_key_is_an_error() {
        let e = parse_err(r#"{"seed": 1, "nods": {}}"#);
        assert_eq!(e.path, "nods");
        assert!(e.msg.contains("unknown key"), "{e}");
        assert!(e.msg.contains("nodes"), "suggestion list must name valid keys: {e}");
    }

    #[test]
    fn unknown_nested_key_reports_json_path() {
        let e = parse_err(&minimal(r#""faults": [{"kind": "vm_crash", "at_secs": 1, "outage": 5}]"#));
        assert_eq!(e.path, "faults[0].outage");
    }

    #[test]
    fn bad_fault_kind_lists_valid_kinds() {
        let e = parse_err(&minimal(r#""faults": [{"kind": "meteor", "at_secs": 1}]"#));
        assert_eq!(e.path, "faults[0].kind");
        assert!(e.msg.contains("vm_crash") && e.msg.contains("power_off"), "{e}");
    }

    #[test]
    fn out_of_range_node_reference_is_an_error() {
        let e = parse_err(&minimal(r#""faults": [{"kind": "vm_crash", "at_secs": 1, "target": "n99"}]"#));
        assert_eq!(e.path, "faults[0]");
        assert!(e.msg.contains("n99") && e.msg.contains("n01"), "{e}");
    }

    #[test]
    fn fault_timing_must_be_exactly_one_mode() {
        let e = parse_err(&minimal(r#""faults": [{"kind": "vm_crash", "at_secs": 1, "every_secs": 2, "count": 3}]"#));
        assert!(e.msg.contains("exactly one"), "{e}");
        let e = parse_err(&minimal(r#""faults": [{"kind": "vm_crash"}]"#));
        assert!(e.msg.contains("exactly one"), "{e}");
    }

    #[test]
    fn every_requires_count_and_seeded_requires_window() {
        let e = parse_err(&minimal(r#""faults": [{"kind": "net_drop", "every_secs": 900}]"#));
        assert_eq!(e.path, "faults[0].count");
        let e = parse_err(&minimal(r#""faults": [{"kind": "net_drop", "seeded": 3}]"#));
        assert_eq!(e.path, "faults[0].window_secs");
        let e = parse_err(&minimal(
            r#""faults": [{"kind": "net_drop", "seeded": 3, "window_secs": [100, 10]}]"#,
        ));
        assert!(e.msg.contains("lo must be <= hi"), "{e}");
    }

    #[test]
    fn syntax_error_reports_line_and_column() {
        let e = parse_err("{\n  \"seed\": 1,\n  \"name\": ?\n}");
        assert!(e.path.starts_with("line 3:"), "{e}");
        assert!(e.msg.contains("syntax error"), "{e}");
    }

    #[test]
    fn trace_workload_validates_against_the_grid() {
        let e = parse_err(&minimal(
            r#""workloads": [{"kind": "trace", "compute_secs": 60, "ppn": 64}]"#,
        ));
        assert_eq!(e.path, "workloads[0].ppn");
        assert!(e.msg.contains("12"), "widest table-1 node is 12 cores: {e}");
        let e = parse_err(&minimal(
            r#""workloads": [{"kind": "trace", "compute_secs": 60, "nodes": 9}]"#,
        ));
        assert_eq!(e.path, "workloads[0].nodes");
    }

    #[test]
    fn trace_walltime_defaults_to_4x_compute() {
        let s = ScenarioSpec::parse(&minimal(
            r#""workloads": [{"kind": "trace", "compute_secs": 60}]"#,
        ))
        .unwrap();
        match &s.workloads[0] {
            WorkloadSpec::Trace { compute, walltime, count, nodes, ppn, .. } => {
                assert_eq!(*compute, 60 * DUR_SEC);
                assert_eq!(*walltime, 240 * DUR_SEC);
                assert_eq!((*count, *nodes, *ppn), (1, 1, 1));
            }
            other => panic!("wrong workload: {other:?}"),
        }
    }

    #[test]
    fn fractional_seconds_round_to_ns() {
        let s = ScenarioSpec::parse(&minimal(
            r#""faults": [{"kind": "vm_crash", "at_secs": 1000.2, "outage_secs": 0.5}]"#,
        ))
        .unwrap();
        match &s.faults[0].timing {
            FaultTiming::At(t) => assert_eq!(*t, 1_000_200_000_000),
            other => panic!("wrong timing: {other:?}"),
        }
        assert_eq!(s.faults[0].outage, 500_000_000);
    }

    #[test]
    fn custom_nodes_and_engine_parse() {
        let s = ScenarioSpec::parse(&minimal(
            r#""nodes": {"count": 16, "cores": 4, "prebooted": true}, "engine": "threaded:3""#,
        ))
        .unwrap();
        assert_eq!(s.engine, EngineSpec::Threaded(3));
        assert_eq!(s.nodes.node_count(), 16);
        assert_eq!(s.nodes.max_cores(), 4);
        assert!(s.nodes.prebooted());
        assert_eq!(s.nodes.names()[0], "n01");
        assert_eq!(s.nodes.names()[15], "n16");
    }

    #[test]
    fn table1_preset_rejects_custom_fields() {
        let e = parse_err(&minimal(r#""nodes": {"preset": "table1", "count": 8}"#));
        assert_eq!(e.path, "nodes.count");
    }

    #[test]
    fn storm_requires_a_rate() {
        let e = parse_err(&minimal(r#""storm": {"scale": 2}"#));
        assert_eq!(e.path, "storm");
        let s = ScenarioSpec::parse(&minimal(r#""storm": {"preset": "lab", "scale": 5}"#)).unwrap();
        let plan = s.storm.unwrap().to_plan();
        let want = FaultPlan::lab_default().scaled(5.0);
        assert_eq!(plan.mtbf_power_off, want.mtbf_power_off);
        assert_eq!(plan.mtbf_vm_crash, want.mtbf_vm_crash);
        assert_eq!(plan.mean_outage, want.mean_outage);
    }

    #[test]
    fn targets_all_and_lists_resolve() {
        let s = ScenarioSpec::parse(&minimal(
            r#""faults": [
                {"kind": "net_drop", "at_secs": 5, "targets": "all"},
                {"kind": "net_drop", "at_secs": 5, "targets": ["n02", "n03"]}
            ]"#,
        ))
        .unwrap();
        assert_eq!(s.faults[0].targets.len(), 4);
        assert_eq!(s.faults[1].targets, vec!["n02".to_string(), "n03".to_string()]);
    }
}
