//! Execute compiled scenarios and sweep scenario corpora.
//!
//! Every run uses a memory event sink, so the caller always gets the
//! full JSONL event log and pretty report JSON back — the two byte
//! streams the determinism contract is stated over.  Nothing here
//! touches the process (no exit, no stdout): the CLI layer owns
//! presentation and exit codes.

use std::path::{Path, PathBuf};

use crate::coordinator::gridlan::Gridlan;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::scenario::run_scenario_logged;
use crate::obs::event::ScenarioLogger;
use crate::rm::job::JobState;
use crate::runtime::engine::EpEngine;
use crate::scenario_dsl::compile::CompiledScenario;
use crate::scenario_dsl::expect::{ExpectReport, RunFacts};
use crate::scenario_dsl::spec::{EngineSpec, ScenarioSpec};
use crate::sim::clock::to_secs_f64;
use crate::workload::ep::EpTally;

/// Everything observable from one scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub name: String,
    pub seed: u64,
    pub metrics: Metrics,
    pub events_executed: u64,
    /// Merged tally across every completed EP job.
    pub ep_total: EpTally,
    /// The full structured event log (newline-terminated JSONL).
    pub events_jsonl: String,
    /// The scenario report as pretty JSON + trailing newline.
    pub report_json: String,
    /// Evaluated `expect` block (empty block = vacuous pass).
    pub expect: ExpectReport,
}

impl ScenarioOutcome {
    pub fn passed(&self) -> bool {
        self.expect.passed()
    }

    /// Human one-screen summary (CLI `scenario` output).
    pub fn render_summary(&self) -> String {
        let m = &self.metrics;
        let verdict = if self.passed() { "PASS" } else { "FAIL" };
        let mut out = format!("scenario '{}' (seed {}): {}\n", self.name, self.seed, verdict);
        out.push_str(&format!(
            "  jobs: {} submitted, {} completed, {} requeued, {} killed\n",
            m.jobs_submitted, m.jobs_completed, m.jobs_requeued, m.jobs_killed
        ));
        out.push_str(&format!(
            "  faults: {} ({} watchdog restarts)  goodput: {:.3}  makespan: {:.1} s\n",
            m.faults,
            m.watchdog_restarts,
            m.goodput(),
            to_secs_f64(m.makespan)
        ));
        if m.ep_jobs_completed > 0 || m.ep_pairs_executed > 0 {
            out.push_str(&format!(
                "  ep: {} jobs, {} pairs executed\n",
                m.ep_jobs_completed, m.ep_pairs_executed
            ));
        }
        out.push_str(&self.expect.render());
        out
    }
}

/// Run a compiled scenario to completion on the DES and evaluate its
/// `expect` block.
pub fn run_compiled(c: &CompiledScenario) -> ScenarioOutcome {
    let mut g = Gridlan::build(c.config.clone());
    if c.prebooted {
        g.boot_all(0);
    }
    let engine = match c.engine {
        EngineSpec::Scalar => EpEngine::scalar(),
        EngineSpec::Threaded(n) => EpEngine::threaded(n),
    };
    let run = run_scenario_logged(g, c.trace.clone(), &c.scenario, engine, ScenarioLogger::memory());
    let report = &run.report;
    // Terminal = every job the RM accepted ran to completion AND the
    // counters account for every submission (accepted or rejected).
    let all_terminal = run.gridlan.pbs.jobs().all(|j| j.state == JobState::Completed)
        && report.metrics.jobs_submitted
            == report.metrics.jobs_completed + report.metrics.jobs_killed;
    let ep_total = report.ep_total();
    let facts =
        RunFacts { metrics: report.metrics.clone(), all_terminal, ep_total };
    let expect = c.expect.check(&facts, &c.ep_ranges);
    ScenarioOutcome {
        name: c.name.clone(),
        seed: c.seed,
        metrics: report.metrics.clone(),
        events_executed: report.events_executed,
        ep_total,
        events_jsonl: run.logger.to_jsonl(),
        report_json: report.to_json().to_pretty() + "\n",
        expect,
    }
}

/// Compile + run a parsed spec.
pub fn run_spec(spec: &ScenarioSpec) -> ScenarioOutcome {
    run_compiled(&spec.compile())
}

/// Read + parse a scenario file, prefixing every error with the path.
pub fn load_file(path: &Path) -> Result<ScenarioSpec, String> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    ScenarioSpec::parse(&src).map_err(|e| format!("{}: {e}", path.display()))
}

/// Load, compile, and run one scenario file.
pub fn run_file(path: &Path) -> Result<ScenarioOutcome, String> {
    Ok(run_spec(&load_file(path)?))
}

/// The `*.json` files of a scenario corpus directory, sorted by name
/// (the sweep order).  An empty corpus is an error — a chaos lab that
/// silently checks nothing must not look green.
pub fn corpus_files(dir: &Path) -> Result<Vec<PathBuf>, String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut out = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("{}: {e}", dir.display()))?;
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) == Some("json") && path.is_file() {
            out.push(path);
        }
    }
    out.sort();
    if out.is_empty() {
        return Err(format!("no *.json scenario files under {}", dir.display()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
        "seed": 5,
        "horizon_secs": 3600,
        "workloads": [
            {"kind": "trace", "count": 4, "every_secs": 30, "compute_secs": 60,
             "walltime_secs": 600, "ppn": 2}
        ],
        "expect": {"jobs_completed": 4, "all_jobs_terminal": true, "min_goodput": 0.99}
    }"#;

    #[test]
    fn mini_scenario_runs_and_passes_expect() {
        let spec = ScenarioSpec::parse(MINI).unwrap();
        let out = run_spec(&spec);
        assert!(out.passed(), "{}", out.render_summary());
        assert_eq!(out.metrics.jobs_completed, 4);
        assert!(!out.events_jsonl.is_empty());
        assert!(out.events_jsonl.ends_with('\n'));
        assert!(out.report_json.ends_with('\n'));
        let summary = out.render_summary();
        assert!(summary.contains("PASS"), "{summary}");
        assert!(summary.contains("4 completed"), "{summary}");
    }

    #[test]
    fn same_spec_twice_is_byte_identical() {
        let spec = ScenarioSpec::parse(MINI).unwrap();
        let a = run_spec(&spec);
        let b = run_spec(&spec);
        assert_eq!(a.events_jsonl, b.events_jsonl);
        assert_eq!(a.report_json, b.report_json);
        assert_eq!(a.metrics, b.metrics);
    }

    #[test]
    fn failed_expect_is_reported_not_panicked() {
        let src = MINI.replace("\"jobs_completed\": 4", "\"jobs_completed\": 5");
        let out = run_spec(&ScenarioSpec::parse(&src).unwrap());
        assert!(!out.passed());
        let summary = out.render_summary();
        assert!(summary.contains("FAIL"), "{summary}");
        assert!(summary.contains("jobs_completed"), "{summary}");
    }

    #[test]
    fn prebooted_ep_spec_matches_the_oracle() {
        let src = r#"{
            "seed": 11,
            "horizon_secs": 3600,
            "nodes": {"preset": "table1", "prebooted": true},
            "workloads": [
                {"kind": "ep", "slices": 4, "pairs_per_slice": 4096, "every_secs": 1}
            ],
            "expect": {"jobs_completed": 4, "ep_tally_exact": true,
                       "ep_pairs_executed": 16384, "all_jobs_terminal": true}
        }"#;
        let out = run_spec(&ScenarioSpec::parse(src).unwrap());
        assert!(out.passed(), "{}", out.render_summary());
        assert_eq!(out.ep_total.pairs, 16_384);
    }

    #[test]
    fn corpus_files_sorts_and_rejects_empty_dirs() {
        let dir = std::env::temp_dir().join("gridlan_dsl_corpus_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(corpus_files(&dir).is_err(), "empty corpus must be an error");
        std::fs::write(dir.join("02_b.json"), "{}").unwrap();
        std::fs::write(dir.join("01_a.json"), "{}").unwrap();
        std::fs::write(dir.join("README.md"), "not a scenario").unwrap();
        let files = corpus_files(&dir).unwrap();
        let names: Vec<_> =
            files.iter().map(|p| p.file_name().unwrap().to_str().unwrap()).collect();
        assert_eq!(names, vec!["01_a.json", "02_b.json"]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
