//! `expect` blocks: invariant assertions a scenario run must satisfy.
//!
//! An expectation is data in the scenario file, checked against the
//! post-run [`Metrics`] and merged EP tallies.  `ep_tally_exact` is the
//! strongest check: it recomputes every declared pair range through the
//! scalar oracle ([`ep_scalar`]) and demands the merged scenario tally
//! match it — counters exactly, accumulator sums to 1e-7 absolute — the
//! same contract the in-code lifecycle tests enforce.

use crate::coordinator::metrics::Metrics;
use crate::scenario_dsl::spec::{check_keys, get_bool, get_count, get_num, join, DslError};
use crate::sim::clock::to_secs_f64;
use crate::util::json::Json;
use crate::workload::ep::{ep_scalar, EpTally};

/// Absolute tolerance for the floating EP accumulators (`sx`, `sy`);
/// counters (`nacc`, `q`, `pairs`) must match exactly.
const EP_SUM_TOL: f64 = 1e-7;

/// Declarative post-run assertions (all optional; empty = report-only).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Expect {
    /// Every submitted job reached a terminal state (completed, or
    /// rejected at qsub and counted killed) — nothing left queued,
    /// running, or held at the end of the drain.
    pub all_jobs_terminal: bool,
    /// Exact completed-job count.
    pub jobs_completed: Option<u64>,
    pub min_completed: Option<u64>,
    pub min_requeued: Option<u64>,
    pub min_faults: Option<u64>,
    pub min_watchdog_restarts: Option<u64>,
    /// Merged EP tally must equal the scalar oracle over the declared
    /// pair ranges.
    pub ep_tally_exact: bool,
    /// Exact count of EP pairs *executed* on the backend — this INCLUDES
    /// wasted re-execution after faults (under salvage recovery a clean
    /// or faulted run both execute exactly the logical pair count).
    pub ep_pairs_executed: Option<u64>,
    /// Upper bound on wasted pairs: executed minus the merged logical
    /// tally.  `0` asserts perfect salvage (no pair ran twice).
    pub max_wasted_pairs: Option<u64>,
    /// At least this many straggler range-steals happened.
    pub min_steals: Option<u64>,
    pub max_makespan_secs: Option<f64>,
    pub min_goodput: Option<f64>,
    pub max_goodput: Option<f64>,
}

impl Expect {
    /// True when no assertion is set (the run is report-only).
    pub fn is_empty(&self) -> bool {
        *self == Expect::default()
    }

    pub fn from_json(j: &Json, path: &str) -> Result<Expect, DslError> {
        let o = j.as_obj().ok_or_else(|| DslError::at(path, "must be an object"))?;
        check_keys(
            o,
            path,
            &[
                "all_jobs_terminal",
                "jobs_completed",
                "min_completed",
                "min_requeued",
                "min_faults",
                "min_watchdog_restarts",
                "ep_tally_exact",
                "ep_pairs_executed",
                "max_wasted_pairs",
                "min_steals",
                "max_makespan_secs",
                "min_goodput",
                "max_goodput",
            ],
        )?;
        let e = Expect {
            all_jobs_terminal: get_bool(o, path, "all_jobs_terminal")?.unwrap_or(false),
            jobs_completed: get_count(o, path, "jobs_completed")?,
            min_completed: get_count(o, path, "min_completed")?,
            min_requeued: get_count(o, path, "min_requeued")?,
            min_faults: get_count(o, path, "min_faults")?,
            min_watchdog_restarts: get_count(o, path, "min_watchdog_restarts")?,
            ep_tally_exact: get_bool(o, path, "ep_tally_exact")?.unwrap_or(false),
            ep_pairs_executed: get_count(o, path, "ep_pairs_executed")?,
            max_wasted_pairs: get_count(o, path, "max_wasted_pairs")?,
            min_steals: get_count(o, path, "min_steals")?,
            max_makespan_secs: get_num(o, path, "max_makespan_secs")?,
            min_goodput: get_num(o, path, "min_goodput")?,
            max_goodput: get_num(o, path, "max_goodput")?,
        };
        for (key, v) in
            [("min_goodput", e.min_goodput), ("max_goodput", e.max_goodput)]
        {
            if let Some(v) = v {
                if !(0.0..=1.0).contains(&v) {
                    return Err(DslError::at(join(path, key), "must be in 0..=1"));
                }
            }
        }
        Ok(e)
    }

    /// Evaluate every set assertion against the run's observed facts.
    /// `ranges` are the `(pair_offset, pair_count)` spans the scenario
    /// declared, used to rebuild the EP oracle for `ep_tally_exact`.
    pub fn check(&self, facts: &RunFacts, ranges: &[(u64, u64)]) -> ExpectReport {
        let mut r = ExpectReport { checks: Vec::new() };
        if self.all_jobs_terminal {
            r.push(facts.all_terminal, "all_jobs_terminal".to_string(), || {
                "some jobs never reached a terminal state".to_string()
            });
        }
        let m = &facts.metrics;
        if let Some(want) = self.jobs_completed {
            r.eq("jobs_completed", m.jobs_completed, want);
        }
        if let Some(want) = self.min_completed {
            r.ge("min_completed", m.jobs_completed, want);
        }
        if let Some(want) = self.min_requeued {
            r.ge("min_requeued", m.jobs_requeued, want);
        }
        if let Some(want) = self.min_faults {
            r.ge("min_faults", m.faults, want);
        }
        if let Some(want) = self.min_watchdog_restarts {
            r.ge("min_watchdog_restarts", m.watchdog_restarts, want);
        }
        if let Some(want) = self.ep_pairs_executed {
            r.eq("ep_pairs_executed", m.ep_pairs_executed, want);
        }
        if let Some(want) = self.max_wasted_pairs {
            // Waste = executions beyond the merged logical range.
            let wasted = m.ep_pairs_executed.saturating_sub(facts.ep_total.pairs);
            r.push(wasted <= want, format!("max_wasted_pairs <= {want}"), || {
                format!("{wasted} pairs were re-executed waste")
            });
        }
        if let Some(want) = self.min_steals {
            r.ge("min_steals", m.ep_steals, want);
        }
        if self.ep_tally_exact {
            let mut oracle = EpTally::default();
            for &(offset, count) in ranges {
                oracle.merge(&ep_scalar(offset, count));
            }
            let got = &facts.ep_total;
            let counters_ok =
                got.nacc == oracle.nacc && got.q == oracle.q && got.pairs == oracle.pairs;
            let sums_ok = (got.sx - oracle.sx).abs() < EP_SUM_TOL
                && (got.sy - oracle.sy).abs() < EP_SUM_TOL;
            r.push(counters_ok && sums_ok, "ep_tally_exact".to_string(), || {
                format!(
                    "merged tally diverged from the scalar oracle: \
                     got nacc={} pairs={}, want nacc={} pairs={}",
                    got.nacc, got.pairs, oracle.nacc, oracle.pairs
                )
            });
        }
        if let Some(want) = self.max_makespan_secs {
            let got = to_secs_f64(m.makespan);
            r.push(got <= want, format!("max_makespan_secs <= {want}"), || {
                format!("makespan was {got} s")
            });
        }
        if let Some(want) = self.min_goodput {
            let got = m.goodput();
            r.push(got >= want, format!("min_goodput >= {want}"), || {
                format!("goodput was {got}")
            });
        }
        if let Some(want) = self.max_goodput {
            let got = m.goodput();
            r.push(got <= want, format!("max_goodput <= {want}"), || {
                format!("goodput was {got}")
            });
        }
        r
    }
}

/// What actually happened in a run, as far as `expect` is concerned.
#[derive(Debug, Clone)]
pub struct RunFacts {
    pub metrics: Metrics,
    pub all_terminal: bool,
    /// Merged tally across every EP job the run completed.
    pub ep_total: EpTally,
}

/// One evaluated assertion.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpectCheck {
    pub ok: bool,
    /// `ok <label>` or `FAIL <label>: <detail>`.
    pub line: String,
}

/// The outcome of every assertion in an `expect` block.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExpectReport {
    pub checks: Vec<ExpectCheck>,
}

impl ExpectReport {
    fn push(&mut self, ok: bool, label: String, detail: impl FnOnce() -> String) {
        let line = if ok { format!("ok   {label}") } else { format!("FAIL {label}: {}", detail()) };
        self.checks.push(ExpectCheck { ok, line });
    }

    fn eq(&mut self, label: &str, got: u64, want: u64) {
        self.push(got == want, format!("{label} = {want}"), || format!("got {got}"));
    }

    fn ge(&mut self, label: &str, got: u64, want: u64) {
        self.push(got >= want, format!("{label}: {got} >= {want}"), || {
            format!("got {got}, want >= {want}")
        });
    }

    /// Vacuously true for an empty `expect` block.
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.ok)
    }

    pub fn failures(&self) -> impl Iterator<Item = &ExpectCheck> {
        self.checks.iter().filter(|c| !c.ok)
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.checks {
            out.push_str("  ");
            out.push_str(&c.line);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::DUR_SEC;

    fn facts(completed: u64, requeued: u64, faults: u64) -> RunFacts {
        let mut m = Metrics::default();
        m.jobs_submitted = completed;
        m.jobs_completed = completed;
        m.jobs_requeued = requeued;
        m.faults = faults;
        m.makespan = 100 * DUR_SEC;
        RunFacts { metrics: m, all_terminal: true, ep_total: EpTally::default() }
    }

    #[test]
    fn empty_expect_passes_vacuously() {
        let e = Expect::default();
        assert!(e.is_empty());
        let r = e.check(&facts(0, 0, 0), &[]);
        assert!(r.checks.is_empty());
        assert!(r.passed());
    }

    #[test]
    fn count_checks_pass_and_fail() {
        let e = Expect {
            jobs_completed: Some(10),
            min_requeued: Some(1),
            min_faults: Some(2),
            all_jobs_terminal: true,
            ..Default::default()
        };
        assert!(e.check(&facts(10, 1, 2), &[]).passed());
        let r = e.check(&facts(9, 0, 2), &[]);
        assert!(!r.passed());
        let fails: Vec<_> = r.failures().map(|c| c.line.clone()).collect();
        assert_eq!(fails.len(), 2, "{fails:?}");
        assert!(fails[0].contains("jobs_completed"), "{fails:?}");
        assert!(fails[1].contains("min_requeued") && fails[1].contains("got 0"), "{fails:?}");
    }

    #[test]
    fn ep_tally_exact_matches_the_scalar_oracle() {
        let mut f = facts(2, 0, 0);
        let mut total = EpTally::default();
        total.merge(&ep_scalar(0, 5_000));
        total.merge(&ep_scalar(5_000, 5_000));
        f.ep_total = total;
        let e = Expect { ep_tally_exact: true, ..Default::default() };
        assert!(e.check(&f, &[(0, 5_000), (5_000, 5_000)]).passed());
        // A perturbed tally must fail.
        f.ep_total.nacc += 1;
        let r = e.check(&f, &[(0, 5_000), (5_000, 5_000)]);
        assert!(!r.passed());
        assert!(r.failures().next().unwrap().line.contains("oracle"));
    }

    #[test]
    fn goodput_and_makespan_bounds() {
        let mut f = facts(4, 0, 0);
        f.metrics.core_secs_useful = 99.0;
        f.metrics.core_secs_wasted = 1.0;
        let e = Expect {
            min_goodput: Some(0.9),
            max_goodput: Some(1.0),
            max_makespan_secs: Some(150.0),
            ..Default::default()
        };
        assert!(e.check(&f, &[]).passed());
        let tight = Expect { max_makespan_secs: Some(50.0), ..Default::default() };
        assert!(!tight.check(&f, &[]).passed());
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_ranges() {
        let doc = Json::parse(r#"{"jobs_compleeted": 3}"#).unwrap();
        let e = Expect::from_json(&doc, "expect").unwrap_err();
        assert_eq!(e.path, "expect.jobs_compleeted");
        let doc = Json::parse(r#"{"min_goodput": 1.5}"#).unwrap();
        let e = Expect::from_json(&doc, "expect").unwrap_err();
        assert_eq!(e.path, "expect.min_goodput");
    }

    #[test]
    fn parse_fills_every_field() {
        let doc = Json::parse(
            r#"{
                "all_jobs_terminal": true,
                "jobs_completed": 8,
                "min_requeued": 1,
                "min_faults": 2,
                "min_watchdog_restarts": 1,
                "ep_tally_exact": true,
                "ep_pairs_executed": 240000,
                "max_wasted_pairs": 0,
                "min_steals": 1,
                "min_goodput": 0.5
            }"#,
        )
        .unwrap();
        let e = Expect::from_json(&doc, "expect").unwrap();
        assert!(e.all_jobs_terminal && e.ep_tally_exact);
        assert_eq!(e.jobs_completed, Some(8));
        assert_eq!(e.ep_pairs_executed, Some(240_000));
        assert_eq!(e.max_wasted_pairs, Some(0));
        assert_eq!(e.min_steals, Some(1));
        assert!(!e.is_empty());
    }

    #[test]
    fn wasted_pairs_and_steal_checks() {
        // Executed 12_000 pairs but the merged logical range was only
        // 10_000 — 2_000 pairs of post-fault waste.
        let mut f = facts(2, 1, 1);
        f.metrics.ep_pairs_executed = 12_000;
        f.ep_total.pairs = 10_000;
        f.metrics.ep_steals = 2;
        let loose = Expect {
            max_wasted_pairs: Some(2_000),
            min_steals: Some(2),
            ..Default::default()
        };
        assert!(loose.check(&f, &[]).passed());
        let tight = Expect { max_wasted_pairs: Some(0), ..Default::default() };
        let r = tight.check(&f, &[]);
        assert!(!r.passed());
        assert!(r.failures().next().unwrap().line.contains("2000 pairs"), "{r:?}");
        let greedy = Expect { min_steals: Some(3), ..Default::default() };
        assert!(!greedy.check(&f, &[]).passed());
    }
}
