//! Declarative scenario DSL: chaos experiments as data, not code.
//!
//! A scenario file is one JSON document (parsed by the in-tree
//! [`crate::util::json`] layer — the offline vendor set has no serde)
//! describing a whole chaos experiment:
//!
//! * `nodes` — the grid: the paper's Table-1 testbed or a synthetic
//!   `count` x `cores` deployment, optionally pre-booted;
//! * `faults` — crash / power-off / network-partition events with
//!   one-shot (`at_secs`), periodic (`every_secs` + `count`), or seeded
//!   (`seeded` + `window_secs`, QSL-style `k = seed + idx` placement)
//!   timing, plus an optional random `storm` block (MTBF-driven
//!   [`crate::host::faults::FaultPlan`]);
//! * `workloads` — synthetic trace batches, `ep:<offset>:<count>`
//!   real-compute floods, and open-loop arrival generators;
//! * `seed` — the single root of all randomness in the run;
//! * `expect` — invariant assertions checked after the run (all jobs
//!   terminal, exact merged EP tallies, minimum completions, ...).
//!
//! The pipeline is `spec` (parse + validate, path-aware errors) ->
//! `compile` (lower to the existing [`crate::coordinator::scenario`]
//! trace/fault machinery) -> `runner` (execute on the DES, check the
//! `expect` block) -> [`crate::obs::event`] JSONL + report JSON.
//!
//! **Determinism contract:** a scenario file plus its `seed` fully
//! determines the run.  Re-running the same file produces byte-identical
//! `events.jsonl` and report JSON — the corpus replay suite
//! (`rust/tests/integration_scenario_dsl.rs`) holds this line for every
//! committed file under `scenarios/`.

pub mod compile;
pub mod expect;
pub mod runner;
pub mod spec;

pub use compile::CompiledScenario;
pub use expect::{Expect, ExpectReport, RunFacts};
pub use runner::{corpus_files, load_file, run_compiled, run_file, run_spec, ScenarioOutcome};
pub use spec::{
    DslError, EngineSpec, FaultSpec, FaultTiming, NodesSpec, ScenarioSpec, WorkloadSpec,
};
