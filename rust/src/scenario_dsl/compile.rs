//! Lower a parsed [`ScenarioSpec`] onto the existing run machinery:
//! a [`Config`] (the grid), a trace (`Vec<TraceJob>`), and a
//! [`Scenario`] (horizon + fault plan + scripted fault events).
//!
//! Everything here is a pure function of the spec — no clocks, no
//! ambient randomness.  Seeded fault placement derives one
//! [`SplitMix64`] per event from `seed + (block << 32) + idx` (the
//! QSL-style mapping), so inserting a new fault block never perturbs
//! the placement of the blocks after it.

use crate::config::{ClientConfig, Config};
use crate::coordinator::scenario::Scenario;
use crate::host::client::ClientOs;
use crate::host::faults::{FaultEvent, FaultPlan};
use crate::rm::alloc::ResourceRequest;
use crate::scenario_dsl::expect::Expect;
use crate::scenario_dsl::spec::{
    EngineSpec, FaultTiming, NodesSpec, ScenarioSpec, WorkloadSpec,
};
use crate::util::rng::SplitMix64;
use crate::vm::cpu::CpuModel;
use crate::workload::ep::EpSlice;
use crate::workload::trace::{JobPayload, TraceGenerator, TraceJob};

/// A scenario lowered to runnable parts.  `run` order is deterministic:
/// the trace is stable-sorted by submit time (file order breaks ties)
/// and scripted faults are stable-sorted by fire time.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    pub name: String,
    pub seed: u64,
    pub config: Config,
    /// Boot every client to Online before t=0 (skip the boot storm).
    pub prebooted: bool,
    pub engine: EngineSpec,
    pub trace: Vec<TraceJob>,
    pub scenario: Scenario,
    /// Declared EP `(pair_offset, pair_count)` spans, one per `ep`
    /// workload block — the oracle input for `expect.ep_tally_exact`.
    pub ep_ranges: Vec<(u64, u64)>,
    pub expect: Expect,
}

impl ScenarioSpec {
    pub fn compile(&self) -> CompiledScenario {
        let mut config = build_config(self);
        config.seed = self.seed;
        config.sched = self.sched;

        let mut trace = Vec::new();
        let mut ep_ranges = Vec::new();
        for (bidx, w) in self.workloads.iter().enumerate() {
            match w {
                WorkloadSpec::Trace {
                    count,
                    start,
                    every,
                    nodes,
                    ppn,
                    compute,
                    walltime,
                    owner,
                } => {
                    for i in 0..*count {
                        trace.push(TraceJob {
                            at: start.saturating_add(every.saturating_mul(i as u64)),
                            owner: owner.clone(),
                            request: ResourceRequest { nodes: *nodes, ppn: *ppn },
                            compute: *compute,
                            walltime: *walltime,
                            payload: JobPayload::Synthetic,
                        });
                    }
                }
                WorkloadSpec::Ep { slices, pair_offset, pairs_per_slice, start, every, walltime } => {
                    for i in 0..*slices {
                        let slice = EpSlice {
                            proc: i,
                            pair_offset: pair_offset + i as u64 * pairs_per_slice,
                            pair_count: *pairs_per_slice,
                        };
                        trace.push(slice.trace_job(
                            start.saturating_add(every.saturating_mul(i as u64)),
                            *walltime,
                        ));
                    }
                    ep_ranges.push((*pair_offset, *slices as u64 * *pairs_per_slice));
                }
                WorkloadSpec::Arrivals { users, horizon, mean_gap, wide_fraction } => {
                    let gen = TraceGenerator {
                        users: *users,
                        horizon: horizon.unwrap_or(self.horizon),
                        mean_gap: *mean_gap,
                        wide_fraction: *wide_fraction,
                    };
                    // Same seed derivation the `gridlan trace` CLI uses,
                    // salted per block so two arrivals blocks differ.
                    let mut rng =
                        SplitMix64::new((self.seed ^ 0xABCD).wrapping_add((bidx as u64) << 32));
                    trace.extend(gen.generate(&mut rng));
                }
            }
        }
        // Stable: ties keep workload-block file order.
        trace.sort_by_key(|j| j.at);

        let scenario = Scenario {
            horizon: self.horizon,
            sched_period: self.sched_period,
            faults: self.storm.as_ref().map(|s| s.to_plan()).unwrap_or_else(FaultPlan::none),
            scripted_faults: expand_faults(self),
            recovery: self.recovery.clone(),
        };

        CompiledScenario {
            name: self.name.clone(),
            seed: self.seed,
            config,
            prebooted: self.nodes.prebooted(),
            engine: self.engine,
            trace,
            scenario,
            ep_ranges,
            expect: self.expect.clone(),
        }
    }
}

fn build_config(spec: &ScenarioSpec) -> Config {
    match &spec.nodes {
        NodesSpec::Table1 { .. } => Config::table1(),
        NodesSpec::Custom {
            count, cores, switch_hops, stack_us, link_mbps, slow_nodes, slow_factor, ..
        } => {
            let mut cfg = Config::table1();
            cfg.clients.clear();
            for (i, name) in spec.nodes.names().into_iter().enumerate() {
                // The last `slow_nodes` clients are stragglers: same chip,
                // 1/slow_factor the per-cycle EP throughput.
                let slow = (i as u32) >= count - slow_nodes;
                let ppc = if slow { 0.0045 / slow_factor } else { 0.0045 };
                cfg.clients.push(ClientConfig {
                    cpu: CpuModel {
                        name: format!("custom-{name}{}", if slow { "-slow" } else { "" }),
                        cores: *cores,
                        base_ghz: 3.0,
                        max_turbo_ghz: 3.4,
                        all_core_ghz: 3.1,
                        pairs_per_cycle: ppc,
                    },
                    name,
                    os: ClientOs::Linux,
                    hypervisor: None,
                    switch_hops: *switch_hops,
                    stack_us: *stack_us,
                    link_mbps: *link_mbps,
                });
            }
            cfg
        }
    }
}

/// Expand every declarative fault block into concrete [`FaultEvent`]s,
/// clip to the horizon (mirroring [`FaultPlan::generate`]), and
/// stable-sort by fire time.
fn expand_faults(spec: &ScenarioSpec) -> Vec<FaultEvent> {
    let mut out = Vec::new();
    for (bidx, f) in spec.faults.iter().enumerate() {
        match f.timing {
            FaultTiming::At(at) => {
                for t in &f.targets {
                    out.push(FaultEvent { at, client: t.clone(), kind: f.kind, outage: f.outage });
                }
            }
            FaultTiming::Every { start, every, count } => {
                for i in 0..count {
                    let at = start.saturating_add(every.saturating_mul(i as u64));
                    for t in &f.targets {
                        out.push(FaultEvent {
                            at,
                            client: t.clone(),
                            kind: f.kind,
                            outage: f.outage,
                        });
                    }
                }
            }
            FaultTiming::Seeded { count, window: (lo, hi) } => {
                for i in 0..count {
                    // One generator per event: time draw, then target draw.
                    let mut rng = SplitMix64::new(
                        spec.seed.wrapping_add((bidx as u64) << 32).wrapping_add(i as u64),
                    );
                    let at = lo + (rng.next_f64() * (hi - lo) as f64) as u64;
                    let t = &f.targets[rng.gen_range(f.targets.len() as u64) as usize];
                    out.push(FaultEvent { at, client: t.clone(), kind: f.kind, outage: f.outage });
                }
            }
        }
    }
    out.retain(|e| e.at < spec.horizon);
    out.sort_by_key(|e| e.at);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchedPolicy;
    use crate::host::faults::FaultKind;
    use crate::sim::clock::DUR_SEC;

    fn spec(body: &str) -> ScenarioSpec {
        ScenarioSpec::parse(body).expect("test spec parses")
    }

    #[test]
    fn every_timing_expands_per_target_and_sorts() {
        let c = spec(
            r#"{"seed": 1, "horizon_secs": 7200, "faults": [
                {"kind": "net_drop", "every_secs": 900, "count": 3, "targets": ["n01", "n02"]},
                {"kind": "vm_crash", "at_secs": 100, "target": "n04", "outage_secs": 5}
            ]}"#,
        )
        .compile();
        let f = &c.scenario.scripted_faults;
        assert_eq!(f.len(), 3 * 2 + 1);
        assert_eq!(f[0].at, 100 * DUR_SEC);
        assert_eq!(f[0].kind, FaultKind::VmCrash);
        assert_eq!(f[0].outage, 5 * DUR_SEC);
        // 900s block: pairs (n01, n02) at 900, 1800, 2700 — stable order.
        assert_eq!(f[1].at, 900 * DUR_SEC);
        assert_eq!(f[1].client, "n01");
        assert_eq!(f[2].client, "n02");
        assert!(f.windows(2).all(|w| w[0].at <= w[1].at));
    }

    #[test]
    fn events_at_or_past_the_horizon_are_clipped() {
        let c = spec(
            r#"{"seed": 1, "horizon_secs": 1800, "faults": [
                {"kind": "power_off", "every_secs": 900, "count": 10, "target": "n01"}
            ]}"#,
        )
        .compile();
        // Only the t=900s shot survives (t=1800s == horizon is out).
        assert_eq!(c.scenario.scripted_faults.len(), 1);
        assert_eq!(c.scenario.scripted_faults[0].at, 900 * DUR_SEC);
    }

    #[test]
    fn seeded_placement_is_deterministic_and_in_window() {
        let src = r#"{"seed": 42, "horizon_secs": 14400, "faults": [
            {"kind": "vm_crash", "seeded": 6, "window_secs": [600, 5400]}
        ]}"#;
        let a = spec(src).compile();
        let b = spec(src).compile();
        assert_eq!(a.scenario.scripted_faults, b.scenario.scripted_faults);
        assert_eq!(a.scenario.scripted_faults.len(), 6);
        for e in &a.scenario.scripted_faults {
            assert!(e.at >= 600 * DUR_SEC && e.at < 5400 * DUR_SEC, "{} out of window", e.at);
            assert!(["n01", "n02", "n03", "n04"].contains(&e.client.as_str()));
        }
        // A different seed must move the salvo.
        let c = spec(&src.replace("42", "43")).compile();
        assert_ne!(a.scenario.scripted_faults, c.scenario.scripted_faults);
    }

    #[test]
    fn trace_blocks_compile_sorted_with_ep_ranges() {
        let c = spec(
            r#"{"seed": 9, "sched": "backfill", "workloads": [
                {"kind": "trace", "count": 3, "start_secs": 10, "every_secs": 10,
                 "compute_secs": 60, "owner": "alice"},
                {"kind": "ep", "slices": 4, "pair_offset": 1000, "pairs_per_slice": 500,
                 "start_secs": 5, "every_secs": 20}
            ]}"#,
        )
        .compile();
        assert_eq!(c.config.seed, 9);
        assert_eq!(c.config.sched, SchedPolicy::Backfill);
        assert_eq!(c.trace.len(), 7);
        assert!(c.trace.windows(2).all(|w| w[0].at <= w[1].at), "trace sorted by at");
        assert_eq!(c.trace[0].at, 5 * DUR_SEC);
        match c.trace[0].payload {
            JobPayload::Ep { offset, count } => assert_eq!((offset, count), (1000, 500)),
            other => panic!("expected EP payload, got {other:?}"),
        }
        // Consecutive slices tile the declared range.
        let offsets: Vec<u64> = c
            .trace
            .iter()
            .filter_map(|j| match j.payload {
                JobPayload::Ep { offset, .. } => Some(offset),
                _ => None,
            })
            .collect();
        assert_eq!(offsets, vec![1000, 1500, 2000, 2500]);
        assert_eq!(c.ep_ranges, vec![(1000, 2000)]);
        assert_eq!(c.trace.last().unwrap().owner, "alice");
    }

    #[test]
    fn custom_grid_builds_a_homogeneous_config() {
        let c = spec(
            r#"{"seed": 3, "nodes": {"count": 16, "cores": 4, "prebooted": true,
                "switch_hops": 1, "stack_us": 90, "link_mbps": 1000}}"#,
        )
        .compile();
        assert!(c.prebooted);
        assert_eq!(c.config.clients.len(), 16);
        assert_eq!(c.config.clients[0].name, "n01");
        assert_eq!(c.config.clients[15].name, "n16");
        assert!(c.config.clients.iter().all(|cl| cl.cpu.cores == 4));
        assert_eq!(c.config.clients[0].switch_hops, 1);
    }

    #[test]
    fn recovery_policy_flows_into_the_scenario() {
        let c = spec(
            r#"{"seed": 4, "recovery": {"salvage": false, "checkpoint_interval_pairs": 4096,
                "steal": true}}"#,
        )
        .compile();
        assert!(!c.scenario.recovery.salvage);
        assert_eq!(c.scenario.recovery.checkpoint_interval, 4096);
        assert!(c.scenario.recovery.steal);
        // Absent block: the runner defaults (salvage on, auto interval).
        let d = spec(r#"{"seed": 4}"#).compile();
        assert!(d.scenario.recovery.salvage && !d.scenario.recovery.steal);
        assert_eq!(d.scenario.recovery.checkpoint_interval, 0);
    }

    #[test]
    fn slow_nodes_derate_the_tail_of_a_custom_grid() {
        let c = spec(
            r#"{"seed": 5, "nodes": {"count": 3, "cores": 2, "slow_nodes": 1,
                "slow_factor": 16}}"#,
        )
        .compile();
        assert_eq!(c.config.clients.len(), 3);
        let fast = &c.config.clients[0].cpu;
        let slow = &c.config.clients[2].cpu;
        assert_eq!(c.config.clients[2].name, "n03");
        assert!(slow.name.ends_with("-slow"));
        assert!((fast.pairs_per_cycle / slow.pairs_per_cycle - 16.0).abs() < 1e-12);
        assert_eq!(c.config.clients[1].cpu.pairs_per_cycle, fast.pairs_per_cycle);
    }

    #[test]
    fn arrivals_blocks_are_seed_stable() {
        let src = r#"{"seed": 77, "workloads": [
            {"kind": "arrivals", "users": 5, "horizon_secs": 28800}
        ]}"#;
        let a = spec(src).compile();
        let b = spec(src).compile();
        assert_eq!(a.trace, b.trace);
        assert!(!a.trace.is_empty());
        let c = spec(&src.replace("77", "78")).compile();
        assert_ne!(a.trace, c.trace, "a different seed must move the arrivals");
    }
}
