//! Fig. 3: the EP class-D speed-up experiment.
//!
//! Reproduces the paper's methodology exactly: for each run, draw a random
//! core count n ∈ [1, 26], scatter n processes randomly over the clients
//! (respecting core counts), record the elapsed time; plot against the
//! comparison server's curve and the ideal t1/n line.

use crate::perf::amdahl;
use crate::perf::speedmodel::{ComparisonServer, GridlanPool};
use crate::util::rng::SplitMix64;
use crate::util::table::{Align, Table};
use crate::workload::ep::EpClass;

/// One measured point.
#[derive(Debug, Clone)]
pub struct Fig3Point {
    pub cores: u32,
    pub gridlan_secs: f64,
    pub server_secs: f64,
    pub ideal_secs: f64,
}

/// The whole series.
#[derive(Debug, Clone)]
pub struct Fig3Series {
    pub class: EpClass,
    pub points: Vec<Fig3Point>,
    /// Measured single-core time used for the ideal line.
    pub t1_secs: f64,
    /// Elapsed with all 26 Gridlan cores.
    pub full_pool_secs: f64,
    /// Cores the comparison server needs to match the full pool.
    pub server_cores_to_match: Option<u32>,
}

/// Run the experiment: `runs` random core counts (the paper's protocol),
/// plus the deterministic 1..max sweep for the curve.
pub fn fig3_series(pool: &GridlanPool, class: EpClass, runs: usize, seed: u64) -> Fig3Series {
    let mut rng = SplitMix64::new(seed);
    let server = ComparisonServer::opteron();
    let max = pool.total_cores();
    let pairs = class.pairs();

    // t1: measured single-core run (random client — the paper's t1 is one
    // draw; we use the median of a few draws for stability).
    let mut t1s: Vec<f64> = (0..5)
        .map(|_| pool.elapsed_secs(pairs, &pool.random_placement(1, &mut rng)))
        .collect();
    t1s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let t1 = t1s[t1s.len() / 2];

    let mut points = Vec::new();
    for run in 0..runs {
        // Paper: "a random number of Gridlan cores ... from 1 to 26".
        let n = 1 + (rng.gen_range(max as u64) as u32);
        let placement = pool.random_placement(n, &mut rng);
        let g = pool.elapsed_secs(pairs, &placement);
        let s = server.elapsed_secs(pairs, n.min(server.cpu.cores));
        points.push(Fig3Point {
            cores: n,
            gridlan_secs: g,
            server_secs: s,
            ideal_secs: t1 / n as f64,
        });
        let _ = run;
    }
    points.sort_by_key(|p| p.cores);

    // Full-pool reference + crossover.
    let full_placement = {
        let mut rng2 = SplitMix64::new(seed ^ 0xFFFF);
        pool.random_placement(max, &mut rng2)
    };
    let full = pool.elapsed_secs(pairs, &full_placement);
    let need = server.cores_to_match(pairs, full);
    Fig3Series {
        class,
        points,
        t1_secs: t1,
        full_pool_secs: full,
        server_cores_to_match: need,
    }
}

/// Paper-style rendering: the series plus the headline facts.
pub fn render(series: &Fig3Series) -> String {
    let mut t = Table::new(&["cores", "Gridlan t(s)", "Server t(s)", "ideal t1/n", "dev vs ideal"])
        .title(&format!(
            "FIG 3 — NPB-EP class {} speed-up (t1 = {:.0}s)",
            series.class.name(),
            series.t1_secs
        ))
        .align(&[Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    for p in &series.points {
        t.row(&[
            p.cores.to_string(),
            format!("{:.1}", p.gridlan_secs),
            format!("{:.1}", p.server_secs),
            format!("{:.1}", p.ideal_secs),
            format!("{:+.1}%", 100.0 * (p.gridlan_secs - p.ideal_secs) / p.ideal_secs),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\nfull pool ({} cores): {:.0}s   (paper: ~212s)\n",
        26,
        series.full_pool_secs
    ));
    out.push_str(&format!(
        "comparison server cores to match: {}   (paper: ~38)\n",
        series
            .server_cores_to_match
            .map(|n| n.to_string())
            .unwrap_or_else(|| ">64".into())
    ));
    out
}

/// The Fig-3 qualitative checks as data (used by tests and EXPERIMENTS.md).
pub fn shape_checks(series: &Fig3Series) -> Vec<(String, bool)> {
    let mut checks = Vec::new();
    // 1. Gridlan beats the server at every sampled core count.
    checks.push((
        "gridlan outperforms server at equal cores (all samples)".into(),
        series.points.iter().all(|p| p.gridlan_secs < p.server_secs),
    ));
    // 2. Points sit on/above the ideal line (Turbo effect), tolerantly.
    let above = series
        .points
        .iter()
        .filter(|p| p.cores > 2)
        .filter(|p| p.gridlan_secs >= p.ideal_secs * 0.98)
        .count();
    let total = series.points.iter().filter(|p| p.cores > 2).count().max(1);
    checks.push((
        "multi-core points at/above ideal t1/n".into(),
        above as f64 / total as f64 > 0.9,
    ));
    // 3. Full pool lands near the paper's 212 s.
    checks.push((
        "26-core elapsed within 190..235s".into(),
        (190.0..235.0).contains(&series.full_pool_secs),
    ));
    // 4. Crossover near 38 server cores.
    checks.push((
        "server needs 34..42 cores to match".into(),
        series.server_cores_to_match.map(|n| (34..=42).contains(&n)).unwrap_or(false),
    ));
    // 5. Deviation grows with core count (heterogeneity + turbo).
    let fit = amdahl::fit_ideal(
        &series.points.iter().map(|p| (p.cores, p.gridlan_secs)).collect::<Vec<_>>(),
    );
    checks.push(("mean deviation from fitted ideal >= 0".into(), fit.mean_rel_deviation >= -0.02));
    checks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_shape_checks_pass() {
        let pool = GridlanPool::table1();
        let series = fig3_series(&pool, EpClass::D, 40, 7);
        for (name, ok) in shape_checks(&series) {
            assert!(ok, "shape check failed: {name}");
        }
    }

    #[test]
    fn series_is_deterministic() {
        let pool = GridlanPool::table1();
        let a = fig3_series(&pool, EpClass::D, 10, 3);
        let b = fig3_series(&pool, EpClass::D, 10, 3);
        assert_eq!(a.points.len(), b.points.len());
        assert_eq!(a.full_pool_secs, b.full_pool_secs);
    }

    #[test]
    fn render_mentions_headlines() {
        let pool = GridlanPool::table1();
        let s = render(&fig3_series(&pool, EpClass::D, 5, 1));
        assert!(s.contains("FIG 3"));
        assert!(s.contains("paper: ~212s"));
        assert!(s.contains("paper: ~38"));
    }

    #[test]
    fn smaller_classes_scale_down() {
        let pool = GridlanPool::table1();
        let d = fig3_series(&pool, EpClass::D, 5, 2);
        let a = fig3_series(&pool, EpClass::A, 5, 2);
        assert!(a.full_pool_secs < d.full_pool_secs / 100.0);
    }
}
