//! Table 2: "Ping from Gridlan server" — host vs node (VM) RTTs.

use crate::coordinator::gridlan::Gridlan;
use crate::util::table::{Align, Table};

/// One measured row.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub node: String,
    pub host_mean_us: f64,
    pub host_std_us: f64,
    pub node_mean_us: f64,
    pub node_std_us: f64,
}

impl Table2Row {
    pub fn overhead_us(&self) -> f64 {
        self.node_mean_us - self.host_mean_us
    }
}

/// The paper's reference values for shape checking: (node, host, vm).
pub const PAPER_TABLE2: [(&str, f64, f64); 4] = [
    ("n01", 550.0, 1250.0),
    ("n02", 660.0, 1500.0),
    ("n03", 750.0, 1650.0),
    ("n04", 610.0, 1400.0),
];

/// Run the Table-2 measurement on a booted Gridlan.
pub fn table2_rows(g: &mut Gridlan, probes: usize) -> Vec<Table2Row> {
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
    names
        .iter()
        .map(|n| {
            let host = g.ping_host(n, probes).expect("host reachable");
            let node = g.ping_node(n, probes).expect("node reachable");
            Table2Row {
                node: n.clone(),
                host_mean_us: host.mean_us(),
                host_std_us: host.std_us(),
                node_mean_us: node.mean_us(),
                node_std_us: node.std_us(),
            }
        })
        .collect()
}

/// Paper-style rendering with paper reference columns.
pub fn render(rows: &[Table2Row]) -> String {
    let mut t = Table::new(&[
        "Node",
        "Client ping (host)",
        "Node ping (VM)",
        "Overhead",
        "Paper host",
        "Paper VM",
    ])
    .title("TABLE 2 — Ping from Gridlan server (mean(std) µs)")
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    for r in rows {
        let paper = PAPER_TABLE2.iter().find(|p| p.0 == r.node);
        t.row(&[
            r.node.clone(),
            format!("{:.0}({:.0})", r.host_mean_us, r.host_std_us),
            format!("{:.0}({:.0})", r.node_mean_us, r.node_std_us),
            format!("+{:.0}", r.overhead_us()),
            paper.map(|p| format!("{:.0}", p.1)).unwrap_or_default(),
            paper.map(|p| format!("{:.0}", p.2)).unwrap_or_default(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_track_paper_within_tolerance() {
        let mut g = Gridlan::table1();
        g.boot_all(0);
        let rows = table2_rows(&mut g, 100);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            let (_, ph, pv) = *PAPER_TABLE2.iter().find(|p| p.0 == r.node).unwrap();
            assert!((r.host_mean_us - ph).abs() / ph < 0.06, "{}: {} vs {}", r.node, r.host_mean_us, ph);
            assert!((r.node_mean_us - pv).abs() / pv < 0.09, "{}: {} vs {}", r.node, r.node_mean_us, pv);
            assert!(r.host_std_us > 0.0 && r.node_std_us > 0.0);
        }
    }

    #[test]
    fn ordering_preserved() {
        // The paper's ordering facts: n03 has the slowest host ping, n01
        // the fastest; VM overhead is positive everywhere.
        let mut g = Gridlan::table1();
        g.boot_all(0);
        let rows = table2_rows(&mut g, 100);
        let host = |n: &str| rows.iter().find(|r| r.node == n).unwrap().host_mean_us;
        assert!(host("n03") > host("n02"));
        assert!(host("n02") > host("n04"));
        assert!(host("n04") > host("n01"));
        assert!(rows.iter().all(|r| r.overhead_us() > 500.0));
    }

    #[test]
    fn render_is_complete() {
        let mut g = Gridlan::table1();
        g.boot_all(0);
        let s = render(&table2_rows(&mut g, 50));
        assert!(s.contains("n01") && s.contains("TABLE 2"));
    }
}
