//! M1: the §3.3 MPI latency cross-check.
//!
//! Paper: "The results for the latency in node n01 are 1200(80) µs for the
//! MPI latency test and 550(20) µs for the [host] ping test" — i.e. the
//! MPI RTT to the *node* is consistent with the node's ICMP ping (1250(30))
//! and the host ping stays much lower.

use crate::coordinator::gridlan::Gridlan;
use crate::mpi::comm::{Communicator, RankLoc};
use crate::mpi::latency::mpi_latency_test;
use crate::util::table::{Align, Table};

/// One node's cross-check row.
#[derive(Debug, Clone)]
pub struct MpiLatRow {
    pub node: String,
    pub mpi_mean_us: f64,
    pub mpi_std_us: f64,
    pub icmp_node_mean_us: f64,
    pub icmp_host_mean_us: f64,
}

/// Measure MPI ping-pong (server rank ↔ node rank) next to the ICMP pings.
pub fn mpi_latency_rows(g: &mut Gridlan, iters: usize) -> Vec<MpiLatRow> {
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
    names
        .iter()
        .map(|n| {
            let vnet = g.client(n).unwrap().hypervisor.vnet_one_way_us;
            let comm = Communicator::new(vec![
                RankLoc::Server,
                RankLoc::Node { client: n.clone(), vnet_us: vnet },
            ]);
            let mut rng = g.rng.fork();
            let s = mpi_latency_test(&comm, &g.net, &g.hub, 0, 1, 56, iters, &mut rng)
                .expect("node reachable");
            let icmp_node = g.ping_node(n, iters).unwrap().mean_us();
            let icmp_host = g.ping_host(n, iters).unwrap().mean_us();
            MpiLatRow {
                node: n.clone(),
                mpi_mean_us: s.mean(),
                mpi_std_us: s.std(),
                icmp_node_mean_us: icmp_node,
                icmp_host_mean_us: icmp_host,
            }
        })
        .collect()
}

pub fn render(rows: &[MpiLatRow]) -> String {
    let mut t = Table::new(&["Node", "MPI 56B RTT", "ICMP node RTT", "ICMP host RTT"])
        .title("M1 — MPI latency vs ICMP ping (µs); paper: n01 MPI 1200(80) vs node ICMP 1250(30)")
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right]);
    for r in rows {
        t.row(&[
            r.node.clone(),
            format!("{:.0}({:.0})", r.mpi_mean_us, r.mpi_std_us),
            format!("{:.0}", r.icmp_node_mean_us),
            format!("{:.0}", r.icmp_host_mean_us),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpi_consistent_with_node_icmp() {
        let mut g = Gridlan::table1();
        g.boot_all(0);
        for r in mpi_latency_rows(&mut g, 100) {
            // The paper's claim: MPI RTT ~ node ICMP RTT (within ~10%),
            // both far above host ICMP.
            let ratio = r.mpi_mean_us / r.icmp_node_mean_us;
            assert!((0.85..1.15).contains(&ratio), "{}: ratio={ratio}", r.node);
            assert!(r.mpi_mean_us > 1.5 * r.icmp_host_mean_us);
        }
    }

    #[test]
    fn n01_matches_paper_numbers() {
        let mut g = Gridlan::table1();
        g.boot_all(0);
        let rows = mpi_latency_rows(&mut g, 200);
        let n01 = rows.iter().find(|r| r.node == "n01").unwrap();
        // Paper: 1200(80) µs MPI.  Allow 10%.
        assert!((n01.mpi_mean_us - 1200.0).abs() < 140.0, "mpi={}", n01.mpi_mean_us);
    }
}
