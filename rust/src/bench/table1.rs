//! Table 1: "Gridlan clients in the experiment."

use crate::config::Config;
use crate::host::client::ClientOs;
use crate::util::table::Table;

/// Rows: (node, processor, cores, client OS).
pub fn inventory_rows(cfg: &Config) -> Vec<(String, String, u32, String)> {
    cfg.clients
        .iter()
        .map(|c| {
            let os = match c.os {
                ClientOs::Linux => "GNU/Linux (Debian 8.1)".to_string(),
                ClientOs::Windows => "Windows".to_string(),
            };
            (c.name.clone(), c.cpu.name.clone(), c.cpu.cores, os)
        })
        .collect()
}

/// The paper-style rendering.
pub fn render_inventory(cfg: &Config) -> String {
    let mut t = Table::new(&["Node", "Processor", "No. of cores", "Client OS"])
        .title(&format!(
            "TABLE 1 — Gridlan clients. Total cores: {}",
            cfg.total_gridlan_cores()
        ));
    for (node, cpu, cores, os) in inventory_rows(cfg) {
        t.row(&[node, cpu, cores.to_string(), os]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_rows_reproduced() {
        let rows = inventory_rows(&Config::table1());
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].1, "Xeon E5-2630");
        assert_eq!(rows[0].2, 12);
        assert_eq!(rows[1].1, "Core i7-3930K");
        assert_eq!(rows[2].1, "Core i7-2920XM");
        assert_eq!(rows[3].1, "Core i7 960");
    }

    #[test]
    fn render_contains_all_nodes() {
        let s = render_inventory(&Config::table1());
        for n in ["n01", "n02", "n03", "n04"] {
            assert!(s.contains(n));
        }
        assert!(s.contains("26"));
    }
}
