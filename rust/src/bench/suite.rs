//! The shared bench suite: every `benches/*.rs` target is a thin wrapper
//! around a `run_<name>()` function here, so `cargo bench`, the CLI
//! (`gridlan bench <name|all>`), and the CI regression gate all execute
//! the same code.
//!
//! Each function renders the human-readable stdout report the bench has
//! always printed AND fills a [`BenchHarness`] with the *deterministic*
//! series (simulated times, model predictions, counters, EP tallies).
//! Wall-clock measurements stay on stdout only — they never enter the
//! JSON, so `BENCH_<name>.json` is byte-identical across same-seed runs
//! and safe to diff in CI.
//!
//! `GRIDLAN_BENCH_QUICK=1` (see [`harness::quick`]) shrinks only the
//! wall-clock stdout loops; every JSON-feeding computation uses fixed
//! parameters, so quick-mode output matches the committed baselines.

use crate::boot::nfs::NfsExport;
use crate::boot::pxe::{BootParams, BootPlan};
use crate::boot::tftp::{TftpServer, BLKSIZE_DEFAULT, BLKSIZE_PXE};
use crate::config::{ClientConfig, Config, SchedPolicy};
use crate::coordinator::gridlan::Gridlan;
use crate::coordinator::scenario::{run_scenario, run_trace, RecoveryPolicy, Scenario, ScenarioRun};
use crate::host::client::{ClientAgent, ClientOs};
use crate::host::faults::{FaultEvent, FaultKind, FaultPlan};
use crate::mpi::collectives::{allreduce_us, bcast_us};
use crate::mpi::comm::{Communicator, RankLoc};
use crate::mpi::latency::mpi_latency_test;
use crate::netsim::packet::Packet;
use crate::obs::harness::{self, BenchHarness};
use crate::perf::speedmodel::{ComparisonServer, GridlanPool};
use crate::rm::alloc::ResourceRequest;
use crate::rm::queue::NodePool;
use crate::rm::sched::FifoScheduler;
use crate::rm::script::PbsScript;
use crate::rm::server::PbsServer;
use crate::runtime::backend::{ComputeBackend, ScalarBackend};
use crate::runtime::engine::EpEngine;
use crate::runtime::threaded::ThreadedBackend;
use crate::sim::clock::{DUR_MS, DUR_SEC};
use crate::sim::{HeapSimulator, Simulator};
use crate::util::rng::SplitMix64;
use crate::util::table::{secs, Align, Table};
use crate::vm::cpu::CpuModel;
use crate::vm::hypervisor::{Hypervisor, HypervisorKind};
use crate::vpn::tunnel::TunnelCost;
use crate::workload::ep::{ep_scalar, EpClass, EpSlice};
use crate::workload::trace::{JobPayload, TraceGenerator, TraceJob};

/// Canonical bench names, in the order `gridlan bench all` runs them.
pub const BENCH_NAMES: [&str; 10] = [
    "boot_storm",
    "ep_throughput",
    "fault_recovery",
    "fig3_speedup",
    "mpi_latency",
    "sched_ablation",
    "sim_engine",
    "table1_inventory",
    "table2_latency",
    "vpn_overhead",
];

/// Resolve a user-facing name (including the historical CLI aliases
/// `table1`/`inventory`, `table2`, `mpi`, `fig3`) to its canonical form.
pub fn resolve(name: &str) -> Option<&'static str> {
    let canon = match name {
        "table1" | "inventory" => "table1_inventory",
        "table2" => "table2_latency",
        "mpi" => "mpi_latency",
        "fig3" => "fig3_speedup",
        other => other,
    };
    BENCH_NAMES.iter().copied().find(|n| *n == canon)
}

/// Run one bench by (possibly aliased) name; `None` if unknown.
pub fn run(name: &str) -> Option<BenchHarness> {
    Some(match resolve(name)? {
        "boot_storm" => run_boot_storm(),
        "ep_throughput" => run_ep_throughput(),
        "fault_recovery" => run_fault_recovery(),
        "fig3_speedup" => run_fig3_speedup(),
        "mpi_latency" => run_mpi_latency(),
        "sched_ablation" => run_sched_ablation(),
        "sim_engine" => run_sim_engine(),
        "table1_inventory" => run_table1_inventory(),
        "table2_latency" => run_table2_latency(),
        "vpn_overhead" => run_vpn_overhead(),
        _ => unreachable!(),
    })
}

// ---------------------------------------------------------------------
// boot_storm
// ---------------------------------------------------------------------

fn scaled_config(n: u32) -> Config {
    let mut cfg = Config::table1();
    let template = cfg.clients[0].clone();
    cfg.clients = (0..n)
        .map(|i| {
            let mut c = template.clone();
            c.name = format!("n{:02}", i + 1);
            c.cpu = CpuModel::i7_960();
            c.os = if i % 2 == 0 { ClientOs::Linux } else { ClientOs::Windows };
            c.switch_hops = 2 + (i % 3);
            c
        })
        .collect();
    cfg
}

/// Bench A3: boot-storm scaling — node count and TFTP block size vs
/// PXE/nfsroot boot time.  Everything here is simulated time, so the
/// whole report feeds the JSON.
pub fn run_boot_storm() -> BenchHarness {
    let cfg = Config::table1();
    let mut h = BenchHarness::new("boot_storm", cfg.seed);
    h.param_str("fleet_sizes", "1,4,8,16,32,64");
    h.param_u64("storm100k_nodes", 100_000);
    h.param_u64("blksize_default", BLKSIZE_DEFAULT as u64);
    h.param_u64("blksize_pxe", BLKSIZE_PXE as u64);

    // Per-node boot decomposition on the paper's testbed.
    let mut g = Gridlan::table1();
    println!("per-node boot plans (paper testbed):");
    for name in ["n01", "n02", "n03", "n04"] {
        g.connect_client(name).unwrap();
        let plan = g.boot_plan(name);
        print!("  {name}: total {:>8}  ", secs(plan.total() as f64 / 1e9));
        for (state, dur) in &plan.phases {
            if *dur > 0 {
                print!("{state:?}={} ", secs(*dur as f64 / 1e9));
            }
        }
        println!();
        h.sample(&format!("boot_{name}"), "s", plan.total() as f64 / 1e9);
    }

    // Scaling the fleet: slowest boot vs node count.
    println!("\nboot storm: fleet size vs slowest boot:");
    let mut t = Table::new(&["nodes", "slowest boot", "mean boot"])
        .align(&[Align::Right, Align::Right, Align::Right]);
    for n in [1u32, 4, 8, 16, 32, 64] {
        let mut g = Gridlan::build(scaled_config(n));
        let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
        let mut total = 0u64;
        let mut slowest = 0u64;
        for name in &names {
            g.connect_client(name).unwrap();
            let p = g.boot_plan(name).total();
            total += p;
            slowest = slowest.max(p);
        }
        t.row(&[n.to_string(), secs(slowest as f64 / 1e9), secs(total as f64 / n as f64 / 1e9)]);
        h.sample(&format!("fleet_slowest_{n}"), "s", slowest as f64 / 1e9);
        h.sample(&format!("fleet_mean_{n}"), "s", total as f64 / n as f64 / 1e9);
    }
    print!("{}", t.render());

    // 100k-node storm, analytic: per-node plans straight through
    // `BootPlan::compute` (the same arithmetic the scenario runner uses),
    // skipping the full grid build.  Deterministic, so it runs — and feeds
    // the JSON — in quick mode too.
    {
        let nfs = NfsExport::debian();
        let tftp = TftpServer::new(BLKSIZE_PXE);
        let n: u32 = 100_000;
        let t0 = std::time::Instant::now();
        let mut slowest = 0u64;
        let mut total = 0u64;
        for i in 0..n {
            let hv = match i % 3 {
                0 => HypervisorKind::QemuKvm,
                1 => HypervisorKind::VirtualBox,
                _ => HypervisorKind::PureQemu,
            };
            let params = BootParams {
                one_way_us: 500.0 + 25.0 * (i % 8) as f64,
                us_per_byte: 0.008,
                kernel_init_ms: 2500.0 + 100.0 * (i % 5) as f64,
            };
            let p = BootPlan::compute(&Hypervisor::new(hv), &tftp, &nfs, &params).total();
            slowest = slowest.max(p);
            total += p;
        }
        println!(
            "\n100k-node analytic storm: slowest {}  mean {}  ({:.0} ms wall)",
            secs(slowest as f64 / 1e9),
            secs(total as f64 / n as f64 / 1e9),
            t0.elapsed().as_secs_f64() * 1e3
        );
        h.sample("storm100k_slowest", "s", slowest as f64 / 1e9);
        h.sample("storm100k_mean", "s", total as f64 / n as f64 / 1e9);
    }

    // Ablation: TFTP block size x hypervisor kernel-init penalty.
    println!("\nTFTP blksize x hypervisor ablation (n01-like node, 700 µs one-way):");
    let nfs = NfsExport::debian();
    let params = BootParams { one_way_us: 700.0, us_per_byte: 0.008, kernel_init_ms: 2800.0 };
    for blk in [BLKSIZE_DEFAULT, BLKSIZE_PXE] {
        for hv in [HypervisorKind::QemuKvm, HypervisorKind::VirtualBox, HypervisorKind::PureQemu] {
            let plan =
                BootPlan::compute(&Hypervisor::new(hv), &TftpServer::new(blk), &nfs, &params);
            println!("  blksize {blk:>5}, {hv:?}: {}", secs(plan.total() as f64 / 1e9));
            h.sample(&format!("pxe_{blk}_{hv:?}"), "s", plan.total() as f64 / 1e9);
        }
    }
    h
}

// ---------------------------------------------------------------------
// ep_throughput
// ---------------------------------------------------------------------

/// EP pairs used for the deterministic tally invariants in the JSON.
/// Fixed regardless of quick mode — the JSON must not depend on it.
const EP_VERIFY_PAIRS: u64 = 1 << 16;

fn measure(backend: &mut dyn ComputeBackend, label: &str, total: u64, base: Option<f64>) -> f64 {
    backend.run_pairs(0, 1 << 16).unwrap(); // warm-up (spawn paths, caches)
    let t0 = std::time::Instant::now();
    backend.run_pairs(0, total).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    let rate = total as f64 / dt / 1e6;
    let speedup = base.map(|b| format!(" {:>8.2}x", rate / b.max(1e-9))).unwrap_or_default();
    println!("{label:>12} {total:>14} {:>12.1} {rate:>14.1}{speedup}", dt * 1e3);
    rate
}

/// Runtime perf bench: EP throughput through the `ComputeBackend` trait.
/// Wall-clock rates stay on stdout; the JSON carries the bit-exact tally
/// invariants every backend geometry must reproduce.
pub fn run_ep_throughput() -> BenchHarness {
    let mut h = BenchHarness::new("ep_throughput", 0);
    h.param_u64("verify_pairs", EP_VERIFY_PAIRS);
    h.param_str("chunks", "1024,16384,1048576");
    h.param_str("threads", "1,2,4,8");

    // 4M pairs per wall-clock measurement; quick mode shrinks it.
    let total: u64 = harness::pick(1 << 22, 1 << 18);
    if harness::quick() {
        println!("(quick mode: {total} pairs per wall-clock measurement)");
    }

    // Backend selection report (the `--features pjrt` story).
    let mut auto = EpEngine::auto();
    if let Some(note) = auto.fallback_note.take() {
        println!("note: {note}");
    }
    println!("active backend: {}\n", auto.backend_name());

    println!("{:>12} {:>14} {:>12} {:>14}", "chunk", "pairs", "wall ms", "Mpairs/s");
    let mut scalar_rate = 0.0f64;
    for chunk in [1u64 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18, 1 << 20] {
        let mut b = ScalarBackend::with_chunk(chunk);
        let r = measure(&mut b, &format!("scalar/{chunk}"), total, None);
        if chunk == 1 << 16 {
            scalar_rate = r;
        }
    }

    println!(
        "\n{:>12} {:>14} {:>12} {:>14} {:>9}   ({} hw threads, speedup vs scalar/65536)",
        "threads",
        "pairs",
        "wall ms",
        "Mpairs/s",
        "speedup",
        ThreadedBackend::available()
    );
    for threads in [1usize, 2, 4, 8] {
        let mut b = ThreadedBackend::new(threads);
        measure(&mut b, &format!("threaded/{threads}"), total, Some(scalar_rate));
    }

    // The auto-selected engine end-to-end (what `gridlan ep` uses).
    let t0 = std::time::Instant::now();
    auto.run_pairs(0, total).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\nauto engine ({}): {:.1} Mpairs/s over {} pairs",
        auto.backend_name(),
        total as f64 / dt / 1e6,
        total
    );

    // Deterministic tally invariants (these feed the JSON): the raw
    // oracle over a fixed range, and bit-exactness of every chunk / thread
    // geometry against it.
    let oracle = ep_scalar(0, EP_VERIFY_PAIRS);
    println!("\ntally nacc={} sx={:.6e} over {EP_VERIFY_PAIRS} pairs", oracle.nacc, oracle.sx);
    h.sample("oracle_nacc", "count", oracle.nacc as f64);
    h.sample("oracle_sx", "sum", oracle.sx);
    h.sample("oracle_sy", "sum", oracle.sy);
    h.sample("acceptance_rate", "frac", oracle.nacc as f64 / EP_VERIFY_PAIRS as f64);
    for chunk in [1u64 << 10, 1 << 14, 1 << 20] {
        let t = ScalarBackend::with_chunk(chunk).run_pairs(0, EP_VERIFY_PAIRS).unwrap();
        h.sample("chunk_nacc", "count", t.nacc as f64);
    }
    for threads in [1usize, 2, 4, 8] {
        let t = ThreadedBackend::new(threads).run_pairs(0, EP_VERIFY_PAIRS).unwrap();
        h.sample("thread_nacc", "count", t.nacc as f64);
    }
    println!(
        "(trait dispatch + chunk merging should cost <2% vs the raw oracle \
         at the default 64Ki chunk; threaded/4 should clear 1.5x scalar.)"
    );
    h
}

// ---------------------------------------------------------------------
// fault_recovery
// ---------------------------------------------------------------------

fn fault_trace() -> Vec<TraceJob> {
    (0..24)
        .map(|i| TraceJob {
            at: i as u64 * 120 * DUR_SEC,
            owner: format!("u{}", i % 4),
            request: ResourceRequest { nodes: 1, ppn: 1 + (i % 4) as u32 },
            compute: (300 + 120 * (i % 4) as u64) * DUR_SEC,
            walltime: 3600 * DUR_SEC,
            payload: JobPayload::Synthetic,
        })
        .collect()
}

/// One partial-range recovery run: Table-1 grid, a single EP job at
/// t=1000s, every client VM-crashed `crash_ms` after the start instant.
/// Everything measured is simulated time — fully deterministic.
fn ep_crash_run(count: u64, crash_ms: u64, salvage: bool) -> ScenarioRun {
    let mut g = Gridlan::build(Config::table1());
    g.boot_all(0);
    let at = 1000 * DUR_SEC;
    let trace =
        vec![EpSlice { proc: 0, pair_offset: 0, pair_count: count }.trace_job(at, 3600 * DUR_SEC)];
    let scripted: Vec<FaultEvent> = ["n01", "n02", "n03", "n04"]
        .iter()
        .map(|n| FaultEvent {
            at: at + crash_ms * DUR_MS,
            client: n.to_string(),
            kind: FaultKind::VmCrash,
            outage: 60 * DUR_SEC,
        })
        .collect();
    let scenario = Scenario {
        horizon: 2 * 3600 * DUR_SEC,
        scripted_faults: scripted,
        recovery: RecoveryPolicy { salvage, ..Default::default() },
        ..Default::default()
    };
    run_scenario(g, trace, &scenario, EpEngine::scalar())
}

/// A two-node grid with a 20x-slow single-core straggler: flat clocks so
/// every rate is exact, one slice lands on the slow core, and the steal
/// window is wide.  Mirrors the lifecycle-test fixture.
fn straggler_grid() -> Config {
    let mk = |name: &str, cores: u32, ppc: f64| ClientConfig {
        name: name.into(),
        os: ClientOs::Linux,
        cpu: CpuModel {
            name: format!("flat-{name}"),
            cores,
            base_ghz: 3.0,
            max_turbo_ghz: 3.0,
            all_core_ghz: 3.0,
            pairs_per_cycle: ppc,
        },
        hypervisor: None,
        switch_hops: 2,
        stack_us: 120.0,
        link_mbps: 1000.0,
    };
    let mut cfg = Config::table1();
    cfg.clients = vec![mk("fast", 4, 0.004), mk("slow", 1, 0.00002)];
    cfg
}

fn straggler_flood(steal: bool) -> ScenarioRun {
    let mut g = Gridlan::build(straggler_grid());
    g.boot_all(0);
    let trace: Vec<TraceJob> = (0..5)
        .map(|i| {
            EpSlice { proc: i, pair_offset: i as u64 * 200_000, pair_count: 200_000 }
                .trace_job(0, 3600 * DUR_SEC)
        })
        .collect();
    let scenario = Scenario {
        horizon: 3600 * DUR_SEC,
        recovery: RecoveryPolicy { steal, ..Default::default() },
        ..Default::default()
    };
    run_scenario(g, trace, &scenario, EpEngine::scalar())
}

/// Bench X1: goodput and completion under increasing fault pressure,
/// plus the partial-range recovery and range-stealing series (DESIGN.md
/// §11): wasted/salvaged pairs and recovery makespan, naive vs
/// checkpointed, and the heterogeneous straggler flood with and without
/// work stealing.
pub fn run_fault_recovery() -> BenchHarness {
    let cfg = Config::table1();
    let mut h = BenchHarness::new("fault_recovery", cfg.seed);
    h.param_str("fault_scales", "0,1,2,4,8,16,32");
    h.param_u64("jobs", 24);
    h.param_u64("horizon_hours", 8);
    h.param_u64("ep_crash_pairs", 2_000_000);
    h.param_str("ep_crash_ms", "360,400,440");
    h.param_u64("straggler_slices", 5);
    h.param_u64("straggler_pairs_per_slice", 200_000);

    let mut t = Table::new(&[
        "fault scale",
        "faults",
        "requeues",
        "wd restarts",
        "completed",
        "goodput",
        "makespan",
    ])
    .title("X1 — resilience under fault pressure (24 jobs, 8h horizon)")
    .align(&[
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for scale in [0u32, 1, 2, 4, 8, 16, 32] {
        let faults = if scale > 0 {
            FaultPlan::lab_default().scaled(scale as f64)
        } else {
            FaultPlan::none()
        };
        let scenario = Scenario { horizon: 8 * 3600 * DUR_SEC, faults, ..Default::default() };
        let report = run_trace(Gridlan::build(Config::table1()), fault_trace(), &scenario);
        let m = report.metrics;
        t.row(&[
            format!("{scale}x"),
            m.faults.to_string(),
            m.jobs_requeued.to_string(),
            m.watchdog_restarts.to_string(),
            format!("{}/24", m.jobs_completed),
            format!("{:.1}%", 100.0 * m.goodput()),
            secs(m.makespan as f64 / 1e9),
        ]);
        h.sample(&format!("faults_x{scale}"), "count", m.faults as f64);
        h.sample(&format!("requeues_x{scale}"), "count", m.jobs_requeued as f64);
        h.sample(&format!("completed_x{scale}"), "count", m.jobs_completed as f64);
        h.sample(&format!("goodput_x{scale}"), "frac", m.goodput());
        h.sample(&format!("makespan_x{scale}"), "s", m.makespan as f64 / 1e9);
    }
    print!("{}", t.render());
    println!("\nexpected shape: goodput decays and makespan stretches with fault scale,");
    println!("but completion stays 24/24 — the §4 script-folder + watchdog loop holds.");

    // X1b — partial-range recovery: one 2M-pair EP job, all clients
    // crashed mid-compute, naive re-execution vs sub-span salvage at the
    // default checkpoint interval.  Waste = executed - logical pairs.
    let count: u64 = 2_000_000;
    let mut t = Table::new(&[
        "crash at",
        "mode",
        "checkpoints",
        "salvaged",
        "wasted",
        "recovery makespan",
    ])
    .title("X1b — partial-range EP recovery (2M pairs, all-node crash)")
    .align(&[Align::Right, Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    println!();
    for crash_ms in [360u64, 400, 440] {
        let mut naive_wasted = 0u64;
        let mut salv_wasted = 0u64;
        for (mode, salvage) in [("naive", false), ("salvage", true)] {
            let run = ep_crash_run(count, crash_ms, salvage);
            let m = &run.report.metrics;
            let wasted = m.ep_pairs_executed.saturating_sub(run.report.ep_total().pairs);
            if salvage {
                salv_wasted = wasted;
            } else {
                naive_wasted = wasted;
            }
            t.row(&[
                format!("{crash_ms} ms"),
                mode.to_string(),
                m.ep_checkpoints.to_string(),
                m.ep_pairs_salvaged.to_string(),
                wasted.to_string(),
                secs(m.makespan as f64 / 1e9),
            ]);
            h.sample(&format!("{mode}_wasted_{crash_ms}ms"), "pairs", wasted as f64);
            h.sample(
                &format!("{mode}_salvaged_{crash_ms}ms"),
                "pairs",
                m.ep_pairs_salvaged as f64,
            );
            h.sample(&format!("{mode}_makespan_{crash_ms}ms"), "s", m.makespan as f64 / 1e9);
        }
        let reduction = if naive_wasted > 0 {
            1.0 - salv_wasted as f64 / naive_wasted as f64
        } else {
            0.0
        };
        h.sample(&format!("waste_reduction_{crash_ms}ms"), "frac", reduction);
        println!(
            "  crash +{crash_ms} ms: wasted pairs {naive_wasted} (naive) -> {salv_wasted} \
             (salvage) = {:.0}% reduction",
            100.0 * reduction
        );
    }
    print!("{}", t.render());
    println!("expected shape: salvage banks every completed sub-span, so its waste is 0");
    println!("and the requeued attempt carries only the remainder of the range.");

    // X1c — straggler range stealing on the heterogeneous flat-clock
    // grid: the slice stranded on the 20x-slow core is split and its
    // tail re-queued onto an idle fast core.
    println!("\nX1c — straggler work stealing (5 x 200k pairs, 20x-slow straggler):");
    let base = straggler_flood(false);
    let stolen = straggler_flood(true);
    let bm = &base.report.metrics;
    let sm = &stolen.report.metrics;
    let speedup = bm.makespan as f64 / sm.makespan.max(1) as f64;
    for (label, key, run) in
        [("steal off", "steal_off", &base), ("steal on", "steal_on", &stolen)]
    {
        let m = &run.report.metrics;
        let wasted = m.ep_pairs_executed.saturating_sub(run.report.ep_total().pairs);
        println!(
            "  {label:<9} makespan {}  steals {}  completed {}  wasted {wasted}",
            secs(m.makespan as f64 / 1e9),
            m.ep_steals,
            m.jobs_completed
        );
        h.sample(&format!("{key}_makespan"), "s", m.makespan as f64 / 1e9);
        h.sample(&format!("{key}_steals"), "count", m.ep_steals as f64);
        h.sample(&format!("{key}_wasted"), "pairs", wasted as f64);
    }
    h.sample("steal_speedup", "ratio", speedup);
    println!(
        "  speedup {speedup:.2}x; lineage {:?}",
        stolen.report.steal_lineage
    );
    println!("expected shape: stealing splits the straggler's remaining span, every pair");
    println!("still executes exactly once, and the flood makespan drops.");
    h
}

// ---------------------------------------------------------------------
// fig3_speedup
// ---------------------------------------------------------------------

/// Bench F3: the paper's Fig. 3 (NPB-EP class D speed-up).  The whole
/// figure is a deterministic model evaluation, so it all feeds the JSON.
pub fn run_fig3_speedup() -> BenchHarness {
    let mut h = BenchHarness::new("fig3_speedup", 42);
    h.param_str("class", "D");
    h.param_u64("runs", 60);
    h.param_u64("curve_seed", 7);
    h.param_u64("curve_draws", 200);

    let pool = GridlanPool::table1();
    let t0 = std::time::Instant::now();
    let series = super::fig3::fig3_series(&pool, EpClass::D, 60, 42);
    print!("{}", super::fig3::render(&series));
    let mut checks_passed = 0u64;
    for (name, ok) in super::fig3::shape_checks(&series) {
        println!("  [{}] {name}", if ok { "ok" } else { "FAIL" });
        if ok {
            checks_passed += 1;
        }
    }
    h.sample("t1", "s", series.t1_secs);
    h.sample("full_pool", "s", series.full_pool_secs);
    let to_match = series.server_cores_to_match.unwrap_or(0) as f64;
    h.sample("server_cores_to_match", "count", to_match);
    h.sample("shape_checks_passed", "count", checks_passed as f64);
    for p in &series.points {
        h.sample("dev_vs_ideal", "frac", (p.gridlan_secs - p.ideal_secs) / p.ideal_secs);
    }

    // The deterministic full curve: Gridlan best/worst placement band.
    println!("\ndeterministic curve (best placement over 200 draws per n):");
    println!("{:>5} {:>12} {:>12} {:>12}", "cores", "gridlan best", "gridlan worst", "server");
    let server = ComparisonServer::opteron();
    let mut rng = SplitMix64::new(7);
    for n in [1u32, 2, 4, 8, 13, 20, 26] {
        let mut best = f64::INFINITY;
        let mut worst = 0.0f64;
        for _ in 0..200 {
            let t = pool.elapsed_secs(EpClass::D.pairs(), &pool.random_placement(n, &mut rng));
            best = best.min(t);
            worst = worst.max(t);
        }
        let s = server.elapsed_secs(EpClass::D.pairs(), n);
        println!("{n:>5} {best:>11.1}s {worst:>11.1}s {s:>11.1}s");
        h.sample(&format!("curve_best_n{n}"), "s", best);
        h.sample(&format!("curve_worst_n{n}"), "s", worst);
    }
    println!("\nwall time: {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    h
}

// ---------------------------------------------------------------------
// mpi_latency
// ---------------------------------------------------------------------

/// Bench M1: the §3.3 MPI-vs-ICMP latency cross-check, plus message-size
/// and collective scaling.  All simulated time — fully deterministic.
pub fn run_mpi_latency() -> BenchHarness {
    let mut h = BenchHarness::new("mpi_latency", 5);
    h.param_u64("iters", 500);
    h.param_u64("sweep_iters", 50);

    let mut g = Gridlan::table1();
    g.boot_all(0);

    let rows = super::mpilat::mpi_latency_rows(&mut g, 500);
    print!("{}", super::mpilat::render(&rows));
    for r in &rows {
        h.sample(&format!("mpi_rtt_{}", r.node), "µs", r.mpi_mean_us);
        h.sample(&format!("icmp_node_{}", r.node), "µs", r.icmp_node_mean_us);
    }

    // Message-size sweep (node<->node through the hub).
    let node = |c: &str| RankLoc::Node {
        client: c.into(),
        vnet_us: g.client(c).unwrap().hypervisor.vnet_one_way_us,
    };
    let ranks = vec![RankLoc::Server, node("n01"), node("n02"), node("n03"), node("n04")];
    let comm = Communicator::new(ranks);
    println!("\nping-pong RTT vs message size (µs):");
    println!("{:>10} {:>14} {:>14}", "bytes", "server<->n01", "n01<->n02");
    let mut rng = SplitMix64::new(5);
    for bytes in [56u32, 1_024, 16_384, 262_144, 1_048_576] {
        let s2n = mpi_latency_test(&comm, &g.net, &g.hub, 0, 1, bytes, 50, &mut rng).unwrap();
        let n2n = mpi_latency_test(&comm, &g.net, &g.hub, 1, 2, bytes, 50, &mut rng).unwrap();
        println!("{bytes:>10} {:>13.0} {:>13.0}", s2n.mean(), n2n.mean());
        h.series(&format!("s2n_{bytes}b"), "µs", s2n);
        h.series(&format!("n2n_{bytes}b"), "µs", n2n);
    }

    // Collectives over the hub star.
    println!("\ncollectives over 5 ranks (µs):");
    for bytes in [56u32, 65_536] {
        let b = bcast_us(&comm, &g.net, &g.hub, 0, bytes, &mut rng).unwrap();
        let ar = allreduce_us(&comm, &g.net, &g.hub, bytes, &mut rng).unwrap();
        println!("  {bytes:>7} B: bcast {b:>8.0}   allreduce {ar:>8.0}");
        h.sample(&format!("bcast_{bytes}b"), "µs", b);
        h.sample(&format!("allreduce_{bytes}b"), "µs", ar);
    }
    h
}

// ---------------------------------------------------------------------
// sched_ablation
// ---------------------------------------------------------------------

fn policy_label(policy: SchedPolicy) -> &'static str {
    match policy {
        SchedPolicy::Fifo => "fifo",
        SchedPolicy::Backfill => "backfill",
    }
}

/// Bench A1: scheduler ablation — FIFO vs EASY backfill on the synthetic
/// lab trace, clean and under faults.
pub fn run_sched_ablation() -> BenchHarness {
    let mut h = BenchHarness::new("sched_ablation", 1234);
    h.param_str("policies", "fifo,backfill");
    h.param_str("fault_combos", "clean,labx4");
    h.param_u64("drain100k_nodes", 100_000);
    h.param_u64("drain100k_jobs", 100_000);

    let gen = TraceGenerator::lab_day();
    let mut t = Table::new(&[
        "scheduler",
        "faults",
        "completed",
        "mean wait",
        "makespan",
        "goodput",
        "sim events",
        "wall ms",
    ])
    .title("A1 — FIFO vs backfill on the lab-day trace")
    .align(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);

    for (flabel, fkey, fscale) in [("none", "clean", 0.0), ("lab x4", "labx4", 4.0)] {
        for policy in [SchedPolicy::Fifo, SchedPolicy::Backfill] {
            let mut cfg = Config::table1();
            cfg.sched = policy;
            // Same trace for both policies: same generator seed.
            let mut rng = SplitMix64::new(1234);
            let trace = gen.generate(&mut rng);
            let n = trace.len() as u64;
            let faults = if fscale > 0.0 {
                FaultPlan::lab_default().scaled(fscale)
            } else {
                FaultPlan::none()
            };
            let scenario = Scenario { horizon: gen.horizon * 4, faults, ..Default::default() };
            let w0 = std::time::Instant::now();
            let report = run_trace(Gridlan::build(cfg), trace, &scenario);
            let m = report.metrics;
            t.row(&[
                format!("{policy:?}"),
                flabel.to_string(),
                format!("{}/{n}", m.jobs_completed),
                secs(m.mean_wait_secs()),
                secs(m.makespan as f64 / 1e9),
                format!("{:.1}%", 100.0 * m.goodput()),
                report.events_executed.to_string(),
                format!("{:.0}", w0.elapsed().as_secs_f64() * 1e3),
            ]);
            let key = format!("{}_{fkey}", policy_label(policy));
            h.sample(&format!("{key}_completed"), "count", m.jobs_completed as f64);
            h.sample(&format!("{key}_mean_wait"), "s", m.mean_wait_secs());
            h.sample(&format!("{key}_makespan"), "s", m.makespan as f64 / 1e9);
            h.sample(&format!("{key}_goodput"), "frac", m.goodput());
            h.sample(&format!("{key}_events"), "count", report.events_executed as f64);
        }
    }
    print!("{}", t.render());
    println!(
        "\nexpected shape: backfill lowers mean wait on mixed traces; both complete everything."
    );

    // Wide-vs-narrow starvation microbenchmark.
    println!("\nhead-of-line case (1 wide job then 12 narrow):");
    for policy in [SchedPolicy::Fifo, SchedPolicy::Backfill] {
        let mut cfg = Config::table1();
        cfg.sched = policy;
        let mut trace = vec![TraceJob {
            at: 0,
            owner: "big".into(),
            request: ResourceRequest { nodes: 3, ppn: 6 },
            compute: 1800 * DUR_SEC,
            walltime: 3600 * DUR_SEC,
            payload: JobPayload::Synthetic,
        }];
        for i in 0..12 {
            trace.push(TraceJob {
                at: 10 * DUR_SEC,
                owner: format!("small{i}"),
                request: ResourceRequest { nodes: 1, ppn: 1 },
                compute: 120 * DUR_SEC,
                walltime: 240 * DUR_SEC,
                payload: JobPayload::Synthetic,
            });
        }
        let scenario = Scenario { horizon: 6 * 3600 * DUR_SEC, ..Default::default() };
        let report = run_trace(Gridlan::build(cfg), trace, &scenario);
        println!(
            "  {policy:?}: mean wait {}, makespan {}",
            secs(report.metrics.mean_wait_secs()),
            secs(report.metrics.makespan as f64 / 1e9)
        );
        let key = format!("hol_{}", policy_label(policy));
        h.sample(&format!("{key}_mean_wait"), "s", report.metrics.mean_wait_secs());
        h.sample(&format!("{key}_makespan"), "s", report.metrics.makespan as f64 / 1e9);
    }

    // 100k-node / 100k-job drain through the indexed hot path.  Fixed
    // size in every mode (the cycle/start counters feed the JSON); only
    // the wall-clock report stays on stdout.  DESIGN.md §7 target:
    // sub-100 µs per scheduling decision at this scale.
    {
        let nodes: u32 = 100_000;
        let jobs: usize = 100_000;
        let mut s = PbsServer::new();
        for i in 0..nodes {
            let name = format!("n{i:06}");
            s.register_node(&name, 8, NodePool::Gridlan);
            s.node_up(&name);
        }
        let script =
            PbsScript::parse("#PBS -q gridlan\n#PBS -l nodes=1:ppn=8,walltime=00:10:00\n./x\n")
                .unwrap();
        for i in 0..jobs {
            s.qsub(&script, "u", "", i as u64).unwrap();
        }
        let t0 = std::time::Instant::now();
        let mut cycles = 0u64;
        let mut started = 0u64;
        loop {
            let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1_000_000);
            if d.is_empty() {
                break;
            }
            cycles += 1;
            started += d.len() as u64;
            for (id, _) in d {
                s.complete(id, 0, 2_000_000);
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "\n100k-node drain: {started} jobs over {cycles} cycle(s) in {:.0} ms \
             ({:.1} µs/job, target <100 µs)",
            dt * 1e3,
            dt * 1e6 / started.max(1) as f64
        );
        h.sample("drain100k_cycles", "count", cycles as f64);
        h.sample("drain100k_started", "count", started as f64);
    }
    h
}

// ---------------------------------------------------------------------
// sim_engine
// ---------------------------------------------------------------------

struct ChainWorld {
    count: u64,
    limit: u64,
}

fn chain_tick(s: &mut Simulator<ChainWorld>, w: &mut ChainWorld) {
    w.count += 1;
    if w.count < w.limit {
        s.schedule_in(1_000, chain_tick);
    }
}

fn run_chains(chains: usize, limit: u64) -> u64 {
    let mut sim = Simulator::new();
    let mut w = ChainWorld { count: 0, limit };
    for _ in 0..chains {
        sim.schedule_at(0, chain_tick);
    }
    sim.run_to_completion(&mut w);
    sim.executed()
}

fn heap_chain_tick(s: &mut HeapSimulator<ChainWorld>, w: &mut ChainWorld) {
    w.count += 1;
    if w.count < w.limit {
        s.schedule_in(1_000, heap_chain_tick);
    }
}

fn run_chains_heap(chains: usize, limit: u64) -> u64 {
    let mut sim = HeapSimulator::new();
    let mut w = ChainWorld { count: 0, limit };
    for _ in 0..chains {
        sim.schedule_at(0, heap_chain_tick);
    }
    sim.run_to_completion(&mut w);
    sim.executed()
}

/// One operation of the deterministic mixed storm both engines replay.
enum StormOp {
    /// Schedule an event `delay` ns out, tagged `key`.
    Schedule { delay: u64, key: u64 },
    /// Cancel the `nth % live` previously issued event id.
    Cancel { nth: usize },
    /// `run_until(now + dt)`.
    Advance { dt: u64 },
}

fn storm_ops(n: usize) -> Vec<StormOp> {
    let mut rng = SplitMix64::new(9);
    let mut ops = Vec::with_capacity(n);
    for k in 0..n as u64 {
        match rng.next_u64() % 10 {
            0..=5 => {
                // Mostly near-future; every ~64th lands past the 2^48 ns
                // wheel horizon to exercise the overflow level.
                let delay = if rng.next_u64() % 64 == 0 {
                    1u64 << 49
                } else {
                    rng.next_u64() % 10_000_000
                };
                ops.push(StormOp::Schedule { delay, key: k });
            }
            6 | 7 => ops.push(StormOp::Cancel { nth: rng.next_u64() as usize }),
            _ => ops.push(StormOp::Advance { dt: rng.next_u64() % 5_000_000 }),
        }
    }
    ops
}

/// (executed, final now, firing trace) of the storm on the wheel engine.
fn storm_wheel(ops: &[StormOp]) -> (u64, u64, Vec<u64>) {
    let mut sim: Simulator<Vec<u64>> = Simulator::new();
    let mut fired: Vec<u64> = Vec::new();
    let mut ids = Vec::new();
    for op in ops {
        match *op {
            StormOp::Schedule { delay, key } => {
                ids.push(sim.schedule_in(delay, move |_s, w: &mut Vec<u64>| w.push(key)));
            }
            StormOp::Cancel { nth } => {
                if !ids.is_empty() {
                    let id = ids[nth % ids.len()];
                    sim.cancel(id);
                }
            }
            StormOp::Advance { dt } => {
                let until = sim.now().saturating_add(dt);
                sim.run_until(&mut fired, until);
            }
        }
    }
    sim.run_to_completion(&mut fired);
    (sim.executed(), sim.now(), fired)
}

/// The same storm on the retired `BinaryHeap` baseline.
fn storm_heap(ops: &[StormOp]) -> (u64, u64, Vec<u64>) {
    let mut sim: HeapSimulator<Vec<u64>> = HeapSimulator::new();
    let mut fired: Vec<u64> = Vec::new();
    let mut ids = Vec::new();
    for op in ops {
        match *op {
            StormOp::Schedule { delay, key } => {
                ids.push(sim.schedule_in(delay, move |_s, w: &mut Vec<u64>| w.push(key)));
            }
            StormOp::Cancel { nth } => {
                if !ids.is_empty() {
                    let id = ids[nth % ids.len()];
                    sim.cancel(id);
                }
            }
            StormOp::Advance { dt } => {
                let until = sim.now().saturating_add(dt);
                sim.run_until(&mut fired, until);
            }
        }
    }
    sim.run_to_completion(&mut fired);
    (sim.executed(), sim.now(), fired)
}

/// L3 perf bench: the discrete-event core and the scheduler hot path.
/// Wall-clock rates stay on stdout; the JSON carries the deterministic
/// event/cycle counters and the simulated ping RTT.
pub fn run_sim_engine() -> BenchHarness {
    let cfg = Config::table1();
    let mut h = BenchHarness::new("sim_engine", cfg.seed);
    h.param_str("drain_depths", "1,10,100,1000");
    h.param_u64("verify_chain_limit", 100_000);
    h.param_u64("verify_chains", 8);
    h.param_u64("ping_probes", 200);
    h.param_u64("storm_ops", 4_000);
    h.param_u64("deep_backlog", 100_000);

    // Self-rescheduling event chains: pure engine overhead (wall clock),
    // timing wheel vs the retired BinaryHeap core on the same workload.
    let n: u64 = harness::pick(2_000_000, 200_000);
    let mut sim = Simulator::new();
    let mut w = ChainWorld { count: 0, limit: n };
    for _ in 0..64 {
        sim.schedule_at(0, chain_tick);
    }
    let t0 = std::time::Instant::now();
    sim.run_to_completion(&mut w);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "event engine (wheel): {} events in {:.3}s = {:.2}M events/s  (target: >=10M/s)",
        sim.executed(),
        dt,
        sim.executed() as f64 / dt / 1e6
    );
    let mut hsim = HeapSimulator::new();
    let mut hwld = ChainWorld { count: 0, limit: n };
    for _ in 0..64 {
        hsim.schedule_at(0, heap_chain_tick);
    }
    let t1 = std::time::Instant::now();
    hsim.run_to_completion(&mut hwld);
    let hdt = t1.elapsed().as_secs_f64();
    println!(
        "heap baseline:        {} events in {:.3}s = {:.2}M events/s  (wheel speedup {:.2}x)",
        hsim.executed(),
        hdt,
        hsim.executed() as f64 / hdt / 1e6,
        hdt / dt.max(1e-12)
    );
    // Fixed-size runs for the JSON (independent of quick mode): both
    // engines must execute the identical count.
    h.sample("engine_events", "count", run_chains(8, 100_000) as f64);
    h.sample("heap_engine_events", "count", run_chains_heap(8, 100_000) as f64);

    // Mixed schedule/cancel/advance storm replayed on both engines.  The
    // firing traces and clock trajectories must be identical — the JSON
    // records the count and a divergence flag that must stay 0.
    let ops = storm_ops(4_000);
    let (we, wnow, wtrace) = storm_wheel(&ops);
    let (he, hnow, htrace) = storm_heap(&ops);
    let diverged = if we == he && wnow == hnow && wtrace == htrace { 0.0 } else { 1.0 };
    println!(
        "storm parity: {we} events to t={wnow} ns; heap-vs-wheel divergence: {}",
        if diverged == 0.0 { "none" } else { "MISMATCH" }
    );
    h.sample("storm_events", "count", we as f64);
    h.sample("storm_final_time", "ns", wnow as f64);
    h.sample("storm_divergence", "count", diverged);

    // Scheduling latency against a deep backlog: 100k pending events,
    // then timed schedule+cancel churn (wall clock only; the pending
    // count after the churn feeds the JSON).
    {
        let mut sim: Simulator<Vec<u64>> = Simulator::new();
        let mut rng = SplitMix64::new(11);
        let mut ids = Vec::with_capacity(100_000);
        for _ in 0..100_000u32 {
            ids.push(sim.schedule_in(rng.next_u64() % (3_600 * DUR_SEC), |_s, _w| {}));
        }
        let churn: usize = harness::pick(100_000, 10_000);
        let t0 = std::time::Instant::now();
        for i in 0..churn {
            let id = sim.schedule_in(rng.next_u64() % (3_600 * DUR_SEC), |_s, _w| {});
            sim.cancel(ids[i % ids.len()]);
            ids[i % ids.len()] = id;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "deep backlog: {churn} schedule+cancel pairs at 100k pending: {:.2} µs/pair \
             (target <100 µs)",
            dt * 1e6 / churn as f64
        );
        h.sample("deep_backlog_pending", "count", sim.pending() as f64);
    }

    // qsub -> scheduling decision latency at realistic queue depths.
    for depth in [1usize, 10, 100, 1000] {
        let mut s = PbsServer::new();
        for (name, cores) in [("n01", 12), ("n02", 6), ("n03", 4), ("n04", 4)] {
            s.register_node(name, cores, NodePool::Gridlan);
            s.node_up(name);
        }
        let script = PbsScript::parse("#PBS -q gridlan\n#PBS -l nodes=1:ppn=2\n./x\n").unwrap();
        for i in 0..depth {
            s.qsub(&script, "u", "", i as u64).unwrap();
        }
        let t0 = std::time::Instant::now();
        let mut cycles = 0u64;
        // Drain the whole queue: schedule, complete, repeat.
        loop {
            let d = s.schedule_cycle(NodePool::Gridlan, &FifoScheduler, 1_000_000);
            cycles += 1;
            if d.is_empty() {
                break;
            }
            for (id, _) in d {
                s.complete(id, 0, 2_000_000);
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "sched cycle: depth {depth:>5}: drained in {:.2} ms over {cycles} cycles ({:.1} µs/job)",
            dt * 1e3,
            dt * 1e6 / depth as f64
        );
        h.sample(&format!("drain_cycles_d{depth}"), "count", cycles as f64);
    }

    // Ping path: simulated RTT is deterministic; the wall-clock loop uses
    // a quick-scaled probe count, the JSON a fixed one.
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let probes: usize = harness::pick(50_000, 5_000);
    let t0 = std::time::Instant::now();
    let s = g.ping_node("n01", probes).unwrap();
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "ping path: {probes} node pings in {:.1} ms = {:.2} µs/ping (mean rtt {:.0} µs sim-time)",
        dt * 1e3,
        dt * 1e6 / probes as f64,
        s.mean_us()
    );
    let mut g2 = Gridlan::table1();
    g2.boot_all(0);
    h.series("ping_rtt", "µs", g2.ping_node("n01", 200).unwrap().rtts_us);
    h
}

// ---------------------------------------------------------------------
// table1_inventory
// ---------------------------------------------------------------------

/// Bench T1: Table 1 (client inventory) + the derived per-client compute
/// capability the Fig. 3 model is built on.  Pure model evaluation.
pub fn run_table1_inventory() -> BenchHarness {
    let cfg = Config::table1();
    let mut h = BenchHarness::new("table1_inventory", cfg.seed);
    h.param_u64("class_d_pairs", 1u64 << 36);

    print!("{}", super::table1::render_inventory(&cfg));

    println!();
    let mut t = Table::new(&[
        "Node",
        "clock@1",
        "clock@all",
        "EP Mpairs/s @1 core",
        "EP Mpairs/s @all cores",
        "hypervisor eff",
    ])
    .title("Derived per-client capability (Turbo + hypervisor model)")
    .align(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    for c in ClientAgent::table1() {
        let rate_all = c.cpu.cores as f64 * c.guest_ep_rate(c.cpu.cores);
        t.row(&[
            c.name.clone(),
            format!("{:.2} GHz", c.cpu.clock_ghz(1)),
            format!("{:.2} GHz", c.cpu.clock_ghz(c.cpu.cores)),
            format!("{:.1}", c.guest_ep_rate(1)),
            format!("{rate_all:.1}"),
            format!("{:.2}", c.hypervisor.cpu_efficiency),
        ]);
        h.sample(&format!("ep_rate1_{}", c.name), "Mpairs/s", c.guest_ep_rate(1));
        h.sample(&format!("ep_rate_all_{}", c.name), "Mpairs/s", rate_all);
        h.sample(&format!("cores_{}", c.name), "count", c.cpu.cores as f64);
    }
    print!("{}", t.render());
    let total: f64 = ClientAgent::table1()
        .iter()
        .map(|c| c.cpu.cores as f64 * c.guest_ep_rate(c.cpu.cores))
        .sum();
    let class_d_secs = (1u64 << 36) as f64 / total / 1e6;
    println!(
        "\naggregate pool throughput: {total:.0} Mpairs/s (class D = 2^36 pairs → ~{:.0} s)",
        class_d_secs
    );
    h.sample("pool_total", "Mpairs/s", total);
    h.sample("class_d_predicted", "s", class_d_secs);
    h.sample("total_cores", "count", cfg.total_gridlan_cores() as f64);
    h
}

// ---------------------------------------------------------------------
// table2_latency
// ---------------------------------------------------------------------

/// Bench T2: the paper's Table 2 (ping from the Gridlan server), plus a
/// probe-count convergence study.  Simulated RTTs — deterministic.
pub fn run_table2_latency() -> BenchHarness {
    let cfg = Config::table1();
    let mut h = BenchHarness::new("table2_latency", cfg.seed);
    h.param_u64("probes", 1000);

    let mut g = Gridlan::table1();
    g.boot_all(0);

    let t0 = std::time::Instant::now();
    let rows = super::table2::table2_rows(&mut g, 1000);
    let elapsed = t0.elapsed();
    print!("{}", super::table2::render(&rows));
    println!("\n(1000 probes x 4 hosts x 2 paths in {:.1} ms wall)", elapsed.as_secs_f64() * 1e3);

    // Shape scoring vs the paper.
    let mut worst = 0.0f64;
    for r in &rows {
        let (_, ph, pv) = *super::table2::PAPER_TABLE2.iter().find(|p| p.0 == r.node).unwrap();
        worst = worst.max(((r.host_mean_us - ph) / ph).abs());
        worst = worst.max(((r.node_mean_us - pv) / pv).abs());
        h.sample(&format!("host_rtt_{}", r.node), "µs", r.host_mean_us);
        h.sample(&format!("node_rtt_{}", r.node), "µs", r.node_mean_us);
        h.sample(&format!("overhead_{}", r.node), "µs", r.overhead_us());
    }
    println!("worst relative error vs paper: {:.1}%", worst * 100.0);
    h.sample("worst_rel_err_vs_paper", "frac", worst);

    // Convergence: how many probes until the mean stabilizes within 1%?
    println!("\nprobe-count convergence (n01 node ping):");
    let reference = rows.iter().find(|r| r.node == "n01").unwrap().node_mean_us;
    for probes in [5usize, 10, 20, 50, 100, 500] {
        let m = g.ping_node("n01", probes).unwrap().mean_us();
        println!(
            "  {probes:>4} probes: {m:7.1} µs ({:+.2}% vs 1000-probe mean)",
            100.0 * (m - reference) / reference
        );
    }
    h
}

// ---------------------------------------------------------------------
// vpn_overhead
// ---------------------------------------------------------------------

/// Bench A2: decompose the node-path latency into wire / VPN / virtio
/// layers, then sweep the tunnel cost (§5's optimization discussion).
pub fn run_vpn_overhead() -> BenchHarness {
    let cfg = Config::table1();
    let mut h = BenchHarness::new("vpn_overhead", cfg.seed);
    h.param_str("packet", "icmp_echo_56B");

    let mut g = Gridlan::table1();
    g.boot_all(0);
    g.net.jitter_sigma_us = 0.0; // decomposition wants means

    let p = Packet::icmp_echo();
    let mut t = Table::new(&[
        "Node",
        "wire RTT",
        "+VPN",
        "+virtio",
        "node RTT",
        "VPN share",
        "virtio share",
    ])
    .title("A2 — node-path overhead decomposition (µs RTT, 56B ICMP)")
    .align(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
    for name in &names {
        let one = g.net.one_way_delay_us(g.server_dev, g.client_dev[name], p.wire_bytes());
        let wire = 2.0 * one.unwrap();
        let mut rng = SplitMix64::new(1);
        let tun_one = g.hub.server_to_client_us(&g.net, name, &p, &mut rng).unwrap();
        let vpn_rtt = 2.0 * tun_one;
        let vnet = g.client(name).unwrap().hypervisor.vnet_one_way_us;
        let node_rtt = vpn_rtt + 2.0 * vnet;
        t.row(&[
            name.clone(),
            format!("{wire:.0}"),
            format!("{vpn_rtt:.0}"),
            format!("{:.0}", 2.0 * vnet),
            format!("{node_rtt:.0}"),
            format!("{:.0}%", 100.0 * (vpn_rtt - wire) / (node_rtt - wire)),
            format!("{:.0}%", 100.0 * 2.0 * vnet / (node_rtt - wire)),
        ]);
        h.sample(&format!("wire_rtt_{name}"), "µs", wire);
        h.sample(&format!("vpn_rtt_{name}"), "µs", vpn_rtt);
        h.sample(&format!("node_rtt_{name}"), "µs", node_rtt);
    }
    print!("{}", t.render());

    // What would the §5 VPN optimizations buy?  Sweep the tunnel cost.
    println!("\nVPN-optimization sweep (n01 node RTT, µs):");
    let base = TunnelCost::default();
    let enc = base.encap_us * 0.7;
    let dec = base.decap_us * 0.7;
    let tuned = TunnelCost { encap_us: enc, decap_us: dec, ..base };
    let wireguard = TunnelCost { encap_us: 25.0, decap_us: 22.0, crypto_us_per_kb: 2.0 };
    let none = TunnelCost { encap_us: 0.0, decap_us: 0.0, crypto_us_per_kb: 0.0 };
    for (label, key, cost) in [
        ("openvpn (paper)", "openvpn", base),
        ("tuned crypto (-30%)", "tuned_crypto", tuned),
        ("kernel wireguard-like", "wireguard_like", wireguard),
        ("no vpn (hypothetical)", "no_vpn", none),
    ] {
        let one_way = cost.one_way_us(p.wire_bytes());
        let mut rng = SplitMix64::new(2);
        // Rebuild the wire path each time (the VPN header still rides).
        let tunneled = Packet::icmp_echo_tunneled().wire_bytes();
        let dev = g.client_dev["n01"];
        let wire_ns = g.net.sample_one_way(g.server_dev, dev, tunneled, &mut rng).unwrap();
        let wire_one = wire_ns as f64 / 1e3;
        let vnet = g.client("n01").unwrap().hypervisor.vnet_one_way_us;
        let rtt = 2.0 * (wire_one + one_way + vnet) + crate::netsim::icmp::ECHO_PROC_US;
        println!("  {label:<24} {rtt:7.0}");
        h.sample(&format!("sweep_{key}"), "µs", rtt);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_canonical_and_aliases() {
        for name in BENCH_NAMES {
            assert_eq!(resolve(name), Some(name));
        }
        assert_eq!(resolve("table1"), Some("table1_inventory"));
        assert_eq!(resolve("inventory"), Some("table1_inventory"));
        assert_eq!(resolve("table2"), Some("table2_latency"));
        assert_eq!(resolve("mpi"), Some("mpi_latency"));
        assert_eq!(resolve("fig3"), Some("fig3_speedup"));
        assert_eq!(resolve("nope"), None);
    }

    #[test]
    fn table1_inventory_is_deterministic_and_valid() {
        let a = run_table1_inventory();
        let b = run_table1_inventory();
        assert_eq!(a.render_json(), b.render_json());
        let doc = crate::util::json::Json::parse(&a.render_json()).unwrap();
        crate::obs::harness::validate(&doc).unwrap();
    }

    #[test]
    fn vpn_overhead_is_deterministic() {
        let a = run_vpn_overhead();
        let b = run_vpn_overhead();
        assert_eq!(a.render_json(), b.render_json());
    }

    #[test]
    fn file_names_match_bench_names() {
        let h = run_table1_inventory();
        assert_eq!(h.file_name(), "BENCH_table1_inventory.json");
    }

    #[test]
    fn recovery_and_steal_series_shapes_hold() {
        // The X1b fixture: naive re-execution wastes the pre-crash spans,
        // salvage wastes nothing — comfortably past the 50% reduction the
        // recovery work targets — and never recovers slower.
        let naive = ep_crash_run(2_000_000, 400, false);
        let salv = ep_crash_run(2_000_000, 400, true);
        let waste = |r: &ScenarioRun| {
            r.report.metrics.ep_pairs_executed.saturating_sub(r.report.ep_total().pairs)
        };
        assert!(waste(&naive) > 0, "mid-compute crash must waste pairs in naive mode");
        assert_eq!(waste(&salv), 0, "salvage must re-execute nothing");
        assert!(waste(&salv) * 2 <= waste(&naive));
        assert!(salv.report.metrics.makespan <= naive.report.metrics.makespan);
        // The X1c fixture: the straggler flood steals at least once and
        // finishes strictly sooner than the no-steal baseline.
        let base = straggler_flood(false);
        let stolen = straggler_flood(true);
        assert!(stolen.report.metrics.ep_steals >= 1);
        assert!(stolen.report.metrics.makespan < base.report.metrics.makespan);
    }
}
