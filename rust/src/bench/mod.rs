//! Benchmark harnesses: one per paper table/figure (DESIGN.md §4).
//!
//! Each harness returns structured rows AND renders the paper-style table,
//! so `cargo bench` targets, the CLI (`gridlan bench ...`), and the
//! integration tests all share one implementation.  [`suite`] wraps every
//! bench target behind a `run_<name>()` function that also fills a
//! [`crate::obs::harness::BenchHarness`] with the deterministic series
//! written to `BENCH_<name>.json`.

pub mod fig3;
pub mod mpilat;
pub mod suite;
pub mod table1;
pub mod table2;

pub use fig3::{fig3_series, Fig3Point, Fig3Series};
pub use mpilat::{mpi_latency_rows, MpiLatRow};
pub use suite::BENCH_NAMES;
pub use table1::{inventory_rows, render_inventory};
pub use table2::{table2_rows, Table2Row};
