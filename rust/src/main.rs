//! `gridlan` — the leader CLI.
//!
//! Subcommands mirror how the paper's users and admins touch the system:
//!
//! ```text
//! gridlan inventory                      # Table 1
//! gridlan bench table2 [--probes N]      # Table 2
//! gridlan bench mpi [--iters N]          # §3.3 MPI latency cross-check
//! gridlan bench fig3 [--runs N] [--class D]
//! gridlan boot                           # per-node PXE boot plans
//! gridlan demo                           # qsub/qstat walkthrough
//! gridlan ep --pairs N [--offset K]      # run REAL EP on the compute backend
//! gridlan ep --pairs N --threads 4       # ... on the multi-threaded backend
//! gridlan ep --class S --rm [--procs N]  # ... through the resource manager
//! gridlan trace [--sched fifo|backfill] [--faults X] [--ep-slices N]
//! ```
//!
//! (arg parsing is hand-rolled: the offline vendor set has no clap.)

use gridlan::bench;
use gridlan::config::{Config, SchedPolicy};
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{run_ep_job, run_trace, Scenario};
use gridlan::host::faults::FaultPlan;
use gridlan::perf::speedmodel::GridlanPool;
use gridlan::rm::script::PbsScript;
use gridlan::runtime::engine::EpEngine;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::rng::SplitMix64;
use gridlan::util::table::secs;
use gridlan::workload::ep::{EpClass, EpJob};
use gridlan::workload::trace::TraceGenerator;

fn main() {
    gridlan::util::log::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn opt_u64(args: &[String], name: &str, default: u64) -> u64 {
    opt(args, name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn load_config(args: &[String]) -> Config {
    match opt(args, "--config") {
        Some(path) => Config::load(std::path::Path::new(&path)).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }),
        None => Config::table1(),
    }
}

fn run(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("inventory") => {
            print!("{}", bench::table1::render_inventory(&load_config(args)));
            0
        }
        Some("bench") => bench_cmd(&args[1..]),
        Some("boot") => boot_cmd(args),
        Some("demo") => demo_cmd(args),
        Some("ep") => ep_cmd(args),
        Some("trace") => trace_cmd(args),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}' (try `gridlan help`)");
            2
        }
    }
}

fn bench_cmd(args: &[String]) -> i32 {
    let mut g = Gridlan::build(load_config(args));
    match args.first().map(String::as_str) {
        Some("inventory") | Some("table1") => {
            print!("{}", bench::table1::render_inventory(&g.config));
            0
        }
        Some("table2") => {
            g.boot_all(0);
            let rows = bench::table2::table2_rows(&mut g, opt_u64(args, "--probes", 200) as usize);
            print!("{}", bench::table2::render(&rows));
            0
        }
        Some("mpi") => {
            g.boot_all(0);
            let rows =
                bench::mpilat::mpi_latency_rows(&mut g, opt_u64(args, "--iters", 200) as usize);
            print!("{}", bench::mpilat::render(&rows));
            0
        }
        Some("fig3") => {
            let class = opt(args, "--class")
                .and_then(|c| EpClass::from_name(&c))
                .unwrap_or(EpClass::D);
            let pool = GridlanPool { clients: g.clients.clone() };
            let series = bench::fig3::fig3_series(
                &pool,
                class,
                opt_u64(args, "--runs", 40) as usize,
                g.config.seed,
            );
            print!("{}", bench::fig3::render(&series));
            for (name, ok) in bench::fig3::shape_checks(&series) {
                println!("  [{}] {name}", if ok { "ok" } else { "FAIL" });
            }
            0
        }
        other => {
            eprintln!("unknown bench target {other:?}; try table1|table2|mpi|fig3");
            2
        }
    }
}

fn boot_cmd(args: &[String]) -> i32 {
    let mut g = Gridlan::build(load_config(args));
    println!("per-node PXE boot plans (VPN + DHCP + TFTP + nfsroot):");
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
    for name in names {
        g.connect_client(&name).unwrap();
        let plan = g.boot_plan(&name);
        print!("  {name}: total {}  [", secs(plan.total() as f64 / 1e9));
        for (state, dur) in &plan.phases {
            if *dur > 0 {
                print!(" {state:?}={}", secs(*dur as f64 / 1e9));
            }
        }
        println!(" ]");
    }
    0
}

fn demo_cmd(args: &[String]) -> i32 {
    let mut g = Gridlan::build(load_config(args));
    println!("== booting the Gridlan (fast-forward) ==");
    let slowest = g.boot_all(0);
    println!("all nodes Up after {}", secs(slowest as f64 / 1e9));
    for n in g.pbs.nodes() {
        println!("  pbsnodes: {:<10} {:>2} cores  {:?}", n.name, n.cores, n.power);
    }
    println!("\n== user submits an EP job to the gridlan queue ==");
    let script_text = "#!/bin/bash\n#PBS -N ep-demo\n#PBS -q gridlan\n#PBS -l nodes=2:ppn=4\n#PBS -l walltime=01:00:00\nmpirun ./ep.D.x\n";
    println!("{script_text}");
    let script = PbsScript::parse(script_text).unwrap();
    let id = g.pbs.qsub(&script, "attila", "demo", 0).unwrap();
    println!("qsub -> {id}");
    let sched = g.scheduler();
    g.pbs.schedule_cycle(gridlan::rm::queue::NodePool::Gridlan, sched.as_ref(), DUR_SEC);
    println!("\n== qstat ==");
    for (id, name, owner, state, queue) in g.pbs.qstat() {
        println!("  {id:<14} {name:<12} {owner:<8} {state}  {queue}");
    }
    let job = g.pbs.job(id).unwrap();
    println!("\nallocation: {:?}", job.allocation.as_ref().map(|a| &a.cores));
    g.pbs.complete(id, 0, 300 * DUR_SEC);
    println!("job completed; exit 0");
    0
}

fn ep_cmd(args: &[String]) -> i32 {
    let class = opt(args, "--class").and_then(|c| EpClass::from_name(&c));
    let pairs = match (opt(args, "--pairs"), class) {
        (Some(p), _) => p.parse().unwrap_or(1 << 16),
        (None, Some(c)) => c.pairs(),
        _ => 1 << 16,
    };
    let offset = opt_u64(args, "--offset", 0);
    let mut engine = match opt(args, "--threads") {
        Some(raw) => match raw.parse::<usize>() {
            Ok(n) if n >= 1 => {
                println!("forcing the threaded backend over {n} OS threads");
                EpEngine::threaded(n)
            }
            _ => {
                eprintln!("ep: invalid --threads value '{raw}' (want a positive integer)");
                return 2;
            }
        },
        None => EpEngine::auto(),
    };
    if let Some(note) = engine.fallback_note.take() {
        eprintln!("note: {note}");
    }

    let t0 = std::time::Instant::now();
    let result = if args.iter().any(|a| a == "--rm") {
        // Through the resource manager: boot the Table-1 grid, scatter
        // single-core slices, execute each for real (Fig. 3 protocol).
        // (--pairs/--offset don't apply here: the class defines the range.)
        let class = class.unwrap_or(EpClass::S);
        let procs = opt_u64(args, "--procs", 26) as u32;
        let mut g = Gridlan::build(load_config(args));
        g.boot_all(0);
        println!(
            "dispatching class {} ({} pairs) over {procs} single-core RM jobs on the '{}' backend...",
            class.name(),
            class.pairs(),
            engine.backend_name()
        );
        run_ep_job(&mut g, &mut engine, class, procs, 0)
    } else {
        println!(
            "running EP over pairs [{offset}, {}) on the '{}' backend...",
            offset + pairs,
            engine.backend_name()
        );
        engine.run_pairs(offset, pairs)
    };

    match result {
        Ok(t) => {
            println!("sx   = {:.15e}", t.sx);
            println!("sy   = {:.15e}", t.sy);
            println!("nacc = {} ({}% accepted)", t.nacc, 100 * t.nacc / t.pairs.max(1));
            for (l, q) in t.q.iter().enumerate() {
                if *q > 0 {
                    println!("  q[{l}] = {q}");
                }
            }
            println!(
                "wall {}  ({:.2} Mpairs/s; {} pairs on '{}')",
                secs(t0.elapsed().as_secs_f64()),
                t.pairs as f64 / t0.elapsed().as_secs_f64() / 1e6,
                engine.pairs_executed(),
                engine.backend_name()
            );
            if t.pairs == EpClass::S.pairs() && (offset == 0 || args.iter().any(|a| a == "--rm")) {
                println!("class S verification: {:?}", t.verify(EpClass::S));
            }
            0
        }
        Err(e) => {
            eprintln!("ep failed: {e}");
            1
        }
    }
}

fn trace_cmd(args: &[String]) -> i32 {
    let mut cfg = load_config(args);
    if let Some(s) = opt(args, "--sched") {
        cfg.sched = match s.as_str() {
            "backfill" => SchedPolicy::Backfill,
            _ => SchedPolicy::Fifo,
        };
    }
    let fault_scale = opt(args, "--faults").and_then(|f| f.parse::<f64>().ok()).unwrap_or(0.0);
    let faults = if fault_scale > 0.0 {
        FaultPlan::lab_default().scaled(fault_scale)
    } else {
        FaultPlan::none()
    };
    let gen = TraceGenerator::lab_day();
    let mut rng = SplitMix64::new(cfg.seed ^ 0xABCD);
    let mut trace = gen.generate(&mut rng);
    // Optional real-compute payload: class S split over N single-core EP
    // jobs mixed into the trace (the event-driven Fig. 3 protocol).
    let ep_slices = opt_u64(args, "--ep-slices", 0) as u32;
    if ep_slices > 0 {
        for s in EpJob::new(EpClass::S, ep_slices).slices() {
            trace.push(s.trace_job(0, 3600 * DUR_SEC));
        }
        trace.sort_by_key(|j| j.at);
    }
    println!(
        "running {} trace jobs ({ep_slices} with real EP payloads) under {:?} scheduler (fault scale {fault_scale})...",
        trace.len(),
        cfg.sched
    );
    let g = Gridlan::build(cfg);
    let scenario = Scenario { horizon: gen.horizon * 3, faults, ..Default::default() };
    let report = run_trace(g, trace, &scenario);
    let m = &report.metrics;
    println!("  submitted   {}", m.jobs_submitted);
    println!("  completed   {}", m.jobs_completed);
    println!("  requeued    {}", m.jobs_requeued);
    println!("  faults      {}", m.faults);
    println!("  wd restarts {}", m.watchdog_restarts);
    println!("  mean wait   {}", secs(m.mean_wait_secs()));
    println!("  makespan    {}", secs(m.makespan as f64 / 1e9));
    println!("  goodput     {:.1}%", 100.0 * m.goodput());
    println!("  sim events  {}", report.events_executed);
    if ep_slices > 0 {
        let total = report.ep_total();
        println!("  ep pairs    {} (over {} jobs)", m.ep_pairs_executed, m.ep_jobs_completed);
        println!("  class S verification: {:?}", total.verify(EpClass::S));
    }
    0
}

fn print_help() {
    println!(
        "gridlan — local grid computing framework (CS.DC 2016 reproduction)

USAGE: gridlan <subcommand> [options]

  inventory                    Table 1: client inventory
  bench table2 [--probes N]    Table 2: host-vs-node ping
  bench mpi    [--iters N]     §3.3 MPI latency cross-check
  bench fig3   [--runs N] [--class S|W|A|B|C|D]
  boot                         per-node PXE/TFTP/nfsroot boot plans
  demo                         qsub/qstat end-to-end walkthrough
  ep --pairs N | --class S     run REAL EP on the compute backend
  ep ... --threads N           force the multi-threaded backend (N OS threads)
  ep --class S --rm [--procs N]  ... as single-core jobs through the RM
  trace [--sched fifo|backfill] [--faults SCALE] [--ep-slices N]
  help

Common options: --config FILE (JSON deployment; default = paper Table 1)
Env: GRIDLAN_LOG=debug|info|warn, GRIDLAN_ARTIFACTS=dir (pjrt builds)"
    );
}
