//! `gridlan` — the leader CLI.
//!
//! Subcommands mirror how the paper's users and admins touch the system:
//!
//! ```text
//! gridlan inventory                      # Table 1
//! gridlan bench <name|all>               # any bench target; writes BENCH_<name>.json
//! gridlan bench all --check              # regression gate vs the committed baselines
//! gridlan report <events.jsonl>          # fold a scenario event log into rollups
//! gridlan boot                           # per-node PXE boot plans
//! gridlan demo                           # qsub/qstat walkthrough
//! gridlan ep --pairs N [--offset K]      # run REAL EP on the compute backend
//! gridlan ep --pairs N --threads 4       # ... on the multi-threaded backend
//! gridlan ep --class S --rm [--procs N]  # ... through the resource manager
//! gridlan trace [--sched fifo|backfill] [--faults X] [--ep-slices N] [--events FILE]
//! gridlan scenario <file.json>           # run one declarative chaos scenario
//! gridlan scenario --corpus scenarios/   # sweep the committed chaos corpus
//! gridlan lint [--format json|human] [--deny-warnings] [PATH...]
//! ```
//!
//! (arg parsing is hand-rolled: the offline vendor set has no clap.)

use std::path::{Path, PathBuf};

use gridlan::bench;
use gridlan::config::{Config, SchedPolicy};
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{run_ep_job, run_scenario_logged, Scenario};
use gridlan::host::faults::FaultPlan;
use gridlan::obs::event::ScenarioLogger;
use gridlan::obs::gate::{compare, DEFAULT_TOLERANCE};
use gridlan::obs::report::EventRollup;
use gridlan::rm::script::PbsScript;
use gridlan::runtime::engine::EpEngine;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::json::Json;
use gridlan::util::rng::SplitMix64;
use gridlan::util::table::secs;
use gridlan::workload::ep::{EpClass, EpJob};
use gridlan::workload::trace::TraceGenerator;

fn main() {
    gridlan::util::log::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&args);
    std::process::exit(code);
}

fn opt(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn opt_u64(args: &[String], name: &str, default: u64) -> u64 {
    opt(args, name).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn load_config(args: &[String]) -> Config {
    match opt(args, "--config") {
        Some(path) => Config::load(std::path::Path::new(&path)).unwrap_or_else(|e| {
            eprintln!("config error: {e}");
            std::process::exit(2);
        }),
        None => Config::table1(),
    }
}

fn run(args: &[String]) -> i32 {
    match args.first().map(String::as_str) {
        Some("inventory") => {
            print!("{}", bench::table1::render_inventory(&load_config(args)));
            0
        }
        Some("bench") => bench_cmd(&args[1..]),
        Some("report") => report_cmd(&args[1..]),
        Some("boot") => boot_cmd(args),
        Some("demo") => demo_cmd(args),
        Some("ep") => ep_cmd(args),
        Some("trace") => trace_cmd(args),
        Some("scenario") => scenario_cmd(&args[1..]),
        Some("lint") => lint_cmd(&args[1..]),
        Some("help") | None => {
            print_help();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}' (try `gridlan help`)");
            2
        }
    }
}

fn bench_cmd(args: &[String]) -> i32 {
    let Some(name) = args.first().map(String::as_str) else {
        eprintln!("usage: gridlan bench <name|all> [--check] [--quick] [--out DIR]");
        eprintln!("benches: {}", bench::suite::BENCH_NAMES.join(", "));
        return 2;
    };
    if args.iter().any(|a| a == "--quick") {
        std::env::set_var("GRIDLAN_BENCH_QUICK", "1");
    }
    let names: Vec<&'static str> = if name == "all" {
        bench::suite::BENCH_NAMES.to_vec()
    } else {
        match bench::suite::resolve(name) {
            Some(canon) => vec![canon],
            None => {
                eprintln!("unknown bench '{name}'; try `all` or one of:");
                eprintln!("  {}", bench::suite::BENCH_NAMES.join(", "));
                return 2;
            }
        }
    };
    let check = args.iter().any(|a| a == "--check");
    let tolerance =
        opt(args, "--tolerance").and_then(|v| v.parse().ok()).unwrap_or(DEFAULT_TOLERANCE);
    // Without --check the JSON lands in the CWD (the baseline-minting
    // workflow); with --check it goes to a scratch dir so the committed
    // baselines stay untouched.
    let default_out = if check { "target/bench-fresh" } else { "." };
    let out = PathBuf::from(opt(args, "--out").unwrap_or_else(|| default_out.into()));
    if let Err(e) = std::fs::create_dir_all(&out) {
        eprintln!("bench: cannot create {}: {e}", out.display());
        return 1;
    }
    let mut regressions = 0u32;
    for name in names {
        println!("==> {name}");
        let h = bench::suite::run(name).expect("registry names resolve");
        match h.write_to(&out) {
            Ok(path) => {
                println!("wrote {}", path.display());
                if check && !gate_one(name, &path, tolerance) {
                    regressions += 1;
                }
            }
            Err(e) => {
                eprintln!("bench {name}: cannot write JSON: {e}");
                return 1;
            }
        }
        println!();
    }
    if regressions > 0 {
        eprintln!("bench --check: {regressions} bench(es) failed the regression gate");
        1
    } else {
        0
    }
}

fn load_bench_json(path: &Path) -> Result<Json, String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| format!("{}: {e:?}", path.display()))
}

/// Gate one fresh BENCH json against the committed baseline in the CWD.
/// A missing baseline passes with a note (the bootstrap path: mint it
/// with `gridlan bench <name>` at the repo root and commit the file).
fn gate_one(name: &str, fresh_path: &Path, tolerance: f64) -> bool {
    let baseline_path = PathBuf::from(format!("BENCH_{name}.json"));
    if !baseline_path.exists() {
        println!("note: no baseline {} — gate skipped (bootstrap)", baseline_path.display());
        return true;
    }
    let baseline = match load_bench_json(&baseline_path) {
        Ok(doc) => doc,
        Err(e) => {
            eprintln!("bench --check: {e}");
            return false;
        }
    };
    let fresh = match load_bench_json(fresh_path) {
        Ok(doc) => doc,
        Err(e) => {
            eprintln!("bench --check: {e}");
            return false;
        }
    };
    match compare(&baseline, &fresh, tolerance) {
        Ok(report) => {
            print!("{}", report.render());
            report.passed()
        }
        Err(e) => {
            eprintln!("bench --check {name}: {e}");
            false
        }
    }
}

fn report_cmd(args: &[String]) -> i32 {
    let Some(path) = args.first() else {
        eprintln!("usage: gridlan report <events.jsonl>");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("report: cannot read {path}: {e}");
            return 1;
        }
    };
    match EventRollup::from_jsonl(&text) {
        Ok(rollup) => {
            print!("{}", rollup.render());
            0
        }
        Err(e) => {
            eprintln!("report: {e}");
            1
        }
    }
}

fn boot_cmd(args: &[String]) -> i32 {
    let mut g = Gridlan::build(load_config(args));
    println!("per-node PXE boot plans (VPN + DHCP + TFTP + nfsroot):");
    let names: Vec<String> = g.config.clients.iter().map(|c| c.name.clone()).collect();
    for name in names {
        g.connect_client(&name).unwrap();
        let plan = g.boot_plan(&name);
        print!("  {name}: total {}  [", secs(plan.total() as f64 / 1e9));
        for (state, dur) in &plan.phases {
            if *dur > 0 {
                print!(" {state:?}={}", secs(*dur as f64 / 1e9));
            }
        }
        println!(" ]");
    }
    0
}

fn demo_cmd(args: &[String]) -> i32 {
    let mut g = Gridlan::build(load_config(args));
    println!("== booting the Gridlan (fast-forward) ==");
    let slowest = g.boot_all(0);
    println!("all nodes Up after {}", secs(slowest as f64 / 1e9));
    for n in g.pbs.nodes() {
        println!("  pbsnodes: {:<10} {:>2} cores  {:?}", n.name, n.cores, n.power);
    }
    println!("\n== user submits an EP job to the gridlan queue ==");
    let script_text = "#!/bin/bash\n#PBS -N ep-demo\n#PBS -q gridlan\n#PBS -l nodes=2:ppn=4\n#PBS -l walltime=01:00:00\nmpirun ./ep.D.x\n";
    println!("{script_text}");
    let script = PbsScript::parse(script_text).unwrap();
    let id = g.pbs.qsub(&script, "attila", "demo", 0).unwrap();
    println!("qsub -> {id}");
    let sched = g.scheduler();
    g.pbs.schedule_cycle(gridlan::rm::queue::NodePool::Gridlan, sched.as_ref(), DUR_SEC);
    println!("\n== qstat ==");
    for (id, name, owner, state, queue) in g.pbs.qstat() {
        println!("  {id:<14} {name:<12} {owner:<8} {state}  {queue}");
    }
    let job = g.pbs.job(id).unwrap();
    println!("\nallocation: {:?}", job.allocation.as_ref().map(|a| &a.cores));
    g.pbs.complete(id, 0, 300 * DUR_SEC);
    println!("job completed; exit 0");
    0
}

fn ep_cmd(args: &[String]) -> i32 {
    let class = opt(args, "--class").and_then(|c| EpClass::from_name(&c));
    let pairs = match (opt(args, "--pairs"), class) {
        (Some(p), _) => p.parse().unwrap_or(1 << 16),
        (None, Some(c)) => c.pairs(),
        _ => 1 << 16,
    };
    let offset = opt_u64(args, "--offset", 0);
    let mut engine = match opt(args, "--threads") {
        Some(raw) => match raw.parse::<usize>() {
            Ok(n) if n >= 1 => {
                println!("forcing the threaded backend over {n} OS threads");
                EpEngine::threaded(n)
            }
            _ => {
                eprintln!("ep: invalid --threads value '{raw}' (want a positive integer)");
                return 2;
            }
        },
        None => EpEngine::auto(),
    };
    if let Some(note) = engine.fallback_note.take() {
        eprintln!("note: {note}");
    }

    // lint:allow(wall-clock): CLI-facing wall timer around the real EP run
    let t0 = std::time::Instant::now();
    let result = if args.iter().any(|a| a == "--rm") {
        // Through the resource manager: boot the Table-1 grid, scatter
        // single-core slices, execute each for real (Fig. 3 protocol).
        // (--pairs/--offset don't apply here: the class defines the range.)
        let class = class.unwrap_or(EpClass::S);
        let procs = opt_u64(args, "--procs", 26) as u32;
        let mut g = Gridlan::build(load_config(args));
        g.boot_all(0);
        println!(
            "dispatching class {} ({} pairs) over {procs} single-core RM jobs on the '{}' backend...",
            class.name(),
            class.pairs(),
            engine.backend_name()
        );
        run_ep_job(&mut g, &mut engine, class, procs, 0)
    } else {
        println!(
            "running EP over pairs [{offset}, {}) on the '{}' backend...",
            offset + pairs,
            engine.backend_name()
        );
        engine.run_pairs(offset, pairs)
    };

    match result {
        Ok(t) => {
            println!("sx   = {:.15e}", t.sx);
            println!("sy   = {:.15e}", t.sy);
            println!("nacc = {} ({}% accepted)", t.nacc, 100 * t.nacc / t.pairs.max(1));
            for (l, q) in t.q.iter().enumerate() {
                if *q > 0 {
                    println!("  q[{l}] = {q}");
                }
            }
            println!(
                "wall {}  ({:.2} Mpairs/s; {} pairs on '{}')",
                secs(t0.elapsed().as_secs_f64()),
                t.pairs as f64 / t0.elapsed().as_secs_f64() / 1e6,
                engine.pairs_executed(),
                engine.backend_name()
            );
            if t.pairs == EpClass::S.pairs() && (offset == 0 || args.iter().any(|a| a == "--rm")) {
                println!("class S verification: {:?}", t.verify(EpClass::S));
            }
            0
        }
        Err(e) => {
            eprintln!("ep failed: {e}");
            1
        }
    }
}

fn trace_cmd(args: &[String]) -> i32 {
    let mut cfg = load_config(args);
    if let Some(s) = opt(args, "--sched") {
        cfg.sched = match s.as_str() {
            "backfill" => SchedPolicy::Backfill,
            _ => SchedPolicy::Fifo,
        };
    }
    let fault_scale = opt(args, "--faults").and_then(|f| f.parse::<f64>().ok()).unwrap_or(0.0);
    let faults = if fault_scale > 0.0 {
        FaultPlan::lab_default().scaled(fault_scale)
    } else {
        FaultPlan::none()
    };
    let gen = TraceGenerator::lab_day();
    let mut rng = SplitMix64::new(cfg.seed ^ 0xABCD);
    let mut trace = gen.generate(&mut rng);
    // Optional real-compute payload: class S split over N single-core EP
    // jobs mixed into the trace (the event-driven Fig. 3 protocol).
    let ep_slices = opt_u64(args, "--ep-slices", 0) as u32;
    if ep_slices > 0 {
        for s in EpJob::new(EpClass::S, ep_slices).slices() {
            trace.push(s.trace_job(0, 3600 * DUR_SEC));
        }
        trace.sort_by_key(|j| j.at);
    }
    println!(
        "running {} trace jobs ({ep_slices} with real EP payloads) under {:?} scheduler (fault scale {fault_scale})...",
        trace.len(),
        cfg.sched
    );
    // Optional structured event log: every lifecycle transition as one
    // JSONL record (`gridlan report <file>` folds it back into rollups).
    let logger = match opt(args, "--events") {
        Some(path) => match std::fs::File::create(&path) {
            Ok(f) => {
                println!("writing scenario events to {path}");
                ScenarioLogger::writer(Box::new(std::io::BufWriter::new(f)))
            }
            Err(e) => {
                eprintln!("trace: cannot create {path}: {e}");
                return 1;
            }
        },
        None => ScenarioLogger::null(),
    };
    let g = Gridlan::build(cfg);
    let scenario = Scenario { horizon: gen.horizon * 3, faults, ..Default::default() };
    let run = run_scenario_logged(g, trace, &scenario, EpEngine::scalar(), logger);
    let report = run.report;
    let m = &report.metrics;
    println!("  submitted   {}", m.jobs_submitted);
    println!("  completed   {}", m.jobs_completed);
    println!("  requeued    {}", m.jobs_requeued);
    println!("  faults      {}", m.faults);
    println!("  wd restarts {}", m.watchdog_restarts);
    println!("  mean wait   {}", secs(m.mean_wait_secs()));
    println!("  makespan    {}", secs(m.makespan as f64 / 1e9));
    println!("  goodput     {:.1}%", 100.0 * m.goodput());
    println!("  sim events  {}", report.events_executed);
    if ep_slices > 0 {
        let total = report.ep_total();
        println!("  ep pairs    {} (over {} jobs)", m.ep_pairs_executed, m.ep_jobs_completed);
        println!("  class S verification: {:?}", total.verify(EpClass::S));
    }
    0
}

/// `gridlan scenario` — run one declarative scenario file, or sweep a
/// corpus directory (`--corpus`) checking every file's `expect` block.
/// Exit codes: 2 = usage/parse error, 1 = a run failed its expectations
/// (corpus mode only under `--deny`), 0 = everything passed.
fn scenario_cmd(args: &[String]) -> i32 {
    if let Some(dir) = opt(args, "--corpus") {
        return scenario_corpus_cmd(Path::new(&dir), args);
    }
    let Some(path) = args.first().filter(|a| !a.starts_with("--")) else {
        eprintln!("usage: gridlan scenario <file.json> [--seed N] [--events FILE] [--report]");
        eprintln!("       gridlan scenario --corpus DIR [--deny] [--events-dir DIR]");
        return 2;
    };
    let mut spec = match gridlan::scenario_dsl::load_file(Path::new(path)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("scenario: {e}");
            return 2;
        }
    };
    if let Some(raw) = opt(args, "--seed") {
        match raw.parse::<u64>() {
            Ok(s) => spec.seed = s,
            Err(_) => {
                eprintln!("scenario: invalid --seed '{raw}' (want an integer)");
                return 2;
            }
        }
    }
    let out = gridlan::scenario_dsl::run_spec(&spec);
    if let Some(path) = opt(args, "--events") {
        if let Err(e) = std::fs::write(&path, &out.events_jsonl) {
            eprintln!("scenario: cannot write {path}: {e}");
            return 1;
        }
        println!("wrote {path}");
    }
    print!("{}", out.render_summary());
    if args.iter().any(|a| a == "--report") {
        print!("{}", out.report_json);
    }
    if out.passed() {
        0
    } else {
        1
    }
}

/// Sweep every `*.json` under a corpus dir (the chaos lab).  Parse
/// errors are always fatal; failed `expect` blocks fail the sweep only
/// under `--deny`.  `--events-dir` writes `<stem>.events.jsonl` +
/// `<stem>.report.json` per scenario (the CI artifact set).
fn scenario_corpus_cmd(dir: &Path, args: &[String]) -> i32 {
    let deny = args.iter().any(|a| a == "--deny");
    let events_dir = opt(args, "--events-dir").map(PathBuf::from);
    if let Some(d) = &events_dir {
        if let Err(e) = std::fs::create_dir_all(d) {
            eprintln!("scenario: cannot create {}: {e}", d.display());
            return 1;
        }
    }
    let files = match gridlan::scenario_dsl::corpus_files(dir) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("scenario: {e}");
            return 1;
        }
    };
    println!("chaos lab: {} scenario file(s) under {}", files.len(), dir.display());
    let mut failed = 0usize;
    for path in &files {
        let out = match gridlan::scenario_dsl::run_file(path) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("scenario: {e}");
                return 1;
            }
        };
        if !out.passed() {
            failed += 1;
        }
        print!("{}", out.render_summary());
        if let Some(d) = &events_dir {
            let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("scenario");
            let write = std::fs::write(d.join(format!("{stem}.events.jsonl")), &out.events_jsonl)
                .and_then(|_| {
                    std::fs::write(d.join(format!("{stem}.report.json")), &out.report_json)
                });
            if let Err(e) = write {
                eprintln!("scenario: cannot write artifacts for {stem}: {e}");
                return 1;
            }
        }
    }
    if failed > 0 {
        eprintln!("chaos lab: {failed}/{} scenario(s) FAILED their expect block", files.len());
        if deny {
            return 1;
        }
    } else {
        println!("chaos lab: all {} scenario(s) passed", files.len());
    }
    0
}

/// `gridlan lint` — the in-tree determinism & invariant static-analysis
/// pass (DESIGN.md §9).  Scans `rust/src` by default; explicit paths
/// (files or directories) override.  Deny findings exit 1; warnings exit 1
/// only under `--deny-warnings`.
fn lint_cmd(args: &[String]) -> i32 {
    let format = opt(args, "--format").unwrap_or_else(|| "human".into());
    if format != "human" && format != "json" {
        eprintln!("lint: unknown --format '{format}' (want human or json)");
        return 2;
    }
    let deny_warnings = args.iter().any(|a| a == "--deny-warnings");
    // Positional paths: everything that isn't a flag or a flag's value.
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut skip_next = false;
    for a in args {
        if skip_next {
            skip_next = false;
            continue;
        }
        match a.as_str() {
            "--format" => skip_next = true,
            "--deny-warnings" => {}
            other if other.starts_with("--") => {
                eprintln!("lint: unknown option '{other}'");
                return 2;
            }
            path => roots.push(PathBuf::from(path)),
        }
    }
    if roots.is_empty() {
        let default = Path::new("rust/src");
        if !default.is_dir() {
            eprintln!(
                "lint: no PATH given and ./rust/src not found — run from the repo root or \
                 pass paths explicitly"
            );
            return 2;
        }
        roots.push(default.to_path_buf());
    }
    match gridlan::analysis::lint_paths(&roots) {
        Ok(report) => {
            if format == "json" {
                println!("{}", report.to_json().to_pretty());
            } else {
                print!("{}", report.render_human());
            }
            report.exit_code(deny_warnings)
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn print_help() {
    println!(
        "gridlan — local grid computing framework (CS.DC 2016 reproduction)

USAGE: gridlan <subcommand> [options]

  inventory                    Table 1: client inventory
  bench <name|all>             run a bench: stdout report + BENCH_<name>.json
        [--check]              gate fresh JSON vs committed baselines (>15% fails)
        [--quick]              shrink wall-clock loops (JSON series unchanged)
        [--out DIR]            JSON output dir (default: CWD, or target/bench-fresh
                               with --check)  [--tolerance F] overrides the 0.15 gate
  report <events.jsonl>        fold a scenario event log into rollup metrics
  boot                         per-node PXE/TFTP/nfsroot boot plans
  demo                         qsub/qstat end-to-end walkthrough
  ep --pairs N | --class S     run REAL EP on the compute backend
  ep ... --threads N           force the multi-threaded backend (N OS threads)
  ep --class S --rm [--procs N]  ... as single-core jobs through the RM
  trace [--sched fifo|backfill] [--faults SCALE] [--ep-slices N] [--events FILE]
  scenario <file.json>         run a declarative chaos scenario (see scenarios/)
       [--seed N]              override the file's seed  [--events FILE] JSONL log
       [--report]              print the scenario report JSON
  scenario --corpus DIR        sweep every *.json in DIR, checking expect blocks
       [--deny]                exit 1 if any expect block fails (what CI runs)
       [--events-dir DIR]      write <stem>.events.jsonl + <stem>.report.json each
  lint [PATH...]               determinism & invariant static analysis (default: rust/src)
       [--format json|human]   machine- or compiler-style output
       [--deny-warnings]       warn-tier findings also fail (what CI runs)
  help

Bench names: boot_storm ep_throughput fault_recovery fig3_speedup mpi_latency
  sched_ablation sim_engine table1_inventory table2_latency vpn_overhead
  (aliases: table1/inventory, table2, mpi, fig3)

Common options: --config FILE (JSON deployment; default = paper Table 1)
Env: GRIDLAN_LOG=debug|info|warn, GRIDLAN_BENCH_QUICK=1 (CI quick mode),
     GRIDLAN_ARTIFACTS=dir (pjrt builds)"
    );
}
