//! Leveled logging with simulation-time stamps.
//!
//! The simulator logs in *simulated* time (ns since scenario start) rather
//! than wall time, so traces are deterministic and diffable run-to-run.
//! Level is a process-global; `GRIDLAN_LOG=debug|info|warn|error|off`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
    Off = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // default: Warn (quiet tests)

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Debug,
        1 => Level::Info,
        2 => Level::Warn,
        3 => Level::Error,
        _ => Level::Off,
    }
}

/// Initialise from the GRIDLAN_LOG env var (call once from main).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("GRIDLAN_LOG") {
        set_level(match v.to_ascii_lowercase().as_str() {
            "debug" => Level::Debug,
            "info" => Level::Info,
            "warn" => Level::Warn,
            "error" => Level::Error,
            "off" => Level::Off,
            _ => Level::Warn,
        });
    }
}

pub fn enabled(l: Level) -> bool {
    l >= level() && level() != Level::Off
}

/// Emit one log line stamped with simulated nanoseconds.
pub fn emit(l: Level, sim_ns: u64, component: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let tag = match l {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
        Level::Off => return,
    };
    let secs = sim_ns as f64 / 1e9;
    eprintln!("[{tag} t={secs:>12.6}s] {component}: {msg}");
}

#[macro_export]
macro_rules! sim_info {
    ($t:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, $t, $comp, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! sim_debug {
    ($t:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, $t, $comp, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! sim_warn {
    ($t:expr, $comp:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, $t, $comp, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Error < Level::Off);
    }

    #[test]
    fn enabled_respects_level() {
        let prev = level();
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(prev);
    }
}
