//! Mini property-based testing harness.
//!
//! The offline vendor set has no `proptest`/`quickcheck`, so this module
//! provides the subset the test suite needs: seeded case generation,
//! configurable case counts, and a greedy shrink loop for integer inputs.
//! Failures report the seed + shrunken counterexample so they can be
//! replayed deterministically.
//!
//! Usage:
//! ```ignore
//! prop::check(200, |g| {
//!     let n = g.u64_in(1..1000);
//!     let v = g.vec_u64(0..50, 0..100);
//!     prop::assert_prop(invariant(n, &v), &format!("n={n} v={v:?}"));
//! });
//! ```

use super::rng::SplitMix64;
use std::ops::Range;

/// Case generator handed to the property closure.
pub struct Gen {
    rng: SplitMix64,
    /// Log of drawn integers, used by the shrinker.
    pub draws: Vec<u64>,
    /// When replaying a shrunk case, draws come from here instead.
    replay: Option<Vec<u64>>,
    replay_idx: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), draws: Vec::new(), replay: None, replay_idx: 0 }
    }

    fn from_replay(draws: Vec<u64>) -> Self {
        Self {
            rng: SplitMix64::new(0),
            draws: Vec::new(),
            replay: Some(draws),
            replay_idx: 0,
        }
    }

    fn draw(&mut self, max_exclusive: u64) -> u64 {
        let v = if let Some(r) = &self.replay {
            let raw = r.get(self.replay_idx).copied().unwrap_or(0);
            self.replay_idx += 1;
            if max_exclusive == 0 { 0 } else { raw % max_exclusive }
        } else if max_exclusive == 0 {
            0
        } else {
            self.rng.gen_range(max_exclusive)
        };
        self.draws.push(v);
        v
    }

    /// Uniform u64 in [range.start, range.end).
    pub fn u64_in(&mut self, range: Range<u64>) -> u64 {
        assert!(range.end > range.start);
        range.start + self.draw(range.end - range.start)
    }

    /// Uniform usize in range.
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        self.u64_in(range.start as u64..range.end as u64) as usize
    }

    /// Uniform f64 in [0,1) with 32-bit granularity (shrinkable).
    pub fn unit_f64(&mut self) -> f64 {
        self.draw(1 << 32) as f64 / (1u64 << 32) as f64
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.unit_f64()
    }

    pub fn bool(&mut self) -> bool {
        self.draw(2) == 1
    }

    /// Vec of u64 with length in len_range, elements in elem_range.
    pub fn vec_u64(&mut self, len_range: Range<usize>, elem_range: Range<u64>) -> Vec<u64> {
        let n = self.usize_in(len_range);
        (0..n).map(|_| self.u64_in(elem_range.clone())).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0..xs.len())]
    }
}

/// The outcome of one property evaluation.
pub enum Outcome {
    Pass,
    Fail(String),
}

/// Run `cases` random cases of `prop`. Panics with seed + shrunk
/// counterexample on failure.  Base seed comes from GRIDLAN_PROP_SEED or
/// defaults to a fixed constant (deterministic CI).
pub fn check<F>(cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Outcome,
{
    let base_seed = std::env::var("GRIDLAN_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00u64);
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen::new(seed);
        if let Outcome::Fail(msg) = prop(&mut g) {
            // Shrink: greedily try to reduce each drawn integer.
            let shrunk = shrink(&g.draws, &prop);
            let mut rg = Gen::from_replay(shrunk.clone());
            let final_msg = match prop(&mut rg) {
                Outcome::Fail(m) => m,
                Outcome::Pass => msg,
            };
            panic!(
                "property failed (seed={seed}, case={case})\n  counterexample: {final_msg}\n  draws={shrunk:?}"
            );
        }
    }
}

fn shrink<F>(draws: &[u64], prop: &F) -> Vec<u64>
where
    F: Fn(&mut Gen) -> Outcome,
{
    let mut current = draws.to_vec();
    let mut improved = true;
    let mut budget = 200usize;
    while improved && budget > 0 {
        improved = false;
        for i in 0..current.len() {
            if current[i] == 0 {
                continue;
            }
            // Binary-search-style: try 0, then x-delta for halving deltas —
            // converges to the minimal failing value per position.
            let x = current[i];
            let mut candidates = vec![0u64];
            let mut delta = x / 2;
            while delta > 0 {
                candidates.push(x - delta);
                delta /= 2;
            }
            for candidate in candidates {
                if candidate >= current[i] {
                    continue;
                }
                budget = budget.saturating_sub(1);
                let mut trial = current.clone();
                trial[i] = candidate;
                let mut g = Gen::from_replay(trial.clone());
                if matches!(prop(&mut g), Outcome::Fail(_)) {
                    current = trial;
                    improved = true;
                    break;
                }
            }
        }
    }
    current
}

/// Helper: build an Outcome from a boolean.
pub fn expect(ok: bool, describe: &str) -> Outcome {
    if ok {
        Outcome::Pass
    } else {
        Outcome::Fail(describe.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(100, |g| {
            let a = g.u64_in(0..1000);
            let b = g.u64_in(0..1000);
            expect(a + b >= a, "addition monotone")
        });
    }

    #[test]
    fn vec_gen_in_bounds() {
        check(50, |g| {
            let v = g.vec_u64(0..10, 5..15);
            expect(v.len() < 10 && v.iter().all(|&x| (5..15).contains(&x)), "bounds")
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check(100, |g| {
            let a = g.u64_in(0..1000);
            expect(a < 500, &format!("a={a}"))
        });
    }

    #[test]
    fn shrinker_finds_small_case() {
        // The minimal failing 'a >= 500' under replay-mod semantics is 500.
        let prop = |g: &mut Gen| {
            let a = g.u64_in(0..1000);
            expect(a < 500, &format!("a={a}"))
        };
        let shrunk = shrink(&[777], &prop);
        assert_eq!(shrunk, vec![500]);
    }

    #[test]
    fn choose_and_bool() {
        check(50, |g| {
            let x = *g.choose(&[1, 2, 3]);
            let b = g.bool();
            expect([1, 2, 3].contains(&x) && (b || !b), "choose in set")
        });
    }
}
