//! Small statistics toolkit used by the benchmark harnesses.
//!
//! Everything the paper reports is a mean with a parenthesised standard
//! deviation — e.g. `550(20) µs` — so [`Summary`] carries exactly that,
//! plus percentiles for the latency benches.
//!
//! All accessors are **total**: on an empty sample set `mean`, `std`,
//! `min`, `max` and `percentile` return `0.0` (documented, not `NaN`), so
//! downstream JSON serialization never has to special-case emptiness.

use crate::util::json::{obj, Json};

/// Running summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        Self { samples: xs.to_vec() }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Arithmetic mean; 0.0 on an empty sample set.
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1 denominator; 0 for n<2).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    /// Smallest sample; 0.0 on an empty sample set.
    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample; 0.0 on an empty sample set.
    pub fn max(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0,100]; 0.0 on an empty set.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    /// Median (50th percentile).
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 99th percentile — the tail the latency benches track.
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// The summary as a JSON object `{n, mean, sd, p50, p99}` — the shape
    /// the bench harness embeds in every `BENCH_*.json` series.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("n", Json::Num(self.len() as f64)),
            ("mean", Json::Num(self.mean())),
            ("sd", Json::Num(self.std())),
            ("p50", Json::Num(self.p50())),
            ("p99", Json::Num(self.p99())),
        ])
    }

    /// Paper-style "mean(std)" with std rounded to the same scale the paper
    /// uses, e.g. `550(20)`.
    pub fn paper_format(&self, round_to: f64) -> String {
        let m = (self.mean() / round_to).round() * round_to;
        let s = (self.std() / round_to).round() * round_to;
        format!("{}({})", fmt_sig(m), fmt_sig(s))
    }
}

fn fmt_sig(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.1}")
    }
}

/// Ordinary least squares fit y = a + b x; returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(n >= 2.0, "linfit needs >= 2 points");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Weighted geometric mean of ratios — used to compare curve shapes.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let s = Summary::from_slice(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolation() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 4.0).abs() < 1e-12);
        assert!((s.percentile(50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn paper_format_rounds() {
        let s = Summary::from_slice(&[548.0, 553.0, 549.0, 551.0]);
        assert_eq!(s.paper_format(10.0), "550(0)");
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_total() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.p50(), 0.0);
        assert_eq!(s.p99(), 0.0);
    }

    #[test]
    fn p50_p99_match_percentile() {
        let s = Summary::from_slice(&(1..=100).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(s.p50(), s.percentile(50.0));
        assert_eq!(s.p99(), s.percentile(99.0));
        assert!((s.p50() - 50.5).abs() < 1e-12);
        assert!((s.p99() - 99.01).abs() < 1e-12);
    }

    #[test]
    fn to_json_shape_and_values() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let j = s.to_json();
        assert_eq!(j.get("n").unwrap().as_u64(), Some(4));
        assert!((j.get("mean").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
        assert!((j.get("p50").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-12);
        assert!(j.get("sd").unwrap().as_f64().is_some());
        assert!(j.get("p99").unwrap().as_f64().is_some());
        // empty summary serializes finite zeros, never NaN
        let e = Summary::new().to_json();
        assert_eq!(e.get("mean").unwrap().as_f64(), Some(0.0));
        assert_eq!(e.to_string(), r#"{"n":0,"mean":0,"sd":0,"p50":0,"p99":0}"#);
    }
}
