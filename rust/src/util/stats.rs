//! Small statistics toolkit used by the benchmark harnesses.
//!
//! Everything the paper reports is a mean with a parenthesised standard
//! deviation — e.g. `550(20) µs` — so [`Summary`] carries exactly that,
//! plus percentiles for the latency benches.

/// Running summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        Self { samples: xs.to_vec() }
    }

    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1 denominator; 0 for n<2).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let ss: f64 = self.samples.iter().map(|x| (x - m) * (x - m)).sum();
        (ss / (n - 1) as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    /// Paper-style "mean(std)" with std rounded to the same scale the paper
    /// uses, e.g. `550(20)`.
    pub fn paper_format(&self, round_to: f64) -> String {
        let m = (self.mean() / round_to).round() * round_to;
        let s = (self.std() / round_to).round() * round_to;
        format!("{}({})", fmt_sig(m), fmt_sig(s))
    }
}

fn fmt_sig(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.1}")
    }
}

/// Ordinary least squares fit y = a + b x; returns (a, b, r2).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    assert!(n >= 2.0, "linfit needs >= 2 points");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    let b = sxy / sxx;
    let a = my - b * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Weighted geometric mean of ratios — used to compare curve shapes.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_basic() {
        let s = Summary::from_slice(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolation() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((s.percentile(100.0) - 4.0).abs() < 1e-12);
        assert!((s.percentile(50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn paper_format_rounds() {
        let s = Summary::from_slice(&[548.0, 553.0, 549.0, 551.0]);
        assert_eq!(s.paper_format(10.0), "550(0)");
    }

    #[test]
    fn linfit_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_nan() {
        assert!(Summary::new().mean().is_nan());
    }
}
