//! ASCII table formatting for the benchmark reports.
//!
//! The bench harnesses print rows shaped like the paper's tables; this
//! keeps column alignment without pulling in a crate.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple table builder.
#[derive(Debug, Clone)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            aligns: header.iter().map(|_| Align::Left).collect(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn title(mut self, t: &str) -> Self {
        self.title = Some(t.to_string());
        self
    }

    pub fn align(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String], aligns: &[Align]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                line.push(' ');
                match aligns[i] {
                    Align::Left => {
                        line.push_str(&cells[i]);
                        line.push_str(&" ".repeat(pad));
                    }
                    Align::Right => {
                        line.push_str(&" ".repeat(pad));
                        line.push_str(&cells[i]);
                    }
                }
                line.push(' ');
                if i + 1 < ncol {
                    line.push('|');
                }
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &self.aligns));
            out.push('\n');
        }
        out
    }
}

/// Format microseconds like the paper: `550(20) µs`.
pub fn us_paper(mean_us: f64, std_us: f64, round: f64) -> String {
    let m = (mean_us / round).round() * round;
    let s = (std_us / round).round() * round;
    format!("{}({}) µs", m as i64, s as i64)
}

/// Format a duration in seconds with adaptive precision.
pub fn secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Node", "Cores"]).align(&[Align::Left, Align::Right]);
        t.row_strs(&["n01", "12"]);
        t.row_strs(&["n02", "6"]);
        let out = t.render();
        assert!(out.contains("n01"));
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 4);
        // all rows same width
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn us_paper_format() {
        assert_eq!(us_paper(548.7, 21.2, 10.0), "550(20) µs");
        assert_eq!(us_paper(1250.0, 30.0, 10.0), "1250(30) µs");
    }

    #[test]
    fn secs_ranges() {
        assert!(secs(0.0000005).contains("µs"));
        assert!(secs(0.05).contains("ms"));
        assert!(secs(12.0).contains("s"));
        assert!(secs(300.0).contains("min"));
    }

    #[test]
    fn unicode_width_alignment() {
        let mut t = Table::new(&["lat"]);
        t.row_strs(&["550(20) µs"]);
        t.row_strs(&["1250(30) µs"]);
        let out = t.render();
        assert!(out.lines().count() == 4);
    }
}
