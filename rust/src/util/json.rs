//! Self-contained JSON parser and serializer.
//!
//! The offline vendor set has no `serde`, so the config system and the
//! artifact manifest reader use this minimal but complete implementation:
//! full RFC 8259 syntax (objects, arrays, strings with escapes incl.
//! `\uXXXX` and surrogate pairs, numbers, bools, null), with object key
//! order preserved (insertion order) for stable round-trips.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object; key order preserved via parallel Vec, with a map for lookup.
    Obj(JsonObj),
}

/// Order-preserving JSON object.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct JsonObj {
    keys: Vec<String>,
    map: BTreeMap<String, Json>,
}

impl JsonObj {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: &str, value: Json) {
        if !self.map.contains_key(key) {
            self.keys.push(key.to_string());
        }
        self.map.insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn len(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Json)> {
        self.keys.iter().map(move |k| (k, &self.map[k]))
    }
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ----------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&JsonObj> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style path lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ------------------------------------------------------------- parsing

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // --------------------------------------------------------- serializing

    /// Compact serialization.  (Deliberately an inherent method — `Json`
    /// has no Display impl, and the call sites read naturally.)
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.i = self.i.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut obj = JsonObj::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            obj.insert(&key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => {
                    self.i = self.i.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    if end > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Convenience: build an object from (key, value) pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut o = JsonObj::new();
    for (k, v) in pairs {
        o.insert(k, v);
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_u64().unwrap(), 1);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"héllo wörld ≈\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo wörld ≈");
    }

    #[test]
    fn roundtrip_preserves_key_order() {
        let src = r#"{"z":1,"a":2,"m":3}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string(), src);
    }

    #[test]
    fn roundtrip_pretty_reparses() {
        let src = r#"{"a":[1,2.5,true,null,"s"],"b":{"c":[]}}"#;
        let v = Json::parse(src).unwrap();
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn errors_have_offsets() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6, "offset={}", e.offset);
        assert!(Json::parse("[1,2").is_err());
        assert!(Json::parse("[1,2]]").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap().as_obj().unwrap().len(), 0);
    }

    #[test]
    fn numbers_int_float_boundary() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-7").unwrap().as_i64(), Some(-7));
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1e2").unwrap().as_u64(), Some(100));
    }

    #[test]
    fn obj_helper() {
        let v = obj(vec![("x", Json::Num(1.0)), ("y", Json::Str("s".into()))]);
        assert_eq!(v.get("x").unwrap().as_u64(), Some(1));
    }
}
