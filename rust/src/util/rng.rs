//! Deterministic pseudo-random number generation for the simulator.
//!
//! Two generators:
//!
//! * [`SplitMix64`] — fast, full-period 64-bit generator used everywhere the
//!   simulator needs noise (jitter, placement shuffles, fault injection).
//!   Deterministic per seed, so every experiment is exactly repeatable.
//! * [`NpbLcg`] — the NPB 46-bit multiplicative LCG (`x' = a*x mod 2^46`,
//!   `a = 5^13`), bit-identical to `python/compile/kernels/ref.py`.  The
//!   coordinator uses it to jump-ahead seed the EP lanes it hands to the
//!   PJRT runtime.

/// NPB EP multiplier `5^13`.
pub const NPB_A: u64 = 1_220_703_125;
/// NPB modulus is `2^46`.
pub const NPB_MASK: u64 = (1u64 << 46) - 1;
/// NPB EP canonical seed.
pub const NPB_SEED: u64 = 271_828_183;
/// `2^-46` as f64 (exact).
pub const R46: f64 = 1.0 / (1u64 << 46) as f64;

/// SplitMix64: Steele et al.'s mixing generator. Full 2^64 period.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire reduction).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Marsaglia polar method.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let x = 2.0 * self.next_f64() - 1.0;
            let y = 2.0 * self.next_f64() - 1.0;
            let t = x * x + y * y;
            if t > 0.0 && t <= 1.0 {
                return x * (-2.0 * t.ln() / t).sqrt();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-component streams).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64() ^ 0xA5A5_A5A5_DEAD_BEEF)
    }
}

/// The NPB 46-bit LCG, plus O(log n) jump-ahead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NpbLcg {
    pub state: u64,
}

impl NpbLcg {
    pub fn new(seed: u64) -> Self {
        Self { state: seed & NPB_MASK }
    }

    /// One LCG step; returns the new state (which is also the raw random).
    pub fn step(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(NPB_A) & NPB_MASK;
        self.state
    }

    /// The next uniform in (0,1): state * 2^-46 after stepping.
    pub fn next_f64(&mut self) -> f64 {
        self.step() as f64 * R46
    }

    /// `a^exp mod 2^46` by binary exponentiation.
    pub fn pow_mult(exp: u64) -> u64 {
        let mut result: u64 = 1;
        let mut base = NPB_A & NPB_MASK;
        let mut e = exp;
        while e > 0 {
            if e & 1 == 1 {
                result = result.wrapping_mul(base) & NPB_MASK;
            }
            base = base.wrapping_mul(base) & NPB_MASK;
            e >>= 1;
        }
        result
    }

    /// State after `n` steps from the current state, without iterating.
    pub fn jumped(&self, n: u64) -> NpbLcg {
        NpbLcg {
            state: self.state.wrapping_mul(Self::pow_mult(n)) & NPB_MASK,
        }
    }

    /// Per-lane seeds for an EP execution: lane `g` covers global pairs
    /// `[offset + g*ppl, offset + (g+1)*ppl)`; each pair consumes 2 randoms.
    /// Mirrors `ref.lane_seeds` + a pair offset for multi-chunk jobs.
    pub fn ep_lane_seeds(n_lanes: usize, pairs_per_lane: u64, pair_offset: u64) -> Vec<u64> {
        let base = NpbLcg::new(NPB_SEED).jumped(2 * pair_offset);
        (0..n_lanes)
            .map(|g| base.jumped(2 * (g as u64) * pairs_per_lane).state)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = SplitMix64::new(1);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.gen_range(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = SplitMix64::new(99);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn npb_lcg_first_values() {
        // Cross-checked against python ref.py (exact integers).
        let mut lcg = NpbLcg::new(NPB_SEED);
        assert_eq!(lcg.step(), 32_883_653_486_115);
        assert_eq!(lcg.step(), 55_063_727_434_591);
        assert_eq!(lcg.step(), 39_106_144_873_291);
        assert_eq!(lcg.step(), 46_899_331_031_975);
    }

    #[test]
    fn npb_jump_matches_iteration() {
        let lcg0 = NpbLcg::new(NPB_SEED);
        let mut it = lcg0;
        for k in 1..=200u64 {
            it.step();
            assert_eq!(lcg0.jumped(k).state, it.state, "k={k}");
        }
    }

    #[test]
    fn npb_pow_homomorphism() {
        for (i, j) in [(3u64, 5u64), (100, 255), (1 << 20, 1 << 13)] {
            let lhs = NpbLcg::pow_mult(i + j);
            let rhs = NpbLcg::pow_mult(i).wrapping_mul(NpbLcg::pow_mult(j)) & NPB_MASK;
            assert_eq!(lhs, rhs);
        }
    }

    #[test]
    fn ep_lane_seeds_partition() {
        // Lane seeds + per-lane iteration must reproduce the global stream.
        let (lanes, ppl) = (8usize, 3u64);
        let seeds = NpbLcg::ep_lane_seeds(lanes, ppl, 0);
        let mut global = NpbLcg::new(NPB_SEED);
        for &s in &seeds {
            let mut lane = NpbLcg::new(s);
            for _ in 0..2 * ppl {
                assert_eq!(lane.step(), global.step());
            }
        }
    }

    #[test]
    fn ep_lane_seeds_offset() {
        // Offset o must equal skipping o pairs of the global stream.
        let seeds = NpbLcg::ep_lane_seeds(4, 5, 1000);
        let direct = NpbLcg::new(NPB_SEED).jumped(2000);
        assert_eq!(seeds[0], direct.state);
    }
}
