//! Self-contained SHA-256 and HMAC-SHA256 (FIPS 180-4 / RFC 2104).
//!
//! The offline vendor set carries no crypto crates, and the VPN PKI
//! ([`crate::vpn::pki`]) needs a real keyed MAC for its trust relation.
//! This is the straightforward single-block-at-a-time implementation —
//! tags are 32 bytes and verified against the standard test vectors.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// SHA-256 digest of `data`.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = H0;
    // Padding: 0x80, zeros to 56 mod 64, then the bit length big-endian.
    let bitlen = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bitlen.to_be_bytes());

    let mut w = [0u32; 64];
    for block in msg.chunks_exact(64) {
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes(block[4 * i..4 * i + 4].try_into().unwrap());
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16].wrapping_add(s0).wrapping_add(w[i - 7]).wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh.wrapping_add(s1).wrapping_add(ch).wrapping_add(K[i]).wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (state, v) in h.iter_mut().zip([a, b, c, d, e, f, g, hh]) {
            *state = state.wrapping_add(v);
        }
    }

    let mut out = [0u8; 32];
    for (chunk, v) in out.chunks_exact_mut(4).zip(h) {
        chunk.copy_from_slice(&v.to_be_bytes());
    }
    out
}

/// HMAC-SHA256 over the concatenation of `parts` (multi-field API so
/// callers don't have to pre-concatenate their message fields).
pub fn hmac_sha256(key: &[u8], parts: &[&[u8]]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        k[..32].copy_from_slice(&sha256(key));
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut inner = Vec::with_capacity(64 + parts.iter().map(|p| p.len()).sum::<usize>());
    for b in k {
        inner.push(b ^ 0x36);
    }
    for p in parts {
        inner.extend_from_slice(p);
    }
    let inner_hash = sha256(&inner);
    let mut outer = Vec::with_capacity(96);
    for b in k {
        outer.push(b ^ 0x5c);
    }
    outer.extend_from_slice(&inner_hash);
    sha256(&outer)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hex(bytes: &[u8]) -> String {
        bytes.iter().map(|b| format!("{b:02x}")).collect()
    }

    #[test]
    fn fips_vectors() {
        assert_eq!(
            hex(&sha256(b"")),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex(&sha256(b"abc")),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex(&sha256(b"The quick brown fox jumps over the lazy dog")),
            "d7a8fbb307d7809469ca9abcb0082e4f8d5651e46d3cdb762d02d0bf37c9e592"
        );
    }

    #[test]
    fn padding_boundaries() {
        // 55/56/64 bytes straddle the length-field block boundary.
        assert_eq!(
            hex(&sha256(&[b'x'; 55])),
            "d5e285683cd4efc02d021a5c62014694958901005d6f71e89e0989fac77e4072"
        );
        assert_eq!(
            hex(&sha256(&[b'x'; 56])),
            "04c26261370ee7541549d16dee320c723e3fd14671e66a099afe0a377c16888e"
        );
        assert_eq!(
            hex(&sha256(&[b'x'; 64])),
            "7ce100971f64e7001e8fe5a51973ecdfe1ced42befe7ee8d5fd6219506b5393c"
        );
    }

    #[test]
    fn rfc4231_case_1() {
        // RFC 4231 test case 1: key = 20x 0x0b, data = "Hi There".
        let tag = hmac_sha256(&[0x0b; 20], &[b"Hi There"]);
        assert_eq!(
            hex(&tag),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn hmac_long_key_and_split_parts() {
        // Keys longer than the block size are pre-hashed.
        let long = vec![b'k'; 100];
        let t1 = hmac_sha256(&long, &[b"mm", b"mm"]);
        let t2 = hmac_sha256(&long, &[b"mmmm"]);
        assert_eq!(t1, t2);
        // Keyedness: different keys give different tags.
        assert_ne!(hmac_sha256(b"a", &[b"x"]), hmac_sha256(b"b", &[b"x"]));
        // Message sensitivity.
        assert_ne!(hmac_sha256(b"k", &[b"x"]), hmac_sha256(b"k", &[b"y"]));
    }
}
