//! Foundation utilities: deterministic RNG, statistics, JSON, tables,
//! logging, and a mini property-testing harness (the offline vendor set
//! carries none of the usual crates — see DESIGN.md §6).

pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod sha256;
pub mod stats;
pub mod table;
