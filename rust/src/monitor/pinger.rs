//! The server's 5-minute ping sweep (paper §2.6).
//!
//! Holds the authoritative node state table: for each node, the last
//! observed state and when it changed.  The coordinator calls
//! [`Pinger::sweep`] on the monitor period with the set of nodes that
//! answered (derived from VPN connectivity + VM state).

use crate::sim::clock::{SimTime, DUR_SEC};
use std::collections::BTreeMap;

/// Observed state of one node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeStatus {
    On,
    Off,
    /// Never observed yet.
    Unknown,
}

/// Node state table + sweep bookkeeping.
#[derive(Debug, Clone)]
pub struct Pinger {
    pub period: SimTime,
    states: BTreeMap<String, (NodeStatus, SimTime)>,
    pub sweeps: u64,
    /// (node, at, old, new) transitions, for the fault benches.
    pub transitions: Vec<(String, SimTime, NodeStatus, NodeStatus)>,
}

impl Pinger {
    pub fn new(nodes: &[String]) -> Self {
        Self {
            period: 300 * DUR_SEC, // the paper's 5 minutes
            states: nodes.iter().map(|n| (n.clone(), (NodeStatus::Unknown, 0))).collect(),
            sweeps: 0,
            transitions: Vec::new(),
        }
    }

    /// One sweep: `responders(name) -> bool` says whether the ping to that
    /// node came back.
    pub fn sweep<F: Fn(&str) -> bool>(&mut self, now: SimTime, responders: F) {
        self.sweeps += 1;
        for (name, entry) in self.states.iter_mut() {
            let new = if responders(name) { NodeStatus::On } else { NodeStatus::Off };
            if entry.0 != new {
                self.transitions.push((name.clone(), now, entry.0, new));
                *entry = (new, now);
            }
        }
    }

    pub fn status(&self, node: &str) -> NodeStatus {
        self.states.get(node).map(|&(s, _)| s).unwrap_or(NodeStatus::Unknown)
    }

    /// When did the node last change state?
    pub fn since(&self, node: &str) -> Option<SimTime> {
        self.states.get(node).map(|&(_, t)| t)
    }

    pub fn on_nodes(&self) -> Vec<String> {
        self.states
            .iter()
            .filter(|(_, &(s, _))| s == NodeStatus::On)
            .map(|(n, _)| n.clone())
            .collect()
    }

    /// Detection latency bound: a node that dies right after a sweep is
    /// discovered at most one period later.
    pub fn worst_case_detection(&self) -> SimTime {
        self.period
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nodes() -> Vec<String> {
        vec!["n01".into(), "n02".into()]
    }

    #[test]
    fn initial_state_unknown() {
        let p = Pinger::new(&nodes());
        assert_eq!(p.status("n01"), NodeStatus::Unknown);
        assert_eq!(p.status("nope"), NodeStatus::Unknown);
    }

    #[test]
    fn sweep_updates_states_and_transitions() {
        let mut p = Pinger::new(&nodes());
        p.sweep(300, |n| n == "n01");
        assert_eq!(p.status("n01"), NodeStatus::On);
        assert_eq!(p.status("n02"), NodeStatus::Off);
        assert_eq!(p.transitions.len(), 2);
        // Same result next sweep: no new transitions.
        p.sweep(600, |n| n == "n01");
        assert_eq!(p.transitions.len(), 2);
        // n02 comes up.
        p.sweep(900, |_| true);
        assert_eq!(p.transitions.len(), 3);
        assert_eq!(p.since("n02"), Some(900));
    }

    #[test]
    fn on_nodes_listing() {
        let mut p = Pinger::new(&nodes());
        p.sweep(1, |_| true);
        assert_eq!(p.on_nodes(), vec!["n01".to_string(), "n02".to_string()]);
    }

    #[test]
    fn default_period_is_five_minutes() {
        assert_eq!(Pinger::new(&nodes()).period, 300 * DUR_SEC);
    }
}
