//! Job resilience: the qsub-script-folder technique (paper §4).
//!
//! "One technique to improve the resilience of submitted jobs is to write
//! all the qsub scripts in a temporary folder.  The last qsub script
//! command must be to delete (or rename) the script.  In this way, the
//! unfinished job's scripts will still remain in the scripts folder and
//! can be restarted later."
//!
//! The folder lives in the server's filesystem image; entries map script
//! paths to the submitted job and its script text, so `recover` can
//! re-submit survivors verbatim.

use crate::boot::fsimage::FsImage;
use crate::rm::job::JobId;
use crate::rm::script::PbsScript;
use std::collections::BTreeMap;

/// The scripts folder.
#[derive(Debug, Clone)]
pub struct ScriptFolder {
    pub dir: String,
    entries: BTreeMap<String, (JobId, String)>, // path -> (job, script text)
    next_seq: u64,
}

impl ScriptFolder {
    pub fn new(dir: &str) -> Self {
        Self { dir: dir.to_string(), entries: BTreeMap::new(), next_seq: 1 }
    }

    /// Called right after qsub: drop the script into the folder.
    pub fn register(&mut self, fs: &mut FsImage, job: JobId, script: &PbsScript) -> String {
        let path = format!("{}/job-{:06}.sh", self.dir, self.next_seq);
        self.next_seq += 1;
        let text = script.render();
        fs.write(&path, text.len() as u64);
        self.entries.insert(path.clone(), (job, text));
        path
    }

    /// The job's last command ran: remove its script (job completed OK).
    pub fn job_completed(&mut self, fs: &mut FsImage, job: JobId) {
        let paths: Vec<String> = self
            .entries
            .iter()
            .filter(|(_, (j, _))| *j == job)
            .map(|(p, _)| p.clone())
            .collect();
        for p in paths {
            fs.remove(&p);
            self.entries.remove(&p);
        }
    }

    /// Scripts still present = jobs that never finished.  Returns their
    /// parsed scripts for re-submission (and reassigns folder ownership to
    /// the new job ids via `register`).
    pub fn survivors(&self) -> Vec<(JobId, PbsScript)> {
        self.entries
            .values()
            .filter_map(|(job, text)| PbsScript::parse(text).ok().map(|s| (*job, s)))
            .collect()
    }

    /// Re-key a survivor to its re-submitted job id.
    pub fn rebind(&mut self, old: JobId, new: JobId) {
        for entry in self.entries.values_mut() {
            if entry.0 == old {
                entry.0 = new;
            }
        }
    }

    pub fn pending_count(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn script() -> PbsScript {
        PbsScript::parse("#PBS -N mc\n#PBS -q gridlan\n#PBS -l nodes=1:ppn=2\n./mc.x\n").unwrap()
    }

    #[test]
    fn completed_jobs_leave_no_trace() {
        let mut fs = FsImage::new();
        let mut folder = ScriptFolder::new("/var/spool/gridlan");
        let p = folder.register(&mut fs, JobId(1), &script());
        assert!(fs.exists(&p));
        folder.job_completed(&mut fs, JobId(1));
        assert!(!fs.exists(&p));
        assert_eq!(folder.pending_count(), 0);
    }

    #[test]
    fn unfinished_jobs_survive() {
        let mut fs = FsImage::new();
        let mut folder = ScriptFolder::new("/var/spool/gridlan");
        folder.register(&mut fs, JobId(1), &script());
        folder.register(&mut fs, JobId(2), &script());
        folder.job_completed(&mut fs, JobId(1));
        let survivors = folder.survivors();
        assert_eq!(survivors.len(), 1);
        assert_eq!(survivors[0].0, JobId(2));
        assert_eq!(survivors[0].1.name.as_deref(), Some("mc"));
    }

    #[test]
    fn rebind_after_resubmission() {
        let mut fs = FsImage::new();
        let mut folder = ScriptFolder::new("/spool");
        folder.register(&mut fs, JobId(2), &script());
        folder.rebind(JobId(2), JobId(7));
        // Now completing job 7 clears the folder.
        folder.job_completed(&mut fs, JobId(7));
        assert_eq!(folder.pending_count(), 0);
    }

    #[test]
    fn survivor_scripts_parse_back() {
        let mut fs = FsImage::new();
        let mut folder = ScriptFolder::new("/spool");
        folder.register(&mut fs, JobId(3), &script());
        let (_, s) = &folder.survivors()[0];
        assert_eq!(s.request.total_cores(), 2);
        assert_eq!(s.queue.as_deref(), Some("gridlan"));
    }
}
