//! Status service: answers the client watchdog's question "is my node on?"
//! from the pinger's state table (paper §2.6: "A script in the client
//! machine asks the server if the virtual machine ... is on").

use super::pinger::{NodeStatus, Pinger};

/// Thin query facade over the pinger table, with a client→node mapping
/// (each client hosts exactly one node in the paper's design).
#[derive(Debug, Clone, Default)]
pub struct StatusService {
    /// client name → node name.
    bindings: std::collections::BTreeMap<String, String>,
    pub queries: u64,
}

impl StatusService {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn bind(&mut self, client: &str, node: &str) {
        self.bindings.insert(client.to_string(), node.to_string());
    }

    /// The watchdog's query. `None` = unknown client (not provisioned).
    pub fn is_node_on(&mut self, pinger: &Pinger, client: &str) -> Option<bool> {
        self.queries += 1;
        let node = self.bindings.get(client)?;
        match pinger.status(node) {
            NodeStatus::On => Some(true),
            NodeStatus::Off => Some(false),
            // Conservative: an unknown node is reported off so the
            // watchdog boots it (first start-up case).
            NodeStatus::Unknown => Some(false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn answers_from_pinger_table() {
        let mut svc = StatusService::new();
        svc.bind("client01", "n01");
        let mut pinger = Pinger::new(&["n01".to_string()]);
        pinger.sweep(300, |_| true);
        assert_eq!(svc.is_node_on(&pinger, "client01"), Some(true));
        pinger.sweep(600, |_| false);
        assert_eq!(svc.is_node_on(&pinger, "client01"), Some(false));
        assert_eq!(svc.queries, 2);
    }

    #[test]
    fn unknown_client_is_none() {
        let mut svc = StatusService::new();
        let pinger = Pinger::new(&[]);
        assert_eq!(svc.is_node_on(&pinger, "stranger"), None);
    }

    #[test]
    fn unknown_node_reports_off() {
        let mut svc = StatusService::new();
        svc.bind("client01", "n01");
        let pinger = Pinger::new(&["n01".to_string()]); // never swept
        assert_eq!(svc.is_node_on(&pinger, "client01"), Some(false));
    }
}
