//! Server-side monitoring and fault tolerance (paper §2.6, §4).
//!
//! * [`pinger`] — "a script pings each node, saving the node state (on or
//!   off).  This procedure is executed every 5 minutes";
//! * [`statusd`] — the query service the client watchdog asks ("is my VM
//!   on?");
//! * [`resilience`] — the §4 qsub-script-folder technique: scripts live in
//!   a folder until their job completes; survivors after a crash are
//!   requeued.

pub mod pinger;
pub mod resilience;
pub mod statusd;

pub use pinger::{NodeStatus, Pinger};
pub use resilience::ScriptFolder;
pub use statusd::StatusService;
