//! Multi-threaded compute backend: one EP pair range fanned out over N OS
//! threads.
//!
//! EP is embarrassingly parallel and the NPB LCG has O(log n) jump-ahead,
//! so a `[offset, offset+count)` range splits into contiguous per-thread
//! spans with zero communication — the same decomposition the paper uses
//! across Gridlan nodes, applied across cores of one host.  Exactness is
//! preserved: integer tally fields are bit-identical to the scalar oracle
//! for any thread count, and the float sums agree to round-off because
//! each span is summed in stream order and spans merge in index order
//! (deterministic association).
//!
//! Plain `std::thread::scope` — no external dependencies, threads live
//! only for the duration of one `run_pairs` call.

use super::backend::{ComputeBackend, ScalarBackend, SCALAR_CHUNK_PAIRS};
use crate::workload::ep::EpTally;
use std::time::Instant;

/// The multi-threaded pure-Rust backend.
#[derive(Debug, Clone)]
pub struct ThreadedBackend {
    threads: usize,
    chunk_pairs: u64,
    pairs: u64,
    secs: f64,
}

impl ThreadedBackend {
    /// A backend fanning work over `threads` OS threads.
    pub fn new(threads: usize) -> Self {
        Self::with_chunk(threads, SCALAR_CHUNK_PAIRS)
    }

    /// Same, with an explicit per-thread chunk granularity (tests sweep
    /// this to prove the geometry is invisible, like the scalar backend).
    pub fn with_chunk(threads: usize, chunk_pairs: u64) -> Self {
        assert!(threads >= 1, "threads must be >= 1");
        assert!(chunk_pairs > 0, "chunk_pairs must be >= 1");
        Self { threads, chunk_pairs, pairs: 0, secs: 0.0 }
    }

    /// Configured thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Hardware parallelism of this host (>= 1).
    pub fn available() -> usize {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }

    /// Split `[offset, offset+count)` into at most `n` contiguous spans,
    /// remainder spread over the first spans (the NPB-MPI partition rule).
    fn spans(offset: u64, count: u64, n: u64) -> Vec<(u64, u64)> {
        let n = n.clamp(1, count.max(1));
        let base = count / n;
        let rem = count % n;
        let mut out = Vec::with_capacity(n as usize);
        let mut at = offset;
        for i in 0..n {
            let c = base + u64::from(i < rem);
            if c > 0 {
                out.push((at, c));
                at += c;
            }
        }
        out
    }
}

impl ComputeBackend for ThreadedBackend {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn run_pairs(&mut self, offset: u64, count: u64) -> Result<EpTally, String> {
        let t0 = Instant::now();
        let spans = Self::spans(offset, count, self.threads as u64);
        let chunk = self.chunk_pairs;
        let tally = std::thread::scope(|scope| {
            // Each worker is a private ScalarBackend over its span, so the
            // chunked stream-order execution path stays single-sourced.
            let handles: Vec<_> = spans
                .iter()
                .map(|&(off, cnt)| {
                    scope.spawn(move || ScalarBackend::with_chunk(chunk).run_pairs(off, cnt))
                })
                .collect();
            let mut total = EpTally::default();
            for h in handles {
                let t = h.join().map_err(|_| "EP worker thread panicked".to_string())??;
                total.merge(&t); // span (index) order: deterministic float association
            }
            Ok::<EpTally, String>(total)
        })?;
        self.secs += t0.elapsed().as_secs_f64();
        self.pairs += count;
        Ok(tally)
    }

    fn pairs_executed(&self) -> u64 {
        self.pairs
    }

    fn compute_secs(&self) -> f64 {
        self.secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ep::ep_scalar;

    #[test]
    fn matches_oracle_for_any_thread_count() {
        let reference = ep_scalar(7_000, 190_001);
        for threads in [1usize, 2, 3, 5, 8] {
            let mut b = ThreadedBackend::new(threads);
            let t = b.run_pairs(7_000, 190_001).unwrap();
            assert_eq!(t.nacc, reference.nacc, "threads={threads}");
            assert_eq!(t.q, reference.q, "threads={threads}");
            assert_eq!(t.pairs, reference.pairs, "threads={threads}");
            assert!((t.sx - reference.sx).abs() < 1e-7, "threads={threads}");
            assert!((t.sy - reference.sy).abs() < 1e-7, "threads={threads}");
        }
    }

    #[test]
    fn single_thread_is_bit_identical_to_scalar_chunking() {
        // threads=1 with the default chunk does exactly what ScalarBackend
        // does: the same chunk sums merged in the same order.
        use crate::runtime::backend::ScalarBackend;
        let mut s = ScalarBackend::new();
        let mut t = ThreadedBackend::new(1);
        let a = s.run_pairs(123, 200_000).unwrap();
        let b = t.run_pairs(123, 200_000).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = ThreadedBackend::new(4);
        let mut b = ThreadedBackend::new(4);
        assert_eq!(a.run_pairs(0, 300_000).unwrap(), b.run_pairs(0, 300_000).unwrap());
    }

    #[test]
    fn spans_partition_exactly() {
        for (count, n) in [(100u64, 7u64), (3, 8), (1 << 20, 4), (1, 1)] {
            let spans = ThreadedBackend::spans(50, count, n);
            let total: u64 = spans.iter().map(|&(_, c)| c).sum();
            assert_eq!(total, count);
            let mut at = 50u64;
            for &(off, c) in &spans {
                assert_eq!(off, at, "contiguous");
                assert!(c > 0, "no empty spans");
                at += c;
            }
            assert!(spans.len() as u64 <= n.min(count));
        }
    }

    #[test]
    fn more_threads_than_pairs_degenerates_cleanly() {
        let mut b = ThreadedBackend::new(64);
        let t = b.run_pairs(0, 3).unwrap();
        assert_eq!(t.nacc, ep_scalar(0, 3).nacc);
        assert_eq!(b.pairs_executed(), 3);
    }

    #[test]
    fn zero_pairs_is_empty_tally() {
        let mut b = ThreadedBackend::new(4);
        let t = b.run_pairs(10, 0).unwrap();
        assert_eq!(t, EpTally::default());
    }

    #[test]
    fn accounting_accumulates() {
        let mut b = ThreadedBackend::new(2);
        b.run_pairs(0, 1 << 16).unwrap();
        b.run_pairs(1 << 16, 1 << 16).unwrap();
        assert_eq!(b.pairs_executed(), 2 << 16);
        assert!(b.compute_secs() > 0.0);
        assert!(b.measured_rate_mpairs().unwrap() > 0.01);
    }

    #[test]
    #[should_panic(expected = "threads")]
    fn zero_threads_rejected() {
        ThreadedBackend::new(0);
    }
}
