//! The pluggable compute backend: who actually executes EP pair ranges.
//!
//! The resource-management fabric (RM, scheduler, scenario runner) is
//! decoupled from the compute payload behind [`ComputeBackend`], mirroring
//! how grid middleware separates brokering from execution.  Three
//! implementations exist:
//!
//! * [`ScalarBackend`] — pure Rust, zero external dependencies, always
//!   available: the `workload::ep::ep_scalar` oracle run in cache-friendly
//!   chunks.  What deterministic scenario runs and CI exercise.
//! * [`ThreadedBackend`](super::threaded::ThreadedBackend) — the same
//!   oracle fanned over N OS threads (`std::thread`, still zero deps) with
//!   an exact merge; the default on multi-core hosts.
//! * [`PjrtBackend`](super::pjrt::PjrtBackend) (`--features pjrt`) — the
//!   AOT HLO artifact path; needs `make artifacts` plus the external
//!   `xla` crate (see runtime/pjrt.rs for the gating story).

use crate::workload::ep::{ep_scalar, EpTally};
use std::time::Instant;

/// An executor of EP work, identified by pair ranges in the global NPB
/// random stream.  Implementations must be *exact*: the tally over
/// `[offset, offset+count)` equals the scalar oracle's, bit-for-bit on the
/// integer fields and to float round-off on the sums.
pub trait ComputeBackend {
    /// Short human-readable backend name ("scalar", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Execute EP over global pairs `[offset, offset + count)`.
    fn run_pairs(&mut self, offset: u64, count: u64) -> Result<EpTally, String>;

    /// Total pairs executed by this backend so far.
    fn pairs_executed(&self) -> u64;

    /// Wall time spent inside compute calls, seconds.
    fn compute_secs(&self) -> f64;

    /// Measured throughput so far (Mpairs/s); None before any run.
    fn measured_rate_mpairs(&self) -> Option<f64> {
        if self.compute_secs() > 0.0 && self.pairs_executed() > 0 {
            Some(self.pairs_executed() as f64 / self.compute_secs() / 1e6)
        } else {
            None
        }
    }
}

/// Default chunk granularity for the scalar backend: large enough to
/// amortize the jump-ahead seek, small enough to keep tallies in cache.
pub const SCALAR_CHUNK_PAIRS: u64 = 1 << 16;

/// The always-available pure-Rust backend.
#[derive(Debug, Clone)]
pub struct ScalarBackend {
    chunk_pairs: u64,
    pairs: u64,
    secs: f64,
}

impl ScalarBackend {
    pub fn new() -> Self {
        Self::with_chunk(SCALAR_CHUNK_PAIRS)
    }

    /// A backend that executes in chunks of `chunk_pairs` (the tests sweep
    /// this to prove tally merging is geometry-independent).
    pub fn with_chunk(chunk_pairs: u64) -> Self {
        assert!(chunk_pairs > 0, "chunk_pairs must be >= 1");
        Self { chunk_pairs, pairs: 0, secs: 0.0 }
    }

    pub fn chunk_pairs(&self) -> u64 {
        self.chunk_pairs
    }
}

impl Default for ScalarBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ComputeBackend for ScalarBackend {
    fn name(&self) -> &'static str {
        "scalar"
    }

    fn run_pairs(&mut self, offset: u64, count: u64) -> Result<EpTally, String> {
        let t0 = Instant::now();
        let mut tally = EpTally::default();
        let mut at = offset;
        let mut left = count;
        while left > 0 {
            let n = left.min(self.chunk_pairs);
            tally.merge(&ep_scalar(at, n));
            at += n;
            left -= n;
        }
        self.secs += t0.elapsed().as_secs_f64();
        self.pairs += count;
        Ok(tally)
    }

    fn pairs_executed(&self) -> u64 {
        self.pairs
    }

    fn compute_secs(&self) -> f64 {
        self.secs
    }
}

/// The best always-available pure-Rust backend for this host: the
/// [`ThreadedBackend`](super::threaded::ThreadedBackend) across all
/// hardware threads on a multi-core machine, the [`ScalarBackend`] on a
/// single-core one.
fn best_cpu_backend() -> Box<dyn ComputeBackend> {
    let n = super::threaded::ThreadedBackend::available();
    if n > 1 {
        Box::new(super::threaded::ThreadedBackend::new(n))
    } else {
        Box::new(ScalarBackend::new())
    }
}

/// Build the best backend available in this build: the PJRT path when the
/// `pjrt` feature is on AND its artifacts load, otherwise the threaded
/// (multi-core) or scalar pure-Rust backend.  Returns the backend plus an
/// optional note explaining a fallback (callers print it so
/// `--features pjrt` without artifacts is loud but not fatal).
#[cfg(feature = "pjrt")]
pub fn default_backend() -> (Box<dyn ComputeBackend>, Option<String>) {
    match super::pjrt::PjrtBackend::load_default() {
        Ok(b) => (Box::new(b), None),
        Err(e) => (
            best_cpu_backend(),
            Some(format!("pjrt backend unavailable ({e}); falling back to cpu")),
        ),
    }
}

/// Build the best backend available in this build (default configuration:
/// threaded on multi-core hosts, scalar otherwise; never a note).
#[cfg(not(feature = "pjrt"))]
pub fn default_backend() -> (Box<dyn ComputeBackend>, Option<String>) {
    (best_cpu_backend(), None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_matches_oracle_exactly() {
        let mut b = ScalarBackend::new();
        let t = b.run_pairs(0, 10_000).unwrap();
        let s = ep_scalar(0, 10_000);
        assert!((t.sx - s.sx).abs() < 1e-9);
        assert!((t.sy - s.sy).abs() < 1e-9);
        assert_eq!(t.q, s.q);
        assert_eq!(t.nacc, s.nacc);
        assert_eq!(t.pairs, 10_000);
    }

    #[test]
    fn chunk_geometry_is_invisible() {
        // The same range through wildly different chunkings tallies the
        // same (integer fields exactly; sums to round-off).
        let reference = ep_scalar(5_000, 70_001);
        for chunk in [1u64, 7, 1 << 10, 1 << 16, 1 << 20] {
            let mut b = ScalarBackend::with_chunk(chunk);
            let t = b.run_pairs(5_000, 70_001).unwrap();
            assert_eq!(t.nacc, reference.nacc, "chunk={chunk}");
            assert_eq!(t.q, reference.q, "chunk={chunk}");
            assert!((t.sx - reference.sx).abs() < 1e-7, "chunk={chunk}");
        }
    }

    #[test]
    fn accounting_accumulates() {
        let mut b = ScalarBackend::new();
        assert!(b.measured_rate_mpairs().is_none());
        b.run_pairs(0, 1 << 16).unwrap();
        b.run_pairs(1 << 16, 1 << 16).unwrap();
        assert_eq!(b.pairs_executed(), 2 << 16);
        assert!(b.compute_secs() > 0.0);
        assert!(b.measured_rate_mpairs().unwrap() > 0.01);
    }

    #[test]
    fn default_backend_always_runs() {
        let (mut b, _note) = default_backend();
        let t = b.run_pairs(0, 2_048).unwrap();
        assert_eq!(t.nacc, ep_scalar(0, 2_048).nacc);
    }

    #[test]
    #[should_panic(expected = "chunk_pairs")]
    fn zero_chunk_rejected() {
        ScalarBackend::with_chunk(0);
    }
}
