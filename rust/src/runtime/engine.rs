//! The EP execution engine: PJRT-compiled chunk executables + exact
//! remainder mop-up.
//!
//! `run_pairs(offset, count)` covers an arbitrary pair range by greedily
//! executing the largest chunk artifact that fits, then finishing the
//! sub-chunk remainder with the scalar rust EP — results are exact
//! regardless of geometry (tested against `workload::ep::ep_scalar`).

use super::manifest::{ArtifactInfo, Manifest};
use crate::util::rng::NpbLcg;
use crate::workload::ep::EpTally;
use std::path::Path;
use std::time::Instant;

/// A compiled chunk executable.
struct ChunkExe {
    info: ArtifactInfo,
    exe: xla::PjRtLoadedExecutable,
}

/// The engine.
pub struct EpEngine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    chunks: Vec<ChunkExe>, // largest first
    /// Total pairs executed through PJRT (not scalar mop-up).
    pub pjrt_pairs: u64,
    /// Wall time spent inside PJRT execute calls.
    pub pjrt_secs: f64,
}

impl EpEngine {
    /// Compile all artifacts in `dir` on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<EpEngine, String> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e:?}"))?;
        let mut chunks = Vec::new();
        for info in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                info.file.to_str().ok_or("non-utf8 artifact path")?,
            )
            .map_err(|e| format!("parse {}: {e:?}", info.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| format!("compile {}: {e:?}", info.name))?;
            chunks.push(ChunkExe { info: info.clone(), exe });
        }
        Ok(EpEngine { client, chunks, pjrt_pairs: 0, pjrt_secs: 0.0 })
    }

    /// Convenience: load from the default artifacts dir.
    pub fn load_default() -> Result<EpEngine, String> {
        Self::load(&Manifest::default_dir())
    }

    pub fn chunk_names(&self) -> Vec<&str> {
        self.chunks.iter().map(|c| c.info.name.as_str()).collect()
    }

    /// Execute one chunk at global pair `offset`.
    fn run_chunk(&mut self, idx: usize, offset: u64) -> Result<EpTally, String> {
        let (grid, lanes, ppl, total_pairs, name) = {
            let c = &self.chunks[idx];
            (c.info.grid, c.info.lanes, c.info.pairs_per_lane, c.info.total_pairs, c.info.name.clone())
        };
        let seeds = NpbLcg::ep_lane_seeds(grid * lanes, ppl, offset);
        let lit = xla::Literal::vec1(&seeds)
            .reshape(&[grid as i64, lanes as i64])
            .map_err(|e| format!("reshape seeds: {e:?}"))?;
        let t0 = Instant::now();
        let result = self.chunks[idx]
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| format!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("fetch {name}: {e:?}"))?;
        self.pjrt_secs += t0.elapsed().as_secs_f64();
        let out = result.to_tuple1().map_err(|e| format!("untuple: {e:?}"))?;
        let v = out.to_vec::<f64>().map_err(|e| format!("to_vec: {e:?}"))?;
        if v.len() != 13 {
            return Err(format!("expected 13 outputs, got {}", v.len()));
        }
        let mut q = [0u64; 10];
        for i in 0..10 {
            q[i] = v[2 + i] as u64;
        }
        self.pjrt_pairs += total_pairs;
        Ok(EpTally { sx: v[0], sy: v[1], q, nacc: v[12] as u64, pairs: total_pairs })
    }

    /// EP over global pairs `[offset, offset+count)`: PJRT chunks plus
    /// scalar remainder. Exact.
    pub fn run_pairs(&mut self, offset: u64, count: u64) -> Result<EpTally, String> {
        let mut tally = EpTally::default();
        let mut at = offset;
        let mut left = count;
        for idx in 0..self.chunks.len() {
            let sz = self.chunks[idx].info.total_pairs;
            while left >= sz {
                tally.merge(&self.run_chunk(idx, at)?);
                at += sz;
                left -= sz;
            }
        }
        if left > 0 {
            tally.merge(&crate::workload::ep::ep_scalar(at, left));
        }
        Ok(tally)
    }

    /// Measured PJRT throughput so far (Mpairs/s); None before any run.
    pub fn measured_rate_mpairs(&self) -> Option<f64> {
        if self.pjrt_secs > 0.0 && self.pjrt_pairs > 0 {
            Some(self.pjrt_pairs as f64 / self.pjrt_secs / 1e6)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ep::ep_scalar;

    fn engine() -> Option<EpEngine> {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            Some(EpEngine::load(&dir).expect("engine loads"))
        } else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn pjrt_chunk_matches_scalar_oracle() {
        let Some(mut e) = engine() else { return };
        let t = e.run_pairs(0, 1024).unwrap();
        let s = ep_scalar(0, 1024);
        assert!((t.sx - s.sx).abs() < 1e-9, "{} vs {}", t.sx, s.sx);
        assert!((t.sy - s.sy).abs() < 1e-9);
        assert_eq!(t.q, s.q);
        assert_eq!(t.nacc, s.nacc);
    }

    #[test]
    fn pjrt_offset_ranges_match_scalar() {
        let Some(mut e) = engine() else { return };
        // A non-aligned range exercising chunk + remainder paths.
        let t = e.run_pairs(12_345, 70_000).unwrap();
        let s = ep_scalar(12_345, 70_000);
        assert!((t.sx - s.sx).abs() < 1e-7, "{} vs {}", t.sx, s.sx);
        assert_eq!(t.nacc, s.nacc);
        assert_eq!(t.pairs, 70_000);
        assert!(e.pjrt_pairs >= 65_536, "bulk went through PJRT");
    }

    #[test]
    fn rate_measurement_after_runs() {
        let Some(mut e) = engine() else { return };
        assert!(e.measured_rate_mpairs().is_none());
        e.run_pairs(0, 65_536).unwrap();
        let r = e.measured_rate_mpairs().unwrap();
        assert!(r > 0.01, "rate={r} Mpairs/s");
    }
}
