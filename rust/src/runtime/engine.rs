//! The EP execution engine: a thin facade over the configured
//! [`ComputeBackend`].
//!
//! `EpEngine::auto()` always succeeds: it picks the PJRT backend when the
//! `pjrt` feature is on and its artifacts load, and otherwise the best
//! pure-Rust backend for the host (threaded on multi-core, scalar on
//! single-core) — so `gridlan ep`, the examples, and the integration
//! tests run real compute in every build, with zero external dependencies
//! in the default configuration.

use super::backend::{default_backend, ComputeBackend, ScalarBackend};
use super::threaded::ThreadedBackend;
use crate::workload::ep::EpTally;

/// The engine.
pub struct EpEngine {
    backend: Box<dyn ComputeBackend>,
    /// Note emitted when backend selection fell back (printed by CLIs).
    pub fallback_note: Option<String>,
}

impl EpEngine {
    /// The best backend available in this build; never fails.
    pub fn auto() -> EpEngine {
        let (backend, fallback_note) = default_backend();
        EpEngine { backend, fallback_note }
    }

    /// Explicitly the pure-Rust scalar backend.
    pub fn scalar() -> EpEngine {
        EpEngine { backend: Box::new(ScalarBackend::new()), fallback_note: None }
    }

    /// Explicitly the multi-threaded backend over `threads` OS threads.
    pub fn threaded(threads: usize) -> EpEngine {
        EpEngine { backend: Box::new(ThreadedBackend::new(threads)), fallback_note: None }
    }

    /// Wrap a caller-supplied backend.
    pub fn with_backend(backend: Box<dyn ComputeBackend>) -> EpEngine {
        EpEngine { backend, fallback_note: None }
    }

    /// Name of the active backend ("scalar", "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// EP over global pairs `[offset, offset+count)`. Exact.
    pub fn run_pairs(&mut self, offset: u64, count: u64) -> Result<EpTally, String> {
        self.backend.run_pairs(offset, count)
    }

    /// Total pairs executed through the backend.
    pub fn pairs_executed(&self) -> u64 {
        self.backend.pairs_executed()
    }

    /// Wall time spent inside backend compute calls, seconds.
    pub fn compute_secs(&self) -> f64 {
        self.backend.compute_secs()
    }

    /// Measured backend throughput so far (Mpairs/s); None before any run.
    pub fn measured_rate_mpairs(&self) -> Option<f64> {
        self.backend.measured_rate_mpairs()
    }
}

impl Default for EpEngine {
    fn default() -> Self {
        Self::auto()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ep::ep_scalar;

    #[test]
    fn engine_chunk_matches_scalar_oracle() {
        let mut e = EpEngine::scalar();
        let t = e.run_pairs(0, 1024).unwrap();
        let s = ep_scalar(0, 1024);
        assert!((t.sx - s.sx).abs() < 1e-9, "{} vs {}", t.sx, s.sx);
        assert!((t.sy - s.sy).abs() < 1e-9);
        assert_eq!(t.q, s.q);
        assert_eq!(t.nacc, s.nacc);
    }

    #[test]
    fn engine_offset_ranges_match_scalar() {
        // A non-aligned range exercising chunk + remainder paths.
        let mut e = EpEngine::scalar();
        let t = e.run_pairs(12_345, 70_000).unwrap();
        let s = ep_scalar(12_345, 70_000);
        assert!((t.sx - s.sx).abs() < 1e-7, "{} vs {}", t.sx, s.sx);
        assert_eq!(t.nacc, s.nacc);
        assert_eq!(t.pairs, 70_000);
        assert_eq!(e.pairs_executed(), 70_000);
    }

    #[test]
    fn rate_measurement_after_runs() {
        let mut e = EpEngine::scalar();
        assert!(e.measured_rate_mpairs().is_none());
        e.run_pairs(0, 65_536).unwrap();
        let r = e.measured_rate_mpairs().unwrap();
        assert!(r > 0.01, "rate={r} Mpairs/s");
    }

    #[test]
    fn threaded_engine_matches_scalar_oracle() {
        let mut e = EpEngine::threaded(4);
        assert_eq!(e.backend_name(), "threaded");
        let t = e.run_pairs(2_000, 130_000).unwrap();
        let s = ep_scalar(2_000, 130_000);
        assert_eq!(t.nacc, s.nacc);
        assert_eq!(t.q, s.q);
        assert!((t.sx - s.sx).abs() < 1e-7);
        assert_eq!(e.pairs_executed(), 130_000);
    }

    #[test]
    fn auto_engine_always_computes() {
        // The tentpole property: no artifacts, no Python, no network —
        // the engine still runs real EP.
        let mut e = EpEngine::auto();
        let t = e.run_pairs(0, 4_096).unwrap();
        assert_eq!(t.nacc, ep_scalar(0, 4_096).nacc);
        assert!(!e.backend_name().is_empty());
    }
}
