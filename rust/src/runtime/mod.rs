//! PJRT runtime: load the AOT HLO artifacts and run real EP compute.
//!
//! This is the only module that touches the `xla` crate.  Python never
//! runs here — `make artifacts` produced HLO *text* (see aot.py for why
//! text, not serialized protos), and this module compiles + executes it
//! on the PJRT CPU client.

pub mod engine;
pub mod manifest;

pub use engine::EpEngine;
pub use manifest::{ArtifactInfo, Manifest};
