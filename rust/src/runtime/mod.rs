//! The compute runtime: executes real EP work for simulated jobs.
//!
//! The [`backend::ComputeBackend`] trait decouples the grid fabric from
//! the compute payload.  The default [`backend::ScalarBackend`] runs the
//! exact scalar EP oracle with zero external dependencies;
//! [`threaded::ThreadedBackend`] fans a pair range over N OS threads with
//! an exact merge; the optional PJRT path (`--features pjrt` + a vendored
//! `xla` crate) executes the AOT HLO artifacts produced by
//! python/compile/aot.py instead.

pub mod backend;
pub mod engine;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod threaded;

pub use backend::{default_backend, ComputeBackend, ScalarBackend};
pub use engine::EpEngine;
pub use manifest::{ArtifactInfo, Manifest};
pub use threaded::ThreadedBackend;
