//! Reader for `artifacts/manifest.json` (written by python/compile/aot.py).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// One exported HLO artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub grid: usize,
    pub lanes: usize,
    pub pairs_per_lane: u64,
    pub total_pairs: u64,
}

/// The artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub grid: usize,
    pub lanes: usize,
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`; artifact paths are resolved
    /// relative to `dir`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("read manifest: {e}"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let grid = v.get("grid").and_then(Json::as_u64).ok_or("missing grid")? as usize;
        let lanes = v.get("lanes").and_then(Json::as_u64).ok_or("missing lanes")? as usize;
        let arts = v.get("artifacts").and_then(Json::as_obj).ok_or("missing artifacts")?;
        let mut artifacts = Vec::new();
        for (name, info) in arts.iter() {
            let file = info.get("file").and_then(Json::as_str).ok_or("missing file")?;
            artifacts.push(ArtifactInfo {
                name: name.clone(),
                file: dir.join(file),
                // Per-artifact geometry, falling back to the manifest-wide
                // default (older manifests).
                grid: info.get("grid").and_then(Json::as_u64).unwrap_or(grid as u64) as usize,
                lanes: info.get("lanes").and_then(Json::as_u64).unwrap_or(lanes as u64) as usize,
                pairs_per_lane: info
                    .get("pairs_per_lane")
                    .and_then(Json::as_u64)
                    .ok_or("missing pairs_per_lane")?,
                total_pairs: info
                    .get("total_pairs")
                    .and_then(Json::as_u64)
                    .ok_or("missing total_pairs")?,
            });
        }
        // Largest first: the engine picks greedily.
        artifacts.sort_by(|a, b| b.total_pairs.cmp(&a.total_pairs));
        if artifacts.is_empty() {
            return Err("no artifacts in manifest".into());
        }
        Ok(Manifest { grid, lanes, artifacts })
    }

    pub fn smallest(&self) -> &ArtifactInfo {
        self.artifacts.last().unwrap()
    }

    /// Default artifacts directory: $GRIDLAN_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("GRIDLAN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "grid": 8, "lanes": 128,
        "outputs": ["sx"],
        "artifacts": {
            "ep_c16": {"file": "ep_c16.hlo.txt", "pairs_per_lane": 64, "total_pairs": 65536},
            "ep_c10": {"file": "ep_c10.hlo.txt", "pairs_per_lane": 1, "total_pairs": 1024}
        }
    }"#;

    #[test]
    fn parses_and_sorts_descending() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.grid, 8);
        assert_eq!(m.lanes, 128);
        assert_eq!(m.artifacts[0].grid, 8);
        assert_eq!(m.artifacts[0].lanes, 128);
        assert_eq!(m.artifacts[0].name, "ep_c16");
        assert_eq!(m.smallest().name, "ep_c10");
        assert_eq!(m.artifacts[0].file, Path::new("/a/ep_c16.hlo.txt"));
    }

    #[test]
    fn rejects_bad_manifests() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(r#"{"grid":8,"lanes":128,"artifacts":{}}"#, Path::new(".")).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.artifacts.len() >= 3);
            assert!(m.artifacts.iter().all(|a| a.file.exists()));
            assert!(m
                .artifacts
                .iter()
                .all(|a| a.grid as u64 * a.lanes as u64 * a.pairs_per_lane == a.total_pairs));
        }
    }
}
