//! PJRT artifact backend (`--features pjrt`).
//!
//! The original L1/L2 pipeline AOT-lowers a JAX/Pallas EP kernel to HLO
//! text (`make artifacts`, python/compile/aot.py); this backend compiles
//! those artifacts on the PJRT CPU client and executes chunks, finishing
//! sub-chunk remainders with the scalar oracle so results are exact for
//! any pair-range geometry.
//!
//! Two layers of gating keep offline builds green:
//!
//! * the `pjrt` cargo feature compiles this module at all (manifest
//!   loading, error reporting, the backend type);
//! * the `gridlan_xla` cfg (`RUSTFLAGS="--cfg gridlan_xla"`) enables the
//!   actual `xla` crate calls.  The crate is not vendored in the offline
//!   set, so without the cfg [`PjrtBackend::load`] reports a clear error
//!   and callers fall back to [`super::backend::ScalarBackend`].

// `gridlan_xla` is a hand-set cfg (not a cargo feature), so rustc's
// check-cfg machinery can't know about it.
#![allow(unexpected_cfgs)]

use super::backend::ComputeBackend;
use super::manifest::Manifest;
use crate::workload::ep::EpTally;
use std::path::Path;

#[cfg(not(gridlan_xla))]
pub use stub::PjrtBackend;
#[cfg(gridlan_xla)]
pub use xla_impl::PjrtBackend;

/// The no-`xla` build: loads and validates manifests (so error messages
/// distinguish "no artifacts" from "no executor"), but cannot execute.
#[cfg(not(gridlan_xla))]
mod stub {
    use super::*;

    /// Placeholder backend; [`PjrtBackend::load`] never returns one in
    /// this build, so the trait methods are effectively unreachable.
    pub struct PjrtBackend {
        _manifest: Manifest,
    }

    impl PjrtBackend {
        /// Validate the artifact manifest in `dir`, then report that this
        /// build has no executor for it.
        pub fn load(dir: &Path) -> Result<PjrtBackend, String> {
            let manifest = Manifest::load(dir)?;
            Err(format!(
                "found {} artifact(s) in {}, but PJRT execution needs the external `xla` \
                 crate: vendor it and rebuild with RUSTFLAGS=\"--cfg gridlan_xla\"",
                manifest.artifacts.len(),
                dir.display()
            ))
        }

        /// Load from `$GRIDLAN_ARTIFACTS` / `./artifacts`.
        pub fn load_default() -> Result<PjrtBackend, String> {
            Self::load(&Manifest::default_dir())
        }

        pub fn chunk_names(&self) -> Vec<&str> {
            Vec::new()
        }
    }

    impl ComputeBackend for PjrtBackend {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        fn run_pairs(&mut self, _offset: u64, _count: u64) -> Result<EpTally, String> {
            Err("pjrt backend is not executable in this build (no `xla` crate)".into())
        }

        fn pairs_executed(&self) -> u64 {
            0
        }

        fn compute_secs(&self) -> f64 {
            0.0
        }
    }
}

/// The real executor, compiled only when the `xla` crate is vendored and
/// `--cfg gridlan_xla` is set.  This is the seed's original PJRT engine
/// behind the [`ComputeBackend`] trait.
#[cfg(gridlan_xla)]
mod xla_impl {
    use super::*;
    use crate::util::rng::NpbLcg;
    use std::time::Instant;

    struct ChunkExe {
        info: super::super::manifest::ArtifactInfo,
        exe: xla::PjRtLoadedExecutable,
    }

    pub struct PjrtBackend {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        chunks: Vec<ChunkExe>, // largest first
        pjrt_pairs: u64,
        pjrt_secs: f64,
    }

    impl PjrtBackend {
        /// Compile all artifacts in `dir` on the PJRT CPU client.
        pub fn load(dir: &Path) -> Result<PjrtBackend, String> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| format!("pjrt cpu client: {e:?}"))?;
            let mut chunks = Vec::new();
            for info in &manifest.artifacts {
                let proto = xla::HloModuleProto::from_text_file(
                    info.file.to_str().ok_or("non-utf8 artifact path")?,
                )
                .map_err(|e| format!("parse {}: {e:?}", info.file.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| format!("compile {}: {e:?}", info.name))?;
                chunks.push(ChunkExe { info: info.clone(), exe });
            }
            Ok(PjrtBackend { client, chunks, pjrt_pairs: 0, pjrt_secs: 0.0 })
        }

        pub fn load_default() -> Result<PjrtBackend, String> {
            Self::load(&Manifest::default_dir())
        }

        pub fn chunk_names(&self) -> Vec<&str> {
            self.chunks.iter().map(|c| c.info.name.as_str()).collect()
        }

        /// Execute one chunk at global pair `offset`.
        fn run_chunk(&mut self, idx: usize, offset: u64) -> Result<EpTally, String> {
            let (grid, lanes, ppl, total_pairs, name) = {
                let c = &self.chunks[idx];
                (c.info.grid, c.info.lanes, c.info.pairs_per_lane, c.info.total_pairs, c.info.name.clone())
            };
            let seeds = NpbLcg::ep_lane_seeds(grid * lanes, ppl, offset);
            let lit = xla::Literal::vec1(&seeds)
                .reshape(&[grid as i64, lanes as i64])
                .map_err(|e| format!("reshape seeds: {e:?}"))?;
            let t0 = Instant::now();
            let result = self.chunks[idx]
                .exe
                .execute::<xla::Literal>(&[lit])
                .map_err(|e| format!("execute {name}: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| format!("fetch {name}: {e:?}"))?;
            self.pjrt_secs += t0.elapsed().as_secs_f64();
            let out = result.to_tuple1().map_err(|e| format!("untuple: {e:?}"))?;
            let v = out.to_vec::<f64>().map_err(|e| format!("to_vec: {e:?}"))?;
            if v.len() != 13 {
                return Err(format!("expected 13 outputs, got {}", v.len()));
            }
            let mut q = [0u64; 10];
            for i in 0..10 {
                q[i] = v[2 + i] as u64;
            }
            self.pjrt_pairs += total_pairs;
            Ok(EpTally { sx: v[0], sy: v[1], q, nacc: v[12] as u64, pairs: total_pairs })
        }
    }

    impl ComputeBackend for PjrtBackend {
        fn name(&self) -> &'static str {
            "pjrt"
        }

        /// PJRT chunks greedily (largest artifact first) plus scalar
        /// remainder mop-up.  Exact for any geometry.
        fn run_pairs(&mut self, offset: u64, count: u64) -> Result<EpTally, String> {
            let mut tally = EpTally::default();
            let mut at = offset;
            let mut left = count;
            for idx in 0..self.chunks.len() {
                let sz = self.chunks[idx].info.total_pairs;
                while left >= sz {
                    tally.merge(&self.run_chunk(idx, at)?);
                    at += sz;
                    left -= sz;
                }
            }
            if left > 0 {
                tally.merge(&crate::workload::ep::ep_scalar(at, left));
            }
            Ok(tally)
        }

        fn pairs_executed(&self) -> u64 {
            self.pjrt_pairs
        }

        fn compute_secs(&self) -> f64 {
            self.pjrt_secs
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifacts_is_a_clean_error() {
        let e = PjrtBackend::load(Path::new("/definitely/not/a/dir")).unwrap_err();
        assert!(e.contains("manifest"), "unexpected error: {e}");
    }
}
