//! Typed configuration + JSON loading.
//!
//! A Gridlan deployment is described declaratively — clients, their CPUs
//! and host OSes, network placement (switch hops), tunnel costs, queues,
//! scheduler policy — and the coordinator assembles the whole system from
//! it.  `Config::table1()` is the paper's exact testbed and the default
//! for every benchmark.

use crate::host::client::ClientOs;
use crate::util::json::Json;
use crate::vm::cpu::CpuModel;
use crate::vm::hypervisor::HypervisorKind;

/// One client workstation entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ClientConfig {
    pub name: String,
    pub os: ClientOs,
    pub cpu: CpuModel,
    /// Hypervisor override (None = OS default per the paper).
    pub hypervisor: Option<HypervisorKind>,
    /// Switches between this client and the server (Fig. 1c: "a few
    /// switches or routers away").
    pub switch_hops: u32,
    /// Host OS+NIC stack latency, µs (per endpoint traversal).
    pub stack_us: f64,
    /// Link speed of this client's drop, Mb/s.
    pub link_mbps: f64,
}

/// Scheduler policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedPolicy {
    Fifo,
    Backfill,
}

/// The whole deployment.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    pub clients: Vec<ClientConfig>,
    /// Server NIC/stack latency, µs.
    pub server_stack_us: f64,
    /// Per-switch processing, µs.
    pub switch_proc_us: f64,
    /// Backbone (server↔switch, switch↔switch) link speed, Mb/s.
    pub backbone_mbps: f64,
    /// Path jitter sigma, µs.
    pub jitter_us: f64,
    pub sched: SchedPolicy,
    /// RNG seed for the whole deployment (placement, jitter, faults).
    pub seed: u64,
    /// Optional conventional cluster partition on the same server
    /// (name, nodes, cores per node) — the paper's "pre-existing cluster".
    pub cluster_partition: Option<(String, u32, u32)>,
}

impl Config {
    /// The paper's Table-1 testbed with latency profiles calibrated to
    /// Table 2 (hop counts/stacks chosen so host pings land at
    /// 550/660/750/610 µs — see DESIGN.md §5).
    pub fn table1() -> Self {
        let mk = |name: &str, os, cpu, hops, stack, mbps| ClientConfig {
            name: name.into(),
            os,
            cpu,
            hypervisor: None,
            switch_hops: hops,
            stack_us: stack,
            link_mbps: mbps,
        };
        Self {
            clients: vec![
                mk("n01", ClientOs::Linux, CpuModel::xeon_e5_2630(), 2, 146.0, 1000.0),
                mk("n02", ClientOs::Windows, CpuModel::i7_3930k(), 2, 201.0, 1000.0),
                mk("n03", ClientOs::Windows, CpuModel::i7_2920xm(), 3, 217.0, 1000.0),
                mk("n04", ClientOs::Windows, CpuModel::i7_960(), 2, 176.0, 1000.0),
            ],
            server_stack_us: 60.0,
            switch_proc_us: 25.0,
            backbone_mbps: 1000.0,
            jitter_us: 7.0,
            sched: SchedPolicy::Fifo,
            seed: 0x6E1D,
            cluster_partition: None,
        }
    }

    /// Parse from JSON (see `examples/gridlan.json` shape in README).
    pub fn from_json(text: &str) -> Result<Config, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        let mut cfg = Config::table1();
        cfg.clients.clear();
        let clients = v.get("clients").and_then(Json::as_arr).ok_or("missing clients[]")?;
        for c in clients {
            let name = c.get("name").and_then(Json::as_str).ok_or("client.name")?;
            let os = match c.get("os").and_then(Json::as_str).unwrap_or("linux") {
                "windows" => ClientOs::Windows,
                _ => ClientOs::Linux,
            };
            let cpu = match c.get("cpu").and_then(Json::as_str) {
                Some("xeon-e5-2630") => CpuModel::xeon_e5_2630(),
                Some("i7-3930k") => CpuModel::i7_3930k(),
                Some("i7-2920xm") => CpuModel::i7_2920xm(),
                Some("i7-960") => CpuModel::i7_960(),
                Some("opteron-6376x4") => CpuModel::opteron_6376_quad(),
                Some(other) => return Err(format!("unknown cpu '{other}'")),
                None => {
                    // Custom CPU spec.
                    CpuModel {
                        name: format!("custom-{name}"),
                        cores: c.get("cores").and_then(Json::as_u64).ok_or("client.cores")? as u32,
                        base_ghz: c.get("base_ghz").and_then(Json::as_f64).unwrap_or(3.0),
                        max_turbo_ghz: c.get("max_turbo_ghz").and_then(Json::as_f64).unwrap_or(3.4),
                        all_core_ghz: c.get("all_core_ghz").and_then(Json::as_f64).unwrap_or(3.1),
                        pairs_per_cycle: c
                            .get("pairs_per_cycle")
                            .and_then(Json::as_f64)
                            .unwrap_or(0.0045),
                    }
                }
            };
            let hypervisor = match c.get("hypervisor").and_then(Json::as_str) {
                Some("kvm") => Some(HypervisorKind::QemuKvm),
                Some("virtualbox") => Some(HypervisorKind::VirtualBox),
                Some("qemu-tcg") => Some(HypervisorKind::PureQemu),
                Some("vmware") => Some(HypervisorKind::Vmware),
                Some(other) => return Err(format!("unknown hypervisor '{other}'")),
                None => None,
            };
            cfg.clients.push(ClientConfig {
                name: name.to_string(),
                os,
                cpu,
                hypervisor,
                switch_hops: c.get("switch_hops").and_then(Json::as_u64).unwrap_or(2) as u32,
                stack_us: c.get("stack_us").and_then(Json::as_f64).unwrap_or(120.0),
                link_mbps: c.get("link_mbps").and_then(Json::as_f64).unwrap_or(1000.0),
            });
        }
        if cfg.clients.is_empty() {
            return Err("config has no clients".into());
        }
        if let Some(s) = v.get("sched").and_then(Json::as_str) {
            cfg.sched = match s {
                "fifo" => SchedPolicy::Fifo,
                "backfill" => SchedPolicy::Backfill,
                other => return Err(format!("unknown sched '{other}'")),
            };
        }
        if let Some(seed) = v.get("seed").and_then(Json::as_u64) {
            cfg.seed = seed;
        }
        if let Some(j) = v.get("jitter_us").and_then(Json::as_f64) {
            cfg.jitter_us = j;
        }
        if let Some(cl) = v.get("cluster").and_then(Json::as_obj) {
            cfg.cluster_partition = Some((
                cl.get("name").and_then(Json::as_str).unwrap_or("batch-nodes").to_string(),
                cl.get("nodes").and_then(Json::as_u64).unwrap_or(1) as u32,
                cl.get("cores_per_node").and_then(Json::as_u64).unwrap_or(64) as u32,
            ));
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::from_json(&text)
    }

    pub fn total_gridlan_cores(&self) -> u32 {
        self.clients.iter().map(|c| c.cpu.cores).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_inventory() {
        let cfg = Config::table1();
        assert_eq!(cfg.clients.len(), 4);
        assert_eq!(cfg.total_gridlan_cores(), 26);
        assert_eq!(cfg.clients[0].os, ClientOs::Linux);
    }

    #[test]
    fn json_roundtrip_custom_deployment() {
        let cfg = Config::from_json(
            r#"{
                "clients": [
                    {"name": "a", "os": "linux", "cpu": "i7-960", "switch_hops": 1},
                    {"name": "b", "os": "windows", "cores": 8, "base_ghz": 2.8,
                     "max_turbo_ghz": 3.3, "all_core_ghz": 3.0, "hypervisor": "vmware"}
                ],
                "sched": "backfill",
                "seed": 99,
                "cluster": {"name": "hpc", "nodes": 2, "cores_per_node": 32}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.clients.len(), 2);
        assert_eq!(cfg.clients[1].cpu.cores, 8);
        assert_eq!(cfg.clients[1].hypervisor, Some(HypervisorKind::Vmware));
        assert_eq!(cfg.sched, SchedPolicy::Backfill);
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.cluster_partition, Some(("hpc".into(), 2, 32)));
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(Config::from_json("{}").is_err());
        assert!(Config::from_json(r#"{"clients": []}"#).is_err());
        assert!(Config::from_json(r#"{"clients": [{"name":"x","cpu":"z80"}]}"#).is_err());
        assert!(
            Config::from_json(r#"{"clients":[{"name":"x","cores":4}],"sched":"lottery"}"#).is_err()
        );
    }
}
