"""Kernel-vs-reference correctness: the CORE L1 signal.

Three trust levels:
  gold  : exact-integer scalar python EP (compile.kernels.ref.ep_gold_scalar)
  ref   : vectorised jnp lane implementation (ep_ref_lanes / ep_ref_grid)
  kernel: the Pallas kernel (interpret=True) via the L2 ep_chunk graph

plus hypothesis sweeps over geometry and seeds.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.ep_kernel import GRID, LANES, ep_pallas, vmem_bytes
from compile.model import CHUNK_SIZES, chunk_pairs, ep_chunk, make_chunk_fn

jax.config.update("jax_enable_x64", True)


def _grid_seeds(grid, lanes, ppl, seed=ref.SEED):
    s = ref.lane_seeds(grid * lanes, ppl, seed)
    return np.array(s, dtype=np.uint64).reshape(grid, lanes)


# ---------------------------------------------------------------- LCG core


def test_lcg_pow_identity():
    assert ref.lcg_pow(0) == 1
    assert ref.lcg_pow(1) == ref.A


def test_lcg_pow_matches_iteration():
    s = ref.SEED
    for k in range(1, 50):
        s = (s * ref.A) & ref.MASK
        assert ref.lcg_jump(ref.SEED, k) == s


@given(st.integers(min_value=0, max_value=2**20), st.integers(min_value=0, max_value=2**20))
@settings(max_examples=50, deadline=None)
def test_lcg_pow_homomorphism(i, j):
    # a^(i+j) == a^i * a^j (mod 2^46)
    assert ref.lcg_pow(i + j) == (ref.lcg_pow(i) * ref.lcg_pow(j)) & ref.MASK


def test_lane_seeds_partition_stream():
    # Lane decomposition covers the global stream without gaps/overlap.
    ppl, lanes = 3, 8
    seeds = ref.lane_seeds(lanes, ppl)
    stream = []
    s = ref.SEED
    for _ in range(2 * ppl * lanes):
        s = (s * ref.A) & ref.MASK
        stream.append(s)
    per_lane = []
    for g in range(lanes):
        s = seeds[g]
        for _ in range(2 * ppl):
            s = (s * ref.A) & ref.MASK
            per_lane.append(s)
    assert per_lane == stream


# ------------------------------------------------------------ ref vs gold


@pytest.mark.parametrize("ppl,lanes", [(1, 4), (2, 8), (5, 16), (8, 32)])
def test_ref_matches_gold(ppl, lanes):
    seeds = np.array(ref.lane_seeds(lanes, ppl), dtype=np.uint64)
    sx, sy, q, nacc = ref.ep_ref_lanes(seeds, ppl)
    gsx, gsy, gq, gnacc = ref.ep_gold_scalar(lanes * ppl)
    assert int(nacc) == gnacc
    assert list(map(int, q)) == gq
    np.testing.assert_allclose(float(sx), gsx, rtol=1e-12, atol=1e-9)
    np.testing.assert_allclose(float(sy), gsy, rtol=1e-12, atol=1e-9)


@given(seed=st.integers(min_value=1, max_value=ref.MASK - 1))
@settings(max_examples=20, deadline=None)
def test_ref_matches_gold_random_seed(seed):
    seed |= 1  # LCG mod 2^46 needs an odd seed for full period behaviour
    ppl, lanes = 3, 8
    seeds = np.array(ref.lane_seeds(lanes, ppl, seed), dtype=np.uint64)
    sx, sy, q, nacc = ref.ep_ref_lanes(seeds, ppl)
    gsx, gsy, gq, gnacc = ref.ep_gold_scalar(lanes * ppl, seed)
    assert int(nacc) == gnacc and list(map(int, q)) == gq
    np.testing.assert_allclose(float(sx), gsx, rtol=1e-12, atol=1e-9)


# --------------------------------------------------------- kernel vs ref


@pytest.mark.parametrize("grid,ppl", [(1, 4), (2, 8), (4, 16), (8, 64)])
def test_pallas_matches_ref(grid, ppl):
    seeds = _grid_seeds(grid, LANES, ppl)
    sx, sy, q, nacc = ep_pallas(jnp.asarray(seeds), ppl)
    rsx, rsy, rq, rnacc = ref.ep_ref_grid(seeds, ppl)
    assert int(nacc.sum()) == int(rnacc)
    np.testing.assert_array_equal(np.asarray(q).sum(axis=0), np.asarray(rq))
    np.testing.assert_allclose(float(sx.sum()), float(rsx), rtol=1e-12, atol=1e-9)
    np.testing.assert_allclose(float(sy.sum()), float(rsy), rtol=1e-12, atol=1e-9)


def test_pallas_per_block_partials():
    # Per-block partials must equal running the ref on each block's lanes.
    grid, ppl = 4, 8
    seeds = _grid_seeds(grid, LANES, ppl)
    sx, sy, q, nacc = ep_pallas(jnp.asarray(seeds), ppl)
    for b in range(grid):
        rsx, rsy, rq, rnacc = ref.ep_ref_lanes(seeds[b], ppl)
        assert int(nacc[b]) == int(rnacc)
        np.testing.assert_allclose(float(sx[b]), float(rsx), rtol=1e-12, atol=1e-9)


@given(
    seed=st.integers(min_value=1, max_value=ref.MASK - 1),
    grid=st.sampled_from([1, 2, 4]),
    ppl=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=15, deadline=None)
def test_pallas_matches_ref_hypothesis(seed, grid, ppl):
    seed |= 1
    seeds = _grid_seeds(grid, LANES, ppl, seed)
    sx, sy, q, nacc = ep_pallas(jnp.asarray(seeds), ppl)
    rsx, rsy, rq, rnacc = ref.ep_ref_grid(seeds, ppl)
    assert int(nacc.sum()) == int(rnacc)
    np.testing.assert_array_equal(np.asarray(q).sum(axis=0), np.asarray(rq))
    np.testing.assert_allclose(float(sx.sum()), float(rsx), rtol=1e-12, atol=1e-9)


# ----------------------------------------------------------- L2 contract


def test_chunk_packing():
    grid, ppl = GRID, 8
    seeds = _grid_seeds(grid, LANES, ppl)
    out = np.asarray(ep_chunk(jnp.asarray(seeds), ppl))
    assert out.shape == (13,)
    gsx, gsy, gq, gnacc = ref.ep_gold_scalar(grid * LANES * ppl)
    np.testing.assert_allclose(out[0], gsx, rtol=1e-12, atol=1e-9)
    np.testing.assert_allclose(out[1], gsy, rtol=1e-12, atol=1e-9)
    assert list(map(int, out[2:12])) == gq
    assert int(out[12]) == gnacc


def test_chunk_sizes_table():
    for name, ppl in CHUNK_SIZES.items():
        assert chunk_pairs(ppl) == GRID * LANES * ppl
    assert chunk_pairs(CHUNK_SIZES["ep_c16"]) == 2**16
    assert chunk_pairs(CHUNK_SIZES["ep_c10"]) == 2**10
    assert chunk_pairs(CHUNK_SIZES["ep_c18"]) == 2**18
    assert chunk_pairs(CHUNK_SIZES["ep_c20"]) == 2**20


def test_chunk_fn_tuple_contract():
    fn = make_chunk_fn(4)
    seeds = jnp.asarray(_grid_seeds(GRID, LANES, 4))
    out = fn(seeds)
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (13,)


# --------------------------------------------------------- invariants


def test_acceptance_rate_near_pi_over_4():
    # P(x^2+y^2<=1) = pi/4 for uniform pairs on (-1,1)^2.
    ppl, lanes = 64, 256
    seeds = np.array(ref.lane_seeds(lanes, ppl), dtype=np.uint64)
    _, _, _, nacc = ref.ep_ref_lanes(seeds, ppl)
    n = lanes * ppl
    rate = int(nacc) / n
    assert abs(rate - math.pi / 4) < 4 / math.sqrt(n)


def test_q_sums_to_nacc():
    ppl, lanes = 32, 128
    seeds = np.array(ref.lane_seeds(lanes, ppl), dtype=np.uint64)
    _, _, q, nacc = ref.ep_ref_lanes(seeds, ppl)
    assert int(np.asarray(q).sum()) == int(nacc)


def test_vmem_estimate_fits():
    # Production tile must fit VMEM (16 MiB) with double-buffer headroom.
    assert vmem_bytes(128) < 16 * 2**20 / 4
