"""Layer-2 JAX compute graph for the EP workload.

The unit the rust runtime executes is an *EP chunk*: a fixed-geometry batch
of ``GRID * LANES * pairs_per_lane`` pairs whose lane seeds are provided by
the caller (the rust coordinator does the LCG jump-ahead when it splits a
job across simulated Gridlan cores).

The graph is just: pallas kernel over blocks -> reduce partials.  One HLO
artifact is exported per chunk size; the rust side picks the largest chunk
that divides the remaining work and iterates.

Outputs are packed into f64 so the rust side deals with one dtype:
  out[0]      = sx
  out[1]      = sy
  out[2..12]  = q[0..9]   (exact: counts < 2^53)
  out[12]     = nacc
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.ep_kernel import GRID, LANES, ep_pallas
from .kernels.ref import NQ

jax.config.update("jax_enable_x64", True)


def ep_chunk(seeds: jnp.ndarray, pairs_per_lane: int) -> jnp.ndarray:
    """EP tallies for one chunk.  seeds: (GRID, LANES) uint64.

    Returns f64[13] = [sx, sy, q0..q9, nacc].
    """
    sx, sy, q, nacc = ep_pallas(seeds, pairs_per_lane)
    return jnp.concatenate(
        [
            sx.sum()[None],
            sy.sum()[None],
            q.sum(axis=0).astype(jnp.float64),
            nacc.sum().astype(jnp.float64)[None],
        ]
    )


def chunk_pairs(pairs_per_lane: int, grid: int = GRID, lanes: int = LANES) -> int:
    """Total pairs consumed by one chunk execution."""
    return grid * lanes * pairs_per_lane


# Chunk geometries exported as AOT artifacts: name -> (grid, lanes,
# pairs_per_lane).  Two families (EXPERIMENTS.md §Perf, L1 iteration 1):
#
# * CPU-optimized (grid=1, wide lanes): one fat block amortizes the scan
#   step over 4096 f64 lanes — ~+14% on the CPU PJRT backend, which is
#   what the rust runtime executes;
# * TPU-shaped (grid=8, lanes=128): the production TPU geometry (one block
#   per core, 128-lane VPU tiles) kept as an exported artifact so the HLO
#   the paper's "real" deployment would ship is built and tested too.
CHUNK_GEOMETRY = {
    "ep_c22": (1, 4096, 1024),  # 4_194_304 pairs, CPU bulk
    "ep_c20": (1, 4096, 256),   # 1_048_576 pairs, CPU bulk
    "ep_c16": (8, 128, 64),     # 65_536 pairs, TPU-shaped
    "ep_c10": (1, 1024, 1),     # 1_024 pairs, remainder mop-up
}

# Back-compat view: name -> pairs_per_lane (tests use it with GRID/LANES).
CHUNK_SIZES = {"ep_c10": 1, "ep_c16": 64, "ep_c18": 256, "ep_c20": 1024}


def make_chunk_fn(pairs_per_lane: int):
    """A jit-able fn of one (grid, lanes) u64 input, returning a 1-tuple
    (the AOT interchange contract lowers with return_tuple=True)."""

    def fn(seeds):
        return (ep_chunk(seeds, pairs_per_lane),)

    return fn


assert NQ == 10, "output packing assumes 10 annuli"
