"""AOT export: lower the L2 EP chunk graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6
rust crate) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Also writes ``manifest.json`` describing each artifact (chunk geometry,
input/output shapes) — the rust runtime reads this instead of hardcoding.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.ep_kernel import GRID, LANES
from .model import CHUNK_GEOMETRY, make_chunk_fn

jax.config.update("jax_enable_x64", True)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        # Default geometry (TPU-shaped); per-artifact geometry below wins.
        "grid": GRID,
        "lanes": LANES,
        "outputs": ["sx", "sy"] + [f"q{i}" for i in range(10)] + ["nacc"],
        "artifacts": {},
    }
    for name, (grid, lanes, ppl) in CHUNK_GEOMETRY.items():
        spec = jax.ShapeDtypeStruct((grid, lanes), jnp.uint64)
        fn = make_chunk_fn(ppl)
        lowered = jax.jit(fn).lower(spec)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        total = grid * lanes * ppl
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "grid": grid,
            "lanes": lanes,
            "pairs_per_lane": ppl,
            "total_pairs": total,
            "hlo_chars": len(text),
        }
        print(f"wrote {path}: grid={grid} lanes={lanes} -> {total} pairs/exec, {len(text)} chars")
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export_all(args.out_dir)


if __name__ == "__main__":
    main()
