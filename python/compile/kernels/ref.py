"""Pure-jnp (and pure-python) correctness oracles for the NPB-EP kernel.

Two oracles at different trust levels:

* ``ep_gold_scalar`` — exact-integer, single-stream Python implementation of
  the NPB "EP" (embarrassingly parallel) benchmark inner loop, following the
  published pseudo-random scheme: a 46-bit multiplicative LCG

      x_{k+1} = a * x_k  mod 2**46,      a = 5**13,  x_0 = seed

  The j-th random of the stream (1-based) is ``r_j = a**j * seed mod 2**46``
  normalised by 2**-46; pair j consumes (r_{2j-1}, r_{2j}).  This is the
  ground truth the lane decomposition is validated against.

* ``ep_ref_lanes`` — vectorised jnp implementation over per-lane seeds with
  the exact layout the Pallas kernel uses (grid x lanes x pairs-per-lane).
  The Pallas kernel must match this bit-for-bit on the integer stream and to
  ~1e-12 on the float tallies.

Both compute the EP observables:
  sx, sy  : sums of the accepted Gaussian deviates
  q[0..9] : annulus counts, l = floor(max(|X|,|Y|))
  nacc    : number of accepted pairs (t = x^2+y^2 <= 1)
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

# NPB EP constants.
A = 5**13  # 1220703125
MOD_BITS = 46
MOD = 1 << MOD_BITS
MASK = MOD - 1
SEED = 271828183
R46 = 2.0**-46
NQ = 10


def lcg_pow(exp: int, mult: int = A) -> int:
    """a**exp mod 2**46 by binary exponentiation (exact python ints)."""
    result = 1
    base = mult & MASK
    e = exp
    while e > 0:
        if e & 1:
            result = (result * base) & MASK
        base = (base * base) & MASK
        e >>= 1
    return result


def lcg_jump(seed: int, nsteps: int) -> int:
    """State after nsteps LCG applications starting from ``seed``."""
    return (seed * lcg_pow(nsteps)) & MASK


def lane_seeds(n_lanes: int, pairs_per_lane: int, seed: int = SEED) -> list[int]:
    """Starting state for each lane so that lane g covers global pairs
    [g*pairs_per_lane, (g+1)*pairs_per_lane).  Lane state is the stream state
    *before* its first random, i.e. after g*2*pairs_per_lane steps."""
    return [lcg_jump(seed, g * 2 * pairs_per_lane) for g in range(n_lanes)]


def ep_gold_scalar(n_pairs: int, seed: int = SEED):
    """Exact-integer scalar EP over ``n_pairs`` pairs. Slow; for small n."""
    s = seed
    sx = 0.0
    sy = 0.0
    q = [0] * NQ
    nacc = 0
    for _ in range(n_pairs):
        s = (s * A) & MASK
        x = 2.0 * (s * R46) - 1.0
        s = (s * A) & MASK
        y = 2.0 * (s * R46) - 1.0
        t = x * x + y * y
        if t <= 1.0:
            f = math.sqrt(-2.0 * math.log(t) / t)
            gx = x * f
            gy = y * f
            l = int(max(abs(gx), abs(gy)))
            if l < NQ:
                q[l] += 1
            sx += gx
            sy += gy
            nacc += 1
    return sx, sy, q, nacc


def _lane_body(seeds: jnp.ndarray, pairs_per_lane: int):
    """Vectorised EP over a vector of lane seeds; returns per-call tallies."""
    a = jnp.uint64(A)
    mask = jnp.uint64(MASK)

    def step(carry, _):
        s, sx, sy, q, nacc = carry
        s = (s * a) & mask
        x = 2.0 * (s.astype(jnp.float64) * R46) - 1.0
        s = (s * a) & mask
        y = 2.0 * (s.astype(jnp.float64) * R46) - 1.0
        t = x * x + y * y
        acc = t <= 1.0
        # Guard log(0)/div0 on rejected pairs.
        tsafe = jnp.where(acc, t, 1.0)
        f = jnp.sqrt(-2.0 * jnp.log(tsafe) / tsafe)
        gx = jnp.where(acc, x * f, 0.0)
        gy = jnp.where(acc, y * f, 0.0)
        l = jnp.maximum(jnp.abs(gx), jnp.abs(gy)).astype(jnp.int32)
        onehot = (l[:, None] == jnp.arange(NQ)[None, :]) & acc[:, None]
        q = q + onehot.sum(axis=0).astype(jnp.int64)
        sx = sx + gx.sum()
        sy = sy + gy.sum()
        nacc = nacc + acc.sum().astype(jnp.int64)
        return (s, sx, sy, q, nacc), None

    init = (
        seeds,
        jnp.float64(0.0),
        jnp.float64(0.0),
        jnp.zeros((NQ,), jnp.int64),
        jnp.int64(0),
    )
    (s, sx, sy, q, nacc), _ = jax.lax.scan(step, init, None, length=pairs_per_lane)
    return sx, sy, q, nacc


def ep_ref_lanes(seeds, pairs_per_lane: int):
    """Reference EP over per-lane seeds, shape (n_lanes,) uint64.

    Returns (sx, sy, q[10] int64, nacc int64) summed over all lanes.
    """
    seeds = jnp.asarray(seeds, dtype=jnp.uint64)
    return _lane_body(seeds, pairs_per_lane)


def ep_ref_grid(seeds, pairs_per_lane: int):
    """Reference with the kernel's (grid, lanes) seed layout."""
    seeds = jnp.asarray(seeds, dtype=jnp.uint64)
    g, l = seeds.shape
    return ep_ref_lanes(seeds.reshape(g * l), pairs_per_lane)
