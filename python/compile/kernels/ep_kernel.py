"""Layer-1 Pallas kernel: the NPB-EP hot loop.

The EP benchmark is pure ALU work: per pair, two 46-bit LCG steps
(u64 multiply + mask), the Marsaglia polar acceptance test, two f64
transcendentals on accepted pairs, and a 10-bin histogram update.

TPU formulation (see DESIGN.md §Hardware-Adaptation): the global random
stream is split into ``grid * LANES`` independent sub-streams via LCG
jump-ahead (done host-side).  Each Pallas program instance owns one
``(LANES,)`` tile of seeds resident in VMEM and loops ``pairs_per_lane``
times with a ``fori_loop`` whose carry (seed vector + tallies) also lives in
VMEM/registers.  All work is element-wise VPU work — no gathers, no MXU —
so the kernel's roofline is the vector ALU, exactly like the CPU original.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO which the rust runtime
executes natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import A, MASK, NQ, R46

jax.config.update("jax_enable_x64", True)

# Default tile geometry.  LANES=128 matches the TPU VPU lane width; GRID
# programs run sequentially in interpret mode but map to parallel cores on
# a real device.
LANES = 128
GRID = 8


def _ep_kernel_body(seed_ref, sx_ref, sy_ref, q_ref, nacc_ref, *, pairs_per_lane: int):
    """One program instance: EP over a (LANES,) seed tile.

    Outputs are per-block tallies; the L2 graph reduces over blocks.

    Perf (EXPERIMENTS.md §Perf, L1 iteration 2): the histogram and the
    acceptance counter accumulate in **int32** when the per-block pair
    count provably fits (lanes * pairs_per_lane < 2^31) — int32 compare+add
    vectorizes 2x wider than int64 on both the CPU backend and the TPU VPU.
    Outputs stay int64.
    """
    a = jnp.uint64(A)
    mask = jnp.uint64(MASK)
    # Block shape is (1, LANES); flatten to a lane vector.
    seeds = seed_ref[...].reshape(-1)
    lanes = seeds.shape[0]
    narrow = lanes * pairs_per_lane < 2**31
    cdt = jnp.int32 if narrow else jnp.int64

    def body(_, carry):
        s, sx, sy, q, nacc = carry
        s = (s * a) & mask
        x = 2.0 * (s.astype(jnp.float64) * R46) - 1.0
        s = (s * a) & mask
        y = 2.0 * (s.astype(jnp.float64) * R46) - 1.0
        t = x * x + y * y
        acc = t <= 1.0
        tsafe = jnp.where(acc, t, 1.0)
        f = jnp.sqrt(-2.0 * jnp.log(tsafe) / tsafe)
        gx = jnp.where(acc, x * f, 0.0)
        gy = jnp.where(acc, y * f, 0.0)
        l = jnp.maximum(jnp.abs(gx), jnp.abs(gy)).astype(jnp.int32)
        # Predicated histogram: one-hot compare against the annulus index.
        onehot = (l[:, None] == jnp.arange(NQ, dtype=jnp.int32)[None, :]) & acc[:, None]
        q = q + onehot.sum(axis=0, dtype=cdt)
        sx = sx + gx.sum()
        sy = sy + gy.sum()
        nacc = nacc + acc.sum(dtype=cdt)
        return (s, sx, sy, q, nacc)

    init = (
        seeds,
        jnp.float64(0.0),
        jnp.float64(0.0),
        jnp.zeros((NQ,), cdt),
        cdt(0),
    )
    _, sx, sy, q, nacc = jax.lax.fori_loop(0, pairs_per_lane, body, init)
    sx_ref[...] = sx[None]
    sy_ref[...] = sy[None]
    q_ref[...] = q.astype(jnp.int64)[None, :]
    nacc_ref[...] = nacc.astype(jnp.int64)[None]


def ep_pallas(seeds: jnp.ndarray, pairs_per_lane: int):
    """EP tallies over a (grid, LANES) uint64 seed array.

    Returns per-block partials: (sx[grid], sy[grid], q[grid, NQ],
    nacc[grid]).  Lane g = block*LANES + lane must be seeded (host-side)
    with the LCG state after ``g * 2 * pairs_per_lane`` steps so the union
    of lanes reproduces the canonical single LCG stream.
    """
    grid, lanes = seeds.shape
    kernel = functools.partial(_ep_kernel_body, pairs_per_lane=pairs_per_lane)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((1, lanes), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, NQ), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((grid,), jnp.float64),
            jax.ShapeDtypeStruct((grid,), jnp.float64),
            jax.ShapeDtypeStruct((grid, NQ), jnp.int64),
            jax.ShapeDtypeStruct((grid,), jnp.int64),
        ],
        interpret=True,
    )(seeds)


def vmem_bytes(lanes: int = LANES) -> int:
    """Estimated VMEM residency of one program instance (perf model).

    Live per-lane arrays in the loop body: seed (u64), x, y, t, tsafe, f,
    gx, gy (f64), l (i32), acc (bool/i8), one-hot (NQ x i8 compare) plus the
    (NQ,) i64 tally. 8 x 8B + 4B + 1B + NQ B per lane, + block outputs.
    """
    per_lane = 8 * 8 + 4 + 1 + NQ
    return lanes * per_lane + NQ * 8 + 3 * 8
