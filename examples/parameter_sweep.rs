//! Parameter sweep (paper §4's second use-case): "each point of the curve
//! is independently obtained from other points using different simulation
//! parameters."
//!
//! Sweeps a damping parameter, runs every point as an independent Gridlan
//! job through the event-driven scenario (so queueing/placement is
//! realistic) with a REAL EP compute payload — each point's Monte-Carlo
//! noise comes from the tally its own job executed on the backend —
//! then prints the resulting curve.
//!
//! Run: `cargo run --release --example parameter_sweep`

use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{parse_pair_range, run_trace, Scenario};
use gridlan::rm::alloc::ResourceRequest;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::table::{secs, Align, Table};
use gridlan::workload::sweep::ParameterSweep;
use gridlan::workload::trace::{JobPayload, TraceJob};

fn main() {
    gridlan::util::log::init_from_env();
    let sweep = ParameterSweep::linspace("resonance", "gamma", 0.05, 0.50, 10, 1 << 16);
    println!("sweep: {} points of '{}'", sweep.n_points(), sweep.param);

    // Run the sweep's jobs through the full scheduler/scenario machinery:
    // all points submitted at t=0, each carrying its own disjoint EP pair
    // range as a real-compute payload.
    let trace: Vec<TraceJob> = (0..sweep.n_points())
        .map(|i| {
            let (offset, count) = parse_pair_range(&sweep.payload(i)).expect("sweep payload");
            TraceJob {
                at: 0,
                owner: "sweeper".into(),
                request: ResourceRequest { nodes: 1, ppn: sweep.cores_per_point },
                compute: 300 * DUR_SEC,
                walltime: 900 * DUR_SEC,
                payload: JobPayload::Ep { offset, count },
            }
        })
        .collect();
    let g = Gridlan::table1();
    let scenario = Scenario { horizon: 2 * 3600 * DUR_SEC, ..Default::default() };
    let report = run_trace(g, trace, &scenario);
    println!(
        "all {} points completed ({} pairs computed for REAL); makespan {} (incl. PXE boots), mean wait {}",
        report.metrics.jobs_completed,
        report.metrics.ep_pairs_executed,
        secs(report.metrics.makespan as f64 / 1e9),
        secs(report.metrics.mean_wait_secs()),
    );
    assert_eq!(report.metrics.jobs_completed as usize, sweep.n_points());
    assert_eq!(report.metrics.ep_jobs_completed as usize, sweep.n_points());

    // The actual per-point "physics": a toy resonance curve whose noise
    // comes from the EP tally each point's job executed on the backend.
    // Job ids are sequential in submission order, so the id-ordered tally
    // map lines up with the sweep's points.
    let tallies: Vec<_> = report.ep_tallies.values().collect();
    let mut t = Table::new(&["gamma", "response", "mc-noise"])
        .align(&[Align::Right, Align::Right, Align::Right]);
    for (i, &gamma) in sweep.values.iter().enumerate() {
        let tally = tallies[i];
        // Lorentzian response + small MC jitter from the tally.
        let jitter = (tally.sx / tally.nacc.max(1) as f64) * 0.05;
        let response = 1.0 / ((0.2 - gamma).powi(2) + gamma * gamma) + jitter;
        t.row(&[format!("{gamma:.3}"), format!("{response:.3}"), format!("{jitter:+.5}")]);
    }
    println!("\n{}", t.render());
}
