//! Monte Carlo campaign (paper §4's first use-case): estimate π from the
//! EP acceptance ratio, with replicas fanned out across the Gridlan as
//! independent single-core jobs.
//!
//! Each replica covers a disjoint slice of the NPB random stream; when the
//! PJRT artifacts are present the compute is REAL (the Pallas-lowered HLO
//! running on the CPU client), otherwise the exact scalar fallback runs.
//!
//! Run: `cargo run --release --example montecarlo_pi`

use gridlan::coordinator::gridlan::Gridlan;
use gridlan::rm::queue::NodePool;
use gridlan::runtime::engine::EpEngine;
use gridlan::sim::clock::DUR_SEC;
use gridlan::workload::ep::{ep_scalar, EpTally};
use gridlan::workload::montecarlo::MonteCarloCampaign;

fn main() {
    let campaign = MonteCarloCampaign::new("pi-estimate", 16, 1 << 18);
    println!(
        "campaign: {} replicas x {} pairs = {} total pairs",
        campaign.replicas,
        campaign.pairs_per_replica,
        campaign.total_pairs()
    );

    // Submit every replica as its own single-core job (the §4 pattern).
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let mut ids = Vec::new();
    for (i, script) in campaign.scripts().iter().enumerate() {
        let id = g.pbs.qsub(script, "mcuser", &campaign.payload(i as u32), 0).expect("accepted");
        ids.push(id);
    }
    let sched = g.scheduler();
    let started = g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), DUR_SEC);
    println!("scheduler started {} of {} replicas immediately", started.len(), ids.len());

    // Execute the replica payloads (real PJRT if artifacts exist).
    let mut engine = EpEngine::load_default().ok();
    match &engine {
        Some(_) => println!("compute: REAL (PJRT artifacts)"),
        None => println!("compute: scalar fallback (run `make artifacts` for PJRT)"),
    }
    let mut total = EpTally::default();
    for id in &ids {
        let payload = g.pbs.job(*id).unwrap().payload.clone();
        // payload = "mc:<offset>:<count>"
        let mut parts = payload.split(':').skip(1);
        let offset: u64 = parts.next().unwrap().parse().unwrap();
        let count: u64 = parts.next().unwrap().parse().unwrap();
        let tally = match engine.as_mut() {
            Some(e) => e.run_pairs(offset, count).expect("pjrt run"),
            None => ep_scalar(offset, count),
        };
        total.merge(&tally);
    }

    // π/4 = P(x²+y² ≤ 1) for uniform pairs on (-1,1)².
    let pi = 4.0 * total.nacc as f64 / total.pairs as f64;
    let err = (pi - std::f64::consts::PI).abs();
    println!("\naccepted {} / {} pairs", total.nacc, total.pairs);
    println!("pi ≈ {pi:.6}   (|err| = {err:.6})");
    assert!(err < 0.01, "π estimate off: {pi}");

    // Book-keeping: complete the jobs.
    for (k, id) in ids.iter().enumerate() {
        if g.pbs.job(*id).unwrap().state == gridlan::rm::job::JobState::Running {
            g.pbs.complete(*id, 0, (60 + k as u64) * DUR_SEC);
        }
    }
    let done = g.pbs.jobs().filter(|j| j.succeeded()).count();
    println!("{done} replicas completed through the resource manager");
}
