//! Monte Carlo campaign (paper §4's first use-case): estimate π from the
//! EP acceptance ratio, with replicas fanned out across the Gridlan as
//! independent single-core jobs.
//!
//! Each replica covers a disjoint slice of the NPB random stream and the
//! compute is REAL on the active `ComputeBackend` — the pure-Rust scalar
//! backend by default, or the PJRT HLO path in `--features pjrt` builds
//! with artifacts present.
//!
//! Run: `cargo run --release --example montecarlo_pi`

use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::parse_pair_range;
use gridlan::rm::queue::NodePool;
use gridlan::runtime::engine::EpEngine;
use gridlan::sim::clock::DUR_SEC;
use gridlan::workload::ep::EpTally;
use gridlan::workload::montecarlo::MonteCarloCampaign;

fn main() {
    gridlan::util::log::init_from_env();
    let campaign = MonteCarloCampaign::new("pi-estimate", 16, 1 << 18);
    println!(
        "campaign: {} replicas x {} pairs = {} total pairs",
        campaign.replicas,
        campaign.pairs_per_replica,
        campaign.total_pairs()
    );

    // Submit every replica as its own single-core job (the §4 pattern).
    let mut g = Gridlan::table1();
    g.boot_all(0);
    let mut ids = Vec::new();
    for (i, script) in campaign.scripts().iter().enumerate() {
        let id = g.pbs.qsub(script, "mcuser", &campaign.payload(i as u32), 0).expect("accepted");
        ids.push(id);
    }
    let sched = g.scheduler();
    let started = g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), DUR_SEC);
    println!("scheduler started {} of {} replicas immediately", started.len(), ids.len());

    // Execute the replica payloads for real on the compute backend.
    let mut engine = EpEngine::auto();
    if let Some(note) = engine.fallback_note.take() {
        println!("note: {note}");
    }
    println!("compute: REAL on the '{}' backend", engine.backend_name());
    let mut total = EpTally::default();
    for id in &ids {
        let payload = g.pbs.job(*id).unwrap().payload.clone();
        // payload = "mc:<offset>:<count>"
        let (offset, count) = parse_pair_range(&payload).expect("mc payload");
        total.merge(&engine.run_pairs(offset, count).expect("backend run"));
    }

    // π/4 = P(x²+y² ≤ 1) for uniform pairs on (-1,1)².
    let pi = 4.0 * total.nacc as f64 / total.pairs as f64;
    let err = (pi - std::f64::consts::PI).abs();
    println!("\naccepted {} / {} pairs", total.nacc, total.pairs);
    println!("pi ≈ {pi:.6}   (|err| = {err:.6})");
    assert!(err < 0.01, "π estimate off: {pi}");

    // Book-keeping: complete the jobs.
    for (k, id) in ids.iter().enumerate() {
        if g.pbs.job(*id).unwrap().state == gridlan::rm::job::JobState::Running {
            g.pbs.complete(*id, 0, (60 + k as u64) * DUR_SEC);
        }
    }
    let done = g.pbs.jobs().filter(|j| j.succeeded()).count();
    println!("{done} replicas completed through the resource manager");
}
