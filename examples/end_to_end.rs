//! END-TO-END DRIVER: exercises every layer on a real workload.
//!
//! 1. assemble + PXE-boot the paper's Table-1 Gridlan (L3 substrates);
//! 2. reproduce Table 2 (ping) and the §3.3 MPI cross-check;
//! 3. reproduce the Fig. 3 speed-up series and its headline numbers;
//! 4. run NPB-EP **class S for real** through the resource manager: the
//!    job is split into 26 per-core slices exactly as Fig. 3's protocol
//!    scatters processes, each slice executes on the active
//!    `ComputeBackend` (scalar by default; PJRT HLO with
//!    `--features pjrt` + artifacts), the tallies merge, and the result
//!    is checked against the official NPB class-S verification sums;
//! 5. report the measured host throughput and the model's extrapolation
//!    to the paper's class-D scale.
//!
//! Run: `cargo run --release --example end_to_end`

use gridlan::bench::{fig3, mpilat, table1, table2};
use gridlan::coordinator::gridlan::Gridlan;
use gridlan::perf::calibrate::Calibration;
use gridlan::perf::speedmodel::GridlanPool;
use gridlan::rm::queue::NodePool;
use gridlan::rm::script::PbsScript;
use gridlan::runtime::engine::EpEngine;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::table::secs;
use gridlan::workload::ep::{EpClass, EpJob, EpTally};

fn main() {
    gridlan::util::log::init_from_env();
    println!("=================================================================");
    println!(" Gridlan end-to-end driver (paper: Rodrigues & Costa, 2016)");
    println!("=================================================================\n");

    // ---- 1. assemble + boot -------------------------------------------
    let mut g = Gridlan::table1();
    print!("{}", table1::render_inventory(&g.config));
    let slowest = g.boot_all(0);
    println!("\nall nodes PXE-booted; slowest {}", secs(slowest as f64 / 1e9));
    for name in ["n01", "n02", "n03", "n04"] {
        let plan = g.boot_plan(name);
        println!("  {name}: boot {}", secs(plan.total() as f64 / 1e9));
    }

    // ---- 2. Table 2 + MPI ---------------------------------------------
    println!();
    let t2 = table2::table2_rows(&mut g, 200);
    print!("{}", table2::render(&t2));
    let overhead: f64 = t2.iter().map(|r| r.overhead_us()).sum::<f64>() / t2.len() as f64;
    println!("mean Gridlan overhead: {overhead:.0} µs (paper: \"roughly 900 µs\")\n");
    let m1 = mpilat::mpi_latency_rows(&mut g, 200);
    print!("{}", mpilat::render(&m1));

    // ---- 3. Fig 3 (model) ---------------------------------------------
    println!();
    let pool = GridlanPool { clients: g.clients.clone() };
    let series = fig3::fig3_series(&pool, EpClass::D, 30, g.config.seed);
    print!("{}", fig3::render(&series));
    let checks = fig3::shape_checks(&series);
    for (name, ok) in &checks {
        println!("  [{}] {name}", if *ok { "ok" } else { "FAIL" });
    }
    assert!(checks.iter().all(|(_, ok)| *ok), "Fig 3 shape check failed");

    // ---- 4. REAL compute: class S through the RM + backend -------------
    println!("\n== real NPB-EP class S through resource manager + compute backend ==");
    let mut engine = EpEngine::auto();
    if let Some(note) = engine.fallback_note.take() {
        println!("note: {note}");
    }
    println!("compute backend: {}", engine.backend_name());

    // Submit one job per Gridlan core, each owning one Fig.3-style slice.
    let job = EpJob::new(EpClass::S, 26);
    let slices = job.slices();
    let mut ids = Vec::new();
    for s in &slices {
        let script = PbsScript::parse(&format!(
            "#PBS -N ep-s-{:02}\n#PBS -q gridlan\n#PBS -l nodes=1:ppn=1\n./ep.S.x\n",
            s.proc
        ))
        .unwrap();
        let payload = format!("ep:{}:{}", s.pair_offset, s.pair_count);
        ids.push(g.pbs.qsub(&script, "attila", &payload, 0).expect("qsub"));
    }
    let sched = g.scheduler();
    let started = g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), DUR_SEC);
    assert_eq!(started.len(), 26, "all 26 single-core slices start at once");

    let t0 = std::time::Instant::now();
    let mut total = EpTally::default();
    for id in &ids {
        let payload = g.pbs.job(*id).unwrap().payload.clone();
        let (offset, count) =
            gridlan::coordinator::scenario::parse_pair_range(&payload).expect("ep payload");
        let tally = engine.run_pairs(offset, count).expect("backend slice");
        total.merge(&tally);
        g.pbs.complete(*id, 0, 200 * DUR_SEC);
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("class S ({} pairs) in {}:", total.pairs, secs(wall));
    println!("  sx = {:.12e}", total.sx);
    println!("  sy = {:.12e}", total.sy);
    println!("  gaussian pairs = {}", total.nacc);
    let verified = total.verify(EpClass::S).unwrap();
    println!("  NPB class-S verification: {}", if verified { "PASS" } else { "FAIL" });
    assert!(verified, "class S sums drifted");
    assert_eq!(total.pairs, EpClass::S.pairs());
    let rate = engine.measured_rate_mpairs().unwrap();
    println!(
        "  measured backend throughput: {rate:.1} Mpairs/s ({} pairs on '{}')",
        engine.pairs_executed(),
        engine.backend_name()
    );

    // ---- 5. extrapolate to the paper's scale ---------------------------
    // Calibration::host_mpairs is a single-core rate, but the auto engine
    // may be the multi-threaded backend: measure one core explicitly.
    let mut one_core = EpEngine::scalar();
    one_core.run_pairs(0, 1 << 20).expect("scalar calibration run");
    let core_rate = one_core.measured_rate_mpairs().unwrap();
    let cal = Calibration::new(core_rate);
    println!("\n== extrapolation to class D (the paper's Fig. 3 workload) ==");
    println!(
        "  this host, 1 core ({core_rate:.1} Mpairs/s): {}",
        secs(cal.secs_for(EpClass::D.pairs()))
    );
    println!("  model, 26 Gridlan cores:  {:.0} s (paper: ~212 s)", series.full_pool_secs);
    println!(
        "  model, comparison server: {} cores to match (paper: ~38)",
        series.server_cores_to_match.map(|n| n.to_string()).unwrap_or(">64".into())
    );

    println!("\nEND-TO-END: all layers composed; all checks passed.");
}
