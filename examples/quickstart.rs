//! Quickstart: assemble the paper's Table-1 Gridlan, boot it, and submit a
//! job exactly the way the paper's users do (SSH → script → qsub → qstat).
//!
//! Run: `cargo run --release --example quickstart`

use gridlan::coordinator::gridlan::Gridlan;
use gridlan::rm::queue::NodePool;
use gridlan::rm::script::PbsScript;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::table::secs;

fn main() {
    gridlan::util::log::init_from_env();
    // 1. The administrator assembled the Gridlan from its config
    //    (defaults = the paper's exact testbed).
    let mut g = Gridlan::table1();
    println!("Gridlan with {} clients / {} cores", g.clients.len(), g.config.total_gridlan_cores());

    // 2. Clients connect the VPN at OS start-up and their VMs PXE-boot
    //    off the server (DHCP → TFTP kernel+initrd → nfsroot).
    let boot = g.boot_all(0);
    println!("slowest node boot: {}", secs(boot as f64 / 1e9));
    for node in g.nodes.values() {
        println!(
            "  {}: {:?} (boot took {})",
            node.name,
            node.state,
            secs(node.last_boot_duration().unwrap_or(0) as f64 / 1e9)
        );
    }

    // 3. A user submits a job script to the gridlan queue.
    let script = PbsScript::parse(
        "#!/bin/bash\n\
         #PBS -N my-simulation\n\
         #PBS -q gridlan\n\
         #PBS -l nodes=1:ppn=4\n\
         #PBS -l walltime=00:30:00\n\
         cd $PBS_O_WORKDIR\n\
         ./simulate --input data.json\n",
    )
    .expect("valid script");
    let id = g.pbs.qsub(&script, "student", "", 0).expect("accepted");
    println!("\nqsub -> {id}");

    // 4. The scheduler places it; qstat shows it running.
    let sched = g.scheduler();
    g.pbs.schedule_cycle(NodePool::Gridlan, sched.as_ref(), DUR_SEC);
    for (id, name, owner, state, queue) in g.pbs.qstat() {
        println!("qstat: {id:<14} {name:<16} {owner:<8} {state}  {queue}");
    }
    let job = g.pbs.job(id).unwrap();
    println!("allocated on: {:?}", job.allocation.as_ref().unwrap().cores);

    // 5. ... compute happens (see examples/end_to_end.rs for real PJRT
    //    compute) ... and the job completes.
    g.pbs.complete(id, 0, 1800 * DUR_SEC);
    let job = g.pbs.job(id).unwrap();
    println!(
        "completed: waited {}, ran {}",
        secs(job.wait_time().unwrap() as f64 / 1e9),
        secs(job.run_time().unwrap() as f64 / 1e9)
    );
    assert!(job.succeeded());
}
