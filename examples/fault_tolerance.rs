//! Fault tolerance demo (paper §2.6 + §4): clients get powered off, VMs
//! crash, the network drops — and the monitor/watchdog/script-folder loop
//! still drives every job to completion.
//!
//! Run: `cargo run --release --example fault_tolerance`

use gridlan::coordinator::gridlan::Gridlan;
use gridlan::coordinator::scenario::{run_trace, Scenario};
use gridlan::host::faults::FaultPlan;
use gridlan::rm::alloc::ResourceRequest;
use gridlan::sim::clock::DUR_SEC;
use gridlan::util::table::secs;
use gridlan::workload::trace::TraceJob;

fn main() {
    gridlan::util::log::init_from_env();
    // 20 medium jobs over the first hour.
    let trace: Vec<TraceJob> = (0..20)
        .map(|i| TraceJob {
            at: i as u64 * 180 * DUR_SEC,
            owner: format!("user{:02}", i % 3),
            request: ResourceRequest { nodes: 1, ppn: 2 + (i % 3) as u32 },
            compute: (600 + 60 * (i % 5) as u64) * DUR_SEC,
            walltime: 3600 * DUR_SEC,
            payload: gridlan::workload::trace::JobPayload::Synthetic,
        })
        .collect();

    println!("{:<22} {:>9} {:>9} {:>8} {:>11} {:>9} {:>9}",
        "fault profile", "completed", "requeued", "faults", "wd-restarts", "goodput", "makespan");
    for (label, scale) in [("clean", 0.0), ("lab (1x)", 1.0), ("hostile (8x)", 8.0), ("brutal (20x)", 20.0)] {
        let faults = if scale > 0.0 { FaultPlan::lab_default().scaled(scale) } else { FaultPlan::none() };
        let scenario = Scenario { horizon: 8 * 3600 * DUR_SEC, faults, ..Default::default() };
        let report = run_trace(Gridlan::table1(), trace.clone(), &scenario);
        let m = &report.metrics;
        println!(
            "{label:<22} {:>6}/20 {:>9} {:>8} {:>11} {:>8.1}% {:>9}",
            m.jobs_completed,
            m.jobs_requeued,
            m.faults,
            m.watchdog_restarts,
            100.0 * m.goodput(),
            secs(m.makespan as f64 / 1e9),
        );
        // The §2.6/§4 claim: resilience machinery completes the work even
        // under heavy churn (it just takes longer and wastes some cycles).
        assert_eq!(m.jobs_completed, 20, "lost jobs under '{label}'");
    }
    println!("\nevery profile completed all 20 jobs — requeue + watchdog recovery held.");
}
